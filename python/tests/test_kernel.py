"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the core correctness signal for the compute layer: values AND
gradients must match the reference to tight tolerances, across shapes and
dtypes (hypothesis sweeps live in test_kernel_properties.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import (attention, attention_forward,
                                       vmem_footprint_bytes)
from compile.kernels.layernorm import layernorm, layernorm_forward
from compile.kernels.ref import attention_ref, layernorm_ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


class TestAttentionForward:
    @pytest.mark.parametrize("bh,s,d", [(1, 8, 16), (4, 32, 16), (8, 32, 64),
                                        (12, 16, 32), (2, 64, 64)])
    def test_matches_ref_causal(self, bh, s, d):
        q, k, v = rand(0, (bh, s, d)), rand(1, (bh, s, d)), rand(2, (bh, s, d))
        out = attention_forward(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("bh,s,d", [(2, 16, 32), (4, 32, 16)])
    def test_matches_ref_noncausal(self, bh, s, d):
        q, k, v = rand(3, (bh, s, d)), rand(4, (bh, s, d)), rand(5, (bh, s, d))
        out = attention_forward(q, k, v, causal=False)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_causal_masks_future(self):
        """Output at position t must not depend on inputs at positions > t."""
        bh, s, d = 2, 16, 8
        q, k, v = rand(6, (bh, s, d)), rand(7, (bh, s, d)), rand(8, (bh, s, d))
        out1 = attention_forward(q, k, v, causal=True)
        # Perturb the last key/value: only the last position may change.
        k2 = k.at[:, -1, :].add(100.0)
        v2 = v.at[:, -1, :].add(100.0)
        out2 = attention_forward(q, k2, v2, causal=True)
        np.testing.assert_allclose(out1[:, :-1], out2[:, :-1],
                                   rtol=1e-5, atol=1e-5)
        assert not np.allclose(out1[:, -1], out2[:, -1])

    def test_softmax_rows_bounded(self):
        """Attention output is a convex combination of V rows."""
        bh, s, d = 2, 32, 16
        q, k = rand(9, (bh, s, d)), rand(10, (bh, s, d))
        v = jnp.ones((bh, s, d), jnp.float32)
        out = attention_forward(q, k, v, causal=True)
        np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5,
                                   atol=1e-5)

    def test_numerical_stability_large_logits(self):
        bh, s, d = 1, 16, 8
        q = rand(11, (bh, s, d)) * 100.0
        k = rand(12, (bh, s, d)) * 100.0
        v = rand(13, (bh, s, d))
        out = attention_forward(q, k, v, causal=True)
        assert np.isfinite(np.asarray(out)).all()


class TestAttentionGrad:
    @pytest.mark.parametrize("bh,s,d", [(2, 16, 16), (4, 32, 32)])
    def test_grads_match_ref(self, bh, s, d):
        q, k, v = rand(20, (bh, s, d)), rand(21, (bh, s, d)), rand(22, (bh, s, d))

        def f_pallas(q, k, v):
            return jnp.sum(jnp.sin(attention(q, k, v, True)))

        def f_ref(q, k, v):
            return jnp.sum(jnp.sin(attention_ref(q, k, v, causal=True)))

        gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestLayerNorm:
    @pytest.mark.parametrize("n,d", [(4, 16), (32, 64), (128, 256), (96, 48)])
    def test_matches_ref(self, n, d):
        x = rand(30, (n, d))
        gamma = rand(31, (d,)) * 0.1 + 1.0
        beta = rand(32, (d,)) * 0.1
        out = layernorm_forward(x, gamma, beta)
        ref = layernorm_ref(x, gamma, beta)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_output_row_statistics(self):
        """With unit gamma / zero beta each row is ~zero-mean unit-var."""
        n, d = 16, 128
        x = rand(33, (n, d)) * 5.0 + 3.0
        out = layernorm_forward(x, jnp.ones((d,)), jnp.zeros((d,)))
        np.testing.assert_allclose(np.mean(out, axis=-1), 0.0, atol=1e-4)
        np.testing.assert_allclose(np.var(np.asarray(out), axis=-1), 1.0,
                                   atol=1e-2)

    @pytest.mark.parametrize("n,d", [(8, 32), (64, 64)])
    def test_grads_match_ref(self, n, d):
        x = rand(34, (n, d))
        gamma = rand(35, (d,)) * 0.1 + 1.0
        beta = rand(36, (d,)) * 0.1

        def f_pallas(x, g, b):
            return jnp.sum(jnp.cos(layernorm(x, g, b)))

        def f_ref(x, g, b):
            return jnp.sum(jnp.cos(layernorm_ref(x, g, b)))

        gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, gamma, beta)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, gamma, beta)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_odd_row_count(self):
        """Row counts that are not powers of two still tile correctly."""
        n, d = 6, 32
        x = rand(37, (n, d))
        out = layernorm_forward(x, jnp.ones((d,)), jnp.zeros((d,)))
        ref = layernorm_ref(x, jnp.ones((d,)), jnp.zeros((d,)))
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


class TestVmemBudget:
    def test_gpt100m_attention_tile_fits_vmem(self):
        """DESIGN.md §Perf: per-program working set must fit a 16MiB VMEM."""
        # gpt100m: seq 32, head_dim 64
        assert vmem_footprint_bytes(32, 64) < 16 * 1024 * 1024
        # even a 512-seq variant would fit
        assert vmem_footprint_bytes(512, 64) < 16 * 1024 * 1024

    def test_footprint_monotone(self):
        assert vmem_footprint_bytes(64, 64) > vmem_footprint_bytes(32, 64)
        assert vmem_footprint_bytes(32, 128) > vmem_footprint_bytes(32, 64)


from compile.kernels.ref import xent_ref
from compile.kernels.xent import xent, xent_forward


class TestXentForward:
    @pytest.mark.parametrize("n,v", [(8, 16), (32, 50), (128, 256),
                                     (96, 1024), (256, 64)])
    def test_matches_ref(self, n, v):
        logits = rand(20, (n, v))
        targets = jax.random.randint(jax.random.PRNGKey(21), (n,), 0, v)
        out = xent_forward(logits, targets)
        ref = xent_ref(logits, targets)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_output_is_float32_nll(self):
        logits = rand(22, (16, 32), jnp.bfloat16)
        targets = jax.random.randint(jax.random.PRNGKey(23), (16,), 0, 32)
        out = xent_forward(logits, targets)
        assert out.dtype == jnp.float32
        assert (np.asarray(out) > 0).all()  # NLL of random logits

    def test_perfect_prediction_near_zero(self):
        """Rows with a dominant target logit have ~0 loss."""
        n, v = 8, 32
        targets = jnp.arange(n) % v
        logits = jax.nn.one_hot(targets, v) * 50.0
        out = xent_forward(logits, targets)
        np.testing.assert_allclose(out, np.zeros(n), atol=1e-6)

    def test_shift_invariance(self):
        """Softmax xent is invariant to a per-row logit shift."""
        logits = rand(24, (32, 64))
        targets = jax.random.randint(jax.random.PRNGKey(25), (32,), 0, 64)
        shifted = logits + 123.0
        a = xent_forward(logits, targets)
        b = xent_forward(shifted, targets)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestXentGrad:
    def test_grad_matches_ref(self):
        n, v = 32, 128
        logits = rand(26, (n, v))
        targets = jax.random.randint(jax.random.PRNGKey(27), (n,), 0, v)

        def loss_pallas(x):
            return jnp.mean(xent(x, targets))

        def loss_ref(x):
            return jnp.mean(xent_ref(x, targets))

        g_pallas = jax.grad(loss_pallas)(logits)
        g_ref = jax.grad(loss_ref)(logits)
        np.testing.assert_allclose(g_pallas, g_ref, rtol=2e-5, atol=2e-5)

    def test_grad_rows_sum_to_zero(self):
        """d(xent)/d(logits) rows sum to 0 (softmax minus one-hot)."""
        logits = rand(28, (16, 32))
        targets = jax.random.randint(jax.random.PRNGKey(29), (16,), 0, 32)
        g = jax.grad(lambda x: jnp.sum(xent(x, targets)))(logits)
        np.testing.assert_allclose(np.asarray(g).sum(axis=-1),
                                   np.zeros(16), atol=1e-5)
