"""AOT pipeline tests: lowering produces parsable HLO text with the
expected interface, and the metadata sidecar is consistent with the model.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_variant, to_hlo_text
from compile.model import CONFIGS, init_params, param_spec, train_step


class TestLowerVariant:
    @pytest.fixture(scope="class")
    def tiny_artifacts(self):
        with tempfile.TemporaryDirectory() as d:
            meta = lower_variant(CONFIGS["tiny"], d)
            files = {
                name: open(os.path.join(d, name)).read()
                for name in os.listdir(d)
            }
            yield meta, files

    def test_meta_matches_param_spec(self, tiny_artifacts):
        meta, _ = tiny_artifacts
        spec = param_spec(CONFIGS["tiny"])
        assert meta["param_count"] == spec.total
        assert len(meta["params"]) == len(spec.names)
        off = 0
        for p in meta["params"]:
            assert p["offset"] == off
            off += int(np.prod(p["shape"]))
        assert off == spec.total

    def test_hlo_text_is_hlo(self, tiny_artifacts):
        meta, files = tiny_artifacts
        hlo = files[meta["train_hlo"]]
        assert hlo.startswith("HloModule"), hlo[:50]
        assert "ENTRY" in hlo
        # Four inputs: params, momentum, tokens, lr.
        assert "f32[%d]" % meta["param_count"] in hlo
        assert "s32[4,32]" in hlo

    def test_eval_hlo_present(self, tiny_artifacts):
        meta, files = tiny_artifacts
        assert files[meta["eval_hlo"]].startswith("HloModule")

    def test_meta_json_roundtrips(self, tiny_artifacts):
        meta, files = tiny_artifacts
        parsed = json.loads(files["tiny.meta.json"])
        assert parsed["param_count"] == meta["param_count"]
        assert parsed["train_outputs"] == ["flat_params", "flat_momentum",
                                           "loss"]


class TestHloTextSemantics:
    def test_lowered_step_matches_eager(self):
        """The HLO-text round trip must compute the same step as eager
        jax (this is the numerical contract the rust runtime relies on)."""
        cfg = CONFIGS["tiny"]
        spec = param_spec(cfg)
        fp = init_params(cfg, seed=1)
        fm = jnp.zeros_like(fp)
        toks = jax.random.randint(
            jax.random.PRNGKey(2), (cfg.batch, cfg.seq_len), 0, cfg.vocab,
            dtype=jnp.int32)
        lr = jnp.float32(0.1)

        eager_p, eager_m, eager_loss = train_step(cfg, fp, fm, toks, lr)

        lowered = jax.jit(
            lambda a, b, c, d: train_step(cfg, a, b, c, d)
        ).lower(
            jax.ShapeDtypeStruct((spec.total,), jnp.float32),
            jax.ShapeDtypeStruct((spec.total,), jnp.float32),
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        text = to_hlo_text(lowered)
        # Parse the HLO text back (the same entry point the rust runtime
        # uses) and check the interface contract the rust side relies on.
        from jax._src.lib import xla_client as xc
        module = xc._xla.hlo_module_from_text(text)
        assert module is not None
        # The jitted function itself must equal eager execution — this is
        # the numerical contract; full text->execute round-trip semantics
        # are asserted on the rust side (tests/runtime_and_deploy.rs).
        jit_p, jit_m, jit_loss = jax.jit(
            lambda a, b, c, d: train_step(cfg, a, b, c, d)
        )(fp, fm, toks, lr)
        np.testing.assert_allclose(jit_p, eager_p, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(jit_m, eager_m, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(jit_loss, eager_loss, rtol=1e-5,
                                   atol=1e-5)
        # Interface: the text names the flat-param and token inputs.
        assert "f32[%d]" % spec.total in text
        assert "s32[%d,%d]" % (cfg.batch, cfg.seq_len) in text
