"""Hypothesis property sweeps over the Pallas kernels: random shapes,
dtypes, and value scales, always asserting allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention_forward
from compile.kernels.layernorm import layernorm_forward
from compile.kernels.ref import attention_ref, layernorm_ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(seed, shape, dtype, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    return x.astype(dtype)


@settings(**SETTINGS)
@given(
    bh=st.integers(1, 12),
    s=st.sampled_from([4, 8, 16, 32, 64]),
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bh, s, d, causal, scale, seed):
    q = rand(seed, (bh, s, d), jnp.float32, scale)
    k = rand(seed + 1, (bh, s, d), jnp.float32, scale)
    v = rand(seed + 2, (bh, s, d), jnp.float32, scale)
    out = attention_forward(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)
    assert out.dtype == q.dtype


@settings(**SETTINGS)
@given(
    bh=st.integers(1, 6),
    s=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_bf16_close_to_f32_ref(bh, s, d, seed):
    q = rand(seed, (bh, s, d), jnp.bfloat16, 1.0)
    k = rand(seed + 1, (bh, s, d), jnp.bfloat16, 1.0)
    v = rand(seed + 2, (bh, s, d), jnp.bfloat16, 1.0)
    out = attention_forward(q, k, v, causal=True).astype(jnp.float32)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    # bf16 storage: ~2-3 decimal digits.
    np.testing.assert_allclose(out, ref, rtol=5e-2, atol=5e-2)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 4, 6, 8, 32, 96, 128]),
    d=st.sampled_from([8, 16, 64, 256]),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
    shift=st.sampled_from([0.0, 5.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(n, d, scale, shift, seed):
    x = rand(seed, (n, d), jnp.float32, scale) + shift
    gamma = rand(seed + 1, (d,), jnp.float32, 0.1) + 1.0
    beta = rand(seed + 2, (d,), jnp.float32, 0.1)
    out = layernorm_forward(x, gamma, beta)
    ref = layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    bh=st.integers(1, 4),
    s=st.sampled_from([8, 16]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_permutation_equivariance_noncausal(bh, s, d, seed):
    """Non-causal attention is equivariant to permuting K/V rows."""
    q = rand(seed, (bh, s, d), jnp.float32, 1.0)
    k = rand(seed + 1, (bh, s, d), jnp.float32, 1.0)
    v = rand(seed + 2, (bh, s, d), jnp.float32, 1.0)
    perm = np.random.RandomState(seed % 1000).permutation(s)
    out1 = attention_forward(q, k, v, causal=False)
    out2 = attention_forward(q, k[:, perm], v[:, perm], causal=False)
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-4)


from compile.kernels.ref import xent_ref
from compile.kernels.xent import xent_forward


@settings(**SETTINGS)
@given(
    n=st.sampled_from([4, 16, 32, 96, 128]),
    v=st.sampled_from([8, 64, 256, 1000]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_xent_matches_ref(n, v, scale, seed):
    logits = rand(seed, (n, v), jnp.float32, scale)
    targets = jax.random.randint(jax.random.PRNGKey(seed + 7), (n,), 0, v)
    out = xent_forward(logits, targets)
    ref = xent_ref(logits, targets)
    np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)
    assert out.shape == (n,)
