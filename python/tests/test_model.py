"""Layer-2 correctness: transformer shapes, loss behaviour, train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (CONFIGS, ModelConfig, forward, init_params,
                           loss_fn, param_spec, train_step)

TINY = CONFIGS["tiny"]


def toks(cfg, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (cfg.batch, cfg.seq_len), 0, cfg.vocab,
                              dtype=jnp.int32)


class TestParamSpec:
    def test_offsets_are_contiguous(self):
        spec = param_spec(TINY)
        off = 0
        for shape, o in zip(spec.shapes, spec.offsets):
            assert o == off
            size = 1
            for s in shape:
                size *= s
            off += size
        assert spec.total == off

    def test_param_counts(self):
        # tiny: embed 256*64 + pos 32*64 + 2 layers + final ln
        spec = param_spec(TINY)
        per_layer = (2 * 64 + 64 * 3 * 64 + 64 * 64 + 2 * 64 +
                     64 * 256 + 256 + 256 * 64 + 64)
        expected = 256 * 64 + 32 * 64 + 2 * per_layer + 2 * 64
        assert spec.total == expected

    def test_gpt100m_is_about_100m(self):
        spec = param_spec(CONFIGS["gpt100m"])
        assert 85e6 < spec.total < 115e6, spec.total

    def test_all_names_unique(self):
        spec = param_spec(CONFIGS["small"])
        assert len(set(spec.names)) == len(spec.names)


class TestForward:
    def test_logits_shape(self):
        fp = init_params(TINY)
        logits = forward(TINY, fp, toks(TINY))
        assert logits.shape == (TINY.batch, TINY.seq_len, TINY.vocab)

    def test_causality(self):
        """Changing a future token must not change past logits."""
        fp = init_params(TINY)
        t1 = toks(TINY)
        t2 = t1.at[:, -1].set((t1[:, -1] + 1) % TINY.vocab)
        l1 = forward(TINY, fp, t1)
        l2 = forward(TINY, fp, t2)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5,
                                   atol=1e-5)

    def test_initial_loss_near_uniform(self):
        """Fresh params => loss ~ ln(vocab)."""
        fp = init_params(TINY)
        loss = float(loss_fn(TINY, fp, toks(TINY)))
        assert abs(loss - np.log(TINY.vocab)) < 1.0, loss


class TestTrainStep:
    def test_one_step_shapes_and_finite(self):
        fp = init_params(TINY)
        fm = jnp.zeros_like(fp)
        np2, nm2, loss = train_step(TINY, fp, fm, toks(TINY),
                                    jnp.float32(0.1))
        assert np2.shape == fp.shape and nm2.shape == fm.shape
        assert np.isfinite(float(loss))
        assert not np.allclose(np2, fp)  # parameters moved

    def test_loss_decreases_on_fixed_batch(self):
        """Overfit a single batch: loss must drop substantially."""
        fp = init_params(TINY)
        fm = jnp.zeros_like(fp)
        batch = toks(TINY, seed=7)
        step = jax.jit(lambda a, b: train_step(TINY, a, b, batch,
                                               jnp.float32(0.5)))
        first = None
        for i in range(30):
            fp, fm, loss = step(fp, fm)
            if first is None:
                first = float(loss)
        assert float(loss) < first * 0.7, (first, float(loss))

    def test_zero_lr_is_identity(self):
        fp = init_params(TINY)
        fm = jnp.zeros_like(fp)
        np2, _, _ = train_step(TINY, fp, fm, toks(TINY), jnp.float32(0.0))
        np.testing.assert_allclose(np2, fp)

    def test_momentum_accumulates(self):
        fp = init_params(TINY)
        fm = jnp.zeros_like(fp)
        _, nm, _ = train_step(TINY, fp, fm, toks(TINY), jnp.float32(0.1))
        assert float(jnp.sum(jnp.abs(nm))) > 0.0


class TestConfigs:
    @pytest.mark.parametrize("name", list(CONFIGS))
    def test_heads_divide_dmodel(self, name):
        cfg = CONFIGS[name]
        assert cfg.d_model % cfg.n_heads == 0

    def test_custom_config(self):
        cfg = ModelConfig("c", vocab=128, d_model=32, n_layers=1, n_heads=2,
                          d_ff=64, seq_len=16, batch=2)
        fp = init_params(cfg)
        logits = forward(cfg, fp, toks(cfg))
        assert logits.shape == (2, 16, 128)
