"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain jax.numpy only (no pallas), used by pytest/hypothesis as the
ground truth for values and gradients.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True):
    """softmax(q kᵀ / sqrt(d)) v over (BH, S, D) tensors, optionally causal."""
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    """Row LayerNorm with affine transform over (N, D)."""
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)


def xent_ref(logits, targets):
    """Per-row NLL of (N, V) logits against (N,) int targets, float32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
