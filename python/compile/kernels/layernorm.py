"""Layer-1 Pallas kernel: row-tiled LayerNorm.

A VPU-shaped kernel: the grid tiles rows of the (N, D) activation matrix;
each program normalizes a block of rows and applies the affine transform.
gamma/beta are broadcast to every program via a constant index_map.

interpret=True for the same reason as attention.py (CPU PJRT execution).
Backward is a pure-jnp custom VJP so the train step stays differentiable.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, D)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def _pick_block_rows(n: int) -> int:
    """Largest power-of-two divisor of n, capped at 128 rows per program."""
    b = 1
    while b < 128 and n % (b * 2) == 0:
        b *= 2
    return b


def layernorm_forward(x, gamma, beta, *, eps: float = 1e-5):
    n, d = x.shape
    block = _pick_block_rows(n)
    return pl.pallas_call(
        partial(_layernorm_kernel, eps=eps),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)


@partial(jax.custom_vjp, nondiff_argnums=())
def layernorm(x, gamma, beta):
    """Differentiable LayerNorm. Forward = Pallas, backward = jnp VJP."""
    return layernorm_forward(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    return layernorm_forward(x, gamma, beta), (x, gamma)


def _ln_bwd(res, g):
    x, gamma = res
    eps = 1e-5
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x32 - mean) * rstd
    dgamma = jnp.sum(g32 * xhat, axis=0)
    dbeta = jnp.sum(g32, axis=0)
    d = x.shape[-1]
    gy = g32 * gamma.astype(jnp.float32)
    dx = rstd * (
        gy
        - jnp.mean(gy, axis=-1, keepdims=True)
        - xhat * jnp.mean(gy * xhat, axis=-1, keepdims=True)
    )
    # exact: dx = rstd * (gy - mean(gy) - xhat * mean(gy * xhat)), with the
    # means over the feature axis of size d.
    del d
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype)


layernorm.defvjp(_ln_fwd, _ln_bwd)
