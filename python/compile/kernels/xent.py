"""Layer-1 Pallas kernel: fused softmax cross-entropy.

The training loss hot-spot: for (N, V) logits and integer targets, each
program computes a block of rows' negative log-likelihood in one pass —
max, log-sum-exp, and target gather fused so the (N, V) probability
matrix is never materialized in HBM (the V-sized softmax intermediate
lives only in VMEM-shaped blocks).

TPU shape notes (DESIGN.md §Hardware-Adaptation): the row block feeds
the VPU with (block_rows, V) tiles; the gather is expressed as an iota
comparison (TPU has no scatter/gather unit — masked reductions are the
idiomatic form). interpret=True as everywhere (CPU PJRT cannot run
Mosaic custom-calls). Backward is a pure-jnp custom VJP
(softmax − one-hot), keeping the train step differentiable.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_kernel(logits_ref, targets_ref, o_ref):
    x = logits_ref[...].astype(jnp.float32)  # (block_rows, V)
    t = targets_ref[...]  # (block_rows,)
    m = jnp.max(x, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1)) + m[..., 0]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        == t[:, None].astype(jnp.int32)
    )
    target_logit = jnp.sum(jnp.where(onehot, x, 0.0), axis=-1)
    o_ref[...] = (lse - target_logit).astype(o_ref.dtype)


def _pick_block_rows(n: int) -> int:
    """Largest power-of-two divisor of n, capped at 128 rows per program."""
    b = 1
    while b < 128 and n % (b * 2) == 0:
        b *= 2
    return b


def xent_forward(logits, targets):
    """Per-row NLL for (N, V) logits and (N,) int targets, as float32."""
    n, v = logits.shape
    block = _pick_block_rows(n)
    return pl.pallas_call(
        _xent_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, v), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(logits, targets)


@partial(jax.custom_vjp, nondiff_argnums=())
def xent(logits, targets):
    """Differentiable fused cross-entropy. Forward = Pallas, backward =
    jnp VJP (targets carry no gradient)."""
    return xent_forward(logits, targets)


def _xent_fwd(logits, targets):
    return xent_forward(logits, targets), (logits, targets)


def _xent_bwd(res, g):
    logits, targets = res
    x = logits.astype(jnp.float32)
    p = jax.nn.softmax(x, axis=-1)
    onehot = jax.nn.one_hot(targets, x.shape[-1], dtype=jnp.float32)
    dlogits = (p - onehot) * g[:, None]
    return (dlogits.astype(logits.dtype), None)


xent.defvjp(_xent_fwd, _xent_bwd)
