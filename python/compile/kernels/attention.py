"""Layer-1 Pallas kernel: fused causal scaled-dot-product attention.

The paper's workload layer (the DNN jobs Synergy schedules) runs image /
language / speech models; our representative real workload is a GPT-style
decoder transformer whose hot-spot — attention — is implemented here as a
Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): instead of porting a
CUDA flash-attention (warps / shared memory / WMMA), the kernel is tiled for
the TPU model Pallas exposes: the grid iterates over (batch*heads), each
program streams one (seq, head_dim) Q/K/V tile HBM->VMEM via BlockSpec and
issues MXU-shaped matmuls; softmax runs on the VPU in f32.

interpret=True is mandatory: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and artifacts produced here are executed by the rust runtime
on the CPU PJRT client.

The backward pass is supplied as a pure-jnp custom VJP (standard
flash-attention practice: recompute probabilities), so the whole train step
remains differentiable and lowers into a single HLO module.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool):
    """One grid step: full attention for a single (batch*head) slice.

    Block shapes are (1, S, D): one Q/K/V tile per program. S and D are
    chosen so the working set (3 input tiles + S*S scores) fits VMEM; see
    vmem_footprint_bytes() below, asserted in tests.
    """
    q = q_ref[0, :, :].astype(jnp.float32)  # (S, D)
    k = k_ref[0, :, :].astype(jnp.float32)  # (S, D)
    v = v_ref[0, :, :].astype(jnp.float32)  # (S, D)

    # MXU matmul: (S, D) x (D, S) -> (S, S)
    scores = jnp.dot(q, k.T) * scale
    if causal:
        s = q.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(col <= row, scores, NEG_INF)

    # Numerically stable softmax on the VPU.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    # MXU matmul: (S, S) x (S, D) -> (S, D)
    o_ref[0, :, :] = jnp.dot(p, v).astype(o_ref.dtype)


def attention_forward(q, k, v, *, causal: bool = True):
    """Fused attention over (BH, S, D) tensors via pallas_call."""
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    spec = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        partial(_attention_kernel, scale=scale, causal=causal),
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=True,
    )(q, k, v)


def _attention_bwd_ref(q, k, v, g, *, causal: bool):
    """Pure-jnp backward (recompute probabilities, flash-attention style)."""
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    dv = jnp.einsum("bqk,bqd->bkd", p, g.astype(jnp.float32))
    dp = jnp.einsum("bqd,bkd->bqk", g.astype(jnp.float32), v.astype(jnp.float32))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bqk,bkd->bqd", ds, k.astype(jnp.float32)) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q.astype(jnp.float32)) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal: bool = True):
    """Differentiable fused attention. Forward = Pallas, backward = jnp VJP."""
    return attention_forward(q, k, v, causal=causal)


def _attention_fwd(q, k, v, causal):
    return attention_forward(q, k, v, causal=causal), (q, k, v)


def _attention_bwd(causal, res, g):
    q, k, v = res
    return _attention_bwd_ref(q, k, v, g, causal=causal)


attention.defvjp(_attention_fwd, _attention_bwd)


def vmem_footprint_bytes(s: int, d: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (see DESIGN.md §Perf).

    3 input tiles + 1 output tile of (s, d) plus the (s, s) score/prob
    buffers in f32. Used by tests and the perf notes to keep the kernel
    under the ~16 MiB VMEM budget of a real TPU core.
    """
    tiles = 4 * s * d * dtype_bytes
    scores = 2 * s * s * 4
    return tiles + scores
