"""AOT lowering: jax train/eval steps -> HLO **text** artifacts for rust.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects with
`proto.id() <= INT_MAX`; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs, per model variant V in model.CONFIGS:
    artifacts/train_step_V.hlo.txt  — (params, momentum, tokens, lr) ->
                                      tuple(params', momentum', loss)
    artifacts/eval_step_V.hlo.txt   — (params, tokens) -> tuple(loss)
    artifacts/V.meta.json           — shapes / layout / param count sidecar

Usage:  cd python && python -m compile.aot --out ../artifacts \
            [--variants tiny,small,gpt100m]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import CONFIGS, param_spec, train_step, eval_step


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg, out_dir: str, no_donate: bool = False) -> dict:
    """Lower train_step and eval_step for one config; write artifacts."""
    spec = param_spec(cfg)
    n = spec.total
    p = jax.ShapeDtypeStruct((n,), jnp.float32)
    m = jax.ShapeDtypeStruct((n,), jnp.float32)
    toks = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    t0 = time.time()
    # Donate the flat param/momentum buffers: the rust trainer feeds each
    # step's outputs straight back as the next step's inputs, so XLA may
    # update them in place (input_output_alias in the HLO). For gpt100m
    # this removes ~2 × 400 MB of buffer copies per step.
    train_lowered = jax.jit(
        lambda fp, fm, tk, l: train_step(cfg, fp, fm, tk, l),
        donate_argnums=() if no_donate else (0, 1),
    ).lower(p, m, toks, lr)
    train_text = to_hlo_text(train_lowered)
    train_path = os.path.join(out_dir, f"train_step_{cfg.name}.hlo.txt")
    with open(train_path, "w") as f:
        f.write(train_text)

    eval_lowered = jax.jit(
        lambda fp, tk: eval_step(cfg, fp, tk)
    ).lower(p, toks)
    eval_text = to_hlo_text(eval_lowered)
    eval_path = os.path.join(out_dir, f"eval_step_{cfg.name}.hlo.txt")
    with open(eval_path, "w") as f:
        f.write(eval_text)
    elapsed = time.time() - t0

    meta = {
        "variant": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "param_count": n,
        "train_hlo": os.path.basename(train_path),
        "eval_hlo": os.path.basename(eval_path),
        # Input order for the rust runtime.
        "train_inputs": [
            {"name": "flat_params", "shape": [n], "dtype": "f32"},
            {"name": "flat_momentum", "shape": [n], "dtype": "f32"},
            {"name": "tokens", "shape": [cfg.batch, cfg.seq_len],
             "dtype": "s32"},
            {"name": "lr", "shape": [], "dtype": "f32"},
        ],
        "train_outputs": ["flat_params", "flat_momentum", "loss"],
        "params": [
            {"name": nm, "shape": list(sh), "offset": off}
            for nm, sh, off in zip(spec.names, spec.shapes, spec.offsets)
        ],
        "lower_seconds": round(elapsed, 2),
    }
    meta_path = os.path.join(out_dir, f"{cfg.name}.meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] {cfg.name}: {n/1e6:.1f}M params, "
          f"train={len(train_text)/1e6:.1f}MB eval={len(eval_text)/1e6:.1f}MB "
          f"({elapsed:.1f}s)")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--variants", default="tiny,small,gpt100m",
                    help="comma-separated variant names from model.CONFIGS")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable param/momentum buffer donation "
                         "(perf ablation; see EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    variants = [v.strip() for v in args.variants.split(",") if v.strip()]
    manifest = {}
    for v in variants:
        if v not in CONFIGS:
            raise SystemExit(f"unknown variant {v!r}; have {list(CONFIGS)}")
        manifest[v] = lower_variant(CONFIGS[v], args.out, args.no_donate)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"variants": list(manifest)}, f, indent=1)
    print(f"[aot] wrote {len(manifest)} variants to {args.out}")


if __name__ == "__main__":
    main()
