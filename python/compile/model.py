"""Layer-2 JAX model: GPT-style decoder transformer with a flat-parameter
train step, AOT-lowered for the rust runtime.

The rust coordinator (Layer 3) holds model parameters and SGD-momentum state
as two flat f32 device buffers and drives training by repeatedly executing
the lowered `train_step` HLO with `execute_b` (buffers never leave the
device between steps). That forces a *flat* parameter interface:

    train_step(flat_params, flat_momentum, tokens, lr)
        -> (flat_params', flat_momentum', mean_loss)

`ParamSpec` records the name/shape/offset of every tensor inside the flat
vector; the same layout is exported to artifacts/<variant>.meta.json so the
rust side can introspect (param count, buffer length, input shapes).

The hot-spots call the Layer-1 Pallas kernels (attention, layernorm), so the
kernels lower into the same HLO module as the surrounding graph.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import attention
from compile.kernels.layernorm import layernorm
from compile.kernels.xent import xent


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + batch configuration for one AOT variant."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The AOT variants built by `make artifacts`. `gpt100m` is the end-to-end
# workload (~100M parameters); `tiny` keeps tests fast; `small` sits between
# for deploy-mode multi-job demos.
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4,
                        d_ff=256, seq_len=32, batch=4),
    "small": ModelConfig("small", vocab=2048, d_model=256, n_layers=4,
                         n_heads=8, d_ff=1024, seq_len=32, batch=4),
    "gpt100m": ModelConfig("gpt100m", vocab=8192, d_model=768, n_layers=12,
                           n_heads=12, d_ff=3072, seq_len=32, batch=4),
}


@dataclass
class ParamSpec:
    """Layout of the flat parameter vector."""

    names: List[str] = field(default_factory=list)
    shapes: List[Tuple[int, ...]] = field(default_factory=list)
    offsets: List[int] = field(default_factory=list)
    total: int = 0

    def add(self, name: str, shape: Tuple[int, ...]) -> None:
        size = 1
        for s in shape:
            size *= s
        self.names.append(name)
        self.shapes.append(tuple(shape))
        self.offsets.append(self.total)
        self.total += size

    def index(self, name: str) -> int:
        return self.names.index(name)


def param_spec(cfg: ModelConfig) -> ParamSpec:
    """Declare every parameter tensor, in flat-vector order."""
    spec = ParamSpec()
    spec.add("tok_embed", (cfg.vocab, cfg.d_model))
    spec.add("pos_embed", (cfg.seq_len, cfg.d_model))
    for layer in range(cfg.n_layers):
        p = f"layer{layer}."
        spec.add(p + "ln1.gamma", (cfg.d_model,))
        spec.add(p + "ln1.beta", (cfg.d_model,))
        spec.add(p + "attn.wqkv", (cfg.d_model, 3 * cfg.d_model))
        spec.add(p + "attn.wo", (cfg.d_model, cfg.d_model))
        spec.add(p + "ln2.gamma", (cfg.d_model,))
        spec.add(p + "ln2.beta", (cfg.d_model,))
        spec.add(p + "mlp.w1", (cfg.d_model, cfg.d_ff))
        spec.add(p + "mlp.b1", (cfg.d_ff,))
        spec.add(p + "mlp.w2", (cfg.d_ff, cfg.d_model))
        spec.add(p + "mlp.b2", (cfg.d_model,))
    spec.add("ln_f.gamma", (cfg.d_model,))
    spec.add("ln_f.beta", (cfg.d_model,))
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> jnp.ndarray:
    """Initialize the flat parameter vector (scaled-normal / zeros / ones)."""
    spec = param_spec(cfg)
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in zip(spec.names, spec.shapes):
        key, sub = jax.random.split(key)
        if name.endswith(".gamma"):
            chunks.append(jnp.ones(shape, jnp.float32).reshape(-1))
        elif name.endswith((".beta", ".b1", ".b2")):
            chunks.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[0]
            std = 0.02 if "embed" in name else (1.0 / fan_in) ** 0.5
            chunks.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1))
    return jnp.concatenate(chunks)


def _unflatten(flat: jnp.ndarray, spec: ParamSpec):
    """Slice the flat vector back into named tensors (static offsets)."""
    params = {}
    for name, shape, off in zip(spec.names, spec.shapes, spec.offsets):
        size = 1
        for s in shape:
            size *= s
        params[name] = jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(shape)
    return params


def forward(cfg: ModelConfig, flat_params: jnp.ndarray,
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Logits for next-token prediction. tokens: (B, S) int32 -> (B, S, V)."""
    spec = param_spec(cfg)
    p = _unflatten(flat_params, spec)
    b, s = tokens.shape
    h = p["tok_embed"][tokens] + p["pos_embed"][None, :s, :]

    for layer in range(cfg.n_layers):
        pre = f"layer{layer}."
        # --- attention block ---
        x = layernorm(h.reshape(b * s, cfg.d_model),
                      p[pre + "ln1.gamma"], p[pre + "ln1.beta"])
        qkv = x @ p[pre + "attn.wqkv"]  # (B*S, 3*D)
        qkv = qkv.reshape(b, s, 3, cfg.n_heads, cfg.head_dim)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(
            b * cfg.n_heads, s, cfg.head_dim)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(
            b * cfg.n_heads, s, cfg.head_dim)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(
            b * cfg.n_heads, s, cfg.head_dim)
        attn = attention(q, k, v, True)  # Pallas kernel (L1)
        attn = attn.reshape(b, cfg.n_heads, s, cfg.head_dim)
        attn = attn.transpose(0, 2, 1, 3).reshape(b * s, cfg.d_model)
        h = h + (attn @ p[pre + "attn.wo"]).reshape(b, s, cfg.d_model)

        # --- MLP block ---
        x = layernorm(h.reshape(b * s, cfg.d_model),
                      p[pre + "ln2.gamma"], p[pre + "ln2.beta"])
        x = jax.nn.gelu(x @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        x = x @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
        h = h + x.reshape(b, s, cfg.d_model)

    x = layernorm(h.reshape(b * s, cfg.d_model),
                  p["ln_f.gamma"], p["ln_f.beta"])
    # Weight-tied output head.
    logits = x @ p["tok_embed"].T
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(cfg: ModelConfig, flat_params: jnp.ndarray,
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy over positions 0..S-2."""
    logits = forward(cfg, flat_params, tokens)  # (B, S, V)
    b, s, v = logits.shape
    logits = logits[:, :-1, :].reshape(b * (s - 1), v)
    targets = tokens[:, 1:].reshape(b * (s - 1))
    # Fused Pallas softmax-xent (L1): never materializes the (N, V)
    # probability matrix in HBM.
    nll = xent(logits, targets)
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, flat_params: jnp.ndarray,
               flat_momentum: jnp.ndarray, tokens: jnp.ndarray,
               lr: jnp.ndarray):
    """One SGD-with-momentum step over the flat parameter vector.

    Returns (flat_params', flat_momentum', loss). This is the function that
    is AOT-lowered; the rust runtime keeps both flat buffers device-resident
    across steps via execute_b.
    """
    loss, grad = jax.value_and_grad(
        lambda fp: loss_fn(cfg, fp, tokens))(flat_params)
    # Global-norm clipping keeps the long e2e run stable with synthetic data.
    gnorm = jnp.sqrt(jnp.sum(jnp.square(grad)))
    grad = grad * jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
    momentum = 0.9 * flat_momentum + grad
    new_params = flat_params - lr * momentum
    return new_params, momentum, loss


def eval_step(cfg: ModelConfig, flat_params: jnp.ndarray,
              tokens: jnp.ndarray):
    """Loss only (no update) — used by the rust profiler path."""
    return (loss_fn(cfg, flat_params, tokens),)
