//! Shard-determinism invariant (ISSUE 8 tentpole): serial planning and
//! `--shards N` planning produce byte-identical results for any N.
//!
//! The sharded planner fans the resumable planner's per-pool placement
//! folds out over `std::thread::scope` workers *after* the shared A.2.2
//! type-assignment fold has partitioned jobs to pools. Each pool's fold
//! is a pure function of (policy-ordered sequence, pool state), pools
//! are disjoint, and per-pool outcomes merge in fixed pool order — so
//! the fan-out width must be invisible everywhere an observer could
//! look: `SimResult` schedule bits, the golden `metrics_json` payload,
//! and the exported telemetry profile.

use synergy::cluster::{GpuGen, ServerSpec, TypeSpec};
use synergy::job::Job;
use synergy::sim::{FaultSpec, SimConfig, SimResult, Simulator};
use synergy::telemetry::{TelemetryConfig, TelemetryRecorder};
use synergy::trace::{Split, TraceConfig};
use synergy::workload::{SyntheticSource, TenantSpec, WorkloadSource};

fn loaded_trace(n: usize, seed: u64) -> (Vec<Job>, TenantSpec) {
    let spec = TenantSpec::parse("a:2,b:1").unwrap();
    let jobs = SyntheticSource::new(TraceConfig {
        n_jobs: n,
        split: Split::new(30, 50, 20),
        multi_gpu: true, // gangs, so per-pool folds do nontrivial work
        jobs_per_hour: Some(10.0),
        seed,
    })
    .with_tenants(spec.clone())
    .drain_jobs();
    (jobs, spec)
}

fn tritype() -> Vec<TypeSpec> {
    vec![
        TypeSpec { gen: GpuGen::K80, spec: ServerSpec::default(), machines: 2 },
        TypeSpec { gen: GpuGen::P100, spec: ServerSpec::default(), machines: 2 },
        TypeSpec { gen: GpuGen::V100, spec: ServerSpec::default(), machines: 2 },
    ]
}

/// Exact schedule bits: per-job finish times, round counts, makespan,
/// utilization trace — bit patterns, so "close" is not "equal".
fn schedule_bits(r: &SimResult) -> (Vec<(u64, u64)>, usize, u64, Vec<u64>) {
    let finished: Vec<(u64, u64)> =
        r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect();
    let util: Vec<u64> = r
        .utilization
        .samples
        .iter()
        .flat_map(|s| {
            [
                s.gpu_util.to_bits(),
                s.cpu_util.to_bits(),
                s.cpu_used.to_bits(),
                s.mem_util.to_bits(),
                s.queued_jobs as u64,
                s.running_jobs as u64,
            ]
        })
        .collect();
    (finished, r.rounds, r.makespan_s.to_bits(), util)
}

/// One recorded run at the given fan-out width: the result, the golden
/// metrics payload string, and the exported telemetry profile.
fn run_recorded(
    jobs: &[Job],
    spec: &TenantSpec,
    policy: &str,
    shards: usize,
) -> (SimResult, String, String) {
    let cfg = SimConfig {
        n_servers: 2,
        policy: policy.into(),
        mechanism: "tune".into(),
        types: Some(tritype()),
        shards,
        ..Default::default()
    };
    let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
    let r = Simulator::with_quotas(cfg, Some(spec.quotas()))
        .run_with_telemetry(jobs.to_vec(), Some(&mut rec));
    let metrics = r.metrics_json(true, false);
    (r, metrics, rec.to_jsonl())
}

#[test]
fn sharded_planning_is_byte_identical_to_serial() {
    let (jobs, spec) = loaded_trace(30, 17);
    for policy in ["fifo", "srtf"] {
        let (serial, serial_metrics, serial_profile) =
            run_recorded(&jobs, &spec, policy, 1);
        assert_eq!(
            serial.finished.len(),
            jobs.len(),
            "{policy}: baseline must drain the trace"
        );
        for shards in [2, 4] {
            let (sharded, metrics, profile) =
                run_recorded(&jobs, &spec, policy, shards);
            assert_eq!(
                schedule_bits(&sharded),
                schedule_bits(&serial),
                "{policy}/shards={shards}: schedule bits diverge"
            );
            assert_eq!(
                metrics, serial_metrics,
                "{policy}/shards={shards}: golden metrics payload diverges"
            );
            assert_eq!(
                profile, serial_profile,
                "{policy}/shards={shards}: telemetry profile diverges"
            );
        }
    }
}

#[test]
fn faulted_runs_are_byte_identical_across_shard_widths() {
    // ISSUE 9: churn events drain at round boundaries, before the plan
    // runs, so the surviving-fleet snapshot a sharded plan fans out over
    // is the same one the serial plan folds over. Fault counters ride
    // the golden payload here via `metrics_json(_, true)`.
    let (jobs, spec) = loaded_trace(30, 17);
    let run = |shards: usize| {
        let cfg = SimConfig {
            n_servers: 2,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            types: Some(tritype()),
            shards,
            faults: Some(FaultSpec::parse("mtbf:10,mttr:2,seed:11").unwrap()),
            ..Default::default()
        };
        let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
        let r = Simulator::with_quotas(cfg, Some(spec.quotas()))
            .run_with_telemetry(jobs.to_vec(), Some(&mut rec));
        let metrics = r.metrics_json(true, true);
        (r, metrics, rec.to_jsonl())
    };
    let (serial, serial_metrics, serial_profile) = run(1);
    assert_eq!(
        serial.finished.len(),
        jobs.len(),
        "faulted baseline must still drain the trace (no job lost)"
    );
    assert!(
        serial.servers_failed > 0,
        "fault generator must actually exercise churn in this window"
    );
    for shards in [2, 4] {
        let (sharded, metrics, profile) = run(shards);
        assert_eq!(
            schedule_bits(&sharded),
            schedule_bits(&serial),
            "shards={shards}: faulted schedule bits diverge"
        );
        assert_eq!(
            metrics, serial_metrics,
            "shards={shards}: faulted metrics payload (incl. churn counters) diverges"
        );
        assert_eq!(
            profile, serial_profile,
            "shards={shards}: faulted telemetry profile diverges"
        );
    }
}

#[test]
fn sharding_a_single_pool_fleet_is_a_no_op() {
    // Homogeneous fleets have one pool: the sharded dispatch falls back
    // to the serial path, and any shard count is accepted and harmless.
    let (jobs, spec) = loaded_trace(20, 5);
    let run = |shards: usize| {
        let cfg = SimConfig {
            n_servers: 2,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            shards,
            ..Default::default()
        };
        Simulator::with_quotas(cfg, Some(spec.quotas())).run(jobs.clone())
    };
    let serial = run(1);
    for shards in [2, 8] {
        assert_eq!(
            schedule_bits(&run(shards)),
            schedule_bits(&serial),
            "shards={shards}: homogeneous run must be unaffected"
        );
    }
}
