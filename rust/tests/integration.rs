//! Integration tests: whole-system behaviour across modules — trace →
//! profiler → policy → mechanism → simulator → metrics.

use synergy::cluster::ServerSpec;
use synergy::job::{Job, JobId, ModelKind};
use synergy::metrics::JctStats;
use synergy::sim::{SimConfig, SimResult, Simulator};
use synergy::trace::{generate, Split, TraceConfig};
use std::collections::BTreeMap;

fn run(policy: &str, mechanism: &str, jobs: Vec<Job>, servers: usize) -> SimResult {
    Simulator::new(SimConfig {
        n_servers: servers,
        policy: policy.into(),
        mechanism: mechanism.into(),
        ..Default::default()
    })
    .run(jobs)
}

fn contended_trace(seed: u64) -> Vec<Job> {
    generate(&TraceConfig {
        n_jobs: 200,
        split: Split::new(40, 40, 20),
        multi_gpu: false,
        jobs_per_hour: Some(12.0),
        seed,
    })
}

#[test]
fn every_policy_mechanism_combination_completes() {
    let trace = generate(&TraceConfig {
        n_jobs: 40,
        split: Split::new(30, 60, 10),
        multi_gpu: true,
        jobs_per_hour: Some(6.0),
        seed: 2,
    });
    for policy in synergy::policy::ALL_POLICIES {
        for mechanism in ["proportional", "tune", "greedy", "fixed"] {
            let r = run(policy, mechanism, trace.clone(), 2);
            assert!(
                r.finished.len() >= 35,
                "{policy}/{mechanism}: only {} finished",
                r.finished.len()
            );
        }
    }
}

#[test]
fn tune_improves_avg_jct_under_contention() {
    let trace = contended_trace(3);
    let prop = run("srtf", "proportional", trace.clone(), 4);
    let tune = run("srtf", "tune", trace, 4);
    let (a, b) = (prop.jct_stats().avg_s, tune.jct_stats().avg_s);
    assert!(b < a, "tune {b} should beat proportional {a}");
}

#[test]
fn opt_tracks_or_beats_tune_modestly() {
    // OPT is an aggregate-throughput bound; its JCT should be in the same
    // ballpark as TUNE (paper: TUNE within 10% of OPT).
    let trace = generate(&TraceConfig {
        n_jobs: 60,
        split: Split::new(40, 40, 20),
        multi_gpu: false,
        jobs_per_hour: Some(8.0),
        seed: 17,
    });
    let tune = run("fifo", "tune", trace.clone(), 2);
    let opt = run("fifo", "opt", trace, 2);
    let (t, o) = (tune.jct_stats().avg_s, opt.jct_stats().avg_s);
    assert!(
        (t - o).abs() / o < 0.35,
        "tune {t} vs opt {o} diverge too much"
    );
}

#[test]
fn srtf_beats_fifo_on_avg_jct() {
    let trace = contended_trace(5);
    let fifo = run("fifo", "tune", trace.clone(), 4);
    let srtf = run("srtf", "tune", trace, 4);
    assert!(
        srtf.jct_stats().avg_s < fifo.jct_stats().avg_s,
        "SRTF should beat FIFO on average JCT"
    );
}

#[test]
fn no_individual_job_much_slower_under_tune_static() {
    // Static trace + FIFO: with identical admission order, per-job JCT
    // under TUNE must never exceed proportional by more than round
    // quantization (the paper's "no job below GPU-proportional" claim,
    // Fig 6c: no slowdowns).
    let trace = generate(&TraceConfig {
        n_jobs: 48,
        split: Split::new(50, 30, 20),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 7,
    });
    let prop = run("fifo", "proportional", trace.clone(), 2);
    let tune = run("fifo", "tune", trace, 2);
    let index = |r: &SimResult| -> BTreeMap<u64, f64> {
        r.finished.iter().map(|f| (f.id.0, f.jct_s)).collect()
    };
    let p = index(&prop);
    let t = index(&tune);
    for (id, jt) in &t {
        let jp = p[id];
        assert!(
            *jt <= jp * 1.10 + 600.0,
            "job {id} slower under tune: {jt} vs {jp}"
        );
    }
}

#[test]
fn greedy_strands_gpus_on_hungry_split() {
    // §5.4: with an all-sensitive split, GREEDY leaves GPUs idle while
    // TUNE keeps them allocated.
    let trace = generate(&TraceConfig {
        n_jobs: 64,
        split: Split::new(50, 0, 50),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 11,
    });
    let greedy = run("fifo", "greedy", trace.clone(), 2);
    let tune = run("fifo", "tune", trace, 2);
    assert!(
        greedy.utilization.mean_gpu_util()
            < tune.utilization.mean_gpu_util(),
        "greedy {:.2} should under-utilize vs tune {:.2}",
        greedy.utilization.mean_gpu_util(),
        tune.utilization.mean_gpu_util()
    );
    assert!(
        greedy.jct_stats().avg_s > tune.jct_stats().avg_s,
        "greedy should lose on JCT under the hungry split"
    );
}

#[test]
fn profiling_cost_accounted_once_per_job() {
    let trace = generate(&TraceConfig {
        n_jobs: 25,
        jobs_per_hour: Some(6.0),
        ..Default::default()
    });
    let r = run("fifo", "tune", trace, 2);
    // Each job profiles once; adaptive sweep uses >=2 and <=49 points.
    assert!(r.profiling_minutes >= 2.0 * 25.0);
    assert!(r.profiling_minutes <= 49.0 * 25.0);
}

#[test]
fn multi_gpu_jobs_fragment_only_when_necessary() {
    // A 16-GPU job must span exactly 2 default servers.
    let mut job = Job::new(JobId(0), ModelKind::Gnmt, 16, 0.0, 1800.0);
    job.rng_stream = 0;
    let r = run("fifo", "tune", vec![job], 4);
    assert_eq!(r.finished.len(), 1);
    // JCT close to baseline (GNMT insensitive).
    let jct = r.finished[0].jct_s;
    assert!((jct - 1800.0).abs() < 400.0, "16-GPU GNMT JCT {jct}");
}

#[test]
fn higher_load_never_reduces_avg_jct() {
    let mut prev = 0.0;
    for load in [4.0, 8.0, 12.0] {
        let trace = generate(&TraceConfig {
            n_jobs: 150,
            split: Split::new(30, 60, 10),
            multi_gpu: false,
            jobs_per_hour: Some(load),
            seed: 21,
        });
        let r = run("fifo", "proportional", trace, 2);
        let avg = r.jct_stats().avg_s;
        assert!(
            avg + 1.0 >= prev,
            "avg JCT decreased with load: {avg} < {prev}"
        );
        prev = avg;
    }
}

#[test]
fn jct_stats_and_finished_jobs_consistent() {
    let trace = contended_trace(31);
    let n = trace.len();
    let r = run("las", "tune", trace, 4);
    assert_eq!(r.finished.len(), n);
    let stats = r.jct_stats();
    assert_eq!(stats.n, n);
    let manual_avg: f64 =
        r.finished.iter().map(|f| f.jct_s).sum::<f64>() / n as f64;
    assert!((stats.avg_s - manual_avg).abs() < 1e-6);
    let recomputed = JctStats::from_jcts(&r.jcts());
    assert_eq!(recomputed.p99_s, stats.p99_s);
}
