//! Property-based tests over the coordinator invariants (DESIGN.md §6),
//! using the in-crate property harness (`util::prop`), all expressed
//! against the one type-generic allocation stack.
//!
//! The invariants:
//! 1. No overallocation: fleet bookkeeping consistent after any round.
//! 2. Fairness floor: TUNE never grants a job throughput below the
//!    oracle `W_j^Fair` (on one type: its GPU-proportional throughput).
//! 3. No stranded GPUs: under TUNE, a runnable job is unplaced only if
//!    its GPU demand cannot be met.
//! 4. Placement shape: multi-server placements split CPU/mem
//!    proportionally to GPUs.
//! 5. Simulator: JCT >= baseline-duration is not required (jobs can beat
//!    baseline), but JCT > 0 and all jobs finish on an idle-enough
//!    cluster; runs are deterministic.
//! 6. Unification: on a one-type fleet the type-assignment phase is a
//!    pass-through — the fleet-level mechanisms reproduce the pool-level
//!    (pre-unification homogeneous) grants bit-for-bit.

use synergy::cluster::{
    Cluster, Fleet, Placement, ServerSpec, Share, TopologySpec,
};
use synergy::job::{DemandVector, Job, JobId, ALL_MODELS};
use synergy::mechanism::{
    best_fit, best_fit_scan, by_name, first_fit, first_fit_scan,
    multi_server_fit, JobRequest, Mechanism, PoolRequest, Tune,
};
use synergy::profiler::{OptimisticProfiler, Sensitivity};
use synergy::prop_assert;
use synergy::sim::{FaultSpec, SimConfig, Simulator};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::util::prop::{check, Gen};

fn random_jobs(
    g: &mut Gen,
    profiler: &OptimisticProfiler,
) -> (Vec<Job>, Vec<Sensitivity>) {
    let n = g.int(1, 24);
    let jobs: Vec<Job> = (0..n)
        .map(|i| {
            let model = g.choose(&ALL_MODELS);
            let gpus = g.choose(&[1u32, 1, 1, 2, 4, 8, 16]);
            Job::new(JobId(i as u64), model, gpus, 0.0, 3600.0)
        })
        .collect();
    let sens = jobs.iter().map(|j| profiler.profile(j)).collect();
    (jobs, sens)
}

fn to_requests<'a>(
    jobs: &'a [Job],
    sens: &'a [Sensitivity],
) -> Vec<JobRequest<'a>> {
    jobs.iter()
        .zip(sens)
        .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
        .collect()
}

#[test]
fn prop_cluster_consistent_after_any_allocation() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    check("cluster consistency", 25, |g| {
        let (jobs, sens) = random_jobs(g, &profiler);
        let requests = to_requests(&jobs, &sens);
        let mech_name = g.choose(&["proportional", "greedy", "tune", "fixed"]);
        let mech = by_name(&mech_name).unwrap();
        let mut fleet = Fleet::homogeneous(spec, g.int(1, 9));
        let grants = mech.allocate(&mut fleet, &requests);
        fleet.check_consistency().map_err(|e| format!("{mech_name}: {e}"))?;
        // Grants must not exceed any server capacity (checked by
        // consistency) and granted GPUs must match the job demand.
        for (id, grant) in &grants {
            let job = jobs.iter().find(|j| j.id == *id).unwrap();
            prop_assert!(
                grant.placement.total().gpus == job.gpus,
                "{mech_name}: job {id:?} got {} GPUs, wanted {}",
                grant.placement.total().gpus,
                job.gpus
            );
        }
        Ok(())
    });
}

#[test]
fn prop_tune_fairness_floor() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    let tune = by_name("tune").unwrap();
    check("tune fairness floor", 25, |g| {
        let (jobs, sens) = random_jobs(g, &profiler);
        let requests = to_requests(&jobs, &sens);
        let mut fleet = Fleet::homogeneous(spec, g.int(1, 9));
        let grants = tune.allocate(&mut fleet, &requests);
        for req in &requests {
            if let Some(grant) = grants.get(&req.id) {
                let m = req.sens.matrix(grant.gen).unwrap();
                let got =
                    m.throughput_at(grant.demand.cpus, grant.demand.mem_gb);
                let floor = req.sens.fair_throughput();
                prop_assert!(
                    got + 1e-6 >= floor,
                    "job {:?} ({:?}): got {got} < floor {floor} \
                     (granted {:?})",
                    req.id,
                    m.model,
                    grant.demand
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tune_no_stranded_gpus() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    let tune = by_name("tune").unwrap();
    check("tune no stranded GPUs", 25, |g| {
        // All 1-GPU jobs, exactly filling the cluster: every job must be
        // placed regardless of how hungry the mix is.
        let n_servers = g.int(1, 5);
        let n = n_servers * 8;
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                Job::new(
                    JobId(i as u64),
                    g.choose(&ALL_MODELS),
                    1,
                    0.0,
                    3600.0,
                )
            })
            .collect();
        let sens: Vec<Sensitivity> =
            jobs.iter().map(|j| profiler.profile(j)).collect();
        let requests = to_requests(&jobs, &sens);
        let mut fleet = Fleet::homogeneous(spec, n_servers);
        let grants = tune.allocate(&mut fleet, &requests);
        prop_assert!(
            grants.len() == n,
            "only {} of {n} jobs placed; {} GPUs stranded",
            grants.len(),
            fleet.free_gpus()
        );
        prop_assert!(
            fleet.free_gpus() == 0,
            "{} GPUs free at full load",
            fleet.free_gpus()
        );
        Ok(())
    });
}

#[test]
fn prop_multi_server_splits_proportional() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    let tune = by_name("tune").unwrap();
    check("proportional split", 15, |g| {
        let gpus = g.choose(&[16u32, 24, 32]);
        let model = g.choose(&ALL_MODELS);
        let job = Job::new(JobId(0), model, gpus, 0.0, 3600.0);
        let sens = profiler.profile(&job);
        let jobs = vec![job];
        let sens = vec![sens];
        let requests = to_requests(&jobs, &sens);
        let mut fleet = Fleet::homogeneous(spec, 8);
        let grants = tune.allocate(&mut fleet, &requests);
        let grant = grants
            .get(&JobId(0))
            .ok_or("big job unplaced on empty cluster")?;
        let total = grant.demand;
        for share in grant.placement.shares.values() {
            let expect_cpu = total.cpus * share.gpus as f64 / gpus as f64;
            let expect_mem = total.mem_gb * share.gpus as f64 / gpus as f64;
            prop_assert!(
                (share.cpus - expect_cpu).abs() < 1e-6
                    && (share.mem_gb - expect_mem).abs() < 1e-6,
                "share {share:?} not proportional to {total:?}"
            );
        }
        Ok(())
    });
}

/// Unification property (a): on a one-type fleet the fleet-level TUNE is
/// exactly the pool-level §4.2 algorithm — same grants, same demands,
/// same placements, bit for bit. `Tune::allocate_pool` *is* the
/// pre-refactor homogeneous mechanism body, so this pins "a single-type
/// fleet reproduces the pre-refactor homogeneous grants".
#[test]
fn prop_single_type_fleet_matches_pool_level_tune_bitwise() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    check("one-type pass-through bit-parity", 20, |g| {
        let (jobs, sens) = random_jobs(g, &profiler);
        let requests = to_requests(&jobs, &sens);
        let n_servers = g.int(1, 9);

        // Fleet-level path (type assignment + delegation).
        let mut fleet = Fleet::homogeneous(spec, n_servers);
        let fleet_grants = Tune::default().allocate(&mut fleet, &requests);

        // Pool-level path (the homogeneous algorithm, driven directly).
        let mut cluster = Cluster::homogeneous(spec, n_servers);
        let pool_requests: Vec<PoolRequest> = requests
            .iter()
            .map(|r| {
                let m = r.sens.primary();
                PoolRequest {
                    id: r.id,
                    gpus: r.gpus,
                    best: m.best_demand(),
                    prop: DemandVector::proportional(
                        r.gpus,
                        spec.cpus as f64 / spec.gpus as f64,
                        spec.mem_gb / spec.gpus as f64,
                    ),
                    matrix: m,
                }
            })
            .collect();
        let pool_grants =
            Tune::default().allocate_pool(&mut cluster, &pool_requests);

        prop_assert!(
            fleet_grants.len() == pool_grants.len(),
            "grant sets differ: fleet {} vs pool {}",
            fleet_grants.len(),
            pool_grants.len()
        );
        for (id, fg) in &fleet_grants {
            let pg = pool_grants
                .get(id)
                .ok_or(format!("{id:?} granted by fleet only"))?;
            prop_assert!(
                fg.placement == pg.placement,
                "{id:?}: placements diverge"
            );
            prop_assert!(
                fg.demand.cpus.to_bits() == pg.demand.cpus.to_bits()
                    && fg.demand.mem_gb.to_bits() == pg.demand.mem_gb.to_bits()
                    && fg.demand.gpus == pg.demand.gpus,
                "{id:?}: demands diverge: {:?} vs {:?}",
                fg.demand,
                pg.demand
            );
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_deterministic_and_complete() {
    check("simulator determinism", 5, |g| {
        let seed = g.int(0, 1000) as u64;
        let trace = generate(&TraceConfig {
            n_jobs: g.int(5, 40),
            split: Split::new(30, 60, 10),
            multi_gpu: g.bool(),
            jobs_per_hour: if g.bool() { Some(g.f64(2.0, 10.0)) } else { None },
            seed,
        });
        let mk = || {
            Simulator::new(SimConfig {
                n_servers: 2,
                policy: "srtf".into(),
                mechanism: "tune".into(),
                ..Default::default()
            })
        };
        let a = mk().run(trace.clone());
        let b = mk().run(trace.clone());
        prop_assert!(a.jcts() == b.jcts(), "nondeterministic JCTs");
        prop_assert!(
            a.finished.len() == trace.len(),
            "{} of {} jobs finished",
            a.finished.len(),
            trace.len()
        );
        prop_assert!(
            a.jcts().iter().all(|&j| j > 0.0 && j.is_finite()),
            "bad JCT values"
        );
        Ok(())
    });
}

#[test]
fn prop_opt_bounds_tune_throughput() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    check("opt upper-bounds tune", 6, |g| {
        let n_servers = g.int(1, 3);
        let n = g.int(2, n_servers * 8 + 1);
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                Job::new(JobId(i as u64), g.choose(&ALL_MODELS), 1, 0.0, 60.0)
            })
            .collect();
        let sens: Vec<Sensitivity> =
            jobs.iter().map(|j| profiler.profile(j)).collect();
        let requests = to_requests(&jobs, &sens);

        let opt = synergy::mechanism::Opt::default();
        let fleet = Fleet::homogeneous(spec, n_servers);
        let alloc = opt
            .solve_allocation(&fleet, &requests)
            .ok_or("opt failed")?;

        let tune = by_name("tune").unwrap();
        let mut fleet2 = Fleet::homogeneous(spec, n_servers);
        let grants = tune.allocate(&mut fleet2, &requests);
        let tune_total: f64 = requests
            .iter()
            .filter_map(|r| grants.get(&r.id).map(|grant| (r, grant)))
            .map(|(r, grant)| {
                r.sens
                    .matrix(grant.gen)
                    .unwrap()
                    .throughput_at(grant.demand.cpus, grant.demand.mem_gb)
            })
            .sum();
        prop_assert!(
            alloc.objective + 1e-3 >= tune_total,
            "opt {} < tune {}",
            alloc.objective,
            tune_total
        );
        Ok(())
    });
}

#[test]
fn prop_lp_solutions_feasible() {
    use synergy::lp::{solve, Lp, Op};
    check("random LP feasibility", 25, |g| {
        let n = g.int(1, 30);
        let m = g.int(1, 15);
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_objective(j, g.f64(0.0, 2.0));
        }
        for _ in 0..m {
            let coeffs: Vec<(usize, f64)> =
                (0..n).map(|j| (j, g.f64(0.1, 1.0))).collect();
            lp.add(coeffs, Op::Le, g.f64(1.0, 20.0));
        }
        let sol = solve(&lp).map_err(|e| format!("{e:?}"))?;
        for (i, c) in lp.constraints.iter().enumerate() {
            let lhs: f64 = c.coeffs.iter().map(|&(j, v)| v * sol.x[j]).sum();
            prop_assert!(
                lhs <= c.rhs + 1e-6,
                "constraint {i} violated: {lhs} > {}",
                c.rhs
            );
        }
        prop_assert!(
            sol.x.iter().all(|&v| v >= -1e-9),
            "negative variable in solution"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Mixed-fleet (paper A.2) invariants — same stack, more pools
// ---------------------------------------------------------------------------

mod fleet_props {
    use super::*;
    use synergy::cluster::{GpuGen, TypeSpec};

    fn random_fleet(g: &mut Gen) -> Fleet {
        let spec = ServerSpec::default();
        let gens = [GpuGen::K80, GpuGen::P100, GpuGen::V100, GpuGen::A100];
        let n_types = g.int(2, 4);
        let types: Vec<TypeSpec> = gens[..n_types]
            .iter()
            .map(|&gen| TypeSpec { gen, spec, machines: g.int(1, 4) })
            .collect();
        Fleet::new(&types)
    }

    fn random_fleet_jobs(
        g: &mut Gen,
        fleet: &Fleet,
    ) -> (Vec<Job>, Vec<Sensitivity>) {
        let profiler = OptimisticProfiler::noiseless_fleet(fleet);
        let n = g.int(1, 16);
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let model = g.choose(&ALL_MODELS);
                let gpus = g.choose(&[1u32, 1, 2, 4, 8]);
                Job::new(JobId(i as u64), model, gpus, 0.0, 3600.0)
            })
            .collect();
        let sens = jobs.iter().map(|j| profiler.profile(j)).collect();
        (jobs, sens)
    }

    #[test]
    fn prop_fleet_consistent_and_single_type() {
        check("fleet consistency + no cross-type spans", 20, |g| {
            let mut fleet = random_fleet(g);
            let (jobs, sens) = random_fleet_jobs(g, &fleet);
            let reqs = to_requests(&jobs, &sens);
            let name = g.choose(&["proportional", "tune", "opt"]);
            let mech = by_name(name).unwrap();
            let grants = mech.allocate(&mut fleet, &reqs);
            fleet
                .check_consistency()
                .map_err(|e| format!("{name}: {e}"))?;
            for (id, grant) in &grants {
                // A.2.2: a job never spans two machine types in a round —
                // its whole placement lives in the chosen pool.
                prop_assert!(
                    fleet.host_gen(*id) == Some(grant.gen),
                    "{name}: job {id:?} not hosted on its granted type"
                );
                let job = jobs.iter().find(|j| j.id == *id).unwrap();
                prop_assert!(
                    grant.placement.total().gpus == job.gpus,
                    "{name}: wrong GPU count for {id:?}"
                );
            }
            Ok(())
        });
    }

    /// Unification property (b): no placed job ever lands below its
    /// fairness floor `W_j^Fair` under unified TUNE (or OPT), on any
    /// fleet shape.
    #[test]
    fn prop_fairness_floor_w_fair_oracle() {
        check("fairness floor (W_fair oracle)", 20, |g| {
            let mut fleet = random_fleet(g);
            let (jobs, sens) = random_fleet_jobs(g, &fleet);
            let reqs = to_requests(&jobs, &sens);
            let name = g.choose(&["tune", "opt"]);
            let mech = by_name(name).unwrap();
            let grants = mech.allocate(&mut fleet, &reqs);
            for (j, s) in jobs.iter().zip(&sens) {
                let Some(grant) = grants.get(&j.id) else { continue };
                let m = s.matrix(grant.gen).expect("profiled type");
                let got = m.throughput_at(
                    grant.demand.cpus,
                    grant.demand.mem_gb,
                );
                prop_assert!(
                    got + 1e-9 >= s.fair_throughput(),
                    "{name}: job {:?} below W_fair: {} < {}",
                    j.id,
                    got,
                    s.fair_throughput()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fleet_sim_deterministic_and_complete() {
        check("fleet sim determinism", 6, |g| {
            use synergy::hetero::{HeteroSimConfig, HeteroSimulator};
            let seed = g.int(0, 10_000) as u64;
            let jobs = generate(&TraceConfig {
                n_jobs: 20,
                split: Split::new(30, 50, 20),
                multi_gpu: g.bool(),
                jobs_per_hour: None,
                seed,
            });
            let run = || {
                HeteroSimulator::new(HeteroSimConfig {
                    policy: "fifo".into(),
                    mechanism: "het-tune".into(),
                    ..Default::default()
                })
                .run(jobs.clone())
            };
            let a = run();
            let b = run();
            prop_assert!(a.jcts.len() == jobs.len(), "all jobs finish");
            prop_assert!(
                a.jcts == b.jcts,
                "fleet sim must be bit-deterministic"
            );
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Free-capacity-index invariants (ISSUE 4): the incrementally-maintained
// index must agree with a fresh scan after arbitrary place/evict
// sequences, and index-driven packing must select the identical servers
// the pre-index linear scans did.
// ---------------------------------------------------------------------------

#[test]
fn prop_free_index_consistent_and_fit_equivalent() {
    check("free index ≡ scan", 30, |g| {
        let spec = ServerSpec {
            gpus: g.choose(&[4u32, 8]),
            cpus: 24,
            mem_gb: 500.0,
        };
        let n = g.int(1, 13);
        let mut cluster = Cluster::homogeneous(spec, n);
        if g.bool() {
            // Racks must be invisible to single-server fits and to the
            // free-capacity index: same picks, same consistency.
            let topo = TopologySpec::racks(g.int(2, 5) as u32);
            cluster.set_topology(topo.for_servers(n));
        }
        let mut resident: Vec<JobId> = Vec::new();
        let mut next_id = 0u64;
        let ops = g.int(5, 80);
        for _ in 0..ops {
            let place = resident.is_empty() || g.bool();
            if place {
                // A random (often infeasible) demand: both the index
                // path and the scan path must agree on the outcome,
                // including "no fit".
                let demand = DemandVector::new(
                    g.int(1, 2 * spec.gpus as usize + 1) as u32,
                    g.f64(0.5, spec.cpus as f64 * 1.3),
                    g.f64(1.0, spec.mem_gb * 1.3),
                );
                let via_index = best_fit(&cluster, &demand);
                let via_scan = best_fit_scan(&cluster, &demand);
                prop_assert!(
                    via_index == via_scan,
                    "best_fit diverged for {demand:?}: index {via_index:?} \
                     vs scan {via_scan:?}"
                );
                let ff_index = first_fit(&cluster, &demand);
                let ff_scan = first_fit_scan(&cluster, &demand);
                prop_assert!(
                    ff_index == ff_scan,
                    "first_fit diverged for {demand:?}"
                );
                if let Some(p) = via_index {
                    let id = JobId(next_id);
                    next_id += 1;
                    cluster.place(id, p);
                    resident.push(id);
                }
            } else {
                let i = g.int(0, resident.len());
                let id = resident.swap_remove(i);
                cluster.evict(id);
            }
            // check_consistency includes the index-vs-fresh-scan check.
            cluster
                .check_consistency()
                .map_err(|e| format!("after op: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_index_survives_round_reset_cycles() {
    // The simulator's per-round evict_all must return the index to the
    // pristine state bit-for-bit (a replanned round then re-packs from
    // scratch; any drift would desync memoized rounds from replans).
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    check("index across round resets", 10, |g| {
        let (jobs, sens) = random_jobs(g, &profiler);
        let requests = to_requests(&jobs, &sens);
        let mech = by_name(&g.choose(&["tune", "greedy", "proportional"]))
            .unwrap();
        let mut fleet = Fleet::homogeneous(spec, g.int(1, 5));
        for _round in 0..3 {
            fleet.evict_all();
            let _ = mech.allocate(&mut fleet, &requests);
            fleet.check_consistency()?;
        }
        fleet.evict_all();
        fleet.check_consistency()?;
        for pool in &fleet.pools {
            prop_assert!(
                pool.cluster.free_gpus() == pool.cluster.total_gpus(),
                "reset pool must be fully free"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Prefix-resumable planning (ISSUE 5) — resumed plans are bit-identical
// ---------------------------------------------------------------------------

#[test]
fn prop_prefix_resumed_plan_matches_fresh_plan_bitwise() {
    // Drive the checkpointing entry point (`Mechanism::plan`) directly
    // through the resumable lifecycle — plan a sequence, then plan an
    // arbitrarily edited sequence against the checkpoint — and compare
    // against the batch path on a pristine fleet: grants *and* the
    // post-plan fleet state must match bit for bit (the fold state after
    // any prefix is a pure function of the prefix; rollback restores
    // recorded bits by assignment).
    use std::collections::BTreeMap;
    use synergy::job::JobId as PJobId;
    use synergy::mechanism::Grant;

    type GrantBits =
        Vec<(u64, String, u32, u64, u64, Vec<(usize, u32, u64, u64)>)>;
    fn grants_bits(grants: &BTreeMap<PJobId, Grant>) -> GrantBits {
        grants
            .iter()
            .map(|(id, g)| {
                (
                    id.0,
                    format!("{:?}", g.gen),
                    g.demand.gpus,
                    g.demand.cpus.to_bits(),
                    g.demand.mem_gb.to_bits(),
                    g.placement
                        .shares
                        .iter()
                        .map(|(&sid, s)| {
                            (sid, s.gpus, s.cpus.to_bits(), s.mem_gb.to_bits())
                        })
                        .collect(),
                )
            })
            .collect()
    }
    fn fleet_bits(fleet: &Fleet) -> Vec<(u32, u64, u64)> {
        fleet
            .pools
            .iter()
            .flat_map(|p| {
                p.cluster.servers.iter().map(|s| {
                    (s.free_gpus, s.free_cpus.to_bits(), s.free_mem_gb.to_bits())
                })
            })
            .collect()
    }

    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    check("prefix-resumed plan == fresh plan", 25, |g| {
        let (jobs, sens) = random_jobs(g, &profiler);
        let reqs = to_requests(&jobs, &sens);
        let name = g.choose(&["proportional", "greedy", "fixed", "tune"]);
        let mech = by_name(&name).unwrap();
        let n_servers = g.int(1, 6);

        let mut fleet = Fleet::homogeneous(spec, n_servers);
        fleet.enable_journal();

        // Round 1: a random subsequence establishes the checkpoint.
        let seq1: Vec<JobRequest> =
            reqs.iter().filter(|_| g.bool()).cloned().collect();
        let out1 = mech.plan(&mut fleet, &seq1, None);

        // Round 2: an arbitrary edit — random subset plus a rotation of
        // some tail (drops, insertions and reorders all in one).
        let mut seq2: Vec<JobRequest> =
            reqs.iter().filter(|_| g.int(0, 4) > 0).cloned().collect();
        if seq2.len() > 1 {
            let cut = g.int(0, seq2.len());
            seq2[cut..].rotate_left(1);
        }
        let out2 = mech.plan(&mut fleet, &seq2, out1.trace);
        fleet.check_consistency().map_err(|e| format!("{name}: {e}"))?;
        prop_assert!(
            out2.steps_reused <= out2.steps_total,
            "{name}: reused {} of {} steps",
            out2.steps_reused,
            out2.steps_total
        );

        // Fresh reference: the batch driver on a pristine fleet.
        let mut fresh_fleet = Fleet::homogeneous(spec, n_servers);
        let fresh = mech.allocate(&mut fresh_fleet, &seq2);
        prop_assert!(
            grants_bits(&out2.grants) == grants_bits(&fresh),
            "{name}: resumed grants diverge from fresh plan"
        );
        prop_assert!(
            fleet_bits(&fleet) == fleet_bits(&fresh_fleet),
            "{name}: post-plan fleet state diverges from fresh plan"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Topology-aware gang placement (ISSUE 7): degenerate demands, rack
// tie-breaks, and flat/blind byte-identity to the pre-topology stack
// ---------------------------------------------------------------------------

#[test]
fn prop_zero_gpu_demand_never_places() {
    check("zero-GPU gang demand", 20, |g| {
        let spec = ServerSpec::default();
        let n = g.int(1, 6);
        let mut cluster = Cluster::homogeneous(spec, n);
        if g.bool() {
            let topo = TopologySpec::racks(g.int(2, 4) as u32);
            cluster.set_topology(topo.for_servers(n));
        }
        // `DemandVector::new` asserts positivity, so the degenerate
        // demand is built field-by-field — exactly what a buggy caller
        // would hand over.
        let demand = DemandVector {
            gpus: 0,
            cpus: g.f64(0.0, spec.cpus as f64),
            mem_gb: g.f64(0.0, spec.mem_gb),
        };
        prop_assert!(
            multi_server_fit(&cluster, &demand, |_| true).is_none(),
            "zero-GPU demand must report no fit, not a 0-GPU placement"
        );
        cluster.check_consistency()?;
        Ok(())
    });
}

#[test]
fn prop_rack_tie_break_consolidates_into_emptiest_rack() {
    // 2 racks × 2 servers; rack 0 carries a random nonzero load, rack 1
    // is empty. Any gang that needs both of rack 1's servers but fits
    // inside it must land there whole — the rack-rank sort orders the
    // emptier rack's servers first, and `racks_spanned == 1` follows.
    let spec = ServerSpec::default();
    check("rack tie-break consolidation", 25, |g| {
        let mut cluster = Cluster::homogeneous(spec, 4);
        cluster.set_topology(TopologySpec::racks(2).for_servers(4));
        for server in [0usize, 1] {
            let gpus = g.int(1, spec.gpus as usize + 1) as u32;
            cluster.place(
                JobId(90 + server as u64),
                Placement::single(
                    server,
                    Share { gpus, cpus: 1.0, mem_gb: 10.0 },
                ),
            );
        }
        let gang = g.int(spec.gpus as usize + 1, 2 * spec.gpus as usize + 1)
            as u32;
        let demand = DemandVector::proportional(gang, 1.0, 10.0);
        let p = multi_server_fit(&cluster, &demand, |_| true)
            .ok_or("gang must fit in the empty rack")?;
        let ids: Vec<usize> = p.shares.iter().map(|(&id, _)| id).collect();
        prop_assert!(
            ids.iter().all(|&id| id >= 2),
            "gang of {gang} leaked into the loaded rack: servers {ids:?}"
        );
        prop_assert!(
            cluster.racks_spanned(&p) == 1,
            "consolidated gang must span one rack"
        );
        cluster.check_consistency()?;
        Ok(())
    });
}

#[test]
fn prop_flat_and_blind_topologies_allocate_identically() {
    // The two "topology exists but must not matter" arms — an explicit
    // flat spec, and racks with `placement_aware = false` — must
    // reproduce the default fleet's grants bit for bit for every
    // mechanism (racks only reorder candidate servers when aware).
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    check("flat/blind topology ≡ default", 15, |g| {
        let (jobs, sens) = random_jobs(g, &profiler);
        let requests = to_requests(&jobs, &sens);
        let n_servers = g.int(1, 6);
        let name = g.choose(&["proportional", "greedy", "tune", "fixed"]);
        let mech = by_name(&name).unwrap();

        let mut base = Fleet::homogeneous(spec, n_servers);
        let base_grants = mech.allocate(&mut base, &requests);

        let variants = [
            ("flat", TopologySpec::flat()),
            (
                "blind-racks",
                TopologySpec {
                    placement_aware: false,
                    ..TopologySpec::racks(3)
                },
            ),
        ];
        for (tag, topo) in variants {
            let mut fleet = Fleet::homogeneous(spec, n_servers);
            fleet.set_topology(topo);
            let grants = mech.allocate(&mut fleet, &requests);
            prop_assert!(
                grants.len() == base_grants.len(),
                "{name}/{tag}: grant counts diverge"
            );
            for (id, bg) in &base_grants {
                let tg = grants
                    .get(id)
                    .ok_or(format!("{name}/{tag}: {id:?} missing"))?;
                prop_assert!(
                    tg.placement == bg.placement,
                    "{name}/{tag}: {id:?} placement diverges"
                );
                prop_assert!(
                    tg.demand.gpus == bg.demand.gpus
                        && tg.demand.cpus.to_bits() == bg.demand.cpus.to_bits()
                        && tg.demand.mem_gb.to_bits()
                            == bg.demand.mem_gb.to_bits(),
                    "{name}/{tag}: {id:?} demand diverges"
                );
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Fault-injection invariants (ISSUE 9): no job is ever lost to churn —
// every admitted job finishes, with completed work preserved across
// preempt-and-requeue — an empty fault schedule is bit-identical to a
// config that never mentions faults, and fleet bookkeeping stays
// consistent through arbitrary fail/restore sequences.
// ---------------------------------------------------------------------------

#[test]
fn prop_no_job_lost_under_churn() {
    check("no job lost under churn", 6, |g| {
        use synergy::hetero::{
            GpuGen, HeteroSimConfig, HeteroSimulator, TypeSpec,
        };
        let trace = generate(&TraceConfig {
            n_jobs: g.int(10, 24),
            split: Split::new(30, 50, 20),
            multi_gpu: g.bool(),
            jobs_per_hour: Some(g.f64(2.0, 8.0)),
            seed: g.int(0, 10_000) as u64,
        });
        let spec = format!(
            "mtbf:{},mttr:{},seed:{}",
            g.int(4, 24),
            g.int(1, 4),
            g.int(0, 1000)
        );
        let faults = FaultSpec::parse(&spec)?;
        let policy = g.choose(&["fifo", "srtf", "las"]);
        let homo = Simulator::new(SimConfig {
            n_servers: 2,
            policy: policy.to_string(),
            mechanism: "tune".into(),
            faults: Some(faults.clone()),
            ..Default::default()
        })
        .run(trace.clone());
        prop_assert!(
            homo.finished.len() == trace.len(),
            "{policy}/homo/{spec}: {} of {} jobs finished",
            homo.finished.len(),
            trace.len()
        );
        let tri = HeteroSimulator::new(HeteroSimConfig {
            types: vec![
                TypeSpec {
                    gen: GpuGen::K80,
                    spec: Default::default(),
                    machines: 1,
                },
                TypeSpec {
                    gen: GpuGen::P100,
                    spec: Default::default(),
                    machines: 1,
                },
                TypeSpec {
                    gen: GpuGen::V100,
                    spec: Default::default(),
                    machines: 2,
                },
            ],
            policy: policy.to_string(),
            mechanism: "het-tune".into(),
            faults: Some(faults),
            ..Default::default()
        })
        .run(trace.clone());
        prop_assert!(
            tri.jcts.len() == trace.len(),
            "{policy}/tritype/{spec}: {} of {} jobs finished",
            tri.jcts.len(),
            trace.len()
        );
        Ok(())
    });
}

#[test]
fn prop_empty_fault_spec_is_bit_identical_to_none() {
    check("empty fault spec ≡ none", 5, |g| {
        let trace = generate(&TraceConfig {
            n_jobs: g.int(5, 30),
            split: Split::new(30, 50, 20),
            multi_gpu: g.bool(),
            jobs_per_hour: if g.bool() { Some(g.f64(2.0, 10.0)) } else { None },
            seed: g.int(0, 10_000) as u64,
        });
        let policy = g.choose(&["fifo", "srtf"]);
        let run = |faults: Option<FaultSpec>| {
            Simulator::new(SimConfig {
                n_servers: 2,
                policy: policy.to_string(),
                mechanism: "tune".into(),
                faults,
                ..Default::default()
            })
            .run(trace.clone())
        };
        let base = run(None);
        let empty = run(Some(FaultSpec::Script(vec![])));
        let bits = |r: &synergy::sim::SimResult| -> Vec<(u64, u64)> {
            r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect()
        };
        prop_assert!(
            bits(&base) == bits(&empty)
                && base.rounds == empty.rounds
                && base.planned_rounds == empty.planned_rounds
                && empty.preemptions == 0
                && empty.servers_failed == 0,
            "an empty fault schedule must be bit-identical to no spec"
        );
        Ok(())
    });
}

#[test]
fn prop_fleet_consistent_under_arbitrary_churn() {
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::noiseless(spec);
    check("fleet consistency under churn", 20, |g| {
        let (jobs, sens) = random_jobs(g, &profiler);
        let requests = to_requests(&jobs, &sens);
        let mech =
            by_name(&g.choose(&["proportional", "greedy", "tune"])).unwrap();
        let mut fleet = Fleet::homogeneous(spec, g.int(2, 6));
        let _ = mech.allocate(&mut fleet, &requests);
        for _ in 0..g.int(1, 12) {
            if g.bool() {
                let _ = fleet.fail_server(0);
            } else {
                let _ = fleet.add_server(0);
            }
            fleet
                .check_consistency()
                .map_err(|e| format!("after churn: {e}"))?;
            let u = fleet.gpu_utilization();
            prop_assert!(u.is_finite(), "utilization must stay finite: {u}");
        }
        Ok(())
    });
}
