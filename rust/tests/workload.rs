//! Integration tests for the `workload/` subsystem: source determinism,
//! the synthetic golden path, CSV fixtures round-tripping, tenant-quota
//! admission invariants end-to-end, and the streaming deploy path.

use synergy::job::{Job, TenantId};
use synergy::sim::{SimConfig, Simulator};
use synergy::trace::{generate, sample_duration_s, GpuDemandDist, Split, TraceConfig};
use synergy::util::rng::Pcg64;
use synergy::workload::{
    AlibabaTraceConfig, AlibabaTraceSource, PhillyTraceConfig,
    PhillyTraceSource, SyntheticSource, TenantSpec, WorkloadSource,
};

fn fixture(name: &str) -> String {
    format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"))
}

fn philly_cfg() -> PhillyTraceConfig {
    PhillyTraceConfig {
        path: fixture("philly_small.csv"),
        ..PhillyTraceConfig::default()
    }
}

// ---------------------------------------------------------------------------
// Golden test: the refactored SyntheticSource is byte-identical to the
// historical in-place generator (same RNG stream, same call order).
// ---------------------------------------------------------------------------

/// The pre-refactor `trace::generate` body, replicated verbatim.
fn legacy_generate(cfg: &TraceConfig) -> Vec<Job> {
    use synergy::job::JobId;
    cfg.split.validate();
    let mut rng = Pcg64::new(cfg.seed, 0x7EACE);
    let demand = GpuDemandDist { multi_gpu: cfg.multi_gpu };
    let mut t = 0.0f64;
    (0..cfg.n_jobs)
        .map(|i| {
            let arrival = match cfg.jobs_per_hour {
                None => 0.0,
                Some(lam) => {
                    t += rng.exponential(lam / 3600.0);
                    t
                }
            };
            let model = cfg.split.sample_model(&mut rng);
            let gpus = demand.sample(&mut rng);
            let duration = sample_duration_s(&mut rng);
            Job::new(JobId(i as u64), model, gpus, arrival, duration)
        })
        .collect()
}

#[test]
fn synthetic_source_golden_vs_legacy_generator() {
    for (seed, multi_gpu, load) in
        [(1, false, Some(8.0)), (77, true, Some(3.0)), (5, true, None)]
    {
        let cfg = TraceConfig {
            n_jobs: 500,
            split: Split::new(20, 70, 10),
            multi_gpu,
            jobs_per_hour: load,
            seed,
        };
        let legacy = legacy_generate(&cfg);
        let new = generate(&cfg);
        assert_eq!(legacy.len(), new.len());
        for (a, b) in legacy.iter().zip(&new) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.model, b.model);
            assert_eq!(a.gpus, b.gpus);
            // Bit-exact, not approximate: same RNG stream.
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
            assert_eq!(
                a.duration_prop_s.to_bits(),
                b.duration_prop_s.to_bits()
            );
            assert_eq!(b.tenant, TenantId::DEFAULT);
        }
    }
}

// ---------------------------------------------------------------------------
// Source determinism under a fixed seed.
// ---------------------------------------------------------------------------

fn drain(mut src: impl WorkloadSource) -> Vec<Job> {
    src.drain_jobs()
}

#[test]
fn every_source_is_deterministic_under_fixed_seed() {
    let syn = |seed| {
        drain(
            SyntheticSource::new(TraceConfig {
                n_jobs: 64,
                seed,
                ..TraceConfig::default()
            })
            .with_tenants(TenantSpec::parse("a:2,b:1").unwrap()),
        )
    };
    let phl = || drain(PhillyTraceSource::new(philly_cfg()).unwrap());
    let ali = || {
        drain(
            AlibabaTraceSource::new(AlibabaTraceConfig {
                path: fixture("alibaba_small.csv"),
                ..AlibabaTraceConfig::default()
            })
            .unwrap(),
        )
    };
    for (a, b) in [
        (syn(3), syn(3)),
        (phl(), phl()),
        (ali(), ali()),
    ] {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.model, y.model);
            assert_eq!(x.gpus, y.gpus);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(
                x.duration_prop_s.to_bits(),
                y.duration_prop_s.to_bits()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Fixture round-trips.
// ---------------------------------------------------------------------------

#[test]
fn philly_fixture_roundtrip() {
    let mut src = PhillyTraceSource::new(philly_cfg()).unwrap();
    assert_eq!(src.tenant_names(), vec!["a", "b"]);
    let hint = src.len_hint().unwrap();
    let jobs = src.drain_jobs();
    assert_eq!(jobs.len(), hint);
    // The fixture has 40 rows, one of which is Killed (dropped).
    assert_eq!(jobs.len(), 39);
    // Arrivals re-based, sorted, ids dense.
    assert_eq!(jobs[0].arrival_s, 0.0);
    for (i, w) in jobs.windows(2).enumerate() {
        assert!(w[0].arrival_s <= w[1].arrival_s, "unsorted at {i}");
    }
    for (i, j) in jobs.iter().enumerate() {
        assert_eq!(j.id.0, i as u64);
        assert!((1..=16).contains(&j.gpus));
        assert!(j.duration_prop_s >= 1.0);
        assert!(j.tenant == TenantId(0) || j.tenant == TenantId(1));
    }
    // Both tenants present.
    assert!(jobs.iter().any(|j| j.tenant == TenantId(0)));
    assert!(jobs.iter().any(|j| j.tenant == TenantId(1)));
}

#[test]
fn philly_fixture_time_warp_knobs() {
    let base = drain(PhillyTraceSource::new(philly_cfg()).unwrap());
    let warped = drain(
        PhillyTraceSource::new(PhillyTraceConfig {
            load_scale: 4.0,
            duration_min_s: 3600.0,
            duration_max_s: 20_000.0,
            gpu_cap: 4,
            ..philly_cfg()
        })
        .unwrap(),
    );
    assert_eq!(base.len(), warped.len());
    for (b, w) in base.iter().zip(&warped) {
        assert!((w.arrival_s - b.arrival_s / 4.0).abs() < 1e-9);
        assert!((3600.0..=20_000.0).contains(&w.duration_prop_s));
        assert!(w.gpus <= 4);
    }
}

#[test]
fn alibaba_fixture_maps_to_big_data_families() {
    let jobs = drain(
        AlibabaTraceSource::new(AlibabaTraceConfig {
            path: fixture("alibaba_small.csv"),
            ..AlibabaTraceConfig::default()
        })
        .unwrap(),
    );
    assert_eq!(jobs.len(), 30);
    // Machines → tenants (fixture uses m_1..m_4).
    let tenants: std::collections::BTreeSet<u32> =
        jobs.iter().map(|j| j.tenant.0).collect();
    assert!(tenants.len() >= 3, "expected several machine-tenants");
    for j in &jobs {
        assert!((1..=4).contains(&j.gpus));
        assert!(j.duration_prop_s >= 60.0);
    }
}

// ---------------------------------------------------------------------------
// Tenant-quota admission invariants, end to end through the simulator.
// ---------------------------------------------------------------------------

#[test]
fn contended_static_trace_respects_weighted_shares() {
    use synergy::workload::TenantQuotas;
    // 2 servers × 8 GPUs; ~equal per-tenant demand (1:1 assignment), but
    // a 3:1 GPU quota. The favoured tenant drains its equal backlog ~3×
    // faster, so its average JCT must come out clearly lower.
    let assign = TenantSpec::parse("big,small").unwrap(); // 1:1 jobs
    let jobs = SyntheticSource::new(TraceConfig {
        n_jobs: 64,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 21,
    })
    .with_tenants(assign)
    .drain_jobs();
    let quotas = TenantQuotas::new()
        .with(TenantId(0), 3.0)
        .with(TenantId(1), 1.0);
    let sim = Simulator::with_quotas(
        SimConfig {
            n_servers: 2,
            policy: "fifo".into(),
            mechanism: "proportional".into(),
            ..Default::default()
        },
        Some(quotas),
    );
    let r = sim.run(jobs);
    assert_eq!(r.finished.len(), 64, "everything must eventually finish");
    let by = r.tenant_stats();
    let big = &by[&TenantId(0)];
    let small = &by[&TenantId(1)];
    assert!(
        big.avg_s < small.avg_s * 0.8,
        "3:1 quota should speed up the favoured tenant: {} vs {}",
        big.avg_s,
        small.avg_s
    );
}

#[test]
fn quotas_do_not_strand_capacity_when_one_tenant_is_idle() {
    // Tenant b never submits; tenant a must still use the whole cluster
    // (work-conserving spill), so quotas must not slow it down.
    let cfg = TraceConfig {
        n_jobs: 40,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 5,
    };
    let jobs = generate(&cfg); // all tenant 0
    let sim_cfg = || SimConfig {
        n_servers: 2,
        policy: "fifo".into(),
        mechanism: "proportional".into(),
        ..Default::default()
    };
    let quotas = TenantSpec::parse("a:1,b:1").unwrap().quotas();
    let plain = Simulator::new(sim_cfg()).run(jobs.clone());
    let quoted =
        Simulator::with_quotas(sim_cfg(), Some(quotas)).run(jobs);
    assert_eq!(plain.finished.len(), quoted.finished.len());
    let (a, b) =
        (plain.jct_stats().avg_s, quoted.jct_stats().avg_s);
    assert!(
        (a - b).abs() < 1e-6,
        "idle-tenant quotas must be work-conserving: {a} vs {b}"
    );
}

#[test]
fn philly_fixture_runs_end_to_end_with_quotas() {
    // The ISSUE acceptance path: fixture trace + a:2,b:1 quotas.
    let mut src = PhillyTraceSource::new(philly_cfg()).unwrap();
    let names = src.tenant_names();
    let jobs = src.drain_jobs();
    let spec = TenantSpec::parse("a:2,b:1").unwrap();
    let sim = Simulator::with_quotas(
        SimConfig {
            n_servers: 4,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            ..Default::default()
        },
        Some(spec.quotas_for(&names)),
    );
    let r = sim.run(jobs);
    assert_eq!(r.finished.len(), 39);
    let by = r.tenant_stats();
    assert_eq!(by.len(), 2);
    assert!(by.values().all(|s| s.n > 0 && s.avg_s.is_finite()));
}
