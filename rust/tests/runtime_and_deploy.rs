//! Integration tests over the PJRT runtime (Layer 1/2 artifacts executed
//! from rust) and the deploy-mode control plane.
//!
//! Runtime tests require `make artifacts` to have produced the `tiny`
//! variant; they are skipped (with a note) when artifacts are absent so
//! `cargo test` works on a fresh checkout.

use synergy::deploy::{Leader, LeaderConfig, Worker, WorkerConfig};
use synergy::runtime::{Runtime, SyntheticCorpus, Trainer};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::workload::{SyntheticSource, TenantSpec, WorkloadSource};
use std::sync::Arc;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/tiny.meta.json")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn tiny_variant_trains_and_loss_descends() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("pjrt");
    let (meta, exe) = rt.load_variant(&dir, "tiny").expect("load tiny");
    assert_eq!(meta.variant, "tiny");
    let uniform = (meta.vocab as f64).ln();
    let mut corpus = SyntheticCorpus::new(meta.vocab, 3);
    let mut trainer = Trainer::new(&rt.client, exe, meta, 1).expect("trainer");
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..30 {
        let toks = corpus.batch(trainer.meta.batch, trainer.meta.seq_len);
        let loss = trainer.train_step(&toks, 0.3).expect("step") as f64;
        assert!(loss.is_finite(), "loss diverged at step {step}");
        if step == 0 {
            first = loss;
            // Fresh init: near the uniform baseline.
            assert!((loss - uniform).abs() < 1.0, "init loss {loss}");
        }
        last = loss;
    }
    assert!(last < first - 0.3, "loss did not descend: {first} -> {last}");
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("pjrt");
    let (meta, exe) = rt.load_variant(&dir, "tiny").expect("load");
    let mut corpus = SyntheticCorpus::new(meta.vocab, 4);
    let mut trainer = Trainer::new(&rt.client, exe, meta, 2).expect("trainer");
    for _ in 0..3 {
        let toks = corpus.batch(trainer.meta.batch, trainer.meta.seq_len);
        trainer.train_step(&toks, 0.1).expect("step");
    }
    let ckpt = trainer.params_to_host().expect("checkpoint");
    assert_eq!(ckpt.len(), trainer.meta.param_count);
    // Restore into a fresh trainer; next losses must match a trainer that
    // never checkpointed (same tokens, same params).
    let (meta2, exe2) = rt.load_variant(&dir, "tiny").expect("load");
    let mut restored =
        Trainer::new(&rt.client, exe2, meta2, 99).expect("trainer2");
    restored.restore(&ckpt).expect("restore");
    let toks = corpus.batch(trainer.meta.batch, trainer.meta.seq_len);
    let a = trainer.train_step(&toks, 0.0).expect("a");
    let b = restored.train_step(&toks, 0.0).expect("b");
    assert!((a - b).abs() < 1e-5, "restored loss {b} != original {a}");
}

#[test]
fn deploy_protocol_roundtrip_without_compute() {
    // Leader + 2 workers over localhost, no PJRT (protocol-only): a small
    // static trace must fully drain and report JCTs.
    let jobs = generate(&TraceConfig {
        n_jobs: 6,
        split: Split::new(0, 100, 0), // fast, insensitive jobs
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 9,
    });
    let n = jobs.len();
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 2,
        round_real_s: 0.2,
        time_scale: 40_000.0, // compress hours into seconds
        policy: "fifo".into(),
        mechanism: "tune".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        quotas: None,
        telemetry: None,
        telemetry_timing: false,
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run(jobs));
    let addr = loop {
        if let Some(a) = *leader.addr.lock().unwrap() {
            break a;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    let mut workers = Vec::new();
    for _ in 0..2 {
        let cfg = WorkerConfig {
            leader_addr: addr.to_string(),
            real_compute: false,
            ..Default::default()
        };
        workers.push(std::thread::spawn(move || Worker::run(cfg)));
    }
    let report = t.join().unwrap().expect("leader run");
    for w in workers {
        let _ = w.join();
    }
    assert_eq!(report.jcts.len(), n, "all jobs must finish");
    assert!(report.rounds > 0);
    for (_, jct) in &report.jcts {
        assert!(*jct > 0.0 && jct.is_finite());
    }
}

#[test]
fn deploy_streams_arrivals_from_a_workload_source() {
    // run_stream: the leader pulls jobs from a WorkloadSource as
    // simulated time passes their arrivals (no up-front job list), and
    // the report carries tenant tags through to per-tenant stats.
    let source = SyntheticSource::new(TraceConfig {
        n_jobs: 6,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None, // static: stream drains immediately
        seed: 11,
    })
    .with_tenants(TenantSpec::parse("a,b").unwrap());
    let expected = source.len_hint().unwrap();
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 1,
        round_real_s: 0.2,
        time_scale: 40_000.0,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        quotas: None,
        telemetry: None,
        telemetry_timing: false,
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run_stream(Box::new(source)));
    let addr = loop {
        if let Some(a) = *leader.addr.lock().unwrap() {
            break a;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    let cfg = WorkerConfig {
        leader_addr: addr.to_string(),
        real_compute: false,
        ..Default::default()
    };
    let w = std::thread::spawn(move || Worker::run(cfg));
    let report = t.join().unwrap().expect("leader run_stream");
    let _ = w.join();
    assert_eq!(report.jcts.len(), expected, "stream must fully drain");
    assert_eq!(report.tenant_of.len(), expected);
    let by_tenant = report.tenant_stats();
    assert!(!by_tenant.is_empty());
    let n: usize = by_tenant.values().map(|s| s.n).sum();
    assert_eq!(n, expected);
}

#[test]
fn deploy_round_cadence_follows_absolute_grid() {
    // The leader schedules round boundaries on absolute multiples of
    // `round_real_s` (RoundTicker), subtracting planning time from each
    // sleep instead of sleeping the full period after planning. Smoke
    // check with generous CI tolerance: R rounds must take at least
    // (R-1) periods of wall time (rounds can never fire early) and not
    // wildly more than R periods.
    let jobs = generate(&TraceConfig {
        n_jobs: 4,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 5,
    });
    let n = jobs.len();
    let period = 0.25;
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 1,
        round_real_s: period,
        time_scale: 40_000.0,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        quotas: None,
        telemetry: None,
        telemetry_timing: false,
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || {
        let t0 = std::time::Instant::now();
        let report = l2.run(jobs);
        (report, t0.elapsed().as_secs_f64())
    });
    let addr = loop {
        if let Some(a) = *leader.addr.lock().unwrap() {
            break a;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    let cfg = WorkerConfig {
        leader_addr: addr.to_string(),
        real_compute: false,
        ..Default::default()
    };
    let w = std::thread::spawn(move || Worker::run(cfg));
    let (report, elapsed) = t.join().unwrap();
    let report = report.expect("leader run");
    let _ = w.join();
    assert_eq!(report.jcts.len(), n);
    let rounds = report.rounds as f64;
    assert!(
        elapsed >= (rounds - 1.0) * period - 0.05,
        "{} rounds finished in {elapsed:.2}s — rounds fired early \
         (period {period}s)",
        report.rounds
    );
    assert!(
        elapsed <= rounds * period + 5.0,
        "{} rounds took {elapsed:.2}s — cadence drifted far past the \
         absolute grid (period {period}s)",
        report.rounds
    );
}

#[test]
fn deploy_survives_worker_crash() {
    // Leader + 2 workers; one worker crashes mid-run (fault injection).
    // The leader must fail it over and drain the whole trace on the
    // survivor.
    let jobs = generate(&TraceConfig {
        n_jobs: 5,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 4,
    });
    let n = jobs.len();
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 2,
        round_real_s: 0.2,
        time_scale: 40_000.0,
        policy: "srtf".into(),
        mechanism: "tune".into(),
        variant: "tiny".into(),
        max_real_s: 90.0,
        quotas: None,
        telemetry: None,
        telemetry_timing: false,
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run(jobs));
    let addr = loop {
        if let Some(a) = *leader.addr.lock().unwrap() {
            break a;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    let mut workers = Vec::new();
    for i in 0..2 {
        let cfg = WorkerConfig {
            leader_addr: addr.to_string(),
            real_compute: false,
            // Worker 1 crashes 2 seconds in; worker 0 survives.
            fail_after_s: if i == 1 { Some(2.0) } else { None },
            ..Default::default()
        };
        workers.push(std::thread::spawn(move || Worker::run(cfg)));
    }
    let report = t.join().unwrap().expect("leader must survive the crash");
    let crashed = workers.remove(1).join().unwrap();
    assert!(crashed.is_err(), "worker 1 must report the injected crash");
    let _ = workers.remove(0).join();
    assert_eq!(
        report.jcts.len(),
        n,
        "all jobs must finish despite the worker crash"
    );
}
