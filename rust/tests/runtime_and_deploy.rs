//! Integration tests over the PJRT runtime (Layer 1/2 artifacts executed
//! from rust) and the deploy-mode control plane.
//!
//! Runtime tests require `make artifacts` to have produced the `tiny`
//! variant; they are skipped (with a note) when artifacts are absent so
//! `cargo test` works on a fresh checkout. Deploy tests are
//! protocol-only (`real_compute: false`) and run on localhost.

use synergy::deploy::proto::Conn;
use synergy::deploy::{
    Leader, LeaderConfig, Message, Worker, WorkerConfig,
};
use synergy::job::{Job, JobId, ModelKind};
use synergy::runtime::{Runtime, SyntheticCorpus, Trainer};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::workload::{SyntheticSource, TenantSpec, WorkloadSource};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/tiny.meta.json")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Hand-built jobs with exactly known GPU-proportional durations, so a
/// test can pick its wall-clock envelope: under `mechanism:
/// "proportional"` a job of duration D finishes after D simulated
/// seconds of allocation, i.e. D / time_scale wall seconds of runtime.
fn fixed_jobs(n: usize, gpus: u32, duration_s: f64) -> Vec<Job> {
    (0..n)
        .map(|i| {
            Job::new(JobId(i as u64), ModelKind::ResNet18, gpus, 0.0, duration_s)
        })
        .collect()
}

/// Wait for the leader thread to publish its ephemeral bind address.
fn wait_addr(leader: &Arc<Leader>) -> std::net::SocketAddr {
    loop {
        if let Some(a) = *leader.addr.lock().unwrap() {
            break a;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn tiny_variant_trains_and_loss_descends() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("pjrt");
    let (meta, exe) = rt.load_variant(&dir, "tiny").expect("load tiny");
    assert_eq!(meta.variant, "tiny");
    let uniform = (meta.vocab as f64).ln();
    let mut corpus = SyntheticCorpus::new(meta.vocab, 3);
    let mut trainer = Trainer::new(&rt.client, exe, meta, 1).expect("trainer");
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..30 {
        let toks = corpus.batch(trainer.meta.batch, trainer.meta.seq_len);
        let loss = trainer.train_step(&toks, 0.3).expect("step") as f64;
        assert!(loss.is_finite(), "loss diverged at step {step}");
        if step == 0 {
            first = loss;
            // Fresh init: near the uniform baseline.
            assert!((loss - uniform).abs() < 1.0, "init loss {loss}");
        }
        last = loss;
    }
    assert!(last < first - 0.3, "loss did not descend: {first} -> {last}");
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("pjrt");
    let (meta, exe) = rt.load_variant(&dir, "tiny").expect("load");
    let mut corpus = SyntheticCorpus::new(meta.vocab, 4);
    let mut trainer = Trainer::new(&rt.client, exe, meta, 2).expect("trainer");
    for _ in 0..3 {
        let toks = corpus.batch(trainer.meta.batch, trainer.meta.seq_len);
        trainer.train_step(&toks, 0.1).expect("step");
    }
    let ckpt = trainer.params_to_host().expect("checkpoint");
    assert_eq!(ckpt.len(), trainer.meta.param_count);
    // Restore into a fresh trainer; next losses must match a trainer that
    // never checkpointed (same tokens, same params).
    let (meta2, exe2) = rt.load_variant(&dir, "tiny").expect("load");
    let mut restored =
        Trainer::new(&rt.client, exe2, meta2, 99).expect("trainer2");
    restored.restore(&ckpt).expect("restore");
    let toks = corpus.batch(trainer.meta.batch, trainer.meta.seq_len);
    let a = trainer.train_step(&toks, 0.0).expect("a");
    let b = restored.train_step(&toks, 0.0).expect("b");
    assert!((a - b).abs() < 1e-5, "restored loss {b} != original {a}");
}

#[test]
fn deploy_protocol_roundtrip_without_compute() {
    // Leader + 2 workers over localhost, no PJRT (protocol-only): a small
    // static trace must fully drain and report JCTs.
    let jobs = generate(&TraceConfig {
        n_jobs: 6,
        split: Split::new(0, 100, 0), // fast, insensitive jobs
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 9,
    });
    let n = jobs.len();
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 2,
        round_real_s: 0.2,
        time_scale: 40_000.0, // compress hours into seconds
        policy: "fifo".into(),
        mechanism: "tune".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        ..LeaderConfig::default()
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run(jobs));
    let addr = wait_addr(&leader);
    let mut workers = Vec::new();
    for _ in 0..2 {
        let cfg = WorkerConfig {
            leader_addr: addr.to_string(),
            real_compute: false,
            ..Default::default()
        };
        workers.push(std::thread::spawn(move || Worker::run(cfg)));
    }
    let report = t.join().unwrap().expect("leader run");
    for w in workers {
        let _ = w.join();
    }
    assert_eq!(report.jcts.len(), n, "all jobs must finish");
    assert!(report.rounds > 0);
    assert_eq!(report.recoveries, 0, "fresh run must not report recovery");
    for (_, jct) in &report.jcts {
        assert!(*jct > 0.0 && jct.is_finite());
    }
}

#[test]
fn deploy_streams_arrivals_from_a_workload_source() {
    // run_stream: the leader admits every job a WorkloadSource yields
    // (arrival times respected by the event-driven core), and the report
    // carries tenant tags through to per-tenant stats.
    let source = SyntheticSource::new(TraceConfig {
        n_jobs: 6,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None, // static: stream drains immediately
        seed: 11,
    })
    .with_tenants(TenantSpec::parse("a,b").unwrap());
    let expected = source.len_hint().unwrap();
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 1,
        round_real_s: 0.2,
        time_scale: 40_000.0,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        ..LeaderConfig::default()
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run_stream(Box::new(source)));
    let addr = wait_addr(&leader);
    let cfg = WorkerConfig {
        leader_addr: addr.to_string(),
        real_compute: false,
        ..Default::default()
    };
    let w = std::thread::spawn(move || Worker::run(cfg));
    let report = t.join().unwrap().expect("leader run_stream");
    let _ = w.join();
    assert_eq!(report.jcts.len(), expected, "stream must fully drain");
    assert_eq!(report.tenant_of.len(), expected);
    let by_tenant = report.tenant_stats();
    assert!(!by_tenant.is_empty());
    let n: usize = by_tenant.values().map(|s| s.n).sum();
    assert_eq!(n, expected);
}

#[test]
fn deploy_round_cadence_follows_absolute_grid() {
    // Round boundaries land on absolute multiples of `round_real_s`
    // (WallGrid), subtracting planning time from each sleep instead of
    // sleeping the full period after planning. Smoke check with generous
    // CI tolerance: R rounds must take at least (R-1) periods of wall
    // time (rounds can never fire early) and not wildly more than R
    // periods. Fixed-duration jobs pin the round count: 3 one-GPU jobs
    // of 25 000 sim-seconds at scale 40 000 under proportional
    // allocation span 3 rounds of 10 000 sim-seconds.
    let jobs = fixed_jobs(3, 1, 25_000.0);
    let n = jobs.len();
    let period = 0.25;
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 1,
        round_real_s: period,
        time_scale: 40_000.0,
        policy: "fifo".into(),
        mechanism: "proportional".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        ..LeaderConfig::default()
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || {
        let t0 = Instant::now();
        let report = l2.run(jobs);
        (report, t0.elapsed().as_secs_f64())
    });
    let addr = wait_addr(&leader);
    let cfg = WorkerConfig {
        leader_addr: addr.to_string(),
        real_compute: false,
        ..Default::default()
    };
    let w = std::thread::spawn(move || Worker::run(cfg));
    let (report, elapsed) = t.join().unwrap();
    let report = report.expect("leader run");
    let _ = w.join();
    assert_eq!(report.jcts.len(), n);
    let rounds = report.rounds as f64;
    assert!(
        elapsed >= (rounds - 1.0) * period - 0.05,
        "{} rounds finished in {elapsed:.2}s — rounds fired early \
         (period {period}s)",
        report.rounds
    );
    assert!(
        elapsed <= rounds * period + 5.0,
        "{} rounds took {elapsed:.2}s — cadence drifted far past the \
         absolute grid (period {period}s)",
        report.rounds
    );
}

#[test]
fn deploy_survives_worker_crash() {
    // Leader + 2 workers; one worker crashes mid-run (fault injection).
    // The leader must fail it over through the preempt-and-requeue
    // churn path and drain the whole trace on the survivor. Four 4-GPU
    // jobs fill both 8-GPU workers, so the crashed worker is guaranteed
    // to be hosting jobs when it dies; 2400 sim-second durations at
    // scale 600 put the unperturbed drain at ~4 s wall — the 2 s crash
    // lands mid-run.
    let jobs = fixed_jobs(4, 4, 2400.0);
    let n = jobs.len();
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 2,
        round_real_s: 0.2,
        time_scale: 600.0,
        policy: "srtf".into(),
        mechanism: "proportional".into(),
        variant: "tiny".into(),
        max_real_s: 90.0,
        ..LeaderConfig::default()
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run(jobs));
    let addr = wait_addr(&leader);
    let mut workers = Vec::new();
    for i in 0..2 {
        let cfg = WorkerConfig {
            leader_addr: addr.to_string(),
            real_compute: false,
            // Worker 1 crashes 2 seconds in; worker 0 survives.
            fail_after_s: if i == 1 { Some(2.0) } else { None },
            ..Default::default()
        };
        workers.push(std::thread::spawn(move || Worker::run(cfg)));
    }
    let report = t.join().unwrap().expect("leader must survive the crash");
    let crashed = workers.remove(1).join().unwrap();
    assert!(crashed.is_err(), "worker 1 must report the injected crash");
    let _ = workers.remove(0).join();
    assert_eq!(
        report.jcts.len(),
        n,
        "all jobs must finish despite the worker crash"
    );
    assert_eq!(report.servers_failed, 1, "crash must register as churn");
    assert!(
        report.preemptions >= 1,
        "jobs on the crashed worker must be preempted-and-requeued, \
         not lost"
    );
}

#[test]
fn heartbeat_lease_expiry_fails_over_a_silent_worker() {
    // A worker that registers but never heartbeats has its lease
    // expired after 3 periods and is failed over exactly like a
    // disconnect — its jobs requeue with progress preserved and the
    // run drains on the live worker.
    let jobs = fixed_jobs(4, 4, 1800.0);
    let n = jobs.len();
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 2,
        round_real_s: 0.2,
        time_scale: 600.0,
        policy: "srtf".into(),
        mechanism: "proportional".into(),
        variant: "tiny".into(),
        max_real_s: 90.0,
        heartbeat_s: 0.3,
        ..LeaderConfig::default()
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run(jobs));
    let addr = wait_addr(&leader);
    // Worker 0: a real worker (its heartbeat thread beats at 0.15 s).
    let cfg = WorkerConfig {
        leader_addr: addr.to_string(),
        real_compute: false,
        ..Default::default()
    };
    let w = std::thread::spawn(move || Worker::run(cfg));
    // Worker 1: registers by hand, then goes silent — the connection
    // stays open (no EOF), so only the heartbeat lease can catch it.
    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut silent = Conn::new(stream).expect("conn");
    silent
        .send(&Message::Register {
            gpus: 8,
            cpus: 24,
            mem_gb: 500.0,
            gen: "v100".into(),
        })
        .expect("register");
    match silent.recv().expect("ack") {
        Some(Message::RegisterAck { heartbeat_s, .. }) => {
            assert_eq!(heartbeat_s, 0.3, "ack must carry the lease period");
        }
        other => panic!("expected ack, got {other:?}"),
    }
    let report = t.join().unwrap().expect("leader must survive the expiry");
    drop(silent);
    let _ = w.join();
    assert_eq!(report.jcts.len(), n, "all jobs must finish");
    assert!(
        report.heartbeat_expiries >= 1,
        "the silent worker's lease must expire"
    );
    assert_eq!(report.servers_failed, 1);
}

#[test]
fn duplicate_registration_gets_a_typed_fleet_full_error() {
    // The fleet is full (1/1 workers alive): a second registration must
    // be answered with a typed Error frame — not a panic, not a silent
    // replacement of the live worker.
    let jobs = fixed_jobs(2, 1, 2400.0); // ~4 s run: plenty of rounds
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 1,
        round_real_s: 0.2,
        time_scale: 600.0,
        policy: "fifo".into(),
        mechanism: "proportional".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        ..LeaderConfig::default()
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run(jobs));
    let addr = wait_addr(&leader);
    let cfg = WorkerConfig {
        leader_addr: addr.to_string(),
        real_compute: false,
        ..Default::default()
    };
    let w = std::thread::spawn(move || Worker::run(cfg));
    // Give the round loop time to start (rejoins drain once per poll).
    std::thread::sleep(Duration::from_millis(600));
    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut dup = Conn::new(stream).expect("conn");
    dup.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    dup.send(&Message::Register {
        gpus: 8,
        cpus: 24,
        mem_gb: 500.0,
        gen: "v100".into(),
    })
    .expect("register");
    match dup.recv().expect("reply") {
        Some(Message::Error { reason }) => {
            assert!(
                reason.contains("fleet full"),
                "duplicate registration must be rejected as fleet-full, \
                 got: {reason}"
            );
        }
        other => panic!("expected Error, got {other:?}"),
    }
    let report = t.join().unwrap().expect("leader run");
    let _ = w.join();
    assert_eq!(report.jcts.len(), 2, "run must be undisturbed");
    assert_eq!(report.servers_failed, 0, "no churn from the duplicate");
}

#[test]
fn submissions_are_idempotent_and_conflicts_get_typed_errors() {
    // Network admission: a resubmitted job id with the same spec is
    // acked as a duplicate (never double-admitted), a conflicting spec
    // under a known id gets a typed Error, and malformed submissions
    // (unknown model, infeasible gang) are rejected before admission.
    let leader = Arc::new(Leader::new(LeaderConfig {
        bind: "127.0.0.1:0".into(),
        n_workers: 1,
        round_real_s: 0.2,
        time_scale: 600.0,
        policy: "fifo".into(),
        mechanism: "proportional".into(),
        variant: "tiny".into(),
        max_real_s: 60.0,
        expect_jobs: 2,
        ..LeaderConfig::default()
    }));
    let l2 = Arc::clone(&leader);
    let t = std::thread::spawn(move || l2.run(Vec::new()));
    let addr = wait_addr(&leader);
    let cfg = WorkerConfig {
        leader_addr: addr.to_string(),
        real_compute: false,
        ..Default::default()
    };
    let w = std::thread::spawn(move || Worker::run(cfg));

    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut client = Conn::new(stream).expect("conn");
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let submit = |c: &mut Conn, id: u64, gpus: u32, model: &str| {
        c.send(&Message::Submit {
            job_id: id,
            tenant: "team-a".into(),
            model: model.into(),
            gpus,
            arrival_s: 0.0,
            duration_s: 600.0,
        })
        .expect("send");
        c.recv().expect("reply").expect("reply frame")
    };
    // Fresh admission.
    match submit(&mut client, 7, 1, "resnet18") {
        Message::SubmitAck { job_id: 7, duplicate: false } => {}
        other => panic!("expected fresh ack, got {other:?}"),
    }
    // Same id, same spec: idempotent duplicate ack.
    match submit(&mut client, 7, 1, "resnet18") {
        Message::SubmitAck { job_id: 7, duplicate: true } => {}
        other => panic!("expected duplicate ack, got {other:?}"),
    }
    // Same id, different spec: typed conflict error.
    match submit(&mut client, 7, 2, "resnet18") {
        Message::Error { reason } => {
            assert!(reason.contains("different spec"), "got: {reason}")
        }
        other => panic!("expected conflict Error, got {other:?}"),
    }
    // Unknown model: rejected before admission.
    match submit(&mut client, 9, 1, "not-a-model") {
        Message::Error { reason } => {
            assert!(reason.contains("unknown model"), "got: {reason}")
        }
        other => panic!("expected Error, got {other:?}"),
    }
    // Infeasible gang (one 8-GPU worker): rejected.
    match submit(&mut client, 9, 99, "resnet18") {
        Message::Error { reason } => {
            assert!(reason.contains("capacity"), "got: {reason}")
        }
        other => panic!("expected Error, got {other:?}"),
    }
    // Second distinct job releases the expect_jobs gate.
    match submit(&mut client, 8, 1, "resnet18") {
        Message::SubmitAck { job_id: 8, duplicate: false } => {}
        other => panic!("expected fresh ack, got {other:?}"),
    }
    // Status query on the same connection (client sessions are loops).
    client.send(&Message::QueryStatus).expect("query");
    match client.recv().expect("status").expect("frame") {
        Message::Status { submitted, .. } => assert_eq!(submitted, 2),
        other => panic!("expected Status, got {other:?}"),
    }
    drop(client);

    let report = t.join().unwrap().expect("leader run");
    let _ = w.join();
    assert_eq!(
        report.jcts.len(),
        2,
        "exactly the two distinct jobs run — duplicates are not \
         double-admitted"
    );
    let ids: Vec<u64> = report.jcts.iter().map(|&(id, _)| id).collect();
    assert!(ids.contains(&7) && ids.contains(&8), "ids 7 and 8: {ids:?}");
}

// ---------------------------------------------------------------------
// Kill-and-recover: the tentpole invariant, driven end-to-end through
// the real binary (SIGKILL, new process, --recover).
// ---------------------------------------------------------------------

/// Wait for the leader subprocess to write its port file; return the
/// dial address.
fn wait_port_file(path: &std::path::Path) -> String {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let s = s.trim();
            if let Some(port) = s.rsplit(':').next() {
                if !port.is_empty() && s.contains(':') {
                    return format!("127.0.0.1:{port}");
                }
            }
        }
        assert!(Instant::now() < deadline, "leader never wrote {path:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn spawn_leader(
    bin: &str,
    dir: &std::path::Path,
    recover: bool,
) -> std::process::Child {
    let mut cmd = std::process::Command::new(bin);
    cmd.args([
        "leader",
        "--port",
        "0",
        "--workers",
        "1",
        "--jobs",
        "0", // empty source: jobs arrive over the network
        "--round-real",
        "0.2",
        "--time-scale",
        "600",
        "--policy",
        "srtf",
        "--mechanism",
        "proportional",
        "--max-real",
        "90",
        "--expect-jobs",
        "3",
    ])
    .arg("--journal")
    .arg(dir.join("wal"))
    .arg("--report")
    .arg(dir.join("report.json"))
    .arg("--port-file")
    .arg(dir.join("port"))
    .stdout(std::process::Stdio::null())
    .stderr(std::process::Stdio::null());
    if recover {
        cmd.arg("--recover");
    }
    cmd.spawn().expect("spawn leader")
}

fn spawn_worker(bin: &str, addr: &str) -> std::process::Child {
    std::process::Command::new(bin)
        .args(["worker", "--leader", addr, "--no-compute"])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn worker")
}

fn submit_job(bin: &str, addr: &str, id: u64) {
    let id_s = id.to_string();
    let out = std::process::Command::new(bin)
        .args([
            "submit",
            "--leader",
            addr,
            "--id",
            id_s.as_str(),
            "--model",
            "resnet18",
            "--gpus",
            "2",
            "--duration",
            "2400",
            "--tenant",
            "team-a",
        ])
        .output()
        .expect("run submit");
    assert!(
        out.status.success(),
        "submit {id} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn killed_and_recovered_leader_matches_unkilled_run_bytewise() {
    let bin = env!("CARGO_BIN_EXE_synergy");
    let base = std::env::temp_dir()
        .join(format!("synergy-recover-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);

    // One deploy service run: leader + worker subprocesses, 3 network
    // submissions. When `kill_after` is set, SIGKILL the leader mid-run
    // (then the worker), restart with --recover, and let the recovered
    // leader finish the run. Returns the final report bytes.
    let run = |tag: &str, kill_after: Option<Duration>| -> Vec<u8> {
        let dir = base.join(tag);
        std::fs::create_dir_all(&dir).unwrap();
        let mut leader = spawn_leader(bin, &dir, false);
        let addr = wait_port_file(&dir.join("port"));
        let mut worker = spawn_worker(bin, &addr);
        for id in 1..=3 {
            submit_job(bin, &addr, id);
        }
        if let Some(delay) = kill_after {
            std::thread::sleep(delay);
            // SIGKILL the leader first: the worker must NOT die before
            // the leader does, or the leader would journal churn the
            // control run never saw.
            leader.kill().expect("kill leader");
            let _ = leader.wait();
            let _ = worker.kill();
            let _ = worker.wait();
            // Cold restart from the journal: a new process, a fresh
            // worker, the same flags.
            std::fs::remove_file(dir.join("port")).unwrap();
            leader = spawn_leader(bin, &dir, true);
            let addr = wait_port_file(&dir.join("port"));
            worker = spawn_worker(bin, &addr);
        }
        let status = leader.wait().expect("leader wait");
        assert!(status.success(), "[{tag}] leader exited with {status}");
        let _ = worker.wait();
        std::fs::read(dir.join("report.json")).expect("report written")
    };

    // Control: never killed. Then the same workload killed mid-run
    // (~1.5 s in = several journaled round checkpoints, jobs part-done)
    // and recovered in a new process.
    let control = run("control", None);
    let recovered = run("killed", Some(Duration::from_millis(1500)));
    assert!(!control.is_empty());
    assert_eq!(
        String::from_utf8_lossy(&control),
        String::from_utf8_lossy(&recovered),
        "recovered leader must produce a schedule byte-identical to the \
         unkilled control run"
    );
    let _ = std::fs::remove_dir_all(&base);
}
