//! Property tests for the tenant-quota admission invariants (ISSUE 2),
//! using the in-crate property harness (`util::prop`):
//!
//! 1. **Quota cap** — no tenant exceeds its weighted integer GPU cap
//!    through the quota pass; only the work-conserving spill pass may
//!    push a tenant past its cap, and only into capacity no other
//!    tenant could use.
//! 2. **Work conservation** — a job is left unadmitted only when the
//!    remaining capacity cannot hold its gang: no GPU idles because of
//!    quotas alone.
//! 3. **Accounting** — per-tenant GPU tallies sum exactly to the
//!    admitted total, the admitted set is duplicate-free, spilled ⊆
//!    admitted, and completed-job accounting through the simulators
//!    never goes negative.

use std::collections::{BTreeMap, BTreeSet};
use synergy::job::{JobId, TenantId};
use synergy::prop_assert;
use synergy::util::prop::{check, Gen};
use synergy::workload::{admit, AdmissionJob, TenantQuotas};

/// A random policy-ordered queue + quota set + GPU capacity.
fn random_round(g: &mut Gen) -> (Vec<AdmissionJob>, TenantQuotas, u32) {
    let n_tenants = g.int(1, 5);
    let mut quotas = TenantQuotas::new();
    for t in 0..n_tenants {
        // Leave some tenants unspecified sometimes (default weight 1).
        if g.bool() {
            quotas.set(TenantId(t as u32), g.f64(0.5, 4.0));
        }
    }
    let mut jobs = g.vec(40, |g| AdmissionJob {
        id: JobId(0),
        tenant: TenantId(g.int(0, n_tenants) as u32),
        gpus: g.choose(&[1u32, 1, 1, 2, 4, 8]),
    });
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = JobId(i as u64);
    }
    let total_gpus = g.int(1, 65) as u32;
    (jobs, quotas, total_gpus)
}

#[test]
fn prop_no_tenant_exceeds_quota_without_spill() {
    check("quota cap", 200, |g| {
        let (jobs, quotas, total) = random_round(g);
        let out = admit(&jobs, total, Some(&quotas));
        let present: Vec<TenantId> = {
            let mut p: Vec<TenantId> = jobs.iter().map(|j| j.tenant).collect();
            p.sort_unstable();
            p.dedup();
            p
        };
        let caps = quotas.integer_caps(&present, total);
        let spilled: BTreeSet<JobId> = out.spilled.iter().copied().collect();
        let by_id: BTreeMap<JobId, &AdmissionJob> =
            jobs.iter().map(|j| (j.id, j)).collect();
        // GPUs admitted per tenant inside the quota pass only.
        let mut in_quota: BTreeMap<TenantId, u32> = BTreeMap::new();
        for id in &out.admitted {
            if !spilled.contains(id) {
                let j = by_id[id];
                *in_quota.entry(j.tenant).or_insert(0) += j.gpus;
            }
        }
        for (t, used) in &in_quota {
            let cap = caps.get(t).copied().unwrap_or(0);
            prop_assert!(
                *used <= cap,
                "tenant {t:?} used {used} GPUs in-quota, cap {cap}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_spill_is_work_conserving() {
    check("work-conserving spill", 200, |g| {
        let (jobs, quotas, total) = random_round(g);
        let with_quotas = g.bool();
        let out = admit(&jobs, total, with_quotas.then_some(&quotas));
        let admitted: BTreeSet<JobId> = out.admitted.iter().copied().collect();
        let used: u32 = jobs
            .iter()
            .filter(|j| admitted.contains(&j.id))
            .map(|j| j.gpus)
            .sum();
        prop_assert!(used <= total, "overcommitted: {used} > {total}");
        // No idle GPU while a job waits: every unadmitted job's gang must
        // overflow the leftover capacity.
        for j in &jobs {
            if !admitted.contains(&j.id) {
                prop_assert!(
                    used + j.gpus > total,
                    "job {:?} ({} GPUs) left waiting with {} of {} GPUs free",
                    j.id,
                    j.gpus,
                    total - used,
                    total
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_admission_accounting_consistent() {
    check("admission accounting", 200, |g| {
        let (jobs, quotas, total) = random_round(g);
        let out = admit(&jobs, total, Some(&quotas));
        // Admitted ids unique and drawn from the queue.
        let ids: BTreeSet<JobId> = out.admitted.iter().copied().collect();
        prop_assert!(
            ids.len() == out.admitted.len(),
            "duplicate admissions: {:?}",
            out.admitted
        );
        let queue_ids: BTreeSet<JobId> = jobs.iter().map(|j| j.id).collect();
        prop_assert!(
            ids.is_subset(&queue_ids),
            "admitted a job that never queued"
        );
        // Spilled jobs are admitted jobs.
        prop_assert!(
            out.spilled.iter().all(|id| ids.contains(id)),
            "spilled job not admitted"
        );
        // Per-tenant tallies sum exactly to the admitted GPU total.
        let tally: u32 = out.gpus_by_tenant.values().sum();
        let admitted_gpus: u32 = jobs
            .iter()
            .filter(|j| ids.contains(&j.id))
            .map(|j| j.gpus)
            .sum();
        prop_assert!(
            tally == admitted_gpus,
            "tenant tally {tally} != admitted GPUs {admitted_gpus}"
        );
        Ok(())
    });
}

#[test]
fn prop_admission_deterministic() {
    check("admission determinism", 100, |g| {
        let (jobs, quotas, total) = random_round(g);
        let a = admit(&jobs, total, Some(&quotas));
        let b = admit(&jobs, total, Some(&quotas));
        prop_assert!(a.admitted == b.admitted, "nondeterministic admit");
        prop_assert!(a.spilled == b.spilled, "nondeterministic spill");
        Ok(())
    });
}

/// Completed-job accounting never goes negative, end to end through both
/// engines (homogeneous + heterogeneous run the same core loop).
#[test]
fn prop_sim_accounting_never_negative() {
    use synergy::hetero::{HeteroSimConfig, HeteroSimulator};
    use synergy::sim::{SimConfig, Simulator};
    use synergy::trace::{generate, Split, TraceConfig};
    use synergy::workload::TenantSpec;

    check("sim accounting", 6, |g| {
        let n_jobs = g.int(2, 12);
        let jobs: Vec<synergy::job::Job> = generate(&TraceConfig {
            n_jobs,
            split: Split::new(30, 50, 20),
            multi_gpu: false,
            jobs_per_hour: if g.bool() { Some(6.0) } else { None },
            seed: g.int(0, 1000) as u64,
        })
        .into_iter()
        .enumerate()
        .map(|(i, j)| j.with_tenant(TenantId((i % 2) as u32)))
        .collect();
        let quotas = TenantSpec::parse("a:2,b:1").unwrap().quotas();

        let homo = Simulator::with_quotas(
            SimConfig {
                n_servers: 1,
                policy: "srtf".into(),
                mechanism: "tune".into(),
                ..Default::default()
            },
            Some(quotas.clone()),
        )
        .run(jobs.clone());
        let het = HeteroSimulator::with_quotas(
            HeteroSimConfig {
                policy: "srtf".into(),
                mechanism: "het-tune".into(),
                ..Default::default()
            },
            Some(quotas),
        )
        .run(jobs.clone());

        prop_assert!(
            homo.finished.len() == jobs.len(),
            "homo lost jobs: {} of {}",
            homo.finished.len(),
            jobs.len()
        );
        prop_assert!(
            het.finished.len() == jobs.len(),
            "hetero lost jobs: {} of {}",
            het.finished.len(),
            jobs.len()
        );
        for f in homo.finished.iter().chain(het.finished.iter()) {
            prop_assert!(
                f.jct_s > 0.0 && f.jct_s.is_finite(),
                "bad JCT {} for {:?}",
                f.jct_s,
                f.id
            );
            prop_assert!(
                f.duration_prop_s > 0.0 && f.arrival_s >= 0.0,
                "negative accounting for {:?}",
                f.id
            );
        }
        // Tenant stats partition the finished set.
        let n_sum: usize = homo.tenant_stats().values().map(|s| s.n).sum();
        prop_assert!(
            n_sum == homo.finished.len(),
            "tenant stats lose jobs: {n_sum}"
        );
        let n_sum: usize = het.tenant_stats().values().map(|s| s.n).sum();
        prop_assert!(
            n_sum == het.finished.len(),
            "hetero tenant stats lose jobs: {n_sum}"
        );
        Ok(())
    });
}
