//! Telemetry (ISSUE 6 tentpole) contracts, outside-in:
//!
//! 1. the delta codec round-trips arbitrary rows (property test over
//!    extreme i64s, variable row lengths, and every prefix width);
//! 2. attaching a recorder changes **zero scheduled bytes**: the
//!    `SimResult` — finish-time bit patterns, round counts, utilization
//!    trace, and the golden `metrics_json` payload — is identical with
//!    telemetry on or off;
//! 3. the recorded series reconcile with the run they observed
//!    (one sample + one plan event per round, tier counts matching
//!    `planned_rounds`/`resumed_rounds`, counters-only exports free of
//!    wall-clock fields);
//! 4. at the CLI, `sweep --telemetry-dir` per-cell profiles are
//!    byte-identical across `--threads`, report/telemetry paths create
//!    missing parents instead of panicking (and name the path on
//!    failure), and `hetero --json --plan-stats` speaks the same
//!    payload shape as `sim --json --plan-stats`.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::process::Command;

use synergy::job::Job;
use synergy::sim::{SimConfig, SimResult, Simulator};
use synergy::telemetry::{
    DeltaLog, PlanTier, TelemetryConfig, TelemetryRecorder,
};
use synergy::trace::{Split, TraceConfig};
use synergy::util::json::Json;
use synergy::util::prop;
use synergy::workload::{SyntheticSource, TenantSpec, WorkloadSource};

// ---------------------------------------------------------------- codec

#[test]
fn delta_log_round_trips_arbitrary_rows() {
    prop::check("delta_log_round_trip", 300, |g| {
        let prefix = g.int(0, 8);
        let mut log = DeltaLog::new(prefix);
        let rows: Vec<Vec<i64>> = g.vec(24, |g| {
            g.vec(10, |g| match g.int(0, 5) {
                0 => i64::MIN,
                1 => i64::MAX,
                2 => -(g.int(0, 1_000_000) as i64),
                3 => 0,
                _ => g.int(0, 1_000_000) as i64,
            })
        });
        for row in &rows {
            log.push(row);
        }
        let decoded = log.decode();
        if decoded != rows {
            return Err(format!(
                "prefix={prefix}: decode mismatch\n in: {rows:?}\nout: {decoded:?}"
            ));
        }
        Ok(())
    });
}

// ------------------------------------------- zero-scheduled-bytes rule

fn tenant_trace(n: usize, seed: u64) -> (Vec<Job>, TenantSpec) {
    let spec = TenantSpec::parse("a:2,b:1").unwrap();
    let jobs = SyntheticSource::new(TraceConfig {
        n_jobs: n,
        split: Split::new(30, 50, 20),
        multi_gpu: true,
        jobs_per_hour: Some(10.0),
        seed,
    })
    .with_tenants(spec.clone())
    .drain_jobs();
    (jobs, spec)
}

/// The schedule as comparable bits (same shape as the memo-parity
/// harness): exact finish times, round counters, utilization trace.
fn schedule_bits(r: &SimResult) -> (Vec<(u64, u64)>, [usize; 3], Vec<u64>) {
    let finished: Vec<(u64, u64)> =
        r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect();
    let util: Vec<u64> = r
        .utilization
        .samples
        .iter()
        .flat_map(|s| {
            [
                s.gpu_util.to_bits(),
                s.cpu_util.to_bits(),
                s.cpu_used.to_bits(),
                s.mem_util.to_bits(),
                s.queued_jobs as u64,
                s.running_jobs as u64,
            ]
        })
        .collect();
    (finished, [r.rounds, r.planned_rounds, r.resumed_rounds], util)
}

#[test]
fn recorder_changes_zero_scheduled_bytes() {
    // SRTF reorders the runnable sequence almost every round, so all
    // three planning tiers fire; quotas exercise the spill counters.
    for (policy, mechanism) in
        [("srtf", "tune"), ("fifo", "proportional"), ("las", "greedy")]
    {
        let (jobs, spec) = tenant_trace(120, 11);
        let mk = || SimConfig {
            n_servers: 2,
            policy: policy.to_string(),
            mechanism: mechanism.to_string(),
            ..Default::default()
        };
        let plain = Simulator::with_quotas(mk(), Some(spec.quotas()))
            .run(jobs.clone());
        let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
        let recorded = Simulator::with_quotas(mk(), Some(spec.quotas()))
            .run_with_telemetry(jobs, Some(&mut rec));
        assert_eq!(
            schedule_bits(&plain),
            schedule_bits(&recorded),
            "{policy}/{mechanism}: telemetry perturbed the schedule"
        );
        assert_eq!(
            plain.metrics_json(true, false),
            recorded.metrics_json(true, false),
            "{policy}/{mechanism}: golden metrics payload changed"
        );
        assert!(rec.n_rounds() > 0, "{policy}/{mechanism}: empty recording");
    }
}

#[test]
fn recording_reconciles_with_the_run() {
    let (jobs, spec) = tenant_trace(150, 7);
    let cfg = SimConfig {
        n_servers: 2,
        policy: "srtf".into(),
        mechanism: "tune".into(),
        ..Default::default()
    };
    let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
    let r = Simulator::with_quotas(cfg, Some(spec.quotas()))
        .run_with_telemetry(jobs, Some(&mut rec));

    // One sample and one plan event per executed round.
    assert_eq!(rec.n_rounds(), r.rounds);
    assert_eq!(rec.n_plan_events(), r.rounds);

    let rounds = rec.rounds();
    for (i, s) in rounds.iter().enumerate() {
        assert_eq!(s.round, i as u64, "round ids are dense");
        assert_eq!(s.wall_ms, 0, "counters-only mode carries no wall time");
        assert!(s.free_gpus <= s.total_gpus);
        assert!(s.free_cpus <= s.total_cpus + 1e-6);
        assert!(s.free_mem_gb <= s.total_mem_gb + 1e-6);
        // Fleet figures are the pool sums.
        let pg: u32 = s.pools.iter().map(|p| p.free_gpus).sum();
        assert_eq!(pg, s.free_gpus);
        // Tenant rows are sorted and running counts reconcile.
        let running: u32 = s.tenants.iter().map(|t| t.running).sum();
        assert_eq!(running, s.running);
        for w in s.tenants.windows(2) {
            assert!(w[0].tenant < w[1].tenant, "tenant rows sorted");
        }
    }
    // Under quotas at least one round spills (the trace oversubscribes
    // two servers) — the spill series must see it.
    assert!(
        rounds.iter().any(|s| s.spilled_gpus > 0),
        "expected admission spill under quotas"
    );

    // Plan-tier attribution reconciles with the planner's own counters:
    // Full + Resumed events = planned rounds, the rest served memoized.
    let events = rec.plan_events();
    let full =
        events.iter().filter(|e| e.tier == PlanTier::Full).count();
    let resumed =
        events.iter().filter(|e| e.tier == PlanTier::Resumed).count();
    let memoized =
        events.iter().filter(|e| e.tier == PlanTier::Memoized).count();
    assert_eq!(full + resumed, r.planned_rounds);
    assert_eq!(resumed, r.resumed_rounds);
    assert_eq!(memoized, r.rounds - r.planned_rounds);
    assert!(resumed > 0, "SRTF under load must exercise prefix resume");
    let reused: u64 = events.iter().map(|e| e.steps_reused).sum();
    assert_eq!(
        reused,
        r.plan_steps_reused as u64
            + events
                .iter()
                .filter(|e| e.tier == PlanTier::Memoized)
                .map(|e| e.steps_reused)
                .sum::<u64>(),
        "per-event reuse sums to the run totals plus memoized replays"
    );
    // Full replans walk the fit index; the trace must capture that.
    assert!(
        events.iter().any(|e| e.fit_walk > 0),
        "fit-index walk counter never fired"
    );

    // Counters-only exports: no wall-clock anywhere, meta line first.
    let jsonl = rec.to_jsonl();
    assert!(jsonl.starts_with("{\"counters_only\":true"));
    assert!(!jsonl.contains("wall_ms"));
    assert!(!rec.to_csv().contains("wall_ms"));
}

#[test]
fn fault_counters_ride_the_round_rows() {
    // ISSUE 9: churn telemetry. The per-round `preemptions` /
    // `servers_failed` / `servers_restored` tallies are instantaneous,
    // and every churn event drains at the top of some executed round —
    // so the row sums must reconcile exactly with the run totals.
    let (jobs, _) = tenant_trace(60, 3);
    let cfg = SimConfig {
        n_servers: 2,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        faults: Some(
            synergy::sim::FaultSpec::parse("mtbf:6,mttr:2,seed:5").unwrap(),
        ),
        ..Default::default()
    };
    let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
    let r = Simulator::new(cfg).run_with_telemetry(jobs, Some(&mut rec));
    let rounds = rec.rounds();
    let failed: u64 = rounds.iter().map(|s| u64::from(s.servers_failed)).sum();
    let restored: u64 =
        rounds.iter().map(|s| u64::from(s.servers_restored)).sum();
    let preempted: u64 =
        rounds.iter().map(|s| u64::from(s.preemptions)).sum();
    assert_eq!(failed, r.servers_failed, "row sums must match run totals");
    assert_eq!(restored, r.servers_restored);
    assert_eq!(preempted, r.preemptions);
    assert!(failed > 0, "a 6h MTBF over weeks of sim time must fire");

    // Both exports carry the three new columns/keys.
    let header = rec.to_csv().lines().next().unwrap_or("").to_string();
    assert!(
        header
            .contains("cross_rack_gangs,preemptions,servers_failed,servers_restored"),
        "CSV round header missing churn columns: {header}"
    );
    assert!(rec.to_jsonl().contains("\"servers_failed\""));
}

#[test]
fn service_line_is_deploy_only_and_counters_only() {
    // ISSUE 10: the deploy leader's lifecycle counters (recoveries,
    // journal replay size, heartbeat expiries) ride the same profile as
    // one `service` JSONL line — opt-in via record_service, absent from
    // simulator runs, counters-only, and invisible to the CSV shape.
    use synergy::telemetry::ServiceCounters;
    let (jobs, _) = tenant_trace(40, 2);
    let cfg = SimConfig {
        n_servers: 2,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        ..Default::default()
    };
    let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
    Simulator::new(cfg).run_with_telemetry(jobs, Some(&mut rec));
    assert!(
        !rec.to_jsonl().contains("\"kind\":\"service\""),
        "simulator profiles must carry no service line"
    );
    let csv_before = rec.to_csv();
    rec.record_service(ServiceCounters {
        recoveries: 1,
        journal_records_replayed: 9,
        heartbeat_expiries: 2,
    });
    let jsonl = rec.to_jsonl();
    let last = jsonl.lines().last().unwrap();
    assert!(
        last.contains("\"kind\":\"service\"")
            && last.contains("\"journal_records_replayed\":9"),
        "service line must close the export: {last}"
    );
    assert!(!jsonl.contains("wall_ms"), "service line leaked wall time");
    assert_eq!(rec.to_csv(), csv_before, "CSV shape must be untouched");
}

// ------------------------------------------------------------- CLI layer

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_synergy"))
}

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("synergy-telemetry-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const SMALL_WORKLOAD: [&str; 8] = [
    "--jobs", "60", "--seed", "5", "--servers", "2", "--max-sim-days", "40",
];

#[test]
fn sweep_telemetry_is_byte_identical_across_threads() {
    let root = scratch("sweep");
    let mut outs = Vec::new();
    for threads in ["1", "4"] {
        let dir = root.join(format!("t{threads}"));
        let out = dir.join("report.txt");
        let status = bin()
            .args(["sweep", "--policies", "fifo,srtf", "--mechanisms", "tune"])
            .args(SMALL_WORKLOAD)
            .args(["--tenants", "a:2,b:1", "--plan-stats"])
            .args(["--threads", threads])
            .args(["--out", out.to_str().unwrap()])
            .args(["--telemetry-dir", dir.to_str().unwrap()])
            .status()
            .expect("spawn synergy sweep");
        assert!(status.success(), "sweep --threads {threads} failed");
        outs.push(dir);
    }
    for cell in ["fifo_tune.jsonl", "srtf_tune.jsonl"] {
        let a = std::fs::read(outs[0].join(cell)).unwrap();
        let b = std::fs::read(outs[1].join(cell)).unwrap();
        assert!(!a.is_empty(), "{cell}: empty telemetry profile");
        assert_eq!(a, b, "{cell}: differs between --threads 1 and 4");
    }
    assert_eq!(
        std::fs::read(outs[0].join("report.txt")).unwrap(),
        std::fs::read(outs[1].join("report.txt")).unwrap(),
        "sweep report differs between thread counts"
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn out_paths_create_parents_and_fail_with_named_paths() {
    let root = scratch("fsx");
    // Missing parents are created, not panicked on.
    let nested = root.join("a/b/c/report.txt");
    let status = bin()
        .args(["sweep", "--policies", "fifo", "--mechanisms", "tune"])
        .args(SMALL_WORKLOAD)
        .args(["--out", nested.to_str().unwrap()])
        .status()
        .expect("spawn synergy sweep");
    assert!(status.success());
    assert!(nested.is_file(), "parent directories were not created");

    // A file used as a directory component fails with the path named,
    // exit code 2 — not a raw io::Error panic.
    let blocker = root.join("plain");
    std::fs::write(&blocker, b"x").unwrap();
    let bad = blocker.join("sub/report.txt");
    let output = bin()
        .args(["sim", "--policy", "fifo"])
        .args(SMALL_WORKLOAD)
        .args(["--telemetry", bad.to_str().unwrap()])
        .output()
        .expect("spawn synergy sim");
    assert_eq!(output.status.code(), Some(2), "expected clean exit(2)");
    let err = String::from_utf8_lossy(&output.stderr);
    assert!(
        err.contains("cannot create directory") && err.contains("plain"),
        "error does not name the offending path: {err}"
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn sim_telemetry_export_formats_and_timing_gate() {
    let root = scratch("formats");
    let jsonl = root.join("run.jsonl");
    let csv = root.join("run.csv");
    let timed = root.join("timed.jsonl");
    for (path, extra) in [
        (&jsonl, None),
        (&csv, None),
        (&timed, Some("--telemetry-timing")),
    ] {
        let mut cmd = bin();
        cmd.args(["sim", "--policy", "srtf"])
            .args(SMALL_WORKLOAD)
            .args(["--telemetry", path.to_str().unwrap()]);
        if let Some(flag) = extra {
            cmd.arg(flag);
        }
        let status = cmd.status().expect("spawn synergy sim");
        assert!(status.success());
    }
    let jsonl_text = std::fs::read_to_string(&jsonl).unwrap();
    assert!(jsonl_text.starts_with("{\"counters_only\":true"));
    assert!(!jsonl_text.contains("wall_ms"), "deterministic export leaked wall time");
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert!(csv_text.starts_with("round,"), "CSV header missing: {}",
        csv_text.lines().next().unwrap_or(""));
    assert!(!csv_text.contains("wall_ms"));
    let timed_text = std::fs::read_to_string(&timed).unwrap();
    assert!(timed_text.starts_with("{\"counters_only\":false"));
    assert!(timed_text.contains("\"wall_ms\""));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn hetero_json_payload_matches_sim_shape() {
    fn keys(v: &Json) -> BTreeSet<String> {
        v.as_obj().expect("object payload").keys().cloned().collect()
    }
    let sim_out = bin()
        .args(["sim", "--policy", "srtf", "--json", "--plan-stats"])
        .args(SMALL_WORKLOAD)
        .args(["--tenants", "a:2,b:1"])
        .output()
        .expect("spawn synergy sim");
    assert!(sim_out.status.success());
    let het_out = bin()
        .args(["hetero", "--policy", "srtf", "--json", "--plan-stats"])
        .args(["--jobs", "60", "--seed", "5", "--machines", "1"])
        .args(["--max-sim-days", "40", "--tenants", "a:2,b:1"])
        .output()
        .expect("spawn synergy hetero");
    assert!(het_out.status.success(), "hetero --json --plan-stats failed");

    let sim_json =
        Json::parse(&String::from_utf8_lossy(&sim_out.stdout)).unwrap();
    let het_json =
        Json::parse(&String::from_utf8_lossy(&het_out.stdout)).unwrap();
    assert_eq!(
        keys(&sim_json),
        keys(&het_json),
        "hetero --json top-level shape diverged from sim --json"
    );
    // --plan-stats appends the planning split as flat keys on both.
    for payload in [&sim_json, &het_json] {
        for key in
            ["planned_rounds", "resumed_rounds", "reused_steps", "total_steps"]
        {
            assert!(
                payload.get(key).as_f64().is_some(),
                "missing plan-stats key {key}"
            );
        }
        let tenants = payload.get("per_tenant").as_arr().unwrap();
        assert_eq!(tenants.len(), 2, "two tenants in the payload");
    }
}
