//! Forced-replan vs memoized-run equivalence (ISSUE 4 tentpole proof).
//!
//! The simulation core memoizes the round plan: the allocation mechanism
//! reruns only when the policy-ordered, admission-cut runnable sequence
//! changed since the last planned round (`sim/core.rs` module docs state
//! the invariant). Because the plan is a pure function of that sequence,
//! disabling memoization (`SimConfig::force_replan`, which reruns the
//! mechanism on every non-fast-forwardable round — the pre-memoization
//! behaviour) must yield the *bit-identical* schedule: same finish
//! times, same round count, same utilization trace, same metrics JSON.
//!
//! The matrix below mirrors the golden scenario matrix's axes (workload
//! shape × quotas × fleet shape) across time-stable (FIFO) and
//! time-varying (SRTF/LAS) policies — the latter exercise rounds where
//! the cheap pass runs but the runnable sequence shifts mid-stream.

use synergy::cluster::{GpuGen, ServerSpec, TypeSpec};
use synergy::job::Job;
use synergy::sim::{SimConfig, SimResult, Simulator};
use synergy::trace::{Split, TraceConfig};
use synergy::workload::{SyntheticSource, TenantSpec, WorkloadSource};

/// A loaded multi-tenant synthetic trace: a non-empty queue through most
/// of the run, so memoized steady-state rounds actually occur.
fn loaded_trace(n: usize, seed: u64) -> (Vec<Job>, TenantSpec) {
    let spec = TenantSpec::parse("a:2,b:1").unwrap();
    let jobs = SyntheticSource::new(TraceConfig {
        n_jobs: n,
        split: Split::new(30, 50, 20),
        multi_gpu: false,
        jobs_per_hour: Some(10.0),
        seed,
    })
    .with_tenants(spec.clone())
    .drain_jobs();
    (jobs, spec)
}

fn tritype() -> Vec<TypeSpec> {
    vec![
        TypeSpec { gen: GpuGen::K80, spec: ServerSpec::default(), machines: 1 },
        TypeSpec { gen: GpuGen::P100, spec: ServerSpec::default(), machines: 1 },
        TypeSpec { gen: GpuGen::V100, spec: ServerSpec::default(), machines: 1 },
    ]
}

/// The full schedule as comparable bits: exact finish times per job,
/// round counts, and the per-round utilization trace (bit-patterns, so
/// "close" is not "equal").
fn schedule_bits(r: &SimResult) -> (Vec<(u64, u64)>, usize, u64, Vec<u64>) {
    let finished: Vec<(u64, u64)> =
        r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect();
    let util: Vec<u64> = r
        .utilization
        .samples
        .iter()
        .flat_map(|s| {
            [
                s.gpu_util.to_bits(),
                s.cpu_util.to_bits(),
                s.cpu_used.to_bits(),
                s.mem_util.to_bits(),
                s.queued_jobs as u64,
                s.running_jobs as u64,
            ]
        })
        .collect();
    (finished, r.rounds, r.makespan_s.to_bits(), util)
}

#[test]
fn memoized_and_forced_replan_schedules_are_bit_identical() {
    let (jobs, spec) = loaded_trace(28, 41);
    for policy in ["fifo", "srtf", "las"] {
        for with_quotas in [false, true] {
            for types in [None, Some(tritype())] {
                let fleet_tag = if types.is_some() { "tritype" } else { "homo" };
                let cfg = |force: bool| SimConfig {
                    n_servers: 2,
                    policy: policy.into(),
                    mechanism: "tune".into(),
                    types: types.clone(),
                    force_replan: force,
                    ..Default::default()
                };
                let quotas = with_quotas.then(|| spec.quotas());
                let memo = Simulator::with_quotas(cfg(false), quotas.clone())
                    .run(jobs.clone());
                let forced = Simulator::with_quotas(cfg(true), quotas)
                    .run(jobs.clone());
                assert_eq!(
                    schedule_bits(&memo),
                    schedule_bits(&forced),
                    "{policy}/quotas={with_quotas}/{fleet_tag}: memoized \
                     schedule must be bit-identical to forced replans"
                );
                assert!(
                    memo.planned_rounds <= forced.planned_rounds,
                    "{policy}/quotas={with_quotas}/{fleet_tag}: memoization \
                     may only remove mechanism runs ({} > {})",
                    memo.planned_rounds,
                    forced.planned_rounds
                );
            }
        }
    }
}

#[test]
fn memoization_engages_under_steady_load() {
    // A contended FIFO run holds a non-empty queue across many rounds
    // with an unchanged runnable sequence: exactly the rounds the
    // memoization exists for. It must (a) skip a strictly positive
    // number of mechanism runs relative to forced replanning and
    // (b) stay within the arrivals + completions + 1 planning bound
    // (FIFO keys are static, so the sequence only changes on events).
    let (jobs, _) = loaded_trace(32, 7);
    let n = jobs.len();
    let cfg = |force: bool| SimConfig {
        n_servers: 1,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        force_replan: force,
        ..Default::default()
    };
    let memo = Simulator::new(cfg(false)).run(jobs.clone());
    let forced = Simulator::new(cfg(true)).run(jobs);
    assert_eq!(memo.finished.len(), n);
    assert!(
        memo.planned_rounds < forced.planned_rounds,
        "steady-state rounds should be memoized: planned {} vs forced {}",
        memo.planned_rounds,
        forced.planned_rounds
    );
    assert!(
        memo.planned_rounds <= 2 * n + 1,
        "fifo planning bound violated: {} > {}",
        memo.planned_rounds,
        2 * n + 1
    );
}
