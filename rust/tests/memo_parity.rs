//! Planning-tier equivalence (ISSUE 4 tentpole proof, extended by
//! ISSUE 5's prefix resume): forced-replan ≡ memoized ≡ prefix-resumed,
//! bit for bit.
//!
//! The simulation core plans a round through three tiers:
//!
//! 1. **forced replan** (`SimConfig::force_replan`) — the mechanism runs
//!    on every non-fast-forwardable round from a hard fleet reset (the
//!    pre-memoization behaviour);
//! 2. **memoized** (`SimConfig::no_resume`) — the mechanism reruns only
//!    when the policy-ordered, admission-cut runnable sequence changed,
//!    always from a hard reset;
//! 3. **prefix-resumed** (the default) — a changed sequence additionally
//!    resumes the mechanism from the previous plan's checkpoint,
//!    rolling the per-pool fold back to the longest common prefix of
//!    the processing order and replaying only the divergent suffix
//!    (`mechanism/resume.rs`).
//!
//! Because the round plan is a pure function of the ordered runnable
//! sequence — and the per-pool fold state after a step prefix is a pure
//! function of that prefix — all three tiers must yield the
//! *bit-identical* schedule: same finish times, same round counts, same
//! utilization trace. The matrix below mirrors the golden scenario
//! matrix's axes (workload shape × quotas × fleet shape) across
//! time-stable (FIFO) and time-varying (SRTF/LAS) policies — the latter
//! shift the runnable sequence almost every round, which the
//! exact-match memoizer almost never catches but the resume tier does
//! (asserted: nonzero resumed rounds).

use synergy::cluster::{GpuGen, ServerSpec, TopologySpec, TypeSpec};
use synergy::job::Job;
use synergy::sim::{FaultSpec, SimConfig, SimResult, Simulator};
use synergy::trace::{Split, TraceConfig};
use synergy::workload::{SyntheticSource, TenantSpec, WorkloadSource};

/// A loaded multi-tenant synthetic trace: a non-empty queue through most
/// of the run, so memoized steady-state rounds actually occur.
fn loaded_trace(n: usize, seed: u64) -> (Vec<Job>, TenantSpec) {
    let spec = TenantSpec::parse("a:2,b:1").unwrap();
    let jobs = SyntheticSource::new(TraceConfig {
        n_jobs: n,
        split: Split::new(30, 50, 20),
        multi_gpu: false,
        jobs_per_hour: Some(10.0),
        seed,
    })
    .with_tenants(spec.clone())
    .drain_jobs();
    (jobs, spec)
}

fn tritype() -> Vec<TypeSpec> {
    vec![
        TypeSpec { gen: GpuGen::K80, spec: ServerSpec::default(), machines: 1 },
        TypeSpec { gen: GpuGen::P100, spec: ServerSpec::default(), machines: 1 },
        TypeSpec { gen: GpuGen::V100, spec: ServerSpec::default(), machines: 1 },
    ]
}

/// The full schedule as comparable bits: exact finish times per job,
/// round counts, and the per-round utilization trace (bit-patterns, so
/// "close" is not "equal").
fn schedule_bits(r: &SimResult) -> (Vec<(u64, u64)>, usize, u64, Vec<u64>) {
    let finished: Vec<(u64, u64)> =
        r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect();
    let util: Vec<u64> = r
        .utilization
        .samples
        .iter()
        .flat_map(|s| {
            [
                s.gpu_util.to_bits(),
                s.cpu_util.to_bits(),
                s.cpu_used.to_bits(),
                s.mem_util.to_bits(),
                s.queued_jobs as u64,
                s.running_jobs as u64,
            ]
        })
        .collect();
    (finished, r.rounds, r.makespan_s.to_bits(), util)
}

/// The three planning tiers of one scenario cell.
enum Tier {
    Forced,
    Memoized,
    Resumed,
}

#[test]
fn all_three_planning_tiers_are_bit_identical() {
    let (jobs, spec) = loaded_trace(28, 41);
    for policy in ["fifo", "srtf", "las"] {
        for with_quotas in [false, true] {
            for types in [None, Some(tritype())] {
                let fleet_tag = if types.is_some() { "tritype" } else { "homo" };
                let cfg = |tier: &Tier| SimConfig {
                    n_servers: 2,
                    policy: policy.into(),
                    mechanism: "tune".into(),
                    types: types.clone(),
                    force_replan: matches!(tier, Tier::Forced),
                    no_resume: matches!(tier, Tier::Memoized),
                    ..Default::default()
                };
                let run = |tier: Tier| {
                    Simulator::with_quotas(
                        cfg(&tier),
                        with_quotas.then(|| spec.quotas()),
                    )
                    .run(jobs.clone())
                };
                let forced = run(Tier::Forced);
                let memo = run(Tier::Memoized);
                let resumed = run(Tier::Resumed);
                let tag = format!("{policy}/quotas={with_quotas}/{fleet_tag}");
                assert_eq!(
                    schedule_bits(&memo),
                    schedule_bits(&forced),
                    "{tag}: memoized schedule must be bit-identical to \
                     forced replans"
                );
                assert_eq!(
                    schedule_bits(&resumed),
                    schedule_bits(&forced),
                    "{tag}: prefix-resumed schedule must be bit-identical \
                     to forced replans"
                );
                assert!(
                    memo.planned_rounds <= forced.planned_rounds,
                    "{tag}: memoization may only remove mechanism runs \
                     ({} > {})",
                    memo.planned_rounds,
                    forced.planned_rounds
                );
                // The resume tier changes *how* a replan runs, never
                // *whether* it runs: identical planned-round counts, and
                // only the resumed arm reports resumed rounds.
                assert_eq!(
                    resumed.planned_rounds, memo.planned_rounds,
                    "{tag}: resume must not change the replan set"
                );
                assert_eq!(forced.resumed_rounds, 0, "{tag}");
                assert_eq!(memo.resumed_rounds, 0, "{tag}");
                assert!(
                    resumed.resumed_rounds <= resumed.planned_rounds,
                    "{tag}"
                );
                assert!(
                    resumed.plan_steps_reused <= resumed.plan_steps_total,
                    "{tag}"
                );
                if policy != "fifo" {
                    // Time-varying policies shift the sequence without
                    // arrival/completion events — exactly the rounds the
                    // exact-match memoizer misses and resume catches.
                    assert!(
                        resumed.resumed_rounds > 0,
                        "{tag}: SRTF/LAS cells must resume at least once \
                         (planned {} rounds, reused {}/{} steps)",
                        resumed.planned_rounds,
                        resumed.plan_steps_reused,
                        resumed.plan_steps_total,
                    );
                }
            }
        }
    }
}

#[test]
fn planning_tiers_stay_bit_identical_under_racked_topology() {
    // ISSUE 7 cell: the rack-aware candidate order and per-gang link
    // cost are pure functions of the (topology-carrying) fleet state, so
    // the three planning tiers must stay bit-identical with racks >= 2 —
    // including the gang counters, which memoized and fast-forwarded
    // rounds carry from the last planned round.
    let spec = TenantSpec::parse("a:2,b:1").unwrap();
    let jobs = SyntheticSource::new(TraceConfig {
        n_jobs: 30,
        split: Split::new(30, 50, 20),
        multi_gpu: true, // gangs, so racks can actually matter
        jobs_per_hour: Some(8.0),
        seed: 11,
    })
    .with_tenants(spec.clone())
    .drain_jobs();
    for policy in ["fifo", "srtf"] {
        let cfg = |tier: &Tier| SimConfig {
            n_servers: 4,
            policy: policy.into(),
            mechanism: "tune".into(),
            topology: TopologySpec::racks(2),
            force_replan: matches!(tier, Tier::Forced),
            no_resume: matches!(tier, Tier::Memoized),
            ..Default::default()
        };
        let run = |tier: Tier| {
            Simulator::with_quotas(cfg(&tier), Some(spec.quotas()))
                .run(jobs.clone())
        };
        let forced = run(Tier::Forced);
        let memo = run(Tier::Memoized);
        let resumed = run(Tier::Resumed);
        assert_eq!(
            schedule_bits(&memo),
            schedule_bits(&forced),
            "{policy}/racks2: memoized schedule diverges"
        );
        assert_eq!(
            schedule_bits(&resumed),
            schedule_bits(&forced),
            "{policy}/racks2: resumed schedule diverges"
        );
        for (tag, r) in [("memo", &memo), ("resumed", &resumed)] {
            assert_eq!(
                (r.gangs_placed, r.cross_rack_gangs),
                (forced.gangs_placed, forced.cross_rack_gangs),
                "{policy}/racks2/{tag}: gang counters diverge from forced"
            );
        }
        assert_eq!(forced.finished.len(), jobs.len(), "{policy}/racks2");
    }
}

#[test]
fn planning_tiers_stay_bit_identical_under_sharding() {
    // ISSUE 8 tentpole proof: the sharded planner fans the per-pool
    // placement folds out over worker threads, but each pool's fold is
    // a pure function of (ordered sequence, pool state) and results
    // merge in fixed pool order — so every tier × shard-count
    // combination must reproduce the serial forced schedule bit for
    // bit, with the memo/resume counters unchanged too.
    let (jobs, spec) = loaded_trace(28, 41);
    for policy in ["fifo", "srtf"] {
        let cfg = |tier: &Tier, shards: usize| SimConfig {
            n_servers: 2,
            policy: policy.into(),
            mechanism: "tune".into(),
            types: Some(tritype()),
            shards,
            force_replan: matches!(tier, Tier::Forced),
            no_resume: matches!(tier, Tier::Memoized),
            ..Default::default()
        };
        let run = |tier: Tier, shards: usize| {
            Simulator::with_quotas(cfg(&tier, shards), Some(spec.quotas()))
                .run(jobs.clone())
        };
        let serial = run(Tier::Resumed, 1);
        let serial_forced = run(Tier::Forced, 1);
        assert_eq!(
            schedule_bits(&serial),
            schedule_bits(&serial_forced),
            "{policy}: serial baseline tiers diverge"
        );
        for shards in [2, 4] {
            for (tag, tier) in [
                ("forced", Tier::Forced),
                ("memoized", Tier::Memoized),
                ("resumed", Tier::Resumed),
            ] {
                let sharded = run(tier, shards);
                assert_eq!(
                    schedule_bits(&sharded),
                    schedule_bits(&serial_forced),
                    "{policy}/shards={shards}/{tag}: sharded schedule \
                     must be bit-identical to the serial forced baseline"
                );
                if tag == "resumed" {
                    assert_eq!(
                        (
                            sharded.planned_rounds,
                            sharded.resumed_rounds,
                            sharded.plan_steps_total,
                            sharded.plan_steps_reused,
                        ),
                        (
                            serial.planned_rounds,
                            serial.resumed_rounds,
                            serial.plan_steps_total,
                            serial.plan_steps_reused,
                        ),
                        "{policy}/shards={shards}: memo/resume counters \
                         must not depend on the fan-out width"
                    );
                }
            }
        }
    }
}

#[test]
fn planning_tiers_stay_bit_identical_under_host_churn() {
    // ISSUE 9 cell: a host failure preempts running jobs back into the
    // queue, bumps the fleet epoch (invalidating the memo), and drops
    // the resume checkpoint; a restore grows the fleet again. All of
    // that happens *between* rounds, so each tier still sees the same
    // runnable sequence over the same surviving fleet — the three tiers
    // must stay bit-identical, churn counters included.
    let (jobs, spec) = loaded_trace(28, 41);
    for policy in ["fifo", "srtf"] {
        for types in [None, Some(tritype())] {
            let fleet_tag = if types.is_some() { "tritype" } else { "homo" };
            let cfg = |tier: &Tier| SimConfig {
                n_servers: 2,
                policy: policy.into(),
                mechanism: "tune".into(),
                types: types.clone(),
                faults: Some(
                    FaultSpec::parse("mtbf:8,mttr:2,seed:13").unwrap(),
                ),
                force_replan: matches!(tier, Tier::Forced),
                no_resume: matches!(tier, Tier::Memoized),
                ..Default::default()
            };
            let run = |tier: Tier| {
                Simulator::with_quotas(cfg(&tier), Some(spec.quotas()))
                    .run(jobs.clone())
            };
            let forced = run(Tier::Forced);
            let memo = run(Tier::Memoized);
            let resumed = run(Tier::Resumed);
            let tag = format!("{policy}/{fleet_tag}/churn");
            assert_eq!(
                forced.finished.len(),
                jobs.len(),
                "{tag}: no job may be lost to churn"
            );
            assert!(
                forced.servers_failed > 0,
                "{tag}: the fault generator must actually fire"
            );
            assert_eq!(
                schedule_bits(&memo),
                schedule_bits(&forced),
                "{tag}: memoized schedule diverges under churn"
            );
            assert_eq!(
                schedule_bits(&resumed),
                schedule_bits(&forced),
                "{tag}: resumed schedule diverges under churn"
            );
            for (arm, r) in [("memo", &memo), ("resumed", &resumed)] {
                assert_eq!(
                    (
                        r.preemptions,
                        r.preempted_gpu_rounds_lost,
                        r.servers_failed,
                        r.servers_restored,
                    ),
                    (
                        forced.preemptions,
                        forced.preempted_gpu_rounds_lost,
                        forced.servers_failed,
                        forced.servers_restored,
                    ),
                    "{tag}/{arm}: churn counters diverge from forced"
                );
            }
        }
    }
}

#[test]
fn memoization_engages_under_steady_load() {
    // A contended FIFO run holds a non-empty queue across many rounds
    // with an unchanged runnable sequence: exactly the rounds the
    // memoization exists for. It must (a) skip a strictly positive
    // number of mechanism runs relative to forced replanning and
    // (b) stay within the arrivals + completions + 1 planning bound
    // (FIFO keys are static, so the sequence only changes on events).
    let (jobs, _) = loaded_trace(32, 7);
    let n = jobs.len();
    let cfg = |force: bool| SimConfig {
        n_servers: 1,
        policy: "fifo".into(),
        mechanism: "tune".into(),
        force_replan: force,
        ..Default::default()
    };
    let memo = Simulator::new(cfg(false)).run(jobs.clone());
    let forced = Simulator::new(cfg(true)).run(jobs);
    assert_eq!(memo.finished.len(), n);
    assert!(
        memo.planned_rounds < forced.planned_rounds,
        "steady-state rounds should be memoized: planned {} vs forced {}",
        memo.planned_rounds,
        forced.planned_rounds
    );
    assert!(
        memo.planned_rounds <= 2 * n + 1,
        "fifo planning bound violated: {} > {}",
        memo.planned_rounds,
        2 * n + 1
    );
}

#[test]
fn resume_works_across_mechanisms_and_reports_reuse() {
    // Every pool-decomposable mechanism must satisfy the three-tier
    // parity (OPT keeps the non-resumable default: still bit-identical,
    // never resumed). SRTF keeps the sequence shifting so checkpoints
    // actually get consulted.
    let (jobs, _) = loaded_trace(20, 23);
    for mechanism in ["proportional", "greedy", "fixed", "tune", "opt"] {
        let cfg = |force: bool, no_resume: bool| SimConfig {
            n_servers: 2,
            policy: "srtf".into(),
            mechanism: mechanism.into(),
            force_replan: force,
            no_resume,
            ..Default::default()
        };
        let forced = Simulator::new(cfg(true, false)).run(jobs.clone());
        let resumed = Simulator::new(cfg(false, false)).run(jobs.clone());
        assert_eq!(
            schedule_bits(&resumed),
            schedule_bits(&forced),
            "{mechanism}: resumed tier must match forced replans"
        );
        if mechanism == "opt" {
            assert_eq!(
                resumed.resumed_rounds, 0,
                "opt is non-resumable by design"
            );
        } else {
            assert!(
                resumed.resumed_rounds > 0,
                "{mechanism}: SRTF churn must hit the resume tier \
                 ({} planned rounds)",
                resumed.planned_rounds
            );
        }
    }
}
