//! Deterministic scenario/property harness over the unified simulation
//! core (ISSUE 2 acceptance, extended by ISSUE 3's one-resource-model
//! unification):
//!
//! - a fixed-seed scenario matrix — {synthetic, philly_small.csv,
//!   alibaba_small.csv} × {quotas off, on} × {homogeneous, two-type
//!   P100+V100, tri-type V100+P100+K80} — asserting repeated runs
//!   produce *identical* metrics JSON, checked against golden files
//!   under `tests/golden/`;
//! - cross-entry-point determinism: a single-type V100 fleet driven
//!   through the hetero front-end reproduces the homogeneous front-end's
//!   schedule bit-for-bit (both are fleet descriptions handed to the
//!   same engine).
//!
//! Golden files bootstrap themselves: a missing golden is written on
//! first run (and should be committed); set `UPDATE_GOLDENS=1` to
//! regenerate after an intentional behaviour change. See
//! `tests/golden/README.md` for how to add a scenario.

use synergy::cluster::TopologySpec;
use synergy::hetero::{GpuGen, HeteroSimConfig, HeteroSimulator, TypeSpec};
use synergy::job::{Job, TenantId};
use synergy::metrics::metrics_json;
use synergy::sim::{SimConfig, Simulator};
use synergy::trace::{Split, TraceConfig};
use synergy::workload::{
    AlibabaTraceConfig, AlibabaTraceSource, GoogleTraceConfig,
    GoogleTraceSource, PhillyTraceConfig, PhillyTraceSource,
    SyntheticSource, TenantQuotas, TenantSpec, WorkloadSource,
};

fn fixture(name: &str) -> String {
    format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"))
}

/// Fleet shape of one scenario cell.
#[derive(Clone, Copy)]
enum FleetShape {
    /// 4 V100 servers through the homogeneous front-end.
    Homo,
    /// 2 P100 + 2 V100 servers (the A.2 evaluation split).
    TwoTier,
    /// 2 V100 + 1 P100 + 1 K80 servers (mixed-generation fleet cell).
    TriType,
}

/// One cell of the scenario matrix.
struct Scenario {
    name: &'static str,
    jobs: Vec<Job>,
    quotas: Option<TenantQuotas>,
    fleet: FleetShape,
}

/// The workload third of the matrix: (tag, jobs, quotas-when-on).
fn workloads() -> Vec<(&'static str, Vec<Job>, TenantQuotas)> {
    let synthetic = {
        let spec = TenantSpec::parse("a:2,b:1").unwrap();
        let jobs = SyntheticSource::new(TraceConfig {
            n_jobs: 24,
            split: Split::new(30, 50, 20),
            multi_gpu: false,
            jobs_per_hour: Some(6.0),
            seed: 42,
        })
        .with_tenants(spec.clone())
        .drain_jobs();
        ("synthetic", jobs, spec.quotas())
    };
    let philly = {
        let mut src = PhillyTraceSource::new(PhillyTraceConfig {
            path: fixture("philly_small.csv"),
            ..PhillyTraceConfig::default()
        })
        .unwrap();
        let names = src.tenant_names();
        let quotas =
            TenantSpec::parse("a:2,b:1").unwrap().quotas_for(&names);
        ("philly_small", src.drain_jobs(), quotas)
    };
    let alibaba = {
        let mut src = AlibabaTraceSource::new(AlibabaTraceConfig {
            path: fixture("alibaba_small.csv"),
            ..AlibabaTraceConfig::default()
        })
        .unwrap();
        let names = src.tenant_names();
        let quotas =
            TenantSpec::parse("m_1:3").unwrap().quotas_for(&names);
        ("alibaba_small", src.drain_jobs(), quotas)
    };
    vec![synthetic, philly, alibaba]
}

/// The full 3 × 2 × 3 matrix.
fn matrix() -> Vec<Scenario> {
    // Static names so goldens stay stable: <workload>_<quotas>_<fleet>.
    // ("hetero" keeps its pre-unification name for golden continuity;
    // "tritype" cells pin the mixed V100+P100+K80 fleet.)
    const NAMES: [[[&str; 3]; 2]; 3] = [
        [
            [
                "synthetic_plain_homo",
                "synthetic_plain_hetero",
                "synthetic_plain_tritype",
            ],
            [
                "synthetic_quotas_homo",
                "synthetic_quotas_hetero",
                "synthetic_quotas_tritype",
            ],
        ],
        [
            [
                "philly_small_plain_homo",
                "philly_small_plain_hetero",
                "philly_small_plain_tritype",
            ],
            [
                "philly_small_quotas_homo",
                "philly_small_quotas_hetero",
                "philly_small_quotas_tritype",
            ],
        ],
        [
            [
                "alibaba_small_plain_homo",
                "alibaba_small_plain_hetero",
                "alibaba_small_plain_tritype",
            ],
            [
                "alibaba_small_quotas_homo",
                "alibaba_small_quotas_hetero",
                "alibaba_small_quotas_tritype",
            ],
        ],
    ];
    const SHAPES: [FleetShape; 3] =
        [FleetShape::Homo, FleetShape::TwoTier, FleetShape::TriType];
    let mut out = Vec::new();
    for (wi, (_, jobs, quotas)) in workloads().into_iter().enumerate() {
        for (qi, q) in [None, Some(quotas)].into_iter().enumerate() {
            for (fi, fleet) in SHAPES.into_iter().enumerate() {
                out.push(Scenario {
                    name: NAMES[wi][qi][fi],
                    jobs: jobs.clone(),
                    quotas: q.clone(),
                    fleet,
                });
            }
        }
    }
    out
}

fn run_scenario(s: &Scenario) -> String {
    let mixed = |types: Vec<TypeSpec>| {
        let sim = HeteroSimulator::with_quotas(
            HeteroSimConfig {
                types,
                policy: "srtf".into(),
                mechanism: "het-tune".into(),
                ..Default::default()
            },
            s.quotas.clone(),
        );
        let r = sim.run(s.jobs.clone());
        metrics_json(&r.jct_stats(), &r.tenant_stats(), r.makespan_s, r.rounds, None, None)
    };
    match s.fleet {
        FleetShape::Homo => {
            let sim = Simulator::with_quotas(
                SimConfig {
                    n_servers: 4,
                    policy: "srtf".into(),
                    mechanism: "tune".into(),
                    ..Default::default()
                },
                s.quotas.clone(),
            );
            let r = sim.run(s.jobs.clone());
            r.metrics_json(false, false)
        }
        FleetShape::TwoTier => mixed(vec![
            TypeSpec {
                gen: GpuGen::P100,
                spec: Default::default(),
                machines: 2,
            },
            TypeSpec {
                gen: GpuGen::V100,
                spec: Default::default(),
                machines: 2,
            },
        ]),
        FleetShape::TriType => mixed(vec![
            TypeSpec {
                gen: GpuGen::K80,
                spec: Default::default(),
                machines: 1,
            },
            TypeSpec {
                gen: GpuGen::P100,
                spec: Default::default(),
                machines: 1,
            },
            TypeSpec {
                gen: GpuGen::V100,
                spec: Default::default(),
                machines: 2,
            },
        ]),
    }
}

// The metrics document itself is the shared canonical serializer
// (`synergy::metrics::metrics_json`, plan stats off): one definition of
// the golden payload for every front-end, and the plan-stats flag is
// proven off here — goldens pin the default shape byte-for-byte.

/// Compare `payload` against the checked-in golden, bootstrapping the
/// file when absent (first toolchain run) or when `UPDATE_GOLDENS` is
/// set.
fn check_golden(name: &str, payload: &str) {
    let dir = format!("{}/tests/golden", env!("CARGO_MANIFEST_DIR"));
    let path = format!("{dir}/{name}.json");
    let update = std::env::var("UPDATE_GOLDENS").is_ok();
    if update || !std::path::Path::new(&path).exists() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, format!("{payload}\n")).unwrap();
        eprintln!("golden: wrote {path}");
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        want.trim(),
        payload,
        "golden mismatch for '{name}' — if the schedule change is \
         intentional, rerun with UPDATE_GOLDENS=1 and commit the diff"
    );
}

#[test]
fn scenario_matrix_is_deterministic_and_matches_goldens() {
    for s in matrix() {
        let a = run_scenario(&s);
        let b = run_scenario(&s);
        assert_eq!(a, b, "scenario '{}' not deterministic across runs", s.name);
        check_golden(s.name, &a);
    }
}

// ---------------------------------------------------------------------------
// ISSUE 7 topology cells — NEW golden names; the 18 cells above are
// untouched and must stay byte-identical (flat topology is the default).
// ---------------------------------------------------------------------------

/// A gang-heavy synthetic trace (multi-GPU demands) so racks can matter.
fn gang_jobs() -> Vec<Job> {
    SyntheticSource::new(TraceConfig {
        n_jobs: 24,
        split: Split::new(30, 50, 20),
        multi_gpu: true,
        jobs_per_hour: Some(6.0),
        seed: 42,
    })
    .with_tenants(TenantSpec::parse("a:2,b:1").unwrap())
    .drain_jobs()
}

fn run_topology_cell(topology: TopologySpec) -> String {
    let sim = Simulator::new(SimConfig {
        n_servers: 4,
        policy: "srtf".into(),
        mechanism: "tune".into(),
        topology,
        ..Default::default()
    });
    let r = sim.run(gang_jobs());
    r.metrics_json(false, false)
}

#[test]
fn topology_cells_are_deterministic_and_match_goldens() {
    let cells = [
        ("synthetic_gang_flat_homo", TopologySpec::flat()),
        ("synthetic_gang_racks2_homo", TopologySpec::racks(2)),
        (
            "synthetic_gang_racks2_blind_homo",
            TopologySpec {
                placement_aware: false,
                ..TopologySpec::racks(2)
            },
        ),
    ];
    for (name, topo) in cells {
        let a = run_topology_cell(topo);
        let b = run_topology_cell(topo);
        assert_eq!(a, b, "topology cell '{name}' not deterministic");
        check_golden(name, &a);
    }
}

// ---------------------------------------------------------------------------
// ISSUE 8 Google-trace cell — NEW golden name; the matrix cells above
// stay byte-identical (the google reader touches no shared RNG state).
// ---------------------------------------------------------------------------

#[test]
fn google_cell_is_deterministic_and_matches_golden() {
    // Same recipe as the matrix's plain/homo cells (4 V100 servers,
    // srtf/tune), fed from the `google_small` fixture directory through
    // the streaming 2019 Google cluster-data reader.
    let run = || {
        let mut src = GoogleTraceSource::new(GoogleTraceConfig {
            path: fixture("google_small"),
            ..GoogleTraceConfig::default()
        })
        .unwrap();
        let jobs = src.drain_jobs();
        assert_eq!(jobs.len(), 8, "google_small emits 8 schedulable jobs");
        let sim = Simulator::new(SimConfig {
            n_servers: 4,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            ..Default::default()
        });
        sim.run(jobs).metrics_json(false, false)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "google cell not deterministic across runs");
    check_golden("google_plain_homo", &a);
}

#[test]
fn flat_topology_cell_matches_default_byte_for_byte() {
    // `--topology flat` (and racks:1 generally) must be a pure no-op:
    // the metrics JSON — the golden payload itself — is byte-identical
    // to a config that never mentions topology.
    let default_run = {
        let sim = Simulator::new(SimConfig {
            n_servers: 4,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            ..Default::default()
        });
        sim.run(gang_jobs()).metrics_json(false, false)
    };
    assert_eq!(
        run_topology_cell(TopologySpec::flat()),
        default_run,
        "explicit flat topology must not perturb a single byte"
    );
}

// ---------------------------------------------------------------------------
// ISSUE 9 fault-injection cells — NEW golden names; every cell above is
// untouched: a `None` fault spec never enters the churn code path, so
// fault-free runs stay byte-identical to pre-fault builds.
// ---------------------------------------------------------------------------

fn fault_spec(s: &str) -> synergy::sim::FaultSpec {
    synergy::sim::FaultSpec::parse(s).unwrap()
}

/// Homogeneous gang cell under seeded churn; fault payloads pin the
/// churn counters too (`fault_stats` on), so a regression in preemption
/// accounting moves the golden even when the schedule itself survives.
fn run_fault_cell_homo(topology: TopologySpec, spec: &str) -> String {
    let sim = Simulator::new(SimConfig {
        n_servers: 4,
        policy: "srtf".into(),
        mechanism: "tune".into(),
        topology,
        faults: Some(fault_spec(spec)),
        ..Default::default()
    });
    let r = sim.run(gang_jobs());
    assert_eq!(r.finished.len(), 24, "no job may be lost to churn");
    r.metrics_json(false, true)
}

fn run_fault_cell_tritype(spec: &str) -> String {
    let sim = HeteroSimulator::new(HeteroSimConfig {
        types: vec![
            TypeSpec {
                gen: GpuGen::K80,
                spec: Default::default(),
                machines: 1,
            },
            TypeSpec {
                gen: GpuGen::P100,
                spec: Default::default(),
                machines: 1,
            },
            TypeSpec {
                gen: GpuGen::V100,
                spec: Default::default(),
                machines: 2,
            },
        ],
        policy: "srtf".into(),
        mechanism: "het-tune".into(),
        faults: Some(fault_spec(spec)),
        ..Default::default()
    });
    let r = sim.run(gang_jobs());
    assert_eq!(r.finished.len(), 24, "no job may be lost to churn");
    r.metrics_json(false, true)
}

#[test]
fn fault_cells_are_deterministic_and_match_goldens() {
    let homo_flat =
        || run_fault_cell_homo(TopologySpec::flat(), "mtbf:12,mttr:2,seed:9");
    let homo_racked = || {
        run_fault_cell_homo(TopologySpec::racks(2), "mtbf:12,mttr:2,seed:9")
    };
    let tritype = || run_fault_cell_tritype("mtbf:8,mttr:3,seed:4");
    for (name, a, b) in [
        ("synthetic_faults_homo", homo_flat(), homo_flat()),
        ("synthetic_faults_racks2_homo", homo_racked(), homo_racked()),
        ("synthetic_faults_tritype", tritype(), tritype()),
    ] {
        assert_eq!(a, b, "fault cell '{name}' not deterministic");
        check_golden(name, &a);
    }
}

#[test]
fn hetero_single_v100_type_matches_homogeneous_engine_bitwise() {
    // The strongest unification statement: on a heterogeneous "cluster"
    // of one V100 type (compute scale 1.0 — the calibration basis), the
    // heterogeneous engine must reproduce the homogeneous engine's
    // schedule *bit for bit*: same core loop, same admission, same
    // policy keys, same ground truth.
    let spec = TenantSpec::parse("a:2,b:1").unwrap();
    let jobs = SyntheticSource::new(TraceConfig {
        n_jobs: 32,
        split: Split::new(30, 50, 20),
        multi_gpu: false,
        jobs_per_hour: Some(8.0),
        seed: 7,
    })
    .with_tenants(spec.clone())
    .drain_jobs();

    for (policy, with_quotas) in
        [("fifo", false), ("srtf", false), ("srtf", true)]
    {
        let quotas = with_quotas.then(|| spec.quotas());
        let homo = Simulator::with_quotas(
            SimConfig {
                n_servers: 2,
                policy: policy.into(),
                mechanism: "tune".into(),
                ..Default::default()
            },
            quotas.clone(),
        )
        .run(jobs.clone());
        let het = HeteroSimulator::with_quotas(
            HeteroSimConfig {
                types: vec![TypeSpec {
                    gen: GpuGen::V100,
                    spec: Default::default(),
                    machines: 2,
                }],
                policy: policy.into(),
                mechanism: "het-tune".into(),
                ..Default::default()
            },
            quotas,
        )
        .run(jobs.clone());

        assert_eq!(
            homo.rounds, het.rounds,
            "{policy}/quotas={with_quotas}: round counts diverge"
        );
        let homo_bits: Vec<(u64, u64)> = homo
            .finished
            .iter()
            .map(|f| (f.id.0, f.jct_s.to_bits()))
            .collect();
        let het_bits: Vec<(u64, u64)> = het
            .finished
            .iter()
            .map(|f| (f.id.0, f.jct_s.to_bits()))
            .collect();
        assert_eq!(
            homo_bits, het_bits,
            "{policy}/quotas={with_quotas}: single-V100 hetero must equal \
             the homogeneous schedule bit-for-bit"
        );
    }
}

#[test]
fn quota_toggle_changes_hetero_schedule_only_under_contention() {
    // Sanity on the matrix's quota dimension: with one tenant absent the
    // spill pass makes quotas a no-op (work conservation), while a
    // contended two-tenant queue must actually be reshaped.
    let jobs_single: Vec<Job> = SyntheticSource::new(TraceConfig {
        n_jobs: 20,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 11,
    })
    .drain_jobs();
    let quotas = TenantSpec::parse("a:1,b:1").unwrap().quotas();
    let cfg = || HeteroSimConfig {
        types: vec![
            TypeSpec {
                gen: GpuGen::P100,
                spec: Default::default(),
                machines: 1,
            },
            TypeSpec {
                gen: GpuGen::V100,
                spec: Default::default(),
                machines: 1,
            },
        ],
        policy: "fifo".into(),
        mechanism: "het-tune".into(),
        ..Default::default()
    };
    let plain = HeteroSimulator::new(cfg()).run(jobs_single.clone());
    let quoted = HeteroSimulator::with_quotas(cfg(), Some(quotas.clone()))
        .run(jobs_single);
    assert_eq!(
        plain.jcts, quoted.jcts,
        "idle-tenant quotas must be work-conserving on hetero too"
    );

    // Contended: interleave two tenants; quotas must change someone's JCT.
    let jobs_two: Vec<Job> = SyntheticSource::new(TraceConfig {
        n_jobs: 40,
        split: Split::new(0, 100, 0),
        multi_gpu: false,
        jobs_per_hour: None,
        seed: 11,
    })
    .drain_jobs()
    .into_iter()
    .enumerate()
    .map(|(i, j)| j.with_tenant(TenantId(if i < 20 { 0 } else { 1 })))
    .collect();
    let plain = HeteroSimulator::new(cfg()).run(jobs_two.clone());
    let quoted =
        HeteroSimulator::with_quotas(cfg(), Some(quotas)).run(jobs_two);
    assert_ne!(
        plain.jcts, quoted.jcts,
        "contended quotas must reshape the hetero schedule"
    );
}
