//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The native XLA/PJRT shared library is not present in this build
//! environment, so this crate provides the exact API surface
//! `synergy::runtime` compiles against, with every entry point returning a
//! runtime "unavailable" error. [`PjRtClient::cpu`] fails first, which the
//! deploy worker already handles by degrading to its progress-only path
//! (the same fallback used on machines without built artifacts), so the
//! full scheduler — simulator, deploy control plane, benches — runs
//! unchanged. Linking the real bindings back in is a Cargo.toml swap; no
//! source changes are required.

use std::fmt;

/// Error type mirroring xla_extension's error enum (stringly here).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "xla runtime unavailable: built against the offline stub \
         (no native xla_extension library in this environment)"
            .to_string(),
    )
}

/// PJRT client handle (stub: construction always fails).
#[derive(Clone, Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with device-resident buffers; returns per-replica outputs.
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host-side literal value.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation built from an HLO proto.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
