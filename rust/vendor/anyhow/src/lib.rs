//! Offline shim for the `anyhow` crate (crates.io is unavailable in this
//! environment). Implements the subset of the API this workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`] macro, and the [`Context`]
//! extension trait. Semantics match upstream for that subset: any
//! `std::error::Error` converts into [`Error`] via `?`, and context is
//! prepended to the display message.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// The root cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            self.source.as_ref().map(|b| b.as_ref() as _);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// Like upstream anyhow: every std error converts via `?`. Coherent with
// `From<T> for T` because `Error` itself does not implement `StdError`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Context-prepending extension for `Result`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error {
            msg: format!("{context}: {e}"),
            source: Some(Box::new(e)),
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
            source: Some(Box::new(e)),
        })
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error {
            msg: format!("{context}: {}", e.msg),
            source: e.source,
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error {
            msg: format!("{}: {}", f(), e.msg),
            source: e.source,
        })
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("got {n} of {}", 7);
        assert_eq!(b.to_string(), "got 3 of 7");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(e2.to_string(), "step 2: inner");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
    }
}
