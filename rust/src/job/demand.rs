//! Job demand vectors (paper §3.2).
//!
//! A demand vector is (fixed GPU demand, best-case CPU, best-case memory);
//! CPU and memory are *fungible* — the mechanism may grant anything between
//! the GPU-proportional floor and this best-case value (or above it, if
//! spare resources exist).

/// Multi-dimensional resource demand for one job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandVector {
    /// Fixed GPU demand (user-specified, never altered — §3 "Note").
    pub gpus: u32,
    /// Best-case CPU cores (from the sensitivity matrix, §3.2).
    pub cpus: f64,
    /// Best-case memory in GB.
    pub mem_gb: f64,
}

impl DemandVector {
    pub fn new(gpus: u32, cpus: f64, mem_gb: f64) -> DemandVector {
        assert!(gpus > 0, "job must demand at least one GPU");
        assert!(cpus > 0.0 && mem_gb > 0.0);
        DemandVector { gpus, cpus, mem_gb }
    }

    /// The GPU-proportional demand for the same GPU count.
    pub fn proportional(gpus: u32, cpus_per_gpu: f64, mem_per_gpu: f64)
        -> DemandVector
    {
        DemandVector::new(
            gpus,
            cpus_per_gpu * gpus as f64,
            mem_per_gpu * gpus as f64,
        )
    }

    /// Whether this demand exceeds the proportional demand in any fungible
    /// dimension (used by Synergy-TUNE's downgrade step, §4.2).
    pub fn exceeds(&self, proportional: &DemandVector) -> bool {
        self.cpus > proportional.cpus + 1e-9
            || self.mem_gb > proportional.mem_gb + 1e-9
    }

    /// Sort key for Synergy-TUNE: jobs sorted by GPU, then CPU, then memory
    /// demand, descending (§4.2).
    pub fn sort_key(&self) -> (u32, u64, u64) {
        (self.gpus, (self.cpus * 1e6) as u64, (self.mem_gb * 1e6) as u64)
    }

    /// Element-wise minimum of the fungible dimensions (GPUs unchanged).
    /// Used for downgrades: a job is never pushed *up* to proportional in
    /// a dimension where it asked for less.
    pub fn clamp_to(&self, cap: &DemandVector) -> DemandVector {
        DemandVector::new(
            self.gpus,
            self.cpus.min(cap.cpus),
            self.mem_gb.min(cap.mem_gb),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proportional_demand() {
        let d = DemandVector::proportional(4, 3.0, 62.5);
        assert_eq!(d.gpus, 4);
        assert_eq!(d.cpus, 12.0);
        assert_eq!(d.mem_gb, 250.0);
    }

    #[test]
    fn exceeds_detects_any_dimension() {
        let prop = DemandVector::new(1, 3.0, 62.5);
        assert!(DemandVector::new(1, 4.0, 62.5).exceeds(&prop));
        assert!(DemandVector::new(1, 3.0, 100.0).exceeds(&prop));
        assert!(!DemandVector::new(1, 3.0, 62.5).exceeds(&prop));
        assert!(!DemandVector::new(1, 1.0, 20.0).exceeds(&prop));
    }

    #[test]
    fn sort_key_orders_by_gpu_first() {
        let big = DemandVector::new(8, 1.0, 1.0);
        let small = DemandVector::new(1, 24.0, 500.0);
        assert!(big.sort_key() > small.sort_key());
    }

    #[test]
    #[should_panic]
    fn zero_gpus_rejected() {
        DemandVector::new(0, 1.0, 1.0);
    }
}
