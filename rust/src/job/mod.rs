//! Jobs, demand vectors, and the DNN model zoo (paper Table 4).

mod demand;
mod zoo;

pub use demand::DemandVector;
pub use zoo::{ModelKind, PerfCoeffs, Task, ALL_MODELS};

/// Opaque job identifier (dense, assigned by the trace generator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

/// Tenant (virtual cluster / team) identifier. Tenant ids are dense and
/// assigned by the workload source ([`crate::workload`]); single-tenant
/// workloads put every job in [`TenantId::DEFAULT`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

impl TenantId {
    /// The implicit tenant of jobs created without an explicit tenant.
    pub const DEFAULT: TenantId = TenantId(0);
}

/// Lifecycle of a job inside the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Arrived, profiled, waiting in the scheduling queue.
    Queued,
    /// Holding resources in the current round.
    Running,
    /// All work complete.
    Finished,
}

/// A DNN training job as the scheduler sees it.
///
/// GPU demand is fixed for the job's lifetime (user-provided, §2.3); CPU
/// and memory are fungible and re-decided every round. `total_samples` is
/// derived from the trace's duration under GPU-proportional allocation
/// (paper §5.1: "the duration of each job for the baseline GPU-proportional
/// allocation is sampled ...").
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    /// Owning tenant (weighted-quota admission keys on this).
    pub tenant: TenantId,
    pub model: ModelKind,
    pub gpus: u32,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Duration under GPU-proportional allocation, seconds.
    pub duration_prop_s: f64,
    /// Total training work, in samples (set once from duration_prop_s).
    pub total_samples: f64,
    /// Work completed so far, in samples.
    pub progress_samples: f64,
    pub state: JobState,
    /// Completion time (valid when state == Finished).
    pub finish_s: f64,
    /// Total time spent actually running (for LAS).
    pub attained_service_s: f64,
    /// Throughput (samples/s) under the current round's grant; 0 when
    /// queued. Set by the simulator/deployer at deploy time.
    pub progress_rate: f64,
    /// Per-job deterministic RNG stream id (profiling noise).
    pub rng_stream: u64,
}

impl Job {
    pub fn new(
        id: JobId,
        model: ModelKind,
        gpus: u32,
        arrival_s: f64,
        duration_prop_s: f64,
    ) -> Job {
        Job {
            id,
            tenant: TenantId::DEFAULT,
            model,
            gpus,
            arrival_s,
            duration_prop_s,
            total_samples: 0.0, // filled by the simulator once specs are known
            progress_samples: 0.0,
            state: JobState::Queued,
            finish_s: f64::NAN,
            attained_service_s: 0.0,
            progress_rate: 0.0,
            rng_stream: id.0,
        }
    }

    /// Assign the job to a tenant (builder style; default is tenant 0).
    pub fn with_tenant(mut self, tenant: TenantId) -> Job {
        self.tenant = tenant;
        self
    }

    /// Remaining work in samples.
    pub fn remaining_samples(&self) -> f64 {
        (self.total_samples - self.progress_samples).max(0.0)
    }

    pub fn is_finished(&self) -> bool {
        self.state == JobState::Finished
    }

    /// Job completion time (finish - arrival), seconds.
    pub fn jct_s(&self) -> f64 {
        assert!(self.is_finished(), "JCT of unfinished job {:?}", self.id);
        self.finish_s - self.arrival_s
    }
}

/// Dense job arena for the simulation hot path: the trace's jobs stored
/// once (arrival order = arena index), with the *active* set — arrived,
/// unfinished jobs — as a list of arena indices kept sorted by
/// [`JobId`]. This replaces the per-round `BTreeMap<JobId, Job>` (and
/// its per-arrival `Job` clone): state mutates in place, active
/// iteration is a contiguous index walk in the exact order the map
/// iterated (id ascending, which completion recording pins), and id
/// lookups are a binary search over a flat table.
#[derive(Debug)]
pub struct JobArena {
    jobs: Vec<Job>,
    /// Arena indices of active jobs, sorted by `JobId`.
    active: Vec<u32>,
    /// `(id, arena index)` for every job, sorted by id.
    by_id: Vec<(JobId, u32)>,
}

impl JobArena {
    /// Build over a trace (callers sort it however the simulation wants
    /// arena indices assigned — the core uses arrival order). Ids must
    /// be unique.
    pub fn new(jobs: Vec<Job>) -> JobArena {
        let mut by_id: Vec<(JobId, u32)> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| (j.id, i as u32))
            .collect();
        by_id.sort_unstable_by_key(|e| e.0);
        for w in by_id.windows(2) {
            assert!(w[0].0 != w[1].0, "duplicate job id {:?}", w[0].0);
        }
        JobArena { jobs, active: Vec::new(), by_id }
    }

    /// Append a job mid-run (live submission injected by a
    /// [`crate::sim::RoundDriver`]); returns its arena index. Ids must
    /// stay unique — duplicates panic, like [`JobArena::new`].
    pub fn push(&mut self, job: Job) -> usize {
        let idx = self.jobs.len() as u32;
        let pos = self
            .by_id
            .binary_search_by_key(&job.id, |e| e.0)
            .expect_err(&format!("duplicate job id {:?}", job.id));
        self.by_id.insert(pos, (job.id, idx));
        self.jobs.push(job);
        idx as usize
    }

    /// Total jobs in the arena (active or not).
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// All jobs, in arena order.
    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    pub fn job(&self, idx: usize) -> &Job {
        &self.jobs[idx]
    }

    pub fn job_mut(&mut self, idx: usize) -> &mut Job {
        &mut self.jobs[idx]
    }

    /// Arena index of a job id (panics on unknown ids).
    pub fn index_of(&self, id: JobId) -> usize {
        let i = self
            .by_id
            .binary_search_by_key(&id, |e| e.0)
            .unwrap_or_else(|_| panic!("unknown job id {id:?}"));
        self.by_id[i].1 as usize
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Arena indices of active jobs, id-ascending.
    pub fn active_indices(&self) -> &[u32] {
        &self.active
    }

    /// Active jobs in id order (the old map's iteration order).
    pub fn active_jobs(&self) -> impl Iterator<Item = &Job> {
        self.active.iter().map(move |&i| &self.jobs[i as usize])
    }

    /// Active `(arena index, job)` pairs in id order.
    pub fn active_with_indices(&self) -> impl Iterator<Item = (usize, &Job)> {
        self.active
            .iter()
            .map(move |&i| (i as usize, &self.jobs[i as usize]))
    }

    /// Mark an arrived job active (inserted in id order).
    pub fn activate(&mut self, idx: usize) {
        let id = self.jobs[idx].id;
        let pos = self
            .active
            .binary_search_by(|&i| self.jobs[i as usize].id.cmp(&id))
            .expect_err("job already active");
        self.active.insert(pos, idx as u32);
    }

    /// Remove a finished job from the active set (state stays in place).
    pub fn deactivate(&mut self, idx: usize) {
        let id = self.jobs[idx].id;
        let pos = self
            .active
            .binary_search_by(|&i| self.jobs[i as usize].id.cmp(&id))
            .expect("job not active");
        self.active.remove(pos);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_active_set_stays_in_id_order() {
        let jobs: Vec<Job> = [3u64, 1, 2, 0]
            .iter()
            .map(|&i| Job::new(JobId(i), ModelKind::Lstm, 1, i as f64, 60.0))
            .collect();
        let mut a = JobArena::new(jobs);
        assert_eq!(a.n_jobs(), 4);
        assert_eq!(a.n_active(), 0);
        a.activate(0); // id 3
        a.activate(1); // id 1
        a.activate(3); // id 0
        let ids: Vec<u64> = a.active_jobs().map(|j| j.id.0).collect();
        assert_eq!(ids, vec![0, 1, 3], "id order regardless of activation");
        a.deactivate(1);
        let ids: Vec<u64> = a.active_jobs().map(|j| j.id.0).collect();
        assert_eq!(ids, vec![0, 3]);
        assert_eq!(a.index_of(JobId(2)), 2);
        assert_eq!(a.index_of(JobId(3)), 0);
        a.job_mut(2).progress_samples = 7.0;
        assert_eq!(a.job(2).progress_samples, 7.0);
    }

    #[test]
    #[should_panic(expected = "duplicate job id")]
    fn arena_rejects_duplicate_ids() {
        let j = Job::new(JobId(1), ModelKind::Lstm, 1, 0.0, 60.0);
        JobArena::new(vec![j.clone(), j]);
    }

    #[test]
    fn push_appends_and_keeps_id_lookup_sorted() {
        let jobs: Vec<Job> = [5u64, 1]
            .iter()
            .map(|&i| Job::new(JobId(i), ModelKind::Lstm, 1, 0.0, 60.0))
            .collect();
        let mut a = JobArena::new(jobs);
        // An id between the existing ones: lookup table must re-sort.
        let idx = a.push(Job::new(JobId(3), ModelKind::Gnmt, 2, 9.0, 60.0));
        assert_eq!(idx, 2);
        assert_eq!(a.n_jobs(), 3);
        assert_eq!(a.index_of(JobId(3)), 2);
        assert_eq!(a.index_of(JobId(5)), 0);
        a.activate(idx);
        assert_eq!(a.n_active(), 1);
    }

    #[test]
    #[should_panic(expected = "duplicate job id")]
    fn push_rejects_duplicate_ids() {
        let j = Job::new(JobId(1), ModelKind::Lstm, 1, 0.0, 60.0);
        let mut a = JobArena::new(vec![j.clone()]);
        a.push(j);
    }

    #[test]
    fn new_job_is_queued_with_zero_progress() {
        let j = Job::new(JobId(3), ModelKind::ResNet18, 4, 10.0, 3600.0);
        assert_eq!(j.state, JobState::Queued);
        assert_eq!(j.progress_samples, 0.0);
        assert_eq!(j.gpus, 4);
        assert!(!j.is_finished());
    }

    #[test]
    fn jct_is_finish_minus_arrival() {
        let mut j = Job::new(JobId(1), ModelKind::Gnmt, 1, 100.0, 60.0);
        j.state = JobState::Finished;
        j.finish_s = 400.0;
        assert_eq!(j.jct_s(), 300.0);
    }

    #[test]
    #[should_panic(expected = "JCT of unfinished")]
    fn jct_of_running_job_panics() {
        let j = Job::new(JobId(1), ModelKind::Gnmt, 1, 100.0, 60.0);
        let _ = j.jct_s();
    }

    #[test]
    fn default_tenant_and_builder_override() {
        let j = Job::new(JobId(1), ModelKind::Lstm, 1, 0.0, 60.0);
        assert_eq!(j.tenant, TenantId::DEFAULT);
        let j = j.with_tenant(TenantId(3));
        assert_eq!(j.tenant, TenantId(3));
    }

    #[test]
    fn remaining_clamps_at_zero() {
        let mut j = Job::new(JobId(1), ModelKind::M5, 1, 0.0, 60.0);
        j.total_samples = 100.0;
        j.progress_samples = 150.0;
        assert_eq!(j.remaining_samples(), 0.0);
    }
}
