//! Jobs, demand vectors, and the DNN model zoo (paper Table 4).

mod demand;
mod zoo;

pub use demand::DemandVector;
pub use zoo::{ModelKind, PerfCoeffs, Task, ALL_MODELS};

/// Opaque job identifier (dense, assigned by the trace generator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

/// Tenant (virtual cluster / team) identifier. Tenant ids are dense and
/// assigned by the workload source ([`crate::workload`]); single-tenant
/// workloads put every job in [`TenantId::DEFAULT`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

impl TenantId {
    /// The implicit tenant of jobs created without an explicit tenant.
    pub const DEFAULT: TenantId = TenantId(0);
}

/// Lifecycle of a job inside the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Arrived, profiled, waiting in the scheduling queue.
    Queued,
    /// Holding resources in the current round.
    Running,
    /// All work complete.
    Finished,
}

/// A DNN training job as the scheduler sees it.
///
/// GPU demand is fixed for the job's lifetime (user-provided, §2.3); CPU
/// and memory are fungible and re-decided every round. `total_samples` is
/// derived from the trace's duration under GPU-proportional allocation
/// (paper §5.1: "the duration of each job for the baseline GPU-proportional
/// allocation is sampled ...").
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    /// Owning tenant (weighted-quota admission keys on this).
    pub tenant: TenantId,
    pub model: ModelKind,
    pub gpus: u32,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Duration under GPU-proportional allocation, seconds.
    pub duration_prop_s: f64,
    /// Total training work, in samples (set once from duration_prop_s).
    pub total_samples: f64,
    /// Work completed so far, in samples.
    pub progress_samples: f64,
    pub state: JobState,
    /// Completion time (valid when state == Finished).
    pub finish_s: f64,
    /// Total time spent actually running (for LAS).
    pub attained_service_s: f64,
    /// Throughput (samples/s) under the current round's grant; 0 when
    /// queued. Set by the simulator/deployer at deploy time.
    pub progress_rate: f64,
    /// Per-job deterministic RNG stream id (profiling noise).
    pub rng_stream: u64,
}

impl Job {
    pub fn new(
        id: JobId,
        model: ModelKind,
        gpus: u32,
        arrival_s: f64,
        duration_prop_s: f64,
    ) -> Job {
        Job {
            id,
            tenant: TenantId::DEFAULT,
            model,
            gpus,
            arrival_s,
            duration_prop_s,
            total_samples: 0.0, // filled by the simulator once specs are known
            progress_samples: 0.0,
            state: JobState::Queued,
            finish_s: f64::NAN,
            attained_service_s: 0.0,
            progress_rate: 0.0,
            rng_stream: id.0,
        }
    }

    /// Assign the job to a tenant (builder style; default is tenant 0).
    pub fn with_tenant(mut self, tenant: TenantId) -> Job {
        self.tenant = tenant;
        self
    }

    /// Remaining work in samples.
    pub fn remaining_samples(&self) -> f64 {
        (self.total_samples - self.progress_samples).max(0.0)
    }

    pub fn is_finished(&self) -> bool {
        self.state == JobState::Finished
    }

    /// Job completion time (finish - arrival), seconds.
    pub fn jct_s(&self) -> f64 {
        assert!(self.is_finished(), "JCT of unfinished job {:?}", self.id);
        self.finish_s - self.arrival_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_job_is_queued_with_zero_progress() {
        let j = Job::new(JobId(3), ModelKind::ResNet18, 4, 10.0, 3600.0);
        assert_eq!(j.state, JobState::Queued);
        assert_eq!(j.progress_samples, 0.0);
        assert_eq!(j.gpus, 4);
        assert!(!j.is_finished());
    }

    #[test]
    fn jct_is_finish_minus_arrival() {
        let mut j = Job::new(JobId(1), ModelKind::Gnmt, 1, 100.0, 60.0);
        j.state = JobState::Finished;
        j.finish_s = 400.0;
        assert_eq!(j.jct_s(), 300.0);
    }

    #[test]
    #[should_panic(expected = "JCT of unfinished")]
    fn jct_of_running_job_panics() {
        let j = Job::new(JobId(1), ModelKind::Gnmt, 1, 100.0, 60.0);
        let _ = j.jct_s();
    }

    #[test]
    fn default_tenant_and_builder_override() {
        let j = Job::new(JobId(1), ModelKind::Lstm, 1, 0.0, 60.0);
        assert_eq!(j.tenant, TenantId::DEFAULT);
        let j = j.with_tenant(TenantId(3));
        assert_eq!(j.tenant, TenantId(3));
    }

    #[test]
    fn remaining_clamps_at_zero() {
        let mut j = Job::new(JobId(1), ModelKind::M5, 1, 0.0, 60.0);
        j.total_samples = 100.0;
        j.progress_samples = 150.0;
        assert_eq!(j.remaining_samples(), 0.0);
    }
}
