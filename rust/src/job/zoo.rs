//! The 10-model zoo (paper Table 4) with performance coefficients.
//!
//! Each model carries the coefficients of the ground-truth throughput
//! model in [`crate::perf`]. The paper measured these empirically on
//! V100 servers (Fig 2); we cannot, so the coefficients are *calibrated*
//! so that the published sensitivity facts hold (see DESIGN.md §2 and the
//! calibration tests in `crate::perf`):
//!
//! - ShuffleNetv2 needs >12 CPU cores/GPU to saturate (Fig 2a(i));
//! - AlexNet speeds up 3.1× going from 3 to 12 CPUs/GPU (§2.1);
//! - ResNet18 speeds up 2.3× going from 3 to 9 CPUs/GPU (§2.1);
//! - language models saturate at ≈1 CPU/GPU (Fig 2a(ii));
//! - ResNet18-on-OpenImages speeds up ≈2× from 62.5→500 GB cache (§2.1);
//! - GNMT is memory-insensitive down to its working set (§2.1).

/// Task family, used by workload splits (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Image,
    Language,
    Speech,
}

/// One of the ten benchmark models (paper Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    ShuffleNetV2,
    AlexNet,
    ResNet18,
    MobileNetV2,
    ResNet50,
    Gnmt,
    Lstm,
    TransformerXl,
    M5,
    DeepSpeech,
}

/// All models, in Table-4 order.
pub const ALL_MODELS: [ModelKind; 10] = [
    ModelKind::ShuffleNetV2,
    ModelKind::AlexNet,
    ModelKind::ResNet18,
    ModelKind::MobileNetV2,
    ModelKind::ResNet50,
    ModelKind::Gnmt,
    ModelKind::Lstm,
    ModelKind::TransformerXl,
    ModelKind::M5,
    ModelKind::DeepSpeech,
];

/// Calibrated performance coefficients for one model (single-GPU basis).
#[derive(Debug, Clone, Copy)]
pub struct PerfCoeffs {
    /// Samples/second when purely GPU-bound, per GPU.
    pub gpu_tput: f64,
    /// Pre-processing rate, samples/second per CPU core.
    pub cpu_prep_rate: f64,
    /// Average on-storage sample size, KB.
    pub sample_kb: f64,
    /// Dataset size, GB (drives the MinIO cache hit rate).
    pub dataset_gb: f64,
    /// Minimum process working-set memory, GB (floor on any allocation).
    pub min_mem_gb: f64,
}

impl PerfCoeffs {
    /// CPU cores per GPU at which throughput saturates (the Fig-2 knee).
    pub fn cpu_knee(&self) -> f64 {
        self.gpu_tput / self.cpu_prep_rate
    }
}

impl ModelKind {
    pub fn task(&self) -> Task {
        use ModelKind::*;
        match self {
            ShuffleNetV2 | AlexNet | ResNet18 | MobileNetV2 | ResNet50 => {
                Task::Image
            }
            Gnmt | Lstm | TransformerXl => Task::Language,
            M5 | DeepSpeech => Task::Speech,
        }
    }

    pub fn name(&self) -> &'static str {
        use ModelKind::*;
        match self {
            ShuffleNetV2 => "shufflenetv2",
            AlexNet => "alexnet",
            ResNet18 => "resnet18",
            MobileNetV2 => "mobilenetv2",
            ResNet50 => "resnet50",
            Gnmt => "gnmt",
            Lstm => "lstm",
            TransformerXl => "transformer-xl",
            M5 => "m5",
            DeepSpeech => "deepspeech",
        }
    }

    pub fn from_name(name: &str) -> Option<ModelKind> {
        ALL_MODELS.iter().copied().find(|m| m.name() == name)
    }

    /// Calibrated coefficients (see module docs for the constraints).
    pub fn coeffs(&self) -> PerfCoeffs {
        use ModelKind::*;
        match self {
            // --- image (ImageNet-class datasets; heavy augmentations) ---
            ShuffleNetV2 => PerfCoeffs {
                gpu_tput: 1600.0,
                cpu_prep_rate: 100.0,
                sample_kb: 110.0,
                dataset_gb: 140.0,
                min_mem_gb: 8.0,
            },
            AlexNet => PerfCoeffs {
                gpu_tput: 930.0,
                cpu_prep_rate: 100.0,
                sample_kb: 110.0,
                dataset_gb: 140.0,
                min_mem_gb: 8.0,
            },
            ResNet18 => PerfCoeffs {
                // OpenImages in the paper's memory experiment (§2.1).
                gpu_tput: 700.0,
                cpu_prep_rate: 100.0,
                sample_kb: 190.0,
                dataset_gb: 550.0,
                min_mem_gb: 10.0,
            },
            MobileNetV2 => PerfCoeffs {
                gpu_tput: 520.0,
                cpu_prep_rate: 100.0,
                sample_kb: 110.0,
                dataset_gb: 140.0,
                min_mem_gb: 8.0,
            },
            ResNet50 => PerfCoeffs {
                gpu_tput: 380.0,
                cpu_prep_rate: 100.0,
                sample_kb: 110.0,
                dataset_gb: 140.0,
                min_mem_gb: 10.0,
            },
            // --- language (small corpora, trivial pre-processing) ---
            Gnmt => PerfCoeffs {
                gpu_tput: 400.0,
                cpu_prep_rate: 800.0,
                sample_kb: 2.0,
                dataset_gb: 12.0,
                min_mem_gb: 20.0,
            },
            Lstm => PerfCoeffs {
                gpu_tput: 600.0,
                cpu_prep_rate: 1000.0,
                sample_kb: 1.0,
                dataset_gb: 1.0,
                min_mem_gb: 4.0,
            },
            TransformerXl => PerfCoeffs {
                gpu_tput: 500.0,
                cpu_prep_rate: 700.0,
                sample_kb: 2.0,
                dataset_gb: 8.0,
                min_mem_gb: 12.0,
            },
            // --- speech (large audio datasets, decode-heavy prep) ---
            M5 => PerfCoeffs {
                gpu_tput: 900.0,
                cpu_prep_rate: 90.0,
                sample_kb: 800.0,
                dataset_gb: 880.0,
                min_mem_gb: 12.0,
            },
            DeepSpeech => PerfCoeffs {
                gpu_tput: 250.0,
                cpu_prep_rate: 60.0,
                sample_kb: 950.0,
                dataset_gb: 100.0,
                min_mem_gb: 16.0,
            },
        }
    }

    /// Models of a given task family.
    pub fn of_task(task: Task) -> Vec<ModelKind> {
        ALL_MODELS.iter().copied().filter(|m| m.task() == task).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_models_three_tasks() {
        assert_eq!(ALL_MODELS.len(), 10);
        assert_eq!(ModelKind::of_task(Task::Image).len(), 5);
        assert_eq!(ModelKind::of_task(Task::Language).len(), 3);
        assert_eq!(ModelKind::of_task(Task::Speech).len(), 2);
    }

    #[test]
    fn names_roundtrip() {
        for m in ALL_MODELS {
            assert_eq!(ModelKind::from_name(m.name()), Some(m));
        }
        assert_eq!(ModelKind::from_name("vgg16"), None);
    }

    #[test]
    fn cpu_knees_match_fig2_facts() {
        // Image/speech models need many cores; language models need ~1.
        assert!(ModelKind::ShuffleNetV2.coeffs().cpu_knee() > 12.0);
        assert!((ModelKind::AlexNet.coeffs().cpu_knee() - 9.3).abs() < 0.1);
        assert!((ModelKind::ResNet18.coeffs().cpu_knee() - 7.0).abs() < 0.1);
        for m in ModelKind::of_task(Task::Language) {
            assert!(m.coeffs().cpu_knee() <= 1.0, "{m:?}");
        }
        assert!(ModelKind::M5.coeffs().cpu_knee() >= 9.0);
    }

    #[test]
    fn language_datasets_fit_in_proportional_share() {
        // This is what makes language models memory-insensitive (§2.1).
        for m in ModelKind::of_task(Task::Language) {
            assert!(m.coeffs().dataset_gb <= 62.5, "{m:?}");
        }
    }

    #[test]
    fn memory_hungry_models_have_large_datasets() {
        assert!(ModelKind::ResNet18.coeffs().dataset_gb > 500.0);
        assert!(ModelKind::M5.coeffs().dataset_gb > 500.0);
    }
}
