//! Typed experiment configuration.
//!
//! Experiments are described either by CLI flags (see `main.rs`) or by a
//! JSON config file; both funnel into [`ExperimentConfig`]. The config
//! system validates combinations up front so sweeps fail fast.

use crate::cluster::ServerSpec;
use crate::trace::{Split, TraceConfig};
use crate::util::json::Json;

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub spec: ServerSpec,
    pub n_servers: usize,
    pub round_s: f64,
    pub policy: String,
    pub mechanism: String,
    pub trace: TraceConfig,
    pub profile_noise: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            spec: ServerSpec::default(),
            n_servers: 16,
            round_s: 300.0,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            trace: TraceConfig::default(),
            profile_noise: 0.0,
        }
    }
}

impl ExperimentConfig {
    /// Validate the configuration; returns a description of the first
    /// problem found.
    pub fn validate(&self) -> Result<(), String> {
        if crate::policy::by_name(&self.policy).is_none() {
            return Err(format!("unknown policy '{}'", self.policy));
        }
        if crate::mechanism::by_name(&self.mechanism).is_none() {
            return Err(format!("unknown mechanism '{}'", self.mechanism));
        }
        if self.n_servers == 0 {
            return Err("n_servers must be positive".into());
        }
        if self.round_s <= 0.0 {
            return Err("round_s must be positive".into());
        }
        let s = self.trace.split;
        if s.image + s.language + s.speech != 100 {
            return Err(format!(
                "split must sum to 100, got {}",
                s.image + s.language + s.speech
            ));
        }
        if !(0.0..0.5).contains(&self.profile_noise) {
            return Err("profile_noise must be in [0, 0.5)".into());
        }
        Ok(())
    }

    /// Parse from a JSON document (missing keys take defaults).
    pub fn from_json(doc: &Json) -> Result<ExperimentConfig, String> {
        let mut cfg = ExperimentConfig::default();
        if let Some(s) = doc.get("name").as_str() {
            cfg.name = s.to_string();
        }
        if let Some(n) = doc.get("n_servers").as_usize() {
            cfg.n_servers = n;
        }
        if let Some(n) = doc.get("gpus_per_server").as_f64() {
            cfg.spec.gpus = n as u32;
        }
        if let Some(n) = doc.get("cpus_per_server").as_f64() {
            cfg.spec.cpus = n as u32;
        }
        if let Some(n) = doc.get("mem_gb_per_server").as_f64() {
            cfg.spec.mem_gb = n;
        }
        if let Some(n) = doc.get("round_s").as_f64() {
            cfg.round_s = n;
        }
        if let Some(s) = doc.get("policy").as_str() {
            cfg.policy = s.to_string();
        }
        if let Some(s) = doc.get("mechanism").as_str() {
            cfg.mechanism = s.to_string();
        }
        if let Some(n) = doc.get("profile_noise").as_f64() {
            cfg.profile_noise = n;
        }
        if let Some(n) = doc.get("n_jobs").as_usize() {
            cfg.trace.n_jobs = n;
        }
        if let Some(seed) = doc.get("seed").as_f64() {
            cfg.trace.seed = seed as u64;
        }
        if let Some(b) = doc.get("multi_gpu").as_bool() {
            cfg.trace.multi_gpu = b;
        }
        match doc.get("jobs_per_hour") {
            Json::Null => {}
            v => {
                if let Some(l) = v.as_f64() {
                    cfg.trace.jobs_per_hour = if l <= 0.0 { None } else { Some(l) };
                }
            }
        }
        if let Some(arr) = doc.get("split").as_arr() {
            if arr.len() != 3 {
                return Err("split must be [image, language, speech]".into());
            }
            cfg.trace.split = Split::new(
                arr[0].as_usize().ok_or("bad split")? as u32,
                arr[1].as_usize().ok_or("bad split")? as u32,
                arr[2].as_usize().ok_or("bad split")? as u32,
            );
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(ExperimentConfig::default().validate().is_ok());
    }

    #[test]
    fn json_overrides() {
        let doc = Json::parse(
            r#"{"name": "x", "n_servers": 64, "policy": "srtf",
                "mechanism": "opt", "split": [20, 70, 10],
                "jobs_per_hour": 9, "multi_gpu": true}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.n_servers, 64);
        assert_eq!(cfg.policy, "srtf");
        assert_eq!(cfg.mechanism, "opt");
        assert_eq!(cfg.trace.split.language, 70);
        assert_eq!(cfg.trace.jobs_per_hour, Some(9.0));
        assert!(cfg.trace.multi_gpu);
    }

    #[test]
    fn bad_policy_rejected() {
        let doc = Json::parse(r#"{"policy": "lottery"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn bad_split_rejected() {
        let doc = Json::parse(r#"{"split": [50, 50, 50]}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }
}
