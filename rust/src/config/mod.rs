//! Typed experiment configuration.
//!
//! Experiments are described either by CLI flags (see `main.rs`) or by a
//! JSON config file; both funnel into [`ExperimentConfig`]. The config
//! system validates combinations up front so sweeps fail fast.
//!
//! Workload selection mirrors the CLI: with no `trace` key the synthetic
//! generator runs (`n_jobs`/`split`/`seed`/...); with `"trace":
//! "path.csv"` plus `"format": "philly" | "alibaba" | "google"` the
//! file readers from [`crate::workload`] are used (`google` also
//! accepts a trace *directory*), and `"tenants": "a:2,b:1"` turns
//! on weighted-quota admission either way. A `"hetero"` section —
//! `[{"gen": "p100", "machines": 8}, ...]` — describes a mixed-
//! generation fleet (paper A.2) sharing the global server shape; with
//! it absent the run is the homogeneous one-type special case.
//! [`ExperimentConfig::to_json`] round-trips everything
//! [`ExperimentConfig::from_json`] reads.

use crate::cluster::{GpuGen, ServerSpec, TopologySpec, TypeSpec};
use crate::job::Job;
use crate::trace::{Split, TraceConfig};
use crate::util::json::Json;
use crate::workload::{
    AlibabaTraceConfig, AlibabaTraceSource, GoogleTraceConfig,
    GoogleTraceSource, PhillyTraceConfig, PhillyTraceSource,
    SyntheticSource, TenantQuotas, TenantSpec, WorkloadSource,
};

/// A full experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub spec: ServerSpec,
    pub n_servers: usize,
    pub round_s: f64,
    pub policy: String,
    pub mechanism: String,
    pub trace: TraceConfig,
    pub profile_noise: f64,
    /// Path to a trace file (`trace` JSON key); `None` = synthetic.
    pub trace_path: Option<String>,
    /// Trace file format (`format` JSON key): `philly` | `alibaba` |
    /// `google` (the last also accepts a trace directory).
    pub trace_format: String,
    /// Planning fan-out width (`shards` JSON key): worker threads the
    /// resumable planner spreads per-pool placement folds over.
    /// Schedule-invisible — schedules are byte-identical for any value.
    /// 1 = serial (default; the key is omitted from `to_json` then).
    pub shards: usize,
    /// Tenant weights (`tenants` JSON key, `name:weight,...` syntax);
    /// `None` = single-tenant, no quota admission.
    pub tenants: Option<TenantSpec>,
    /// Mixed-fleet description (`hetero` JSON key): machine types +
    /// counts per type, all sharing `spec`'s server shape. Empty =
    /// homogeneous (`n_servers` V100 machines).
    pub hetero: Vec<HeteroType>,
    /// Rack topology (`topology` JSON key, either the CLI string form
    /// `"racks:R"`/`"flat"` or an object `{"racks": R, "link_cost": c,
    /// "placement_aware": b}`). The default flat spec reproduces
    /// pre-topology schedules byte-identically.
    pub topology: TopologySpec,
    /// Deterministic host churn (`faults` JSON key): the raw spec
    /// string — `mtbf:<hours>,mttr:<hours>[,seed:S]` or a path to a
    /// scripted-schedule JSON file (the `--faults` CLI forms). `None`
    /// (default; key omitted from `to_json`) = no churn.
    pub faults: Option<String>,
}

/// One machine type of a config-described mixed fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeteroType {
    pub gen: GpuGen,
    pub machines: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            spec: ServerSpec::default(),
            n_servers: 16,
            round_s: 300.0,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            trace: TraceConfig::default(),
            profile_noise: 0.0,
            trace_path: None,
            trace_format: "philly".into(),
            shards: 1,
            tenants: None,
            hetero: Vec::new(),
            topology: TopologySpec::default(),
            faults: None,
        }
    }
}

impl ExperimentConfig {
    /// Validate the configuration; returns a description of the first
    /// problem found.
    pub fn validate(&self) -> Result<(), String> {
        if crate::policy::by_name(&self.policy).is_none() {
            return Err(format!("unknown policy '{}'", self.policy));
        }
        if crate::mechanism::by_name(&self.mechanism).is_none() {
            return Err(format!("unknown mechanism '{}'", self.mechanism));
        }
        if self.n_servers == 0 {
            return Err("n_servers must be positive".into());
        }
        if self.round_s <= 0.0 {
            return Err("round_s must be positive".into());
        }
        let s = self.trace.split;
        if s.image + s.language + s.speech != 100 {
            return Err(format!(
                "split must sum to 100, got {}",
                s.image + s.language + s.speech
            ));
        }
        if !(0.0..0.5).contains(&self.profile_noise) {
            return Err("profile_noise must be in [0, 0.5)".into());
        }
        if !matches!(
            self.trace_format.as_str(),
            "philly" | "alibaba" | "google"
        ) {
            return Err(format!(
                "unknown trace format '{}' (expected philly|alibaba|google)",
                self.trace_format
            ));
        }
        if self.shards == 0 {
            return Err("shards must be positive".into());
        }
        if let Some(s) = &self.faults {
            // Parses the spec (and reads the script file, for the path
            // form) so a bad schedule fails at config load, not mid-run.
            crate::sim::FaultSpec::parse(s)
                .map_err(|e| format!("faults: {e}"))?;
        }
        self.topology.validate().map_err(|e| format!("topology: {e}"))?;
        for (i, t) in self.hetero.iter().enumerate() {
            if t.machines == 0 {
                return Err(format!(
                    "hetero[{i}]: machines must be positive"
                ));
            }
            for u in &self.hetero[i + 1..] {
                if t.gen == u.gen {
                    return Err(format!(
                        "hetero: duplicate machine type '{}'",
                        t.gen.name()
                    ));
                }
            }
        }
        Ok(())
    }

    /// The fleet description this config drives: `Some` per-type specs
    /// when a `hetero` section is present, `None` for the homogeneous
    /// `n_servers × spec` special case.
    pub fn types(&self) -> Option<Vec<TypeSpec>> {
        if self.hetero.is_empty() {
            return None;
        }
        Some(
            self.hetero
                .iter()
                .map(|t| TypeSpec {
                    gen: t.gen,
                    spec: self.spec,
                    machines: t.machines,
                })
                .collect(),
        )
    }

    /// Parse from a JSON document (missing keys take defaults).
    pub fn from_json(doc: &Json) -> Result<ExperimentConfig, String> {
        let mut cfg = ExperimentConfig::default();
        if let Some(s) = doc.get("name").as_str() {
            cfg.name = s.to_string();
        }
        if let Some(n) = doc.get("n_servers").as_usize() {
            cfg.n_servers = n;
        }
        if let Some(n) = doc.get("gpus_per_server").as_f64() {
            cfg.spec.gpus = n as u32;
        }
        if let Some(n) = doc.get("cpus_per_server").as_f64() {
            cfg.spec.cpus = n as u32;
        }
        if let Some(n) = doc.get("mem_gb_per_server").as_f64() {
            cfg.spec.mem_gb = n;
        }
        if let Some(n) = doc.get("round_s").as_f64() {
            cfg.round_s = n;
        }
        if let Some(s) = doc.get("policy").as_str() {
            cfg.policy = s.to_string();
        }
        if let Some(s) = doc.get("mechanism").as_str() {
            cfg.mechanism = s.to_string();
        }
        if let Some(n) = doc.get("profile_noise").as_f64() {
            cfg.profile_noise = n;
        }
        if let Some(n) = doc.get("n_jobs").as_usize() {
            cfg.trace.n_jobs = n;
        }
        if let Some(seed) = doc.get("seed").as_f64() {
            cfg.trace.seed = seed as u64;
        }
        if let Some(b) = doc.get("multi_gpu").as_bool() {
            cfg.trace.multi_gpu = b;
        }
        match doc.get("jobs_per_hour") {
            Json::Null => {}
            v => {
                if let Some(l) = v.as_f64() {
                    cfg.trace.jobs_per_hour = if l <= 0.0 { None } else { Some(l) };
                }
            }
        }
        if let Some(arr) = doc.get("split").as_arr() {
            if arr.len() != 3 {
                return Err("split must be [image, language, speech]".into());
            }
            cfg.trace.split = Split::new(
                arr[0].as_usize().ok_or("bad split")? as u32,
                arr[1].as_usize().ok_or("bad split")? as u32,
                arr[2].as_usize().ok_or("bad split")? as u32,
            );
        }
        if let Some(s) = doc.get("trace").as_str() {
            cfg.trace_path = Some(s.to_string());
        }
        if let Some(s) = doc.get("format").as_str() {
            cfg.trace_format = s.to_string();
        }
        if let Some(n) = doc.get("shards").as_usize() {
            cfg.shards = n;
        }
        if let Some(s) = doc.get("faults").as_str() {
            cfg.faults = Some(s.to_string());
        }
        if let Some(s) = doc.get("tenants").as_str() {
            cfg.tenants =
                Some(TenantSpec::parse(s).map_err(|e| format!("tenants: {e}"))?);
        }
        if let Some(arr) = doc.get("hetero").as_arr() {
            let mut types = Vec::with_capacity(arr.len());
            for (i, entry) in arr.iter().enumerate() {
                let gen_name = entry
                    .get("gen")
                    .as_str()
                    .ok_or_else(|| format!("hetero[{i}]: missing 'gen'"))?;
                let gen = GpuGen::by_name(gen_name).ok_or_else(|| {
                    format!("hetero[{i}]: unknown generation '{gen_name}'")
                })?;
                let machines = entry
                    .get("machines")
                    .as_usize()
                    .ok_or_else(|| format!("hetero[{i}]: missing 'machines'"))?;
                types.push(HeteroType { gen, machines });
            }
            cfg.hetero = types;
        }
        match doc.get("topology") {
            Json::Null => {}
            v => {
                if let Some(s) = v.as_str() {
                    cfg.topology = TopologySpec::parse(s)
                        .map_err(|e| format!("topology: {e}"))?;
                } else {
                    let mut spec = TopologySpec::default();
                    if let Some(n) = v.get("racks").as_usize() {
                        spec.racks = n as u32;
                    }
                    if let Some(n) = v.get("link_cost").as_f64() {
                        spec.link_cost = n;
                    }
                    if let Some(b) = v.get("placement_aware").as_bool() {
                        spec.placement_aware = b;
                    }
                    cfg.topology = spec;
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Encode as the JSON document [`ExperimentConfig::from_json`] reads
    /// (round-trip tested).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("n_servers", Json::num(self.n_servers as f64)),
            ("gpus_per_server", Json::num(self.spec.gpus as f64)),
            ("cpus_per_server", Json::num(self.spec.cpus as f64)),
            ("mem_gb_per_server", Json::num(self.spec.mem_gb)),
            ("round_s", Json::num(self.round_s)),
            ("policy", Json::str(self.policy.clone())),
            ("mechanism", Json::str(self.mechanism.clone())),
            ("profile_noise", Json::num(self.profile_noise)),
            ("n_jobs", Json::num(self.trace.n_jobs as f64)),
            ("seed", Json::num(self.trace.seed as f64)),
            ("multi_gpu", Json::Bool(self.trace.multi_gpu)),
            (
                "jobs_per_hour",
                match self.trace.jobs_per_hour {
                    Some(l) => Json::num(l),
                    None => Json::num(0.0), // 0 ⇒ static trace on read
                },
            ),
            (
                "split",
                Json::arr(vec![
                    Json::num(self.trace.split.image as f64),
                    Json::num(self.trace.split.language as f64),
                    Json::num(self.trace.split.speech as f64),
                ]),
            ),
            ("format", Json::str(self.trace_format.clone())),
        ];
        if let Some(path) = &self.trace_path {
            pairs.push(("trace", Json::str(path.clone())));
        }
        if self.shards != 1 {
            pairs.push(("shards", Json::num(self.shards as f64)));
        }
        if let Some(s) = &self.faults {
            pairs.push(("faults", Json::str(s.clone())));
        }
        if let Some(spec) = &self.tenants {
            pairs.push(("tenants", Json::str(spec.canonical())));
        }
        if !self.hetero.is_empty() {
            pairs.push((
                "hetero",
                Json::arr(
                    self.hetero
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("gen", Json::str(t.gen.name())),
                                ("machines", Json::num(t.machines as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if self.topology != TopologySpec::default() {
            pairs.push((
                "topology",
                Json::obj(vec![
                    ("racks", Json::num(self.topology.racks as f64)),
                    ("link_cost", Json::num(self.topology.link_cost)),
                    (
                        "placement_aware",
                        Json::Bool(self.topology.placement_aware),
                    ),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&doc)
    }

    /// Materialize the experiment's workload: jobs, tenant quotas (when
    /// `tenants` is set), and tenant names for reporting. Config-file
    /// runs reach the same readers as the CLI's
    /// `--trace/--format/--tenants` flags; the readers' tuning knobs
    /// (λ rescale, duration clamps, GPU cap, row limits) currently take
    /// their defaults here — only the CLI exposes them.
    pub fn workload(
        &self,
    ) -> Result<(Vec<Job>, Option<TenantQuotas>, Vec<String>), String> {
        match &self.trace_path {
            Some(path) => {
                let mut source: Box<dyn WorkloadSource> =
                    match self.trace_format.as_str() {
                        "philly" => Box::new(PhillyTraceSource::new(
                            PhillyTraceConfig {
                                path: path.clone(),
                                split: self.trace.split,
                                seed: self.trace.seed,
                                ..PhillyTraceConfig::default()
                            },
                        )?),
                        "alibaba" => Box::new(AlibabaTraceSource::new(
                            AlibabaTraceConfig {
                                path: path.clone(),
                                seed: self.trace.seed,
                                ..AlibabaTraceConfig::default()
                            },
                        )?),
                        "google" => Box::new(GoogleTraceSource::new(
                            GoogleTraceConfig {
                                path: path.clone(),
                                split: self.trace.split,
                                seed: self.trace.seed,
                                ..GoogleTraceConfig::default()
                            },
                        )?),
                        other => {
                            return Err(format!(
                                "unknown trace format '{other}'"
                            ))
                        }
                    };
                let names = source.tenant_names();
                let quotas = self.tenants.as_ref().map(|s| {
                    // Mirror the CLI's behaviour for spec names absent
                    // from the trace: warn, weight ignored.
                    for name in &s.names {
                        if !names.contains(name) {
                            eprintln!(
                                "warning: tenants name '{name}' matches no \
                                 tenant in the trace (trace tenants: \
                                 {names:?}); its weight is ignored"
                            );
                        }
                    }
                    s.quotas_for(&names)
                });
                Ok((source.drain_jobs(), quotas, names))
            }
            None => match &self.tenants {
                Some(spec) => {
                    let jobs = SyntheticSource::new(self.trace)
                        .with_tenants(spec.clone())
                        .drain_jobs();
                    Ok((jobs, Some(spec.quotas()), spec.names.clone()))
                }
                None => Ok((
                    SyntheticSource::new(self.trace).drain_jobs(),
                    None,
                    vec!["default".to_string()],
                )),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(ExperimentConfig::default().validate().is_ok());
    }

    #[test]
    fn json_overrides() {
        let doc = Json::parse(
            r#"{"name": "x", "n_servers": 64, "policy": "srtf",
                "mechanism": "opt", "split": [20, 70, 10],
                "jobs_per_hour": 9, "multi_gpu": true}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.n_servers, 64);
        assert_eq!(cfg.policy, "srtf");
        assert_eq!(cfg.mechanism, "opt");
        assert_eq!(cfg.trace.split.language, 70);
        assert_eq!(cfg.trace.jobs_per_hour, Some(9.0));
        assert!(cfg.trace.multi_gpu);
        assert_eq!(cfg.trace_path, None);
        assert_eq!(cfg.tenants, None);
    }

    #[test]
    fn trace_and_tenant_keys_parse() {
        let doc = Json::parse(
            r#"{"trace": "t.csv", "format": "alibaba",
                "tenants": "a:2,b:1"}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.trace_path.as_deref(), Some("t.csv"));
        assert_eq!(cfg.trace_format, "alibaba");
        let spec = cfg.tenants.unwrap();
        assert_eq!(spec.names, vec!["a", "b"]);
        assert_eq!(spec.weights, vec![2.0, 1.0]);
    }

    #[test]
    fn bad_policy_rejected() {
        let doc = Json::parse(r#"{"policy": "lottery"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn bad_split_rejected() {
        let doc = Json::parse(r#"{"split": [50, 50, 50]}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn bad_format_and_tenants_rejected() {
        let doc = Json::parse(r#"{"format": "borg"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
        let doc = Json::parse(r#"{"tenants": "a:-3"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn google_format_accepted_and_shards_roundtrip() {
        let doc = Json::parse(
            r#"{"trace": "t/", "format": "google", "shards": 4}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.trace_format, "google");
        assert_eq!(cfg.shards, 4);
        let encoded = cfg.to_json().encode();
        let back =
            ExperimentConfig::from_json(&Json::parse(&encoded).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
        // Serial configs omit the key, keeping existing files byte-stable.
        let plain = ExperimentConfig::default().to_json().encode();
        assert!(!plain.contains("shards"), "{plain}");
        // shards = 0 is rejected up front.
        let doc = Json::parse(r#"{"shards": 0}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn faults_key_roundtrips_and_validates() {
        let doc =
            Json::parse(r#"{"faults": "mtbf:24,mttr:2,seed:7"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.faults.as_deref(), Some("mtbf:24,mttr:2,seed:7"));
        let encoded = cfg.to_json().encode();
        let back =
            ExperimentConfig::from_json(&Json::parse(&encoded).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
        // Default omits the key — existing config files stay byte-stable.
        let plain = ExperimentConfig::default().to_json().encode();
        assert!(!plain.contains("faults"), "{plain}");
        // A malformed spec fails at config load.
        let doc = Json::parse(r#"{"faults": "mtbf:0,mttr:1"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
        // The script-path form must name a readable file.
        let doc = Json::parse(r#"{"faults": "/no/such/file.json"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn workload_reads_google_fixture_dir() {
        let cfg = ExperimentConfig {
            trace_path: Some(format!(
                "{}/tests/fixtures/google_small",
                env!("CARGO_MANIFEST_DIR")
            )),
            trace_format: "google".into(),
            ..ExperimentConfig::default()
        };
        let (jobs, quotas, names) = cfg.workload().unwrap();
        assert_eq!(jobs.len(), 8);
        assert_eq!(names, vec!["c", "a", "b"]);
        assert!(quotas.is_none());
    }

    #[test]
    fn hetero_section_parses_and_maps_to_types() {
        let doc = Json::parse(
            r#"{"hetero": [{"gen": "p100", "machines": 4},
                           {"gen": "v100", "machines": 2}],
                "n_servers": 99}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.hetero.len(), 2);
        let types = cfg.types().expect("mixed fleet");
        assert_eq!(types[0].gen, GpuGen::P100);
        assert_eq!(types[0].machines, 4);
        assert_eq!(types[1].gen, GpuGen::V100);
        assert_eq!(types[1].spec, cfg.spec);
        // Homogeneous configs have no fleet override.
        assert!(ExperimentConfig::default().types().is_none());
    }

    #[test]
    fn bad_hetero_sections_rejected() {
        for doc in [
            r#"{"hetero": [{"gen": "h100", "machines": 4}]}"#,
            r#"{"hetero": [{"gen": "v100", "machines": 0}]}"#,
            r#"{"hetero": [{"gen": "v100", "machines": 1},
                           {"gen": "v100", "machines": 2}]}"#,
            r#"{"hetero": [{"machines": 2}]}"#,
        ] {
            let doc = Json::parse(doc).unwrap();
            assert!(ExperimentConfig::from_json(&doc).is_err(), "{doc:?}");
        }
    }

    #[test]
    fn hetero_roundtrips_through_json() {
        let cfg = ExperimentConfig {
            hetero: vec![
                HeteroType { gen: GpuGen::K80, machines: 2 },
                HeteroType { gen: GpuGen::V100, machines: 6 },
            ],
            ..ExperimentConfig::default()
        };
        let encoded = cfg.to_json().encode();
        let back =
            ExperimentConfig::from_json(&Json::parse(&encoded).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn topology_section_parses_in_both_forms_and_roundtrips() {
        // CLI string form.
        let doc = Json::parse(r#"{"topology": "racks:3"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.topology.racks, 3);
        assert!(cfg.topology.placement_aware);
        // Object form with every knob.
        let doc = Json::parse(
            r#"{"topology": {"racks": 2, "link_cost": 0.5,
                             "placement_aware": false}}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.topology.racks, 2);
        assert_eq!(cfg.topology.link_cost, 0.5);
        assert!(!cfg.topology.placement_aware);
        let encoded = cfg.to_json().encode();
        let back =
            ExperimentConfig::from_json(&Json::parse(&encoded).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
        // Default (flat) configs omit the key entirely, keeping existing
        // config files byte-stable.
        let plain = ExperimentConfig::default().to_json().encode();
        assert!(!plain.contains("topology"), "{plain}");
    }

    #[test]
    fn bad_topology_rejected() {
        for doc in [
            r#"{"topology": "racks:0"}"#,
            r#"{"topology": "mesh"}"#,
            r#"{"topology": {"racks": 0}}"#,
            r#"{"topology": {"link_cost": -1}}"#,
        ] {
            let doc = Json::parse(doc).unwrap();
            assert!(ExperimentConfig::from_json(&doc).is_err(), "{doc:?}");
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut cfg = ExperimentConfig {
            name: "rt".into(),
            n_servers: 4,
            round_s: 120.0,
            policy: "srtf".into(),
            mechanism: "proportional".into(),
            profile_noise: 0.05,
            trace_path: Some("fixtures/philly_small.csv".into()),
            trace_format: "philly".into(),
            tenants: Some(TenantSpec::parse("a:2,b:1").unwrap()),
            ..ExperimentConfig::default()
        };
        cfg.trace.n_jobs = 77;
        cfg.trace.seed = 9;
        cfg.trace.multi_gpu = true;
        cfg.trace.jobs_per_hour = Some(6.5);
        cfg.trace.split = Split::new(30, 50, 20);
        let encoded = cfg.to_json().encode();
        let back =
            ExperimentConfig::from_json(&Json::parse(&encoded).unwrap())
                .unwrap();
        assert_eq!(back, cfg);

        // A static trace (None) also survives the 0-means-static encoding.
        cfg.trace.jobs_per_hour = None;
        let encoded = cfg.to_json().encode();
        let back =
            ExperimentConfig::from_json(&Json::parse(&encoded).unwrap())
                .unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn workload_reads_fixture_with_quotas() {
        let cfg = ExperimentConfig {
            trace_path: Some(format!(
                "{}/tests/fixtures/philly_small.csv",
                env!("CARGO_MANIFEST_DIR")
            )),
            trace_format: "philly".into(),
            tenants: Some(TenantSpec::parse("a:2,b:1").unwrap()),
            ..ExperimentConfig::default()
        };
        let (jobs, quotas, names) = cfg.workload().unwrap();
        assert_eq!(jobs.len(), 39);
        assert_eq!(names, vec!["a", "b"]);
        let q = quotas.expect("tenants set");
        assert_eq!(q.weight(crate::job::TenantId(0)), 2.0);
        assert_eq!(q.weight(crate::job::TenantId(1)), 1.0);
    }

    #[test]
    fn synthetic_workload_with_tenants() {
        let mut cfg = ExperimentConfig {
            tenants: Some(TenantSpec::parse("x:3,y:1").unwrap()),
            ..ExperimentConfig::default()
        };
        cfg.trace.n_jobs = 50;
        let (jobs, quotas, names) = cfg.workload().unwrap();
        assert_eq!(jobs.len(), 50);
        assert_eq!(names, vec!["x", "y"]);
        assert!(quotas.is_some());
        assert!(jobs.iter().any(|j| j.tenant.0 == 0));
        assert!(jobs.iter().any(|j| j.tenant.0 == 1));
    }
}
