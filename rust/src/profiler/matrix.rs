//! The resource sensitivity matrix `W_j[c, m]` (paper Fig 4) and the
//! queries the scheduling mechanisms make against it.

use crate::job::{DemandVector, ModelKind};

/// Job throughput over a discrete (CPU, memory) grid, plus the
/// GPU-proportional reference point.
#[derive(Debug, Clone)]
pub struct SensitivityMatrix {
    pub model: ModelKind,
    pub gpus: u32,
    /// Total-CPU grid (integral cores, ascending).
    pub cpu_points: Vec<f64>,
    /// Total-memory grid in GB (ascending).
    pub mem_points: Vec<f64>,
    /// tput[ci][mi] in samples/second.
    pub tput: Vec<Vec<f64>>,
    /// GPU-proportional allocation (C_g, M_g).
    pub prop_cpus: f64,
    pub prop_mem_gb: f64,
    /// Cached best-case demand (98%-of-peak knee) — queried every round
    /// by the mechanisms and policy views, so computed once here.
    best: DemandVector,
}

impl SensitivityMatrix {
    pub fn new(
        model: ModelKind,
        gpus: u32,
        cpu_points: Vec<f64>,
        mem_points: Vec<f64>,
        tput: Vec<Vec<f64>>,
        prop_cpus: f64,
        prop_mem_gb: f64,
    ) -> SensitivityMatrix {
        assert_eq!(tput.len(), cpu_points.len());
        assert!(tput.iter().all(|r| r.len() == mem_points.len()));
        let mut m = SensitivityMatrix {
            model,
            gpus,
            cpu_points,
            mem_points,
            tput,
            prop_cpus,
            prop_mem_gb,
            best: DemandVector::new(gpus, 1.0, 1.0), // placeholder
        };
        m.best = m.demand_at_saturation(0.98);
        m
    }

    /// Throughput at an arbitrary (c, m): the grid cell at-or-below the
    /// request (conservative — never over-promises).
    pub fn throughput_at(&self, cpus: f64, mem_gb: f64) -> f64 {
        let ci = match self
            .cpu_points
            .iter()
            .rposition(|&c| c <= cpus + 1e-9)
        {
            Some(i) => i,
            None => return 0.0,
        };
        let mi = match self
            .mem_points
            .iter()
            .rposition(|&m| m <= mem_gb + 1e-9)
        {
            Some(i) => i,
            None => return 0.0,
        };
        self.tput[ci][mi]
    }

    /// Throughput at the GPU-proportional allocation: the fairness floor
    /// `W[C_g, M_g]` (paper §4.1 constraint 5).
    pub fn proportional_throughput(&self) -> f64 {
        self.throughput_at(self.prop_cpus, self.prop_mem_gb)
    }

    /// Peak throughput anywhere on the grid.
    pub fn max_throughput(&self) -> f64 {
        self.tput
            .iter()
            .flat_map(|r| r.iter())
            .cloned()
            .fold(0.0, f64::max)
    }

    /// The job demand vector (paper §3.2): the *minimum* (c, m) whose
    /// throughput reaches `saturation` × peak (the paper picks the point
    /// where returns diminish; we use saturation = 0.98 by default via
    /// [`SensitivityMatrix::best_demand`]).
    pub fn demand_at_saturation(&self, saturation: f64) -> DemandVector {
        // Never target below the proportional floor: granting the
        // best-case demand must never degrade a job below its
        // GPU-proportional throughput (paper §2.2).
        let target = (self.max_throughput() * saturation)
            .max(self.proportional_throughput());
        // min CPU first, then min memory at that CPU (CPU is the scarcer
        // resource at ratio 3).
        for (ci, &c) in self.cpu_points.iter().enumerate() {
            let best_mem_tput = self.tput[ci]
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            if best_mem_tput + 1e-12 >= target {
                for (mi, &m) in self.mem_points.iter().enumerate() {
                    if self.tput[ci][mi] + 1e-12 >= target {
                        return DemandVector::new(self.gpus, c, m);
                    }
                }
            }
        }
        // Fallback: everything (should not happen with a proper grid).
        DemandVector::new(
            self.gpus,
            *self.cpu_points.last().unwrap(),
            *self.mem_points.last().unwrap(),
        )
    }

    /// Default best-case demand (98% of peak — the knee of the curve),
    /// cached at construction.
    pub fn best_demand(&self) -> DemandVector {
        self.best
    }

    /// Pareto-pruned allocation options for the OPT ILP: grid points whose
    /// throughput (a) meets the fairness floor and (b) is not dominated by
    /// a cheaper point. Returns (cpus, mem_gb, tput) ascending by cost.
    pub fn pareto_options(&self) -> Vec<(f64, f64, f64)> {
        self.pareto_options_with_floor(self.proportional_throughput())
    }

    /// [`Self::pareto_options`] against an explicit fairness floor — the
    /// heterogeneous OPT (paper A.2.3, constraint 26) floors against the
    /// oracle `W_j^Fair` rather than this type's proportional point.
    pub fn pareto_options_with_floor(
        &self,
        floor: f64,
    ) -> Vec<(f64, f64, f64)> {
        let mut opts: Vec<(f64, f64, f64)> = Vec::new();
        for (ci, &c) in self.cpu_points.iter().enumerate() {
            for (mi, &m) in self.mem_points.iter().enumerate() {
                let t = self.tput[ci][mi];
                if t + 1e-9 >= floor && t > 0.0 {
                    opts.push((c, m, t));
                }
            }
        }
        // Dominance prune: drop options with another option that is
        // cheaper-or-equal in both resources and at least as fast.
        let mut keep: Vec<(f64, f64, f64)> = Vec::new();
        for &(c, m, t) in &opts {
            let dominated = opts.iter().any(|&(c2, m2, t2)| {
                (c2 < c - 1e-9 || m2 < m - 1e-9)
                    && c2 <= c + 1e-9
                    && m2 <= m + 1e-9
                    && t2 + 1e-9 >= t
            });
            if !dominated {
                keep.push((c, m, t));
            }
        }
        // Also drop equal-throughput duplicates, keeping the cheapest.
        keep.sort_by(|a, b| {
            (a.0 + a.1 / 12.5)
                .partial_cmp(&(b.0 + b.1 / 12.5))
                .unwrap()
        });
        let mut out: Vec<(f64, f64, f64)> = Vec::new();
        for o in keep {
            if !out.iter().any(|p| (p.2 - o.2).abs() < 1e-9) {
                out.push(o);
            }
        }
        out
    }

    /// Always-feasible fallback option: the proportional allocation itself.
    pub fn proportional_option(&self) -> (f64, f64, f64) {
        (self.prop_cpus, self.prop_mem_gb, self.proportional_throughput())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{Job, JobId, ModelKind};
    use crate::profiler::OptimisticProfiler;

    fn matrix(model: ModelKind, gpus: u32) -> SensitivityMatrix {
        let p = OptimisticProfiler::noiseless(ServerSpec::default());
        p.profile(&Job::new(JobId(1), model, gpus, 0.0, 60.0)).into_primary()
    }

    #[test]
    fn throughput_lookup_floors_to_grid() {
        let m = matrix(ModelKind::ResNet18, 1);
        let exact = m.throughput_at(3.0, 62.5);
        let above = m.throughput_at(3.9, 70.0);
        assert_eq!(exact, above); // floors to (3, 62.5)
        assert_eq!(m.throughput_at(0.5, 62.5), 0.0); // below grid
    }

    #[test]
    fn proportional_floor_positive() {
        for k in crate::job::ALL_MODELS {
            let m = matrix(k, 1);
            assert!(m.proportional_throughput() > 0.0, "{k:?}");
            assert!(m.max_throughput() >= m.proportional_throughput());
        }
    }

    #[test]
    fn best_demand_cpu_matches_knee() {
        // ResNet18 knee is 7 cores (zoo calibration).
        let m = matrix(ModelKind::ResNet18, 1);
        let d = m.best_demand();
        assert!((6.0..=9.0).contains(&d.cpus), "cpus={}", d.cpus);
        // Memory demand must cover the dataset-ish cache need.
        assert!(d.mem_gb > 62.5, "mem={}", d.mem_gb);
    }

    #[test]
    fn language_best_demand_is_tiny() {
        let m = matrix(ModelKind::Gnmt, 1);
        let d = m.best_demand();
        assert!(d.cpus <= 2.0, "cpus={}", d.cpus);
        assert!(d.mem_gb <= 62.5, "mem={}", d.mem_gb);
    }

    #[test]
    fn pareto_options_small_and_valid() {
        let m = matrix(ModelKind::ResNet18, 1);
        let opts = m.pareto_options();
        assert!(!opts.is_empty());
        assert!(opts.len() <= 60, "{} options survived pruning", opts.len());
        let floor = m.proportional_throughput();
        for &(c, mem, t) in &opts {
            assert!(t + 1e-9 >= floor);
            assert!(c >= 1.0 && mem >= 12.5);
        }
    }

    #[test]
    fn pareto_contains_a_near_peak_option() {
        let m = matrix(ModelKind::AlexNet, 1);
        let opts = m.pareto_options();
        let peak = m.max_throughput();
        assert!(opts.iter().any(|&(_, _, t)| t >= peak * 0.98));
    }

    #[test]
    fn demand_saturation_monotone() {
        let m = matrix(ModelKind::ShuffleNetV2, 1);
        let d90 = m.demand_at_saturation(0.90);
        let d99 = m.demand_at_saturation(0.99);
        assert!(d99.cpus >= d90.cpus);
    }
}
