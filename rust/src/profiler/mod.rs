//! Optimistic profiling (paper §3.1, Figures 4 & 5; type dimension per
//! A.2.1).
//!
//! On job arrival, Synergy builds the job's *resource sensitivity*: its
//! throughput at every discrete (CPU, memory) allocation, for every
//! machine type present in the fleet — the 3-D structure `W_j[k][c, m]`
//! of the heterogeneous formulation, stored as one
//! [`SensitivityMatrix`] per [`GpuGen`] ([`Sensitivity`]). A one-type
//! fleet degenerates to the paper's homogeneous `W_j[c, m]` with exactly
//! the homogeneous profiling cost; each extra type adds one more sweep,
//! so cost scales with `|K|` (A.2: "profiling CPU and memory
//! requirements along an additional dimension — GPU type, at an
//! additional profiling cost").
//!
//! Profiling every cell empirically would take hours (24 CPUs × 10
//! memory levels × 1 min ≈ 4 h per type); optimistic profiling reduces
//! this two ways:
//!
//! 1. **Memory axis is analytic**: with MinIO, the miss rate at memory
//!    `m` is exactly `1 - m/dataset`, and the storage bandwidth is known,
//!    so throughput at (c, m) is `min(empirical_tput(c), fetch_rate(m))`.
//!    Only the CPU axis (at full memory) is measured empirically.
//! 2. **CPU axis is sampled adaptively**: starting from the full range,
//!    regions whose endpoints differ by less than a threshold are assumed
//!    flat; regions with curvature are bisected (paper: ~8 points instead
//!    of 24).
//!
//! The profiler only sees *noisy point measurements* of the ground-truth
//! [`PerfModel`]s — exactly the information a real profiling run yields —
//! so the Fig-5 validation benches compare estimate vs truth honestly.
//! Each (job, type) pair draws an independent deterministic noise
//! stream; the V100 stream is salt-0, so a one-type V100 fleet
//! reproduces the pre-unification homogeneous profiler bit-for-bit.

mod matrix;

pub use matrix::SensitivityMatrix;

use crate::cluster::{Fleet, GpuGen, ServerSpec};
use crate::job::{Job, Task};
use crate::perf::{PerfModel, STORAGE_BW_MB_PER_GPU};
use crate::util::rng::Pcg64;

/// Memory grid granularity, GB. 12.5 keeps the 62.5 GB/GPU proportional
/// share on-grid (DESIGN.md §6).
pub const MEM_UNIT_GB: f64 = 12.5;

/// Profiling cost model: one empirical point ≈ one minute (paper §3.1).
pub const MINUTES_PER_POINT: f64 = 1.0;

/// One job's full resource sensitivity: the 3-D `W_j[k][c, m]` — one
/// matrix per machine type profiled (A.2.1). For a one-type fleet this
/// is the paper's homogeneous `W_j[c, m]` plus its profiling-cost
/// accounting.
#[derive(Debug, Clone)]
pub struct Sensitivity {
    /// `(generation, matrix)` pairs, one per machine type profiled, in
    /// fleet pool order.
    pub per_type: Vec<(GpuGen, SensitivityMatrix)>,
    /// Total empirical (CPU) points measured across all types.
    pub empirical_points: usize,
    /// Estimated profiling wall-clock cost, minutes.
    pub cost_minutes: f64,
    /// Index of the slowest generation in `per_type` (the fairness
    /// oracle's basis), cached at construction — policy views query it
    /// every round for every job.
    floor_idx: usize,
    /// Cached oracle `W_j^Fair`.
    fair: f64,
}

impl Sensitivity {
    /// Build from per-type matrices, caching the fairness oracle.
    pub fn new(
        per_type: Vec<(GpuGen, SensitivityMatrix)>,
        empirical_points: usize,
    ) -> Sensitivity {
        assert!(!per_type.is_empty(), "profiled on at least one type");
        let floor_idx = (0..per_type.len())
            .min_by(|&a, &b| {
                per_type[a]
                    .0
                    .compute_scale(Task::Image)
                    .partial_cmp(&per_type[b].0.compute_scale(Task::Image))
                    .unwrap()
            })
            .unwrap();
        let fair = per_type[floor_idx].1.proportional_throughput();
        Sensitivity {
            per_type,
            empirical_points,
            cost_minutes: empirical_points as f64 * MINUTES_PER_POINT,
            floor_idx,
            fair,
        }
    }

    pub fn matrix(&self, gen: GpuGen) -> Option<&SensitivityMatrix> {
        self.per_type.iter().find(|(g, _)| *g == gen).map(|(_, m)| m)
    }

    /// Generations this job was profiled on.
    pub fn gens(&self) -> Vec<GpuGen> {
        self.per_type.iter().map(|(g, _)| *g).collect()
    }

    /// The first (for a one-type fleet: the only) matrix.
    pub fn primary(&self) -> &SensitivityMatrix {
        &self.per_type[0].1
    }

    /// Consume into the first matrix (single-type convenience).
    pub fn into_primary(self) -> SensitivityMatrix {
        self.per_type
            .into_iter()
            .next()
            .expect("profiled on at least one type")
            .1
    }

    /// The slowest-generation matrix — the basis of the fairness oracle.
    pub fn floor_matrix(&self) -> &SensitivityMatrix {
        &self.per_type[self.floor_idx].1
    }

    /// The conservative fairness oracle `W_j^Fair` (A.2.2): the
    /// GPU-proportional throughput on the slowest generation profiled.
    /// On a one-type fleet this is exactly the homogeneous proportional
    /// floor `W_j[C_g, M_g]` (§4.1). Cached at construction.
    pub fn fair_throughput(&self) -> f64 {
        self.fair
    }
}

/// The optimistic profiler: one instance profiles a job on every machine
/// type of its fleet (one [`PerfModel`] ground truth per type).
#[derive(Debug, Clone)]
pub struct OptimisticProfiler {
    /// Ground truth per machine type, in fleet pool order.
    pub worlds: Vec<PerfModel>,
    /// Multiplicative measurement noise (std dev), e.g. 0.03.
    pub noise_sd: f64,
    /// Flatness threshold for adaptive CPU sampling (paper uses 10%).
    pub threshold: f64,
    /// Grid-widening factor for multi-GPU jobs: profile CPU/memory up to
    /// `span_factor ×` the job's consolidated server span. 1 (default)
    /// is the paper's consolidation-strict assumption (§6: "no more than
    /// a server's worth of CPU or memory ... if its GPU demands can be
    /// satisfied by one server"); 2 lets the scheduler trade
    /// consolidation for allocation (the §6 future-work ablation).
    pub span_factor: usize,
}

impl OptimisticProfiler {
    /// Profiler for a one-type V100 fleet of `spec` servers.
    pub fn new(spec: ServerSpec) -> OptimisticProfiler {
        OptimisticProfiler {
            worlds: vec![PerfModel::new(spec)],
            noise_sd: 0.03,
            threshold: 0.10,
            span_factor: 1,
        }
    }

    /// Noise-free single-type variant (for exactness-sensitive tests).
    pub fn noiseless(spec: ServerSpec) -> OptimisticProfiler {
        OptimisticProfiler { noise_sd: 0.0, ..OptimisticProfiler::new(spec) }
    }

    /// Profiler covering every type pool in `fleet` (A.2's `W_ij` at
    /// `|K|×` the cost).
    pub fn for_fleet(fleet: &Fleet) -> OptimisticProfiler {
        OptimisticProfiler {
            worlds: fleet
                .pools
                .iter()
                .map(|p| PerfModel::with_gen(p.cluster.spec, p.gen))
                .collect(),
            noise_sd: 0.03,
            threshold: 0.10,
            span_factor: 1,
        }
    }

    /// Noise-free fleet variant.
    pub fn noiseless_fleet(fleet: &Fleet) -> OptimisticProfiler {
        OptimisticProfiler { noise_sd: 0.0, ..OptimisticProfiler::for_fleet(fleet) }
    }

    /// Profile a job on every machine type: adaptive CPU sweep at full
    /// memory + analytic memory fill, once per type. Deterministic given
    /// the job's RNG stream (each (job, type) pair draws an independent
    /// noise stream; V100 is salt-0 for homogeneous bit-compatibility).
    pub fn profile(&self, job: &Job) -> Sensitivity {
        let mut per_type = Vec::with_capacity(self.worlds.len());
        let mut points = 0usize;
        for world in &self.worlds {
            let spec = world.spec;
            let mut span =
                ((job.gpus + spec.gpus - 1) / spec.gpus).max(1) as usize;
            if job.gpus > 1 {
                // Single-GPU jobs cannot split across servers (§4.2), so
                // the widened grid only applies to multi-GPU jobs.
                span *= self.span_factor.max(1);
            }
            let max_cpus = spec.cpus as usize * span;
            let max_mem = spec.mem_gb * span as f64;

            let mut rng = Pcg64::new(
                0x5EED_0F11 ^ job.rng_stream,
                job.rng_stream ^ world.gen.seed_salt(),
            );

            // --- adaptive empirical CPU sweep at full memory -------------
            let (pts, n_points) =
                adaptive_cpu_sweep(max_cpus, self.threshold, |c| {
                    let t = world.throughput(
                        job.model,
                        job.gpus,
                        c as f64,
                        max_mem,
                    );
                    if self.noise_sd == 0.0 {
                        t
                    } else {
                        (t * (1.0 + self.noise_sd * rng.normal())).max(0.0)
                    }
                });
            points += n_points;

            // Monotone piecewise-linear interpolation over measured points.
            let cpu_curve: Vec<f64> =
                (0..=max_cpus).map(|c| interp(&pts, c as f64)).collect();

            // --- analytic memory fill ------------------------------------
            let mem_points = mem_grid(max_mem);
            let cpu_points: Vec<f64> =
                (1..=max_cpus).map(|c| c as f64).collect();
            let tput = analytic_memory_fill(
                job.model,
                job.gpus,
                &cpu_curve,
                &mem_points,
            );

            let prop_c =
                spec.cpus as f64 / spec.gpus as f64 * job.gpus as f64;
            let prop_m = spec.mem_gb / spec.gpus as f64 * job.gpus as f64;
            per_type.push((
                world.gen,
                SensitivityMatrix::new(
                    job.model, job.gpus, cpu_points, mem_points, tput,
                    prop_c, prop_m,
                ),
            ));
        }
        Sensitivity::new(per_type, points)
    }
}

/// The memory grid for a job spanning `max_mem` GB: multiples of
/// [`MEM_UNIT_GB`].
pub fn mem_grid(max_mem: f64) -> Vec<f64> {
    let mut v = Vec::new();
    let mut m = MEM_UNIT_GB;
    while m <= max_mem + 1e-9 {
        v.push(m);
        m += MEM_UNIT_GB;
    }
    v
}

/// Adaptive empirical sweep of the CPU axis (paper §3.1's binary-search
/// point selection): measure the endpoints, then recursively bisect only
/// the regions whose endpoints differ by more than `threshold`
/// (relative). Returns the measured `(cpus, tput)` points, ascending, and
/// the number of empirical measurements taken.
///
/// One sweep per machine type (paper A.2: the same sweep runs once per
/// type, at `|K|×` the cost).
pub fn adaptive_cpu_sweep(
    max_cpus: usize,
    threshold: f64,
    mut measure: impl FnMut(usize) -> f64,
) -> (Vec<(usize, f64)>, usize) {
    let mut measured: Vec<Option<f64>> = vec![None; max_cpus + 1];
    let mut n_points = 0usize;
    let mut measure_at = |c: usize, measured: &mut Vec<Option<f64>>| {
        if measured[c].is_none() {
            measured[c] = Some(measure(c));
            n_points += 1;
        }
    };
    measure_at(1, &mut measured);
    measure_at(max_cpus, &mut measured);
    // Recursive bisection of regions with curvature.
    let mut stack = vec![(1usize, max_cpus)];
    while let Some((lo, hi)) = stack.pop() {
        if hi - lo <= 1 {
            continue;
        }
        let tl = measured[lo].unwrap();
        let th = measured[hi].unwrap();
        let rel = if tl > 0.0 { (th - tl).abs() / tl } else { 1.0 };
        if rel < threshold {
            continue; // flat region: skip (paper's lower-half skip)
        }
        let mid = (lo + hi) / 2;
        measure_at(mid, &mut measured);
        stack.push((lo, mid));
        stack.push((mid, hi));
    }
    let pts: Vec<(usize, f64)> = measured
        .iter()
        .enumerate()
        .filter_map(|(c, t)| t.map(|t| (c, t)))
        .collect();
    (pts, n_points)
}

/// Analytic completion of the memory axis (paper §3.1): with MinIO, the
/// throughput at `(c, m)` is the empirical CPU-bound rate capped by the
/// fetch rate the cache's fixed miss fraction allows. The fetch path is
/// host-side, so this fill is identical for every GPU generation.
pub fn analytic_memory_fill(
    model: crate::job::ModelKind,
    gpus: u32,
    cpu_curve: &[f64],
    mem_points: &[f64],
) -> Vec<Vec<f64>> {
    let co = model.coeffs();
    let bw_kb = STORAGE_BW_MB_PER_GPU * 1024.0 * gpus as f64;
    (1..cpu_curve.len())
        .map(|c| {
            mem_points
                .iter()
                .map(|&m| {
                    if m < co.min_mem_gb {
                        return 0.0;
                    }
                    let cache = crate::perf::cache::MinIoCache::new(
                        co.dataset_gb,
                        m - co.min_mem_gb,
                    );
                    let miss = cache.miss_fraction();
                    let fetch = if miss <= 0.0 {
                        f64::INFINITY
                    } else {
                        bw_kb / (miss * co.sample_kb)
                    };
                    cpu_curve[c].min(fetch)
                })
                .collect()
        })
        .collect()
}

/// Linear interpolation over sorted (x, y) integer sample points.
pub fn interp(pts: &[(usize, f64)], x: f64) -> f64 {
    if pts.is_empty() {
        return 0.0;
    }
    if x <= pts[0].0 as f64 {
        return pts[0].1;
    }
    if x >= pts[pts.len() - 1].0 as f64 {
        return pts[pts.len() - 1].1;
    }
    for w in pts.windows(2) {
        let (x0, y0) = (w[0].0 as f64, w[0].1);
        let (x1, y1) = (w[1].0 as f64, w[1].1);
        if x <= x1 {
            let f = (x - x0) / (x1 - x0);
            return y0 + f * (y1 - y0);
        }
    }
    pts[pts.len() - 1].1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{Job, JobId, ModelKind};

    fn job(model: ModelKind, gpus: u32) -> Job {
        Job::new(JobId(9), model, gpus, 0.0, 3600.0)
    }

    fn profiler() -> OptimisticProfiler {
        OptimisticProfiler::noiseless(ServerSpec::default())
    }

    #[test]
    fn profile_estimates_close_to_truth_resnet18() {
        // Fig 5 validation: estimate within a few % of ground truth at
        // every grid point.
        let p = profiler();
        let j = job(ModelKind::ResNet18, 1);
        let out = p.profile(&j).into_primary();
        let world = PerfModel::new(ServerSpec::default());
        let mut worst: f64 = 0.0;
        for (ci, &c) in out.cpu_points.iter().enumerate() {
            for (mi, &m) in out.mem_points.iter().enumerate() {
                let truth = world.throughput(ModelKind::ResNet18, 1, c, m);
                let est = out.tput[ci][mi];
                if truth > 0.0 {
                    worst = worst.max((est - truth).abs() / truth);
                }
            }
        }
        assert!(worst < 0.12, "worst relative error {worst}");
    }

    #[test]
    fn profiling_cost_is_much_below_exhaustive() {
        // Paper §3.1: ~8 CPU points instead of 24.
        let p = profiler();
        let out = p.profile(&job(ModelKind::ResNet18, 1));
        assert!(out.empirical_points <= 12,
                "{} empirical points", out.empirical_points);
        assert!(out.empirical_points >= 3);
        assert!(out.cost_minutes < 24.0 * MINUTES_PER_POINT);
    }

    #[test]
    fn flat_models_profile_with_few_points() {
        // Language models are CPU-insensitive; the sweep should terminate
        // almost immediately.
        let p = profiler();
        let out = p.profile(&job(ModelKind::Gnmt, 1));
        assert!(out.empirical_points <= 4,
                "{} points for a flat curve", out.empirical_points);
    }

    #[test]
    fn matrix_dimensions_cover_grid() {
        let p = profiler();
        let out = p.profile(&job(ModelKind::AlexNet, 1));
        assert_eq!(out.primary().cpu_points.len(), 24);
        assert_eq!(out.primary().mem_points.len(), 40); // 500 / 12.5
    }

    #[test]
    fn multi_gpu_job_spans_more_resources() {
        let p = profiler();
        let out = p.profile(&job(ModelKind::ResNet18, 16)).into_primary();
        assert_eq!(out.cpu_points.len(), 48); // 2 servers
        assert!((out.mem_points.last().unwrap() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_per_job() {
        let p = OptimisticProfiler::new(ServerSpec::default());
        let j = job(ModelKind::MobileNetV2, 2);
        let a = p.profile(&j);
        let b = p.profile(&j);
        assert_eq!(a.empirical_points, b.empirical_points);
        assert_eq!(a.primary().tput, b.primary().tput);
    }

    #[test]
    fn interp_endpoints_and_midpoint() {
        let pts = vec![(1usize, 10.0), (5, 50.0)];
        assert_eq!(interp(&pts, 0.0), 10.0);
        assert_eq!(interp(&pts, 3.0), 30.0);
        assert_eq!(interp(&pts, 9.0), 50.0);
    }

    // --- per-type (A.2) behaviour -------------------------------------

    fn fleet() -> Fleet {
        Fleet::two_tier(2)
    }

    #[test]
    fn profiles_every_type_in_the_fleet() {
        let p = OptimisticProfiler::noiseless_fleet(&fleet());
        let s = p.profile(&job(ModelKind::ResNet18, 1));
        assert_eq!(s.per_type.len(), 2);
        assert!(s.matrix(GpuGen::P100).is_some());
        assert!(s.matrix(GpuGen::V100).is_some());
        assert!(s.matrix(GpuGen::A100).is_none());
    }

    #[test]
    fn per_type_matrices_reflect_generation_speed() {
        let p = OptimisticProfiler::noiseless_fleet(&fleet());
        let s = p.profile(&job(ModelKind::Gnmt, 1)); // compute-bound
        let slow = s.matrix(GpuGen::P100).unwrap().max_throughput();
        let fast = s.matrix(GpuGen::V100).unwrap().max_throughput();
        assert!(
            fast / slow > 1.5,
            "compute-bound job must be faster on V100: {slow} vs {fast}"
        );
    }

    #[test]
    fn cost_scales_with_type_count() {
        let two = OptimisticProfiler::noiseless_fleet(&fleet());
        let j = job(ModelKind::AlexNet, 1);
        let s2 = two.profile(&j);
        let one = OptimisticProfiler {
            worlds: two.worlds[..1].to_vec(),
            ..two.clone()
        };
        let s1 = one.profile(&j);
        assert!(
            s2.cost_minutes > s1.cost_minutes,
            "profiling 2 types must cost more than 1"
        );
    }

    #[test]
    fn one_type_fleet_reproduces_single_type_profile_exactly() {
        // The issue's parity clause: a one-type cluster reproduces the
        // homogeneous cost and matrices exactly — including noise, since
        // V100's seed salt is 0.
        let spec = ServerSpec::default();
        let single = OptimisticProfiler::new(spec);
        let fleet1 = Fleet::homogeneous(spec, 2);
        let via_fleet = OptimisticProfiler {
            noise_sd: single.noise_sd,
            ..OptimisticProfiler::for_fleet(&fleet1)
        };
        let j = job(ModelKind::ResNet50, 1);
        let a = single.profile(&j);
        let b = via_fleet.profile(&j);
        assert_eq!(a.empirical_points, b.empirical_points);
        assert_eq!(a.cost_minutes, b.cost_minutes);
        assert_eq!(a.primary().tput, b.primary().tput);
    }

    #[test]
    fn fair_oracle_is_slowest_type_proportional() {
        let p = OptimisticProfiler::noiseless_fleet(&fleet());
        let s = p.profile(&job(ModelKind::Gnmt, 1));
        let fair = s.fair_throughput();
        let p100 = s.matrix(GpuGen::P100).unwrap().proportional_throughput();
        assert_eq!(fair, p100);
        // Any type's proportional throughput dominates the oracle.
        for (_, m) in &s.per_type {
            assert!(m.proportional_throughput() + 1e-9 >= fair);
        }
    }
}
