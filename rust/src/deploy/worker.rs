//! Worker: hosts job runners that execute real PJRT training steps.
//!
//! Each leased job gets a runner thread owning a [`Trainer`] (the AOT
//! transformer). The runner executes train steps continuously — the *real*
//! compute path through HLO/PJRT — while scheduler-visible progress
//! accrues at the granted throughput (`target_tput`, in simulated
//! samples/s, times the experiment's time scale), which is how the
//! performance model's data-stall behaviour is injected into live runs.
//!
//! Lease semantics (§4.3): a lease not renewed within two round lengths
//! expires; the runner checkpoints (params to worker memory) and stops.
//! A re-lease restores from the checkpoint.

use super::proto::{Conn, Message};
use crate::runtime::{Runtime, SyntheticCorpus, Trainer};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub leader_addr: String,
    pub artifacts_dir: String,
    pub gpus: u32,
    pub cpus: u32,
    pub mem_gb: f64,
    /// GPU generation name reported at registration (mixed-generation
    /// fleets; `--gen p100` etc.).
    pub gen: String,
    /// If false, skip PJRT execution (progress-only worker, for protocol
    /// tests on machines without artifacts).
    pub real_compute: bool,
    /// Fault injection: crash (drop the connection without draining
    /// runners) after this many real seconds. Used by the failover tests.
    pub fail_after_s: Option<f64>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            leader_addr: "127.0.0.1:7331".into(),
            artifacts_dir: "artifacts".into(),
            gpus: 8,
            cpus: 24,
            mem_gb: 500.0,
            gen: "v100".into(),
            real_compute: true,
            fail_after_s: None,
        }
    }
}

struct LeaseState {
    target_tput: f64,
    deadline: Instant,
    total_samples: f64,
    /// Progress (leader's view) to resume from when this runner starts.
    done_samples: f64,
}

struct RunnerHandle {
    stop: Arc<AtomicBool>,
    lease: Arc<Mutex<LeaseState>>,
    join: std::thread::JoinHandle<()>,
}

/// The worker process body.
pub struct Worker;

impl Worker {
    /// Connect to the leader and serve until Shutdown. Blocks.
    pub fn run(cfg: WorkerConfig) -> Result<usize> {
        let stream = connect_with_backoff(&cfg.leader_addr)?;
        let mut conn = Conn::new(stream.try_clone()?)?;
        conn.send(&Message::Register {
            gpus: cfg.gpus,
            cpus: cfg.cpus,
            mem_gb: cfg.mem_gb,
            gen: cfg.gen.clone(),
        })?;
        let (server_id, heartbeat_s) = match conn.recv()? {
            Some(Message::RegisterAck { server_id, heartbeat_s }) => {
                (server_id, heartbeat_s)
            }
            Some(Message::Error { reason }) => {
                return Err(anyhow!("leader rejected registration: {reason}"))
            }
            other => return Err(anyhow!("expected ack, got {other:?}")),
        };

        // Shared writer for runner threads.
        let writer: Arc<Mutex<TcpStream>> =
            Arc::new(Mutex::new(stream.try_clone()?));

        // Heartbeat lease: beat at half the leader's period so one lost
        // frame never expires the lease. The thread dies with the
        // socket (write failure) or when the main loop exits.
        let hb_stop = Arc::new(AtomicBool::new(false));
        if heartbeat_s > 0.0 {
            let writer = Arc::clone(&writer);
            let stop = Arc::clone(&hb_stop);
            std::thread::spawn(move || {
                use std::io::Write;
                let mut line =
                    Message::Heartbeat { server_id }.encode();
                line.push('\n');
                while !stop.load(Ordering::SeqCst) {
                    {
                        let Ok(mut w) = writer.lock() else { break };
                        if w.write_all(line.as_bytes()).is_err() {
                            break;
                        }
                    }
                    std::thread::sleep(Duration::from_secs_f64(
                        heartbeat_s / 2.0,
                    ));
                }
            });
        }
        // Checkpoint store: job -> host params.
        let checkpoints: Arc<Mutex<HashMap<u64, Vec<f32>>>> =
            Arc::new(Mutex::new(HashMap::new()));

        let mut runners: HashMap<u64, RunnerHandle> = HashMap::new();
        let mut jobs_run = 0usize;

        // Fault injection: poll the clock between frames so the "crash"
        // lands even while idle.
        let started = Instant::now();
        if cfg.fail_after_s.is_some() {
            conn.set_read_timeout(Some(Duration::from_millis(50)))?;
        }

        loop {
            if let Some(t) = cfg.fail_after_s {
                if started.elapsed().as_secs_f64() >= t {
                    // Simulated crash: stop runners' progress and vanish
                    // without a protocol goodbye. Shut the socket down
                    // at the fd level — runner/heartbeat threads hold
                    // clones, and the leader must see EOF *now*, not
                    // when the last clone drops.
                    hb_stop.store(true, Ordering::SeqCst);
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                    for (_, h) in runners.drain() {
                        h.stop.store(true, Ordering::SeqCst);
                        let _ = h.join.join();
                    }
                    return Err(anyhow!("injected crash after {t}s"));
                }
            }
            let msg = match conn.recv() {
                Ok(Some(m)) => m,
                Ok(None) => break, // leader hung up
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue // read timeout tick (fault-injection polling)
                }
                Err(e) => return Err(e.into()),
            };
            match msg {
                Message::Lease {
                    job_id,
                    variant,
                    target_tput,
                    round_s,
                    total_samples,
                    done_samples,
                    ..
                } => {
                    // A runner whose lease expired (renewal arrived late)
                    // exits on its own; reap the dead handle so the lease
                    // below restarts it rather than renewing a corpse.
                    if runners
                        .get(&job_id)
                        .is_some_and(|h| h.join.is_finished())
                    {
                        if let Some(h) = runners.remove(&job_id) {
                            let _ = h.join.join();
                        }
                        if std::env::var_os("SYNERGY_DEPLOY_DEBUG").is_some()
                        {
                            eprintln!("[worker] reaped dead runner {job_id}");
                        }
                    }
                    let deadline = Instant::now()
                        + Duration::from_secs_f64(round_s * 3.0);
                    if let Some(h) = runners.get(&job_id) {
                        // Renewal: update rate + extend lease.
                        let mut lease = h.lease.lock().unwrap();
                        lease.target_tput = target_tput;
                        lease.deadline = deadline;
                    } else {
                        let lease = Arc::new(Mutex::new(LeaseState {
                            target_tput,
                            deadline,
                            total_samples,
                            done_samples,
                        }));
                        let stop = Arc::new(AtomicBool::new(false));
                        let join = spawn_runner(
                            job_id,
                            variant,
                            cfg.clone(),
                            Arc::clone(&lease),
                            Arc::clone(&stop),
                            Arc::clone(&writer),
                            Arc::clone(&checkpoints),
                        );
                        runners.insert(
                            job_id,
                            RunnerHandle { stop, lease, join },
                        );
                        jobs_run += 1;
                        if std::env::var_os("SYNERGY_DEPLOY_DEBUG").is_some()
                        {
                            eprintln!(
                                "[worker] spawned runner {job_id} \
                                 done={done_samples:.0}"
                            );
                        }
                    }
                }
                Message::Terminate { job_id } => {
                    if let Some(h) = runners.remove(&job_id) {
                        h.stop.store(true, Ordering::SeqCst);
                        let _ = h.join.join();
                    }
                }
                Message::Shutdown => break,
                other => {
                    // Unknown frames are ignored, not fatal: a newer
                    // leader may speak a superset of this protocol, and
                    // dying here would turn that into a worker "crash"
                    // the leader then fails over.
                    if std::env::var_os("SYNERGY_DEPLOY_DEBUG").is_some() {
                        eprintln!("[worker] ignoring frame {other:?}");
                    }
                }
            }
        }
        hb_stop.store(true, Ordering::SeqCst);
        // Drain runners.
        for (_, h) in runners {
            h.stop.store(true, Ordering::SeqCst);
            let _ = h.join.join();
        }
        let _ = server_id;
        Ok(jobs_run)
    }
}

/// Connect to the leader with deterministic capped exponential backoff:
/// one immediate attempt plus three retries after fixed, jitter-free
/// 100/200/400 ms delays, each attempt bounded by a connect timeout.
/// A worker started moments before its leader binds still joins, and the
/// schedule stays reproducible (no randomized jitter).
fn connect_with_backoff(addr: &str) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    const RETRY_DELAYS_MS: [u64; 3] = [100, 200, 400];
    const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
    let mut attempt = 0usize;
    loop {
        let res = addr
            .to_socket_addrs()
            .map_err(anyhow::Error::from)
            .and_then(|mut addrs| {
                addrs
                    .next()
                    .ok_or_else(|| anyhow!("{addr}: no socket address"))
            })
            .and_then(|sa| {
                TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT)
                    .map_err(anyhow::Error::from)
            });
        match res {
            Ok(stream) => return Ok(stream),
            Err(e) if attempt < RETRY_DELAYS_MS.len() => {
                if std::env::var_os("SYNERGY_DEPLOY_DEBUG").is_some() {
                    eprintln!(
                        "[worker] connect attempt {} to {addr} failed \
                         ({e}); retrying in {} ms",
                        attempt + 1,
                        RETRY_DELAYS_MS[attempt]
                    );
                }
                std::thread::sleep(Duration::from_millis(
                    RETRY_DELAYS_MS[attempt],
                ));
                attempt += 1;
            }
            Err(e) => {
                return Err(anyhow!(
                    "connect to {addr} failed after {} attempts: {e}",
                    RETRY_DELAYS_MS.len() + 1
                ))
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn spawn_runner(
    job_id: u64,
    variant: String,
    cfg: WorkerConfig,
    lease: Arc<Mutex<LeaseState>>,
    stop: Arc<AtomicBool>,
    writer: Arc<Mutex<TcpStream>>,
    checkpoints: Arc<Mutex<HashMap<u64, Vec<f32>>>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let send = |msg: &Message| {
            use std::io::Write;
            let mut line = msg.encode();
            line.push('\n');
            if let Ok(mut w) = writer.lock() {
                let _ = w.write_all(line.as_bytes());
            }
        };

        // Real compute setup. PjRtClient is not Send, so each runner
        // thread owns its own CPU client + compiled executable.
        let mut trainer: Option<Trainer> = None;
        let mut corpus: Option<SyntheticCorpus> = None;
        let runtime: Option<Runtime> = if cfg.real_compute {
            match Runtime::cpu() {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!("[worker] pjrt init: {e}");
                    None
                }
            }
        } else {
            None
        };
        if let Some(rt) = &runtime {
            match rt.load_variant(&cfg.artifacts_dir, &variant) {
                Ok((meta, exe)) => {
                    let vocab = meta.vocab;
                    match Trainer::new(&rt.client, exe, meta, job_id) {
                        Ok(mut t) => {
                            if let Some(ckpt) =
                                checkpoints.lock().unwrap().get(&job_id)
                            {
                                let _ = t.restore(ckpt);
                            }
                            corpus = Some(SyntheticCorpus::new(
                                vocab,
                                job_id ^ 0xDA7A,
                            ));
                            trainer = Some(t);
                        }
                        Err(e) => eprintln!("[worker] trainer init: {e}"),
                    }
                }
                Err(e) => eprintln!("[worker] load {variant}: {e}"),
            }
        }

        // Resume scheduler-visible progress from the leader's view (set
        // when the lease was created; survives migration and expiry).
        let mut samples_done = lease.lock().unwrap().done_samples;
        let mut steps = 0u64;
        let mut loss = f64::NAN;
        let mut last_report = Instant::now();
        let mut last_tick = Instant::now();
        loop {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let (rate, deadline, total) = {
                let l = lease.lock().unwrap();
                (l.target_tput, l.deadline, l.total_samples)
            };
            if Instant::now() > deadline {
                break; // lease expired without renewal
            }
            // One real training step (the actual L1/L2 compute).
            if let (Some(t), Some(c)) = (trainer.as_mut(), corpus.as_mut()) {
                let toks = c.batch(t.meta.batch, t.meta.seq_len);
                match t.train_step(&toks, 0.05) {
                    Ok(l) => {
                        loss = l as f64;
                        steps += 1;
                    }
                    Err(e) => {
                        eprintln!("[worker] step failed: {e}");
                        break;
                    }
                }
            } else {
                std::thread::sleep(Duration::from_millis(5));
            }
            // Progress accrues at the granted throughput.
            let dt = last_tick.elapsed().as_secs_f64();
            last_tick = Instant::now();
            samples_done += rate * dt;
            if samples_done >= total {
                send(&Message::Finished { job_id });
                break;
            }
            if last_report.elapsed() > Duration::from_millis(250) {
                send(&Message::Progress { job_id, samples_done, loss, steps });
                last_report = Instant::now();
                if std::env::var_os("SYNERGY_DEPLOY_DEBUG").is_some() {
                    eprintln!(
                        "[runner {job_id}] rate={rate:.1} done={samples_done:.0} \
                         total={total:.0}"
                    );
                }
            }
        }
        // Checkpoint on exit (termination or expiry).
        if let Some(t) = &trainer {
            if let Ok(p) = t.params_to_host() {
                checkpoints.lock().unwrap().insert(job_id, p);
            }
        }
        send(&Message::Progress { job_id, samples_done, loss, steps });
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn connect_retries_until_briefly_late_leader_binds() {
        // Reserve a port, then release it so the first connect attempt
        // is refused; a leader binding it 250 ms later lands inside the
        // 100+200 ms retry window, so the backoff connect must succeed
        // on a retry instead of erroring out like the old one-shot did.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let leader = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(250));
            let listener = TcpListener::bind(addr).unwrap();
            let _ = listener.accept();
        });
        let started = Instant::now();
        let stream = connect_with_backoff(&addr.to_string())
            .expect("backoff connect must reach the late leader");
        assert!(
            started.elapsed() >= Duration::from_millis(100),
            "success before the first retry delay means the leader was \
             never late"
        );
        drop(stream);
        leader.join().unwrap();
    }

    #[test]
    fn connect_gives_up_after_the_full_deterministic_schedule() {
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let started = Instant::now();
        let err = connect_with_backoff(&addr.to_string());
        assert!(err.is_err(), "no listener ever binds: connect must fail");
        // Fixed schedule: 100 + 200 + 400 ms of inter-attempt sleeps.
        assert!(
            started.elapsed() >= Duration::from_millis(700),
            "must exhaust the whole 100/200/400 ms backoff schedule"
        );
    }
}
