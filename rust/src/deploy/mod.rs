//! Deploy mode: a live mini-cluster on this host (paper §4.3 + §5.2).
//!
//! The paper's physical deployment runs a gRPC control plane between the
//! scheduler and per-job Synergy iterators. Here:
//!
//! - [`leader`] — the scheduler process: accepts worker registrations
//!   and network job submissions, drives the *simulator's own*
//!   event-driven round loop ([`crate::sim::run_events_driven`]) in
//!   scaled real time, grants/terminates leases, and write-ahead
//!   journals its state so a killed leader recovers bit-exactly.
//! - [`journal`] — the write-ahead state journal: fsync'd append-only
//!   JSONL segments recording submissions, churn, round checkpoints,
//!   and completions; recovery truncates torn tails and replays.
//! - [`worker`] — one process (or thread) per server: hosts
//!   [`JobRunner`]s that execute *real* training iterations of the AOT
//!   transformer through the PJRT runtime, with input-pipeline stalls
//!   injected to match the throughput the job's (c, m) grant yields under
//!   the performance model — the worker-side equivalent of the paper's
//!   wrapped data iterator.
//! - [`proto`] — the wire protocol: newline-delimited JSON over TCP
//!   (tokio/gRPC are unavailable offline; std::net + threads suffice).
//!
//! Lease semantics follow §4.3: every running job asks to continue each
//! round; the leader either renews or terminates (checkpoint + requeue).

pub mod journal;
pub mod leader;
pub mod proto;
pub mod worker;

pub use leader::{Leader, LeaderConfig, LeaderReport};
pub use proto::Message;
pub use worker::{Worker, WorkerConfig};
