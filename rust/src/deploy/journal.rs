//! Write-ahead journal for the live leader.
//!
//! Append-only JSONL records across fsync'd segment files
//! (`wal-NNNNNN.jsonl`). The leader journals every admitted submission
//! *before* acknowledging it, every worker-churn event before acting on
//! it, a fold checkpoint at every round boundary, and every completion
//! it folds into the report. A killed-and-restarted leader replays the
//! journal through the same deterministic round loop and lands in a
//! state byte-identical to the unkilled run.
//!
//! Durability contract: [`JournalWriter::append`] returns only after
//! the record bytes are fsync'd (`util::fsx::append_durable`); the
//! first append to a fresh segment also fsyncs the journal directory so
//! the segment's directory entry survives a crash.
//!
//! Codec contract: every `f64` rides the wire as its IEEE-754 bit
//! pattern in 16 lower-hex digits (`f64::to_bits`), so NaN payloads,
//! signed zeros, and subnormals round-trip bitwise — the recovered
//! leader folds *exactly* the numbers the original leader folded.
//!
//! Recovery contract: a truncated or corrupt tail record (the crash
//! landed mid-`write`) is dropped whole, never half-applied, and
//! nothing after it is read. [`decode_prefix`] is the single arbiter of
//! "well-formed prefix" for both recovery and append-reopen (which
//! physically truncates the torn tail before appending).

use crate::util::fsx;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Journal schema version; bumped on any incompatible record change.
pub const JOURNAL_VERSION: u32 = 1;

/// Records per segment before rotating to a fresh file.
const RECORDS_PER_SEGMENT: usize = 256;

/// One journal record. Field order in the encoding is alphabetical
/// (BTreeMap-backed [`Json`]), so encodings are canonical.
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    /// First record of every journal: schema version plus a canonical
    /// signature of the leader configuration. Recovery refuses a
    /// journal whose signature differs from the restarted leader's —
    /// replaying submissions under a different policy would silently
    /// produce a different (valid-looking) schedule.
    Meta { version: u32, sig: String },
    /// An admitted submission, in admission order. `arrival_bits` /
    /// `duration_bits` are `f64::to_bits` of sim-time seconds. `tname`
    /// is the client-visible tenant name backing dense id `tenant`, so
    /// recovery rebuilds the name→id map and post-recovery resubmits
    /// stay idempotent.
    Submit {
        id: u64,
        tenant: u32,
        tname: String,
        model: String,
        gpus: u32,
        arrival_bits: u64,
        duration_bits: u64,
    },
    /// Worker churn the leader observed and injected (`fail` = lease
    /// expiry or disconnect, `!fail` = rejoin). `slot` is the server
    /// id; `at_bits` is the sim time of injection.
    Churn { fail: bool, slot: usize, at_bits: u64 },
    /// Round-boundary fold checkpoint: after round `round` the sim
    /// clock was `at_bits`, `finished` jobs had completed, and the
    /// FNV-1a hash of the completion log was `hash`. Replay validates
    /// each checkpoint it crosses.
    Ckpt { round: u64, at_bits: u64, finished: u64, hash: u64 },
    /// A completion folded into the final report.
    Done { id: u64, jct_bits: u64, finish_bits: u64 },
}

fn hex64(v: u64) -> Json {
    Json::str(format!("{v:016x}"))
}

fn parse_hex64(j: &Json, key: &str) -> Result<u64, String> {
    let s = j.get(key).as_str().ok_or_else(|| format!("missing {key}"))?;
    if s.len() != 16 {
        return Err(format!("{key}: want 16 hex digits, got {s:?}"));
    }
    u64::from_str_radix(s, 16).map_err(|e| format!("{key}: {e}"))
}

impl Record {
    /// Canonical single-line JSON encoding (no trailing newline).
    pub fn encode(&self) -> String {
        let j = match self {
            Record::Meta { version, sig } => Json::obj(vec![
                ("t", Json::str("meta")),
                ("v", Json::num(*version as f64)),
                ("sig", Json::str(sig.clone())),
            ]),
            Record::Submit {
                id,
                tenant,
                tname,
                model,
                gpus,
                arrival_bits,
                duration_bits,
            } => Json::obj(vec![
                ("t", Json::str("submit")),
                ("id", Json::num(*id as f64)),
                ("tenant", Json::num(*tenant as f64)),
                ("tname", Json::str(tname.clone())),
                ("model", Json::str(model.clone())),
                ("gpus", Json::num(*gpus as f64)),
                ("arrival", hex64(*arrival_bits)),
                ("duration", hex64(*duration_bits)),
            ]),
            Record::Churn { fail, slot, at_bits } => Json::obj(vec![
                ("t", Json::str("churn")),
                ("fail", Json::Bool(*fail)),
                ("slot", Json::num(*slot as f64)),
                ("at", hex64(*at_bits)),
            ]),
            Record::Ckpt { round, at_bits, finished, hash } => Json::obj(vec![
                ("t", Json::str("ckpt")),
                ("round", Json::num(*round as f64)),
                ("at", hex64(*at_bits)),
                ("finished", Json::num(*finished as f64)),
                ("hash", hex64(*hash)),
            ]),
            Record::Done { id, jct_bits, finish_bits } => Json::obj(vec![
                ("t", Json::str("done")),
                ("id", Json::num(*id as f64)),
                ("jct", hex64(*jct_bits)),
                ("finish", hex64(*finish_bits)),
            ]),
        };
        j.encode()
    }

    pub fn decode(line: &str) -> Result<Record, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        let ty = j.get("t").as_str().ok_or("missing t")?;
        let num =
            |k: &str| j.get(k).as_f64().ok_or_else(|| format!("missing {k}"));
        Ok(match ty {
            "meta" => Record::Meta {
                version: num("v")? as u32,
                sig: j
                    .get("sig")
                    .as_str()
                    .ok_or("missing sig")?
                    .to_string(),
            },
            "submit" => Record::Submit {
                id: num("id")? as u64,
                tenant: num("tenant")? as u32,
                tname: j
                    .get("tname")
                    .as_str()
                    .ok_or("missing tname")?
                    .to_string(),
                model: j
                    .get("model")
                    .as_str()
                    .ok_or("missing model")?
                    .to_string(),
                gpus: num("gpus")? as u32,
                arrival_bits: parse_hex64(&j, "arrival")?,
                duration_bits: parse_hex64(&j, "duration")?,
            },
            "churn" => Record::Churn {
                fail: j.get("fail").as_bool().ok_or("missing fail")?,
                slot: num("slot")? as usize,
                at_bits: parse_hex64(&j, "at")?,
            },
            "ckpt" => Record::Ckpt {
                round: num("round")? as u64,
                at_bits: parse_hex64(&j, "at")?,
                finished: num("finished")? as u64,
                hash: parse_hex64(&j, "hash")?,
            },
            "done" => Record::Done {
                id: num("id")? as u64,
                jct_bits: parse_hex64(&j, "jct")?,
                finish_bits: parse_hex64(&j, "finish")?,
            },
            other => return Err(format!("unknown record type {other:?}")),
        })
    }
}

/// FNV-1a 64-bit — checkpoint hash over the completion log.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Decode the longest well-formed prefix of `bytes`: complete,
/// newline-terminated, decodable records. Returns the records and the
/// byte length of that prefix. The first truncated (no trailing '\n')
/// or undecodable record ends the prefix — it is dropped whole, never
/// half-applied, and nothing after it is read.
pub fn decode_prefix(bytes: &[u8]) -> (Vec<Record>, usize) {
    let mut records = Vec::new();
    let mut consumed = 0usize;
    let mut pos = 0usize;
    while pos < bytes.len() {
        let Some(nl) = bytes[pos..].iter().position(|&b| b == b'\n') else {
            break; // torn tail: crash landed mid-write
        };
        let line = &bytes[pos..pos + nl];
        let Ok(text) = std::str::from_utf8(line) else { break };
        let Ok(rec) = Record::decode(text) else { break };
        records.push(rec);
        pos += nl + 1;
        consumed = pos;
    }
    (records, consumed)
}

fn segment_name(index: usize) -> String {
    format!("wal-{index:06}.jsonl")
}

fn segment_paths(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read {}: {}", dir.display(), e))?
        .filter_map(|ent| ent.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("wal-") && n.ends_with(".jsonl"))
                .unwrap_or(false)
        })
        .collect();
    paths.sort(); // zero-padded indices sort lexicographically
    Ok(paths)
}

/// Read every well-formed record in `dir`, in write order. Stops at the
/// first torn or corrupt record (and ignores any later segment — writes
/// are sequential, so nothing after a torn record was acknowledged).
pub fn read_journal(dir: &Path) -> Result<Vec<Record>, String> {
    let mut records = Vec::new();
    for path in segment_paths(dir)? {
        let bytes = std::fs::read(&path)
            .map_err(|e| format!("cannot read {}: {}", path.display(), e))?;
        let (mut recs, consumed) = decode_prefix(&bytes);
        let torn = consumed < bytes.len();
        records.append(&mut recs);
        if torn {
            break;
        }
    }
    Ok(records)
}

/// Appending side of the journal. One live segment at a time; rotation
/// after [`RECORDS_PER_SEGMENT`] records.
pub struct JournalWriter {
    dir: PathBuf,
    seg: usize,
    in_seg: usize,
    per_seg: usize,
}

impl JournalWriter {
    /// Start a fresh journal in `dir`, removing any stale segments from
    /// an earlier run (a fresh `--journal` run must not interleave with
    /// a dead one's records — recovery uses `recover`).
    pub fn create(dir: &Path) -> Result<JournalWriter, String> {
        fsx::ensure_dir(dir)?;
        for old in segment_paths(dir)? {
            std::fs::remove_file(&old).map_err(|e| {
                format!("cannot remove {}: {}", old.display(), e)
            })?;
        }
        fsx::sync_dir(dir)?;
        Ok(JournalWriter {
            dir: dir.to_path_buf(),
            seg: 0,
            in_seg: 0,
            per_seg: RECORDS_PER_SEGMENT,
        })
    }

    /// Reopen `dir` for appending after a crash: read the well-formed
    /// record prefix, physically truncate the torn tail (so new records
    /// never follow a partial line), and position the writer at the
    /// end. Returns the writer plus the recovered records.
    pub fn recover(
        dir: &Path,
    ) -> Result<(JournalWriter, Vec<Record>), String> {
        let paths = segment_paths(dir)?;
        if paths.is_empty() {
            return Err(format!(
                "no journal segments in {} (nothing to recover)",
                dir.display()
            ));
        }
        let mut records = Vec::new();
        let mut seg = 0usize;
        let mut in_seg = 0usize;
        for (i, path) in paths.iter().enumerate() {
            let bytes = std::fs::read(path).map_err(|e| {
                format!("cannot read {}: {}", path.display(), e)
            })?;
            let (mut recs, consumed) = decode_prefix(&bytes);
            let torn = consumed < bytes.len();
            if torn {
                let f = std::fs::OpenOptions::new()
                    .write(true)
                    .open(path)
                    .map_err(|e| {
                        format!("cannot open {}: {}", path.display(), e)
                    })?;
                f.set_len(consumed as u64).map_err(|e| {
                    format!("cannot truncate {}: {}", path.display(), e)
                })?;
                f.sync_data().map_err(|e| {
                    format!("cannot fsync {}: {}", path.display(), e)
                })?;
            }
            seg = i;
            in_seg = recs.len();
            records.append(&mut recs);
            if torn {
                // Later segments (if any) follow unacknowledged bytes;
                // remove them so appends continue here.
                for later in &paths[i + 1..] {
                    std::fs::remove_file(later).map_err(|e| {
                        format!("cannot remove {}: {}", later.display(), e)
                    })?;
                }
                break;
            }
        }
        fsx::sync_dir(dir)?;
        let mut w = JournalWriter {
            dir: dir.to_path_buf(),
            seg,
            in_seg,
            per_seg: RECORDS_PER_SEGMENT,
        };
        if w.in_seg >= w.per_seg {
            w.seg += 1;
            w.in_seg = 0;
        }
        Ok((w, records))
    }

    #[cfg(test)]
    fn with_segment_len(mut self, per_seg: usize) -> JournalWriter {
        self.per_seg = per_seg.max(1);
        self
    }

    /// Durably append one record: bytes are fsync'd before returning,
    /// and the first record of a fresh segment also fsyncs the
    /// directory. Once this returns `Ok`, the record survives a crash.
    pub fn append(&mut self, rec: &Record) -> Result<(), String> {
        let mut line = rec.encode();
        line.push('\n');
        let path = self.dir.join(segment_name(self.seg));
        let fresh_segment = self.in_seg == 0;
        fsx::append_durable(&path, line.as_bytes())?;
        if fresh_segment {
            fsx::sync_dir(&self.dir)?;
        }
        self.in_seg += 1;
        if self.in_seg >= self.per_seg {
            self.seg += 1;
            self.in_seg = 0;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn scratch(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "synergy-journal-{}-{}",
            std::process::id(),
            name
        ))
    }

    fn sample_records() -> Vec<Record> {
        vec![
            Record::Meta { version: JOURNAL_VERSION, sig: "srtf/tune".into() },
            Record::Submit {
                id: 7,
                tenant: 1,
                tname: "team-a".into(),
                model: "resnet18".into(),
                gpus: 4,
                arrival_bits: 0.0f64.to_bits(),
                duration_bits: 3600.5f64.to_bits(),
            },
            Record::Churn { fail: true, slot: 1, at_bits: 120.25f64.to_bits() },
            Record::Churn { fail: false, slot: 1, at_bits: 300.0f64.to_bits() },
            Record::Ckpt {
                round: 3,
                at_bits: 900.0f64.to_bits(),
                finished: 2,
                hash: fnv1a(b"log"),
            },
            Record::Done {
                id: 7,
                jct_bits: 1234.5f64.to_bits(),
                finish_bits: 1234.5f64.to_bits(),
            },
        ]
    }

    #[test]
    fn records_roundtrip_bitwise() {
        for rec in sample_records() {
            let enc = rec.encode();
            assert_eq!(Record::decode(&enc).unwrap(), rec, "{enc}");
        }
        // Bit patterns JSON numbers cannot carry must survive: NaN
        // payloads, infinities, negative zero.
        for weird in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0, 5e-324]
        {
            let rec = Record::Done {
                id: 1,
                jct_bits: weird.to_bits(),
                finish_bits: (-weird).to_bits(),
            };
            let back = Record::decode(&rec.encode()).unwrap();
            assert_eq!(back, rec, "f64 bits must round-trip for {weird}");
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Record::decode("{}").is_err());
        assert!(Record::decode("not json").is_err());
        assert!(Record::decode(r#"{"t": "warp"}"#).is_err());
        assert!(Record::decode(r#"{"t": "submit"}"#).is_err());
        // Hex fields must be exactly 16 lower-hex digits.
        assert!(Record::decode(
            r#"{"t": "done", "id": 1, "jct": "zz", "finish": "00"}"#
        )
        .is_err());
    }

    fn random_record(rng: &mut Pcg64) -> Record {
        match rng.below(4) {
            0 => Record::Submit {
                id: rng.next_u64() >> 20,
                tenant: rng.below(8) as u32,
                tname: format!("vc{}", rng.below(8)),
                model: "lstm".into(),
                gpus: 1 + rng.below(8) as u32,
                arrival_bits: rng.next_u64(),
                duration_bits: rng.next_u64(),
            },
            1 => Record::Churn {
                fail: rng.chance(0.5),
                slot: rng.below(16) as usize,
                at_bits: rng.next_u64(),
            },
            2 => Record::Ckpt {
                round: rng.below(1 << 20),
                at_bits: rng.next_u64(),
                finished: rng.below(1 << 20),
                hash: rng.next_u64(),
            },
            _ => Record::Done {
                id: rng.next_u64() >> 20,
                jct_bits: rng.next_u64(),
                finish_bits: rng.next_u64(),
            },
        }
    }

    #[test]
    fn random_records_roundtrip_bitwise() {
        let mut rng = Pcg64::seeded(0x10aded);
        for _ in 0..500 {
            let rec = random_record(&mut rng);
            let enc = rec.encode();
            assert_eq!(Record::decode(&enc).unwrap(), rec, "{enc}");
        }
    }

    #[test]
    fn recovery_from_every_prefix_is_well_defined() {
        // Property: for EVERY byte-prefix of a valid journal,
        // decode_prefix yields an exact record-prefix — the torn tail
        // record is dropped whole, never half-applied.
        let mut rng = Pcg64::seeded(0xf00d);
        let records: Vec<Record> =
            (0..40).map(|_| random_record(&mut rng)).collect();
        let mut bytes = Vec::new();
        let mut ends = Vec::new(); // byte offset after each record
        for rec in &records {
            bytes.extend_from_slice(rec.encode().as_bytes());
            bytes.push(b'\n');
            ends.push(bytes.len());
        }
        for cut in 0..=bytes.len() {
            let (recs, consumed) = decode_prefix(&bytes[..cut]);
            // How many whole records fit in `cut` bytes?
            let want = ends.iter().filter(|&&e| e <= cut).count();
            assert_eq!(recs.len(), want, "prefix of {cut} bytes");
            assert_eq!(consumed, if want == 0 { 0 } else { ends[want - 1] });
            assert_eq!(&recs[..], &records[..want], "prefix of {cut} bytes");
        }
    }

    #[test]
    fn corrupt_middle_record_ends_the_prefix() {
        let recs = sample_records();
        let mut bytes = Vec::new();
        for rec in &recs[..2] {
            bytes.extend_from_slice(rec.encode().as_bytes());
            bytes.push(b'\n');
        }
        let good_len = bytes.len();
        bytes.extend_from_slice(b"{\"t\": \"warp\"}\n");
        for rec in &recs[2..] {
            bytes.extend_from_slice(rec.encode().as_bytes());
            bytes.push(b'\n');
        }
        let (out, consumed) = decode_prefix(&bytes);
        assert_eq!(&out[..], &recs[..2]);
        assert_eq!(consumed, good_len);
    }

    #[test]
    fn writer_rotates_segments_and_reader_reassembles() {
        let dir = scratch("rotate");
        let records: Vec<Record> = {
            let mut rng = Pcg64::seeded(7);
            (0..11).map(|_| random_record(&mut rng)).collect()
        };
        let mut w =
            JournalWriter::create(&dir).unwrap().with_segment_len(4);
        for rec in &records {
            w.append(rec).unwrap();
        }
        // 11 records at 4/segment -> 3 segments.
        assert_eq!(segment_paths(&dir).unwrap().len(), 3);
        assert_eq!(read_journal(&dir).unwrap(), records);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recover_truncates_torn_tail_and_appends_cleanly() {
        let dir = scratch("recover");
        let records: Vec<Record> = {
            let mut rng = Pcg64::seeded(9);
            (0..6).map(|_| random_record(&mut rng)).collect()
        };
        let mut w = JournalWriter::create(&dir).unwrap();
        for rec in &records {
            w.append(rec).unwrap();
        }
        drop(w);
        // Simulate a crash mid-write: chop bytes off the tail record.
        let path = dir.join(segment_name(0));
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let (mut w, recovered) = JournalWriter::recover(&dir).unwrap();
        assert_eq!(&recovered[..], &records[..5], "torn tail dropped whole");
        // Appends continue from the truncated point, well-formed.
        let extra = Record::Done {
            id: 99,
            jct_bits: 1.0f64.to_bits(),
            finish_bits: 2.0f64.to_bits(),
        };
        w.append(&extra).unwrap();
        let mut want = records[..5].to_vec();
        want.push(extra);
        assert_eq!(read_journal(&dir).unwrap(), want);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn create_wipes_stale_segments() {
        let dir = scratch("fresh");
        let mut w = JournalWriter::create(&dir).unwrap();
        w.append(&sample_records()[0]).unwrap();
        drop(w);
        let w2 = JournalWriter::create(&dir).unwrap();
        drop(w2);
        assert!(read_journal(&dir).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
