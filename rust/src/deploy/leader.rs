//! Leader: the live scheduler service (paper §4.3), crash-recoverable.
//!
//! The round loop is the simulator's own event-driven core
//! ([`run_events_driven`]): the leader is a [`RoundDriver`] over the
//! same [`crate::sim::FleetModel`] the simulator plans with, so deploy
//! and simulation share one planning/admission/accounting code path.
//! Simulated time runs at `time_scale` × real time — the driver's
//! `advance` hook sleeps each round out on an absolute wall grid — so a
//! multi-hour trace deploys in minutes (Table 5 compares deploy vs
//! simulate on the same trace).
//!
//! ## Crash recovery
//!
//! With `journal_dir` set, the leader write-ahead-journals (see
//! [`super::journal`]) every admitted submission *before* acknowledging
//! it, every worker-churn event before injecting it, a fold checkpoint
//! at every round boundary, and every completion it folds. A killed
//! leader restarted with `recover` replays the journal through the
//! very same deterministic round loop — instantly, validating each
//! checkpoint it crosses — and flips to live pacing where the journal
//! ends. Because the loop is a pure function of (submissions, churn),
//! the recovered run's schedule, completion log, and final report are
//! **byte-identical** to an unkilled run's.
//!
//! ## Network plane
//!
//! One TCP listener serves three kinds of peer, discriminated by their
//! first frame: workers (`Register` → leases/terminates, heartbeat
//! lease enforcement, preempt-and-requeue degradation on loss), job
//! clients (`Submit`, idempotent by client job id, journaled before
//! ack), and status clients (`QueryStatus`). Duplicate registrations
//! beyond the fleet size and conflicting resubmissions get a typed
//! [`Message::Error`] — never a panic, never a silent double-admit.

use super::journal::{self, JournalWriter, Record, JOURNAL_VERSION};
use super::proto::{Conn, Message};
use crate::cluster::{GpuGen, ServerSpec, TypeSpec};
use crate::job::{Job, JobId, ModelKind, TenantId};
use crate::mechanism::by_name as mechanism_by_name;
use crate::metrics::{per_tenant_stats, JctStats};
use crate::policy::by_name as policy_by_name;
use crate::sim::{
    run_events_driven, CoreConfig, DriverEvent, FaultKind, FinishedJob,
    FleetModel, RoundCtx, RoundDriver, SimConfig,
};
use crate::telemetry::{ServiceCounters, TelemetryConfig, TelemetryRecorder};
use crate::util::fsx;
use crate::util::json::Json;
use crate::workload::{ReplaySource, TenantQuotas, WorkloadSource};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Leader configuration.
pub struct LeaderConfig {
    pub bind: String,
    pub n_workers: usize,
    /// Real seconds per scheduling round.
    pub round_real_s: f64,
    /// Simulated seconds per real second.
    pub time_scale: f64,
    pub policy: String,
    pub mechanism: String,
    /// AOT variant workers should train.
    pub variant: String,
    /// Wall-clock cap for the whole run.
    pub max_real_s: f64,
    /// Tenant GPU quotas for the round planner (None = single tenant).
    pub quotas: Option<TenantQuotas>,
    /// Write a telemetry run profile (JSONL/CSV by extension) here —
    /// the same per-round/per-tenant series + plan-stage trace the
    /// simulator records, off the live round loop. `None` = no recorder
    /// (zero overhead, unchanged behaviour).
    pub telemetry: Option<String>,
    /// Record wall-clock milliseconds per round into the profile.
    /// Off by default: counter-only profiles stay deterministic in the
    /// round structure (sim-time stamps are nominal round multiples).
    pub telemetry_timing: bool,
    /// Write-ahead journal directory. `None` = no journal (a crash
    /// loses the run, like the pre-journal leader).
    pub journal_dir: Option<String>,
    /// Warm-start from the journal in `journal_dir` instead of starting
    /// fresh: replay the journaled run deterministically, validate its
    /// checkpoints, and resume live where it ended.
    pub recover: bool,
    /// Write the deterministic machine-readable final report (JSON)
    /// here — a pure function of the schedule, byte-comparable across
    /// a kill/recover and an unkilled control run.
    pub report_path: Option<String>,
    /// Hold the round loop until this many total jobs are admitted
    /// (workload-source jobs + network submissions + journaled
    /// submissions). 0 = start as soon as the source is drained.
    pub expect_jobs: usize,
    /// Worker heartbeat period, real seconds. A worker silent for 3
    /// periods has its lease expired: it is failed over through the
    /// same preempt-and-requeue churn path as a disconnect. 0 disables
    /// heartbeats entirely (pre-heartbeat behaviour).
    pub heartbeat_s: f64,
    /// Write the bound address (`IP:PORT\n`) here once listening, so
    /// subprocess harnesses can find an ephemeral port.
    pub port_file: Option<String>,
}

impl Default for LeaderConfig {
    fn default() -> Self {
        LeaderConfig {
            bind: "127.0.0.1:0".into(),
            n_workers: 1,
            round_real_s: 2.0,
            time_scale: 600.0,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            variant: "tiny".into(),
            max_real_s: 600.0,
            quotas: None,
            telemetry: None,
            telemetry_timing: false,
            journal_dir: None,
            recover: false,
            report_path: None,
            expect_jobs: 0,
            heartbeat_s: 0.0,
            port_file: None,
        }
    }
}

/// Outcome of a deploy run.
#[derive(Debug)]
pub struct LeaderReport {
    /// (job id, JCT in simulated seconds), in completion order.
    pub jcts: Vec<(u64, f64)>,
    /// Owning tenant of every admitted job.
    pub tenant_of: BTreeMap<u64, TenantId>,
    /// Final reported training loss per job.
    pub losses: BTreeMap<u64, f64>,
    /// Total real train steps executed across workers.
    pub total_steps: u64,
    pub rounds: usize,
    /// Final simulated clock (deterministic — derived from the round
    /// grid, not from wall time).
    pub makespan_sim_s: f64,
    /// 1 when this run warm-started from a journal, else 0.
    pub recoveries: u64,
    /// Journal records replayed during warm start.
    pub journal_records_replayed: u64,
    /// Workers failed over because their heartbeat lease expired.
    pub heartbeat_expiries: u64,
    /// Jobs preempted-and-requeued by worker loss (work preserved).
    pub preemptions: u64,
    pub servers_failed: u64,
    pub servers_restored: u64,
}

impl LeaderReport {
    pub fn jct_stats(&self) -> JctStats {
        let jcts: Vec<f64> = self.jcts.iter().map(|&(_, j)| j).collect();
        JctStats::from_jcts(&jcts)
    }

    /// Per-tenant JCT summaries.
    pub fn tenant_stats(&self) -> BTreeMap<TenantId, JctStats> {
        let pairs: Vec<(TenantId, f64)> = self
            .jcts
            .iter()
            .map(|&(id, jct)| {
                let t = self
                    .tenant_of
                    .get(&id)
                    .copied()
                    .unwrap_or(TenantId::DEFAULT);
                (t, jct)
            })
            .collect();
        per_tenant_stats(&pairs)
    }
}

/// Absolute wall-clock grid for scaled sim time: sim instant `t` has
/// the fixed wall deadline `start + (t - sim0) / scale`. Sleeping to a
/// deadline already past returns 0 — overruns are absorbed, the grid is
/// held, never shifted (the old `sleep(period)`-after-planning loop
/// accumulated every round's planning cost into the grid and drifted).
/// Recovery re-anchors the grid at the replay's end, so live rounds
/// resume on-cadence from the warm-started sim clock. Pure arithmetic
/// so the policy is testable without a wall clock.
struct WallGrid {
    start: Instant,
    sim0: f64,
    scale: f64,
}

impl WallGrid {
    fn new(scale: f64) -> WallGrid {
        WallGrid { start: Instant::now(), sim0: 0.0, scale }
    }

    /// Restart the grid: sim instant `sim_now` maps to "now" on the
    /// wall, later instants to their scaled offsets from it.
    fn re_anchor(&mut self, sim_now: f64) {
        self.start = Instant::now();
        self.sim0 = sim_now;
    }

    /// Wall deadline (seconds past the anchor) of sim instant `t`.
    fn deadline_s(&self, t_sim: f64) -> f64 {
        (t_sim - self.sim0) / self.scale
    }

    /// Seconds to sleep at `elapsed_s` (wall time since the anchor) to
    /// reach sim instant `t_sim`'s deadline; 0 when already past it.
    fn sleep_s(&self, t_sim: f64, elapsed_s: f64) -> f64 {
        (self.deadline_s(t_sim) - elapsed_s).max(0.0)
    }
}

/// Run-progress counters shared with `QueryStatus` client sessions.
#[derive(Debug, Clone, Copy, Default)]
struct StatusShared {
    submitted: u64,
    finished: u64,
    rounds: u64,
    recoveries: u64,
}

/// A worker connection mid-handshake: `Register` read, ack not sent.
struct PendingWorker {
    conn: Conn,
    gpus: u32,
    cpus: u32,
    mem_gb: f64,
    gen: String,
}

/// One client submission awaiting admission, with its reply channel.
/// `Ok(duplicate)` acks; `Err(reason)` becomes a typed `Error` frame.
struct SubmitReq {
    job_id: u64,
    tenant: String,
    model: String,
    gpus: u32,
    arrival_s: f64,
    duration_s: f64,
    resp: mpsc::Sender<std::result::Result<bool, String>>,
}

/// Admission record for idempotent resubmission: what job id N was
/// admitted *as*. `arrival_bits` is the journaled effective arrival
/// (clamped to admission time for mid-run submissions).
#[derive(Debug, Clone, PartialEq)]
struct SubKey {
    tenant: u32,
    model: String,
    gpus: u32,
    arrival_bits: u64,
    duration_bits: u64,
}

enum Mode {
    /// Warm start: rounds execute instantly against the journal's
    /// churn/checkpoint timeline; no leases are sent, nothing is
    /// journaled. `until` is the journal's sim-time frontier.
    Replay { until: f64 },
    Live,
}

/// The leader process body.
pub struct Leader {
    pub cfg: LeaderConfig,
    /// Set after bind so tests can connect workers to an ephemeral port.
    pub addr: std::sync::Mutex<Option<std::net::SocketAddr>>,
}

impl Leader {
    pub fn new(cfg: LeaderConfig) -> Leader {
        Leader { cfg, addr: std::sync::Mutex::new(None) }
    }

    /// Bind, wait for `n_workers` registrations, run a batch trace, shut
    /// workers down, and report. Blocks. (Batch convenience wrapper over
    /// [`Leader::run_stream`].)
    pub fn run(&self, jobs: Vec<Job>) -> Result<LeaderReport> {
        self.run_stream(Box::new(ReplaySource::from_jobs(jobs)))
    }

    /// Like [`Leader::run`], but jobs come from a [`WorkloadSource`]
    /// plus any network submissions gathered while `expect_jobs` is
    /// unmet. The run ends when every admitted job finished (or at
    /// `max_real_s`).
    pub fn run_stream(
        &self,
        source: Box<dyn WorkloadSource>,
    ) -> Result<LeaderReport> {
        let listener = TcpListener::bind(&self.cfg.bind)?;
        let addr = listener.local_addr()?;
        *self.addr.lock().unwrap() = Some(addr);
        if let Some(pf) = &self.cfg.port_file {
            fsx::write_creating(Path::new(pf), format!("{addr}\n").as_bytes())
                .map_err(|e| anyhow!("port file: {e}"))?;
        }

        let status = Arc::new(Mutex::new(StatusShared::default()));
        let (reg_tx, reg_rx) = mpsc::channel::<PendingWorker>();
        let (sub_tx, sub_rx) = mpsc::channel::<SubmitReq>();
        let stop = Arc::new(AtomicBool::new(false));
        {
            let listener = listener.try_clone()?;
            let stop = Arc::clone(&stop);
            let status = Arc::clone(&status);
            std::thread::spawn(move || {
                acceptor(listener, stop, reg_tx, sub_tx, status)
            });
        }

        let result = self.serve(source, reg_rx, sub_rx, status);
        // Unblock the acceptor so its thread exits with the run.
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr);
        result
    }

    /// Canonical signature of the schedule-determining configuration.
    /// Recovery refuses a journal written under a different signature —
    /// replaying submissions under a different policy would silently
    /// produce a different (valid-looking) schedule.
    fn config_sig(&self) -> String {
        format!(
            "v{} policy={} mechanism={} workers={} round_bits={:016x} \
             scale_bits={:016x} expect={} quota_tenants={}",
            JOURNAL_VERSION,
            self.cfg.policy,
            self.cfg.mechanism,
            self.cfg.n_workers,
            self.cfg.round_real_s.to_bits(),
            self.cfg.time_scale.to_bits(),
            self.cfg.expect_jobs,
            self.cfg.quotas.as_ref().map_or(0, |q| q.len()),
        )
    }

    fn serve(
        &self,
        mut source: Box<dyn WorkloadSource>,
        reg_rx: mpsc::Receiver<PendingWorker>,
        sub_rx: mpsc::Receiver<SubmitReq>,
        status: Arc<Mutex<StatusShared>>,
    ) -> Result<LeaderReport> {
        let run_start = Instant::now();

        // --- journal bootstrap -----------------------------------------
        let sig = self.config_sig();
        let (journal, recovered) =
            match (&self.cfg.journal_dir, self.cfg.recover) {
                (Some(dir), true) => {
                    let (w, recs) = JournalWriter::recover(Path::new(dir))
                        .map_err(|e| anyhow!("journal: {e}"))?;
                    match recs.first() {
                        Some(Record::Meta { version, sig: s })
                            if *version == JOURNAL_VERSION && *s == sig => {}
                        Some(Record::Meta { version, sig: s }) => {
                            return Err(anyhow!(
                                "journal/config mismatch: journal v{version} \
                                 sig {s:?} vs leader v{JOURNAL_VERSION} sig \
                                 {sig:?}"
                            ))
                        }
                        _ => {
                            return Err(anyhow!(
                                "journal has no meta record"
                            ))
                        }
                    }
                    (Some(w), recs)
                }
                (Some(dir), false) => {
                    let mut w = JournalWriter::create(Path::new(dir))
                        .map_err(|e| anyhow!("journal: {e}"))?;
                    w.append(&Record::Meta { version: JOURNAL_VERSION, sig })
                        .map_err(|e| anyhow!("journal: {e}"))?;
                    (Some(w), Vec::new())
                }
                (None, true) => {
                    return Err(anyhow!("recover requires a journal dir"))
                }
                (None, false) => (None, Vec::new()),
            };

        // --- registration gate -----------------------------------------
        let mut pending: Vec<PendingWorker> = Vec::new();
        let mut spec: Option<ServerSpec> = None;
        let mut fleet_gen: Option<GpuGen> = None;
        while pending.len() < self.cfg.n_workers {
            if run_start.elapsed().as_secs_f64() > self.cfg.max_real_s {
                return Err(anyhow!(
                    "timed out waiting for {} workers ({} registered)",
                    self.cfg.n_workers,
                    pending.len()
                ));
            }
            let Ok(mut pw) = reg_rx.recv_timeout(Duration::from_millis(100))
            else {
                continue;
            };
            let s =
                ServerSpec { gpus: pw.gpus, cpus: pw.cpus, mem_gb: pw.mem_gb };
            let Some(g) = GpuGen::by_name(&pw.gen) else {
                let reason = format!("unknown gpu gen {:?}", pw.gen);
                let _ = pw.conn.send(&Message::Error { reason: reason.clone() });
                return Err(anyhow!("worker registered {reason}"));
            };
            if spec.is_some_and(|prev| prev != s) {
                let _ = pw.conn.send(&Message::Error {
                    reason: "heterogeneous workers unsupported".into(),
                });
                return Err(anyhow!("heterogeneous workers unsupported"));
            }
            // Workers report their generation; the mirror fleet is still
            // one-type, so a mixed registration is rejected up front
            // rather than silently mis-modeled.
            if fleet_gen.is_some_and(|prev| prev != g) {
                let _ = pw.conn.send(&Message::Error {
                    reason: "mixed-generation workers unsupported".into(),
                });
                return Err(anyhow!(
                    "mixed-generation workers unsupported: {:?} after \
                     {fleet_gen:?}",
                    g
                ));
            }
            spec = Some(s);
            fleet_gen = Some(g);
            pending.push(pw);
        }
        let spec = spec.ok_or_else(|| anyhow!("no workers"))?;
        let gen = fleet_gen.ok_or_else(|| anyhow!("no workers"))?;

        // Ack registrations; reader threads funnel worker messages into
        // one channel, `None` marking a dead connection.
        let (worker_tx, worker_rx) =
            mpsc::channel::<(usize, Option<Message>)>();
        let mut senders: Vec<Option<Conn>> = Vec::new();
        for (wid, mut pw) in pending.into_iter().enumerate() {
            pw.conn.send(&Message::RegisterAck {
                server_id: wid,
                heartbeat_s: self.cfg.heartbeat_s,
            })?;
            senders.push(Some(pw.conn.try_clone_writer()?));
            spawn_reader(pw.conn, wid, worker_tx.clone());
        }
        let total_gpus = spec.gpus * self.cfg.n_workers as u32;

        // Validate policy/mechanism before the model build (which
        // panics on an unknown mechanism).
        let policy = policy_by_name(&self.cfg.policy)
            .ok_or_else(|| anyhow!("bad policy {:?}", self.cfg.policy))?;
        if mechanism_by_name(&self.cfg.mechanism).is_none() {
            return Err(anyhow!("bad mechanism {:?}", self.cfg.mechanism));
        }

        let tenant_names = source.tenant_names();
        let mut driver = LeaderDriver {
            cfg: &self.cfg,
            run_start,
            grid: WallGrid::new(self.cfg.time_scale),
            mode: Mode::Live,
            gating: false,
            journal,
            reg_rx,
            sub_rx,
            worker_rx,
            worker_tx,
            status: Arc::clone(&status),
            spec,
            gen,
            total_gpus,
            senders,
            last_hb: vec![Instant::now(); self.cfg.n_workers],
            fleet_online: vec![true; self.cfg.n_workers],
            hosted_on: HashMap::new(),
            pending_churn: Vec::new(),
            submitted: BTreeMap::new(),
            tenant_ids: BTreeMap::new(),
            next_tenant: 0,
            tenant_of: BTreeMap::new(),
            deferred: Vec::new(),
            replay_churn: VecDeque::new(),
            replay_ckpts: VecDeque::new(),
            replay_dones: BTreeMap::new(),
            completion_hash: journal::fnv1a(&[]),
            losses: BTreeMap::new(),
            steps_total: BTreeMap::new(),
            counters: ServiceCounters::default(),
            fatal: None,
        };
        for (i, name) in tenant_names.iter().enumerate() {
            driver.tenant_ids.insert(name.clone(), i as u32);
        }

        // --- initial jobs ----------------------------------------------
        let mut jobs: Vec<Job> = Vec::new();
        if self.cfg.recover {
            // The journal is the single source of jobs on recovery; the
            // workload source was already folded into it by the
            // original run.
            for rec in &recovered {
                match rec {
                    Record::Submit {
                        id,
                        tenant,
                        tname,
                        model,
                        gpus,
                        arrival_bits,
                        duration_bits,
                    } => {
                        let model =
                            ModelKind::from_name(model).ok_or_else(|| {
                                anyhow!("journal names unknown model {model:?}")
                            })?;
                        jobs.push(
                            Job::new(
                                JobId(*id),
                                model,
                                *gpus,
                                f64::from_bits(*arrival_bits),
                                f64::from_bits(*duration_bits),
                            )
                            .with_tenant(TenantId(*tenant)),
                        );
                        driver.submitted.insert(
                            *id,
                            SubKey {
                                tenant: *tenant,
                                model: model.name().into(),
                                gpus: *gpus,
                                arrival_bits: *arrival_bits,
                                duration_bits: *duration_bits,
                            },
                        );
                        driver.tenant_ids.insert(tname.clone(), *tenant);
                        driver.tenant_of.insert(*id, TenantId(*tenant));
                    }
                    Record::Churn { fail, at_bits, .. } => driver
                        .replay_churn
                        .push_back((f64::from_bits(*at_bits), *fail)),
                    Record::Ckpt { round, at_bits, finished, hash } => {
                        driver
                            .replay_ckpts
                            .push_back((*round, *at_bits, *finished, *hash))
                    }
                    Record::Done { id, jct_bits, finish_bits } => {
                        driver
                            .replay_dones
                            .insert(*id, (*jct_bits, *finish_bits));
                    }
                    Record::Meta { .. } => {}
                }
            }
            let until = recovered
                .iter()
                .filter_map(|r| match r {
                    Record::Churn { at_bits, .. }
                    | Record::Ckpt { at_bits, .. } => {
                        Some(f64::from_bits(*at_bits))
                    }
                    _ => None,
                })
                .fold(0.0f64, f64::max);
            driver.mode = Mode::Replay { until };
            driver.counters.recoveries = 1;
            driver.counters.journal_records_replayed = recovered.len() as u64;
        } else {
            while let Some(job) = pull_feasible(source.as_mut(), total_gpus) {
                let tname = tenant_names
                    .get(job.tenant.0 as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("t{}", job.tenant.0));
                driver
                    .admit_source_job(&job, &tname)
                    .map_err(|e| anyhow!(e))?;
                jobs.push(job);
            }
        }
        driver.next_tenant = driver
            .tenant_ids
            .values()
            .copied()
            .max()
            .map_or(0, |m| m + 1);

        // --- submission gate -------------------------------------------
        // Serve the network until `expect_jobs` distinct jobs are known.
        // Gate admissions become initial jobs; journaled submissions
        // (on recovery) already count.
        driver.gating = true;
        while driver.submitted.len() < self.cfg.expect_jobs {
            if let Some(f) = driver.fatal.take() {
                return Err(anyhow!(f));
            }
            if run_start.elapsed().as_secs_f64() > self.cfg.max_real_s {
                return Err(anyhow!(
                    "timed out waiting for {} submissions ({} admitted)",
                    self.cfg.expect_jobs,
                    driver.submitted.len()
                ));
            }
            let mut inbox = Vec::new();
            driver.pump_network(0.0, &mut inbox);
            for ev in inbox {
                if let DriverEvent::Submit(j) = ev {
                    jobs.push(j);
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        driver.gating = false;
        {
            let mut s = status.lock().unwrap();
            s.submitted = driver.submitted.len().max(jobs.len()) as u64;
            s.recoveries = driver.counters.recoveries;
        }

        // --- the round loop: the simulator's own core ------------------
        let sim_cfg = SimConfig {
            round_s: self.cfg.round_real_s * self.cfg.time_scale,
            max_sim_s: self.cfg.max_real_s * self.cfg.time_scale,
            policy: self.cfg.policy.clone(),
            mechanism: self.cfg.mechanism.clone(),
            types: Some(vec![TypeSpec {
                gen,
                spec,
                machines: self.cfg.n_workers,
            }]),
            ..SimConfig::default()
        };
        let core_cfg = CoreConfig {
            round_s: sim_cfg.round_s,
            max_sim_s: sim_cfg.max_sim_s,
            force_replan: false,
        };
        let mut model = FleetModel::from_config(&sim_cfg);
        model.enable_grant_capture();
        let mut recorder = self.cfg.telemetry.as_ref().map(|_| {
            TelemetryRecorder::new(TelemetryConfig {
                timing: self.cfg.telemetry_timing,
            })
        });
        driver.grid.re_anchor(0.0);
        let result = run_events_driven(
            &mut model,
            policy.as_ref(),
            self.cfg.quotas.as_ref(),
            &core_cfg,
            jobs,
            recorder.as_mut(),
            &[],
            &mut driver,
        );

        // --- shutdown + reports ----------------------------------------
        for s in driver.senders.iter_mut().flatten() {
            let _ = s.send(&Message::Shutdown);
        }
        if let Some(f) = driver.fatal.take() {
            return Err(anyhow!(f));
        }
        if let Some(rec) = recorder.as_mut() {
            rec.record_service(driver.counters);
        }
        if let (Some(path), Some(rec)) = (&self.cfg.telemetry, &recorder) {
            fsx::write_creating(
                Path::new(path),
                rec.render_for_path(path).as_bytes(),
            )
            .map_err(|e| anyhow!("telemetry: {e}"))?;
        }
        {
            let mut s = status.lock().unwrap();
            s.finished = result.finished.len() as u64;
            s.rounds = result.rounds as u64;
        }
        let report = LeaderReport {
            jcts: result.finished.iter().map(|f| (f.id.0, f.jct_s)).collect(),
            tenant_of: driver.tenant_of.clone(),
            losses: driver.losses.clone(),
            total_steps: driver.steps_total.values().sum(),
            rounds: result.rounds,
            makespan_sim_s: result.makespan_s,
            recoveries: driver.counters.recoveries,
            journal_records_replayed: driver.counters.journal_records_replayed,
            heartbeat_expiries: driver.counters.heartbeat_expiries,
            preemptions: result.preemptions,
            servers_failed: result.servers_failed,
            servers_restored: result.servers_restored,
        };
        if let Some(path) = &self.cfg.report_path {
            fsx::write_creating(
                Path::new(path),
                render_report(&report).as_bytes(),
            )
            .map_err(|e| anyhow!("report: {e}"))?;
        }
        Ok(report)
    }
}

/// Deterministic machine-readable report: a pure function of the
/// schedule (JCTs as f64 bit patterns, completion order), so a
/// recovered run's file is byte-identical to the unkilled control's.
/// Worker-reported fields (losses, steps) and recovery counters are
/// deliberately excluded — they describe the *process*, not the
/// schedule.
fn render_report(r: &LeaderReport) -> String {
    let jcts: Vec<Json> = r
        .jcts
        .iter()
        .map(|&(id, jct)| {
            Json::arr(vec![
                Json::num(id as f64),
                Json::str(format!("{:016x}", jct.to_bits())),
            ])
        })
        .collect();
    let tenants: Vec<Json> = r
        .tenant_of
        .iter()
        .map(|(&id, t)| {
            Json::arr(vec![Json::num(id as f64), Json::num(t.0 as f64)])
        })
        .collect();
    let mut doc = Json::obj(vec![
        ("kind", Json::str("synergy_deploy_report")),
        ("finished", Json::num(r.jcts.len() as f64)),
        ("rounds", Json::num(r.rounds as f64)),
        (
            "makespan_bits",
            Json::str(format!("{:016x}", r.makespan_sim_s.to_bits())),
        ),
        ("preemptions", Json::num(r.preemptions as f64)),
        ("servers_failed", Json::num(r.servers_failed as f64)),
        ("servers_restored", Json::num(r.servers_restored as f64)),
        ("jcts", Json::arr(jcts)),
        ("tenants", Json::arr(tenants)),
    ])
    .encode();
    doc.push('\n');
    doc
}

/// Accept loop: every connection gets a greeter thread that routes it
/// by its first frame (worker registration vs client session).
fn acceptor(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    reg_tx: mpsc::Sender<PendingWorker>,
    sub_tx: mpsc::Sender<SubmitReq>,
    status: Arc<Mutex<StatusShared>>,
) {
    loop {
        let Ok((stream, _)) = listener.accept() else { break };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let reg_tx = reg_tx.clone();
        let sub_tx = sub_tx.clone();
        let status = Arc::clone(&status);
        std::thread::spawn(move || greet(stream, reg_tx, sub_tx, status));
    }
}

/// Route one fresh connection. Workers hand their conn to the leader's
/// registration queue; clients get an in-thread session loop (Submit /
/// QueryStatus until they disconnect or idle out).
fn greet(
    stream: TcpStream,
    reg_tx: mpsc::Sender<PendingWorker>,
    sub_tx: mpsc::Sender<SubmitReq>,
    status: Arc<Mutex<StatusShared>>,
) {
    let Ok(mut conn) = Conn::new(stream) else { return };
    if conn.set_read_timeout(Some(Duration::from_secs(30))).is_err() {
        return;
    }
    loop {
        match conn.recv() {
            Ok(Some(Message::Register { gpus, cpus, mem_gb, gen })) => {
                // Worker: hand the whole connection over; the leader
                // acks (or rejects) and owns it from here.
                let _ = conn.set_read_timeout(None);
                let _ = reg_tx
                    .send(PendingWorker { conn, gpus, cpus, mem_gb, gen });
                return;
            }
            Ok(Some(Message::Submit {
                job_id,
                tenant,
                model,
                gpus,
                arrival_s,
                duration_s,
            })) => {
                let (tx, rx) = mpsc::channel();
                let req = SubmitReq {
                    job_id,
                    tenant,
                    model,
                    gpus,
                    arrival_s,
                    duration_s,
                    resp: tx,
                };
                if sub_tx.send(req).is_err() {
                    let _ = conn.send(&Message::Error {
                        reason: "leader is shutting down".into(),
                    });
                    return;
                }
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(Ok(duplicate)) => {
                        if conn
                            .send(&Message::SubmitAck { job_id, duplicate })
                            .is_err()
                        {
                            return;
                        }
                    }
                    Ok(Err(reason)) => {
                        if conn.send(&Message::Error { reason }).is_err() {
                            return;
                        }
                    }
                    Err(_) => {
                        let _ = conn.send(&Message::Error {
                            reason: "submission not processed in time".into(),
                        });
                        return;
                    }
                }
            }
            Ok(Some(Message::QueryStatus)) => {
                let s = *status.lock().unwrap();
                if conn
                    .send(&Message::Status {
                        submitted: s.submitted,
                        finished: s.finished,
                        rounds: s.rounds,
                        recoveries: s.recoveries,
                    })
                    .is_err()
                {
                    return;
                }
            }
            Ok(Some(_)) => {
                let _ = conn.send(&Message::Error {
                    reason: "expected register, submit, or query_status"
                        .into(),
                });
                return;
            }
            Ok(None) => return,
            Err(_) => return, // idle timeout, oversized frame, bad JSON
        }
    }
}

/// Reader thread for one worker connection: frames in, `(wid, None)`
/// on death.
fn spawn_reader(
    mut conn: Conn,
    wid: usize,
    tx: mpsc::Sender<(usize, Option<Message>)>,
) {
    std::thread::spawn(move || {
        loop {
            match conn.recv() {
                Ok(Some(m)) => {
                    if tx.send((wid, Some(m))).is_err() {
                        break;
                    }
                }
                Ok(None) => break, // clean EOF
                Err(e) => {
                    // A malformed frame is a protocol bug; losing the
                    // reader silently stalls every job on this worker,
                    // so shout before giving up.
                    eprintln!("[leader] worker {wid} recv: {e}");
                    break;
                }
            }
        }
        let _ = tx.send((wid, None));
    });
}

/// The leader as a [`RoundDriver`]: owns the network plane, the worker
/// fleet mirror, the journal, and the replay plan, while the sim core
/// owns planning, admission, progress, and completion accounting.
struct LeaderDriver<'a> {
    cfg: &'a LeaderConfig,
    run_start: Instant,
    grid: WallGrid,
    mode: Mode,
    /// True during the pre-loop submission gate (admissions become
    /// initial jobs and are never deferred).
    gating: bool,
    journal: Option<JournalWriter>,
    reg_rx: mpsc::Receiver<PendingWorker>,
    sub_rx: mpsc::Receiver<SubmitReq>,
    worker_rx: mpsc::Receiver<(usize, Option<Message>)>,
    worker_tx: mpsc::Sender<(usize, Option<Message>)>,
    status: Arc<Mutex<StatusShared>>,
    spec: ServerSpec,
    gen: GpuGen,
    total_gpus: u32,
    /// Worker slots: write handles, `None` = down. Slot index is the
    /// worker's server id for the protocol; it is NOT a fleet scan
    /// position — see `fleet_online`.
    senders: Vec<Option<Conn>>,
    last_hb: Vec<Instant>,
    /// Mirror of the model fleet's per-position online state. The core
    /// fails the *highest* online scan position and revives the
    /// *lowest* offline one; lease routing maps the i-th online
    /// position to the i-th alive worker slot, so the mapping is
    /// deterministic without the model ever knowing slot identities.
    fleet_online: Vec<bool>,
    /// job id -> worker slot currently holding its lease.
    hosted_on: HashMap<u64, usize>,
    /// Observed churn (fail/rejoin, worker slot) not yet journaled and
    /// injected — drained in live mode only, so a replaying grid never
    /// sees unjournaled membership changes mid-replay.
    pending_churn: Vec<(bool, usize)>,
    submitted: BTreeMap<u64, SubKey>,
    tenant_ids: BTreeMap<String, u32>,
    next_tenant: u32,
    tenant_of: BTreeMap<u64, TenantId>,
    /// Mid-replay submissions with unknown ids, admitted at the live
    /// flip (new work cannot enter a replaying round grid).
    deferred: Vec<SubmitReq>,
    replay_churn: VecDeque<(f64, bool)>,
    /// (round, at_bits, finished, hash) checkpoints left to validate.
    replay_ckpts: VecDeque<(u64, u64, u64, u64)>,
    /// id -> (jct_bits, finish_bits) completions the dead leader
    /// journaled; replayed completions must match bitwise.
    replay_dones: BTreeMap<u64, (u64, u64)>,
    /// Incremental FNV-1a over (id, jct_bits) in completion order —
    /// the checkpoint hash.
    completion_hash: u64,
    losses: BTreeMap<u64, f64>,
    steps_total: BTreeMap<u64, u64>,
    counters: ServiceCounters,
    fatal: Option<String>,
}

/// Fold one completion into the checkpoint hash (FNV-1a continuation).
fn fold_completion(h: u64, id: u64, jct_bits: u64) -> u64 {
    let mut acc = h;
    for b in id.to_le_bytes().into_iter().chain(jct_bits.to_le_bytes()) {
        acc ^= b as u64;
        acc = acc.wrapping_mul(0x100000001b3);
    }
    acc
}

impl LeaderDriver<'_> {
    fn journal_append(&mut self, rec: &Record) -> std::result::Result<(), String> {
        match self.journal.as_mut() {
            Some(w) => w.append(rec),
            None => Ok(()),
        }
    }

    /// Journal + bookkeep one workload-source job (fresh runs fold the
    /// source into the journal so recovery needs only the journal).
    fn admit_source_job(
        &mut self,
        job: &Job,
        tname: &str,
    ) -> std::result::Result<(), String> {
        self.journal_append(&Record::Submit {
            id: job.id.0,
            tenant: job.tenant.0,
            tname: tname.into(),
            model: job.model.name().into(),
            gpus: job.gpus,
            arrival_bits: job.arrival_s.to_bits(),
            duration_bits: job.duration_prop_s.to_bits(),
        })?;
        self.submitted.insert(
            job.id.0,
            SubKey {
                tenant: job.tenant.0,
                model: job.model.name().into(),
                gpus: job.gpus,
                arrival_bits: job.arrival_s.to_bits(),
                duration_bits: job.duration_prop_s.to_bits(),
            },
        );
        self.tenant_of.insert(job.id.0, job.tenant);
        Ok(())
    }

    fn tenant_id(&mut self, name: &str) -> u32 {
        if let Some(&t) = self.tenant_ids.get(name) {
            return t;
        }
        let t = self.next_tenant;
        self.next_tenant += 1;
        self.tenant_ids.insert(name.into(), t);
        t
    }

    fn note_worker_down(&mut self, wid: usize) {
        if !matches!(self.senders.get(wid), Some(Some(_))) {
            return;
        }
        self.senders[wid] = None;
        self.hosted_on.retain(|_, w| *w != wid);
        self.pending_churn.push((true, wid));
        eprintln!("[leader] worker {wid} down; requeueing its jobs");
    }

    /// Drain worker messages, heartbeat leases, rejoins, submissions.
    /// Shared by the live `poll` hook and the pre-loop gate.
    fn pump_network(&mut self, now: f64, inbox: &mut Vec<DriverEvent>) {
        while let Ok((wid, msg)) = self.worker_rx.try_recv() {
            let Some(msg) = msg else {
                self.note_worker_down(wid);
                continue;
            };
            match msg {
                Message::Heartbeat { .. } => {
                    if let Some(hb) = self.last_hb.get_mut(wid) {
                        *hb = Instant::now();
                    }
                }
                Message::Progress { job_id, loss, steps, .. } => {
                    // Any frame proves liveness; progress numbers feed
                    // the report only — the sim core is the single
                    // arbiter of job progress and completion.
                    if let Some(hb) = self.last_hb.get_mut(wid) {
                        *hb = Instant::now();
                    }
                    if loss.is_finite() {
                        self.losses.insert(job_id, loss);
                    }
                    self.steps_total.insert(job_id, steps);
                }
                _ => {}
            }
        }
        if self.cfg.heartbeat_s > 0.0 {
            let cutoff = 3.0 * self.cfg.heartbeat_s;
            for wid in 0..self.senders.len() {
                if self.senders[wid].is_some()
                    && self.last_hb[wid].elapsed().as_secs_f64() > cutoff
                {
                    self.counters.heartbeat_expiries += 1;
                    eprintln!(
                        "[leader] worker {wid} heartbeat lease expired \
                         (silent > {cutoff:.1}s)"
                    );
                    self.note_worker_down(wid);
                }
            }
        }
        while let Ok(pw) = self.reg_rx.try_recv() {
            self.handle_rejoin(pw);
        }
        while let Ok(req) = self.sub_rx.try_recv() {
            let replaying = matches!(self.mode, Mode::Replay { .. });
            if replaying
                && !self.gating
                && !self.submitted.contains_key(&req.job_id)
            {
                self.deferred.push(req);
            } else {
                self.handle_submit(req, now, inbox);
            }
        }
    }

    /// Process one client submission: validate, dedup idempotently,
    /// journal *before* acking, inject.
    fn handle_submit(
        &mut self,
        req: SubmitReq,
        now: f64,
        inbox: &mut Vec<DriverEvent>,
    ) {
        let Some(model) = ModelKind::from_name(&req.model) else {
            let _ = req
                .resp
                .send(Err(format!("unknown model {:?}", req.model)));
            return;
        };
        if req.gpus == 0 || req.gpus > self.total_gpus {
            let _ = req.resp.send(Err(format!(
                "job {} demands {} GPUs; cluster capacity is {}",
                req.job_id, req.gpus, self.total_gpus
            )));
            return;
        }
        if !req.arrival_s.is_finite()
            || req.arrival_s < 0.0
            || !req.duration_s.is_finite()
            || req.duration_s <= 0.0
        {
            let _ = req.resp.send(Err(
                "arrival_s must be finite and >= 0, duration_s finite and > 0"
                    .into(),
            ));
            return;
        }
        // Mid-run submissions are admitted "now": the clamped arrival
        // is what gets journaled, so replay reproduces it bitwise.
        let arrival = req.arrival_s.max(now);
        let tenant = self.tenant_id(&req.tenant);
        if let Some(k) = self.submitted.get(&req.job_id) {
            // Idempotent resubmission: same spec (the stored arrival
            // may exceed the requested one — that is the clamp above,
            // not a conflict).
            let same = k.tenant == tenant
                && k.model == req.model
                && k.gpus == req.gpus
                && k.duration_bits == req.duration_s.to_bits()
                && f64::from_bits(k.arrival_bits) >= req.arrival_s;
            let _ = if same {
                req.resp.send(Ok(true))
            } else {
                req.resp.send(Err(format!(
                    "job id {} already admitted with a different spec",
                    req.job_id
                )))
            };
            return;
        }
        let rec = Record::Submit {
            id: req.job_id,
            tenant,
            tname: req.tenant.clone(),
            model: req.model.clone(),
            gpus: req.gpus,
            arrival_bits: arrival.to_bits(),
            duration_bits: req.duration_s.to_bits(),
        };
        if let Err(e) = self.journal_append(&rec) {
            let _ = req.resp.send(Err(format!("journal append failed: {e}")));
            self.fatal = Some(format!("journal append failed: {e}"));
            return;
        }
        self.submitted.insert(
            req.job_id,
            SubKey {
                tenant,
                model: req.model.clone(),
                gpus: req.gpus,
                arrival_bits: arrival.to_bits(),
                duration_bits: req.duration_s.to_bits(),
            },
        );
        self.tenant_of.insert(req.job_id, TenantId(tenant));
        if let Ok(mut s) = self.status.lock() {
            s.submitted = self.submitted.len() as u64;
        }
        let _ = req.resp.send(Ok(false));
        inbox.push(DriverEvent::Submit(
            Job::new(JobId(req.job_id), model, req.gpus, arrival, req.duration_s)
                .with_tenant(TenantId(tenant)),
        ));
    }

    /// A registration after the fleet is full is a duplicate (typed
    /// `Error`, no panic); one naming a dead slot's spec revives the
    /// lowest dead slot and re-adds a server through the churn path.
    fn handle_rejoin(&mut self, mut pw: PendingWorker) {
        let s = ServerSpec { gpus: pw.gpus, cpus: pw.cpus, mem_gb: pw.mem_gb };
        if GpuGen::by_name(&pw.gen) != Some(self.gen) || s != self.spec {
            let _ = pw.conn.send(&Message::Error {
                reason: format!(
                    "rejoin spec mismatch: fleet is {:?} {:?}",
                    self.gen, self.spec
                ),
            });
            return;
        }
        let Some(slot) = self.senders.iter().position(|x| x.is_none()) else {
            let _ = pw.conn.send(&Message::Error {
                reason: format!(
                    "fleet full: all {} worker slots alive (duplicate \
                     registration rejected)",
                    self.senders.len()
                ),
            });
            return;
        };
        if pw
            .conn
            .send(&Message::RegisterAck {
                server_id: slot,
                heartbeat_s: self.cfg.heartbeat_s,
            })
            .is_err()
        {
            return;
        }
        let Ok(writer) = pw.conn.try_clone_writer() else { return };
        spawn_reader(pw.conn, slot, self.worker_tx.clone());
        self.senders[slot] = Some(writer);
        self.last_hb[slot] = Instant::now();
        self.pending_churn.push((false, slot));
        eprintln!("[leader] worker {slot} rejoined");
    }

    /// Apply one churn event to the fleet-position mirror, exactly as
    /// the model will: fail the highest online position, revive the
    /// lowest offline one.
    fn mirror_churn(&mut self, fail: bool) {
        if fail {
            if let Some(p) = self.fleet_online.iter().rposition(|&b| b) {
                self.fleet_online[p] = false;
            }
        } else if let Some(p) = self.fleet_online.iter().position(|&b| !b) {
            self.fleet_online[p] = true;
        } else {
            self.fleet_online.push(true); // pool grows past its start size
        }
    }

    /// Live mode: journal + inject churn observed since the last round.
    fn inject_pending(&mut self, now: f64, inbox: &mut Vec<DriverEvent>) {
        for (fail, slot) in std::mem::take(&mut self.pending_churn) {
            if let Err(e) = self.journal_append(&Record::Churn {
                fail,
                slot,
                at_bits: now.to_bits(),
            }) {
                self.fatal = Some(format!("journal append failed: {e}"));
                return;
            }
            self.mirror_churn(fail);
            inbox.push(DriverEvent::Churn {
                kind: if fail { FaultKind::Fail } else { FaultKind::Add },
                pool: 0,
            });
        }
    }

    /// Replay mode: re-inject journaled churn at its recorded sim time
    /// (bitwise — the grid is deterministic, so the times coincide).
    fn inject_replayed(&mut self, now: f64, inbox: &mut Vec<DriverEvent>) {
        while let Some(&(at, fail)) = self.replay_churn.front() {
            if at > now {
                break;
            }
            self.replay_churn.pop_front();
            self.mirror_churn(fail);
            inbox.push(DriverEvent::Churn {
                kind: if fail { FaultKind::Fail } else { FaultKind::Add },
                pool: 0,
            });
        }
    }

    /// Replay is exhausted: re-anchor the wall grid at the warm-started
    /// sim clock and resume live pacing, leases, and journaling.
    fn flip_live(&mut self, now: f64) {
        self.mode = Mode::Live;
        self.grid.re_anchor(now);
        for hb in &mut self.last_hb {
            *hb = Instant::now();
        }
        eprintln!(
            "[leader] replayed {} journal records; live at sim t={now:.0}s",
            self.counters.journal_records_replayed
        );
    }

    /// Map this round's committed grants onto alive workers: terminate
    /// moved leases, send new ones. Grant server ids are fleet scan
    /// positions; the i-th online position routes to the i-th alive
    /// worker slot.
    fn deploy_leases(&mut self, ctx: &RoundCtx) {
        let online: Vec<usize> = self
            .fleet_online
            .iter()
            .enumerate()
            .filter_map(|(i, &b)| b.then_some(i))
            .collect();
        let alive: Vec<usize> = self
            .senders
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.is_some().then_some(i))
            .collect();
        let slot_of_pos: HashMap<usize, usize> =
            online.into_iter().zip(alive).collect();

        let mut newly: HashMap<u64, usize> = HashMap::new();
        for g in ctx.grants {
            let job = ctx.arena.job(ctx.arena.index_of(g.id));
            if job.is_finished() {
                continue; // completed this round; lease already released
            }
            if let Some(&slot) = slot_of_pos.get(&g.server) {
                newly.insert(g.id.0, slot);
            }
        }
        // Terminate moved/preempted/paused jobs on their old workers.
        let to_stop: Vec<(u64, usize)> = self
            .hosted_on
            .iter()
            .filter(|(jid, wid)| newly.get(*jid) != Some(*wid))
            .map(|(&jid, &wid)| (jid, wid))
            .collect();
        for (jid, wid) in to_stop {
            self.hosted_on.remove(&jid);
            let sent = match self.senders[wid].as_mut() {
                Some(conn) => conn.send(&Message::Terminate { job_id: jid }),
                None => continue,
            };
            if sent.is_err() {
                self.note_worker_down(wid);
            }
        }
        // Grant/renew leases.
        for g in ctx.grants {
            let Some(&slot) = newly.get(&g.id.0) else { continue };
            let job = ctx.arena.job(ctx.arena.index_of(g.id));
            let msg = Message::Lease {
                job_id: g.id.0,
                model: job.model.name().into(),
                variant: self.cfg.variant.clone(),
                gpus: g.gpus,
                cpus: g.cpus,
                mem_gb: g.mem_gb,
                // Worker-side progress runs in real time.
                target_tput: job.progress_rate * self.cfg.time_scale,
                round_s: self.cfg.round_real_s,
                total_samples: job.total_samples,
                done_samples: job.progress_samples,
            };
            let sent = match self.senders[slot].as_mut() {
                Some(conn) => conn.send(&msg),
                None => continue,
            };
            match sent {
                Ok(()) => {
                    self.hosted_on.insert(g.id.0, slot);
                }
                Err(_) => self.note_worker_down(slot),
            }
        }
    }
}

impl RoundDriver for LeaderDriver<'_> {
    fn poll(&mut self, now: f64, inbox: &mut Vec<DriverEvent>) {
        self.pump_network(now, inbox);
        if let Mode::Replay { until } = self.mode {
            self.inject_replayed(now, inbox);
            // Flip once the journal's plan is consumed and the clock
            // has reached its frontier — this round runs live.
            if self.replay_churn.is_empty()
                && self.replay_ckpts.is_empty()
                && now >= until
            {
                self.flip_live(now);
            }
        }
        if matches!(self.mode, Mode::Live) {
            for req in std::mem::take(&mut self.deferred) {
                self.handle_submit(req, now, inbox);
            }
            self.inject_pending(now, inbox);
        }
    }

    fn wants_grants(&self) -> bool {
        true
    }

    fn on_round(&mut self, ctx: &RoundCtx) {
        if let Ok(mut s) = self.status.lock() {
            s.submitted = ctx.n_total as u64;
            s.finished = ctx.finished as u64;
            s.rounds = (ctx.round + 1) as u64;
            s.recoveries = self.counters.recoveries;
        }
        match self.mode {
            Mode::Replay { .. } => {
                while let Some(&(round, at_bits, fin, hash)) =
                    self.replay_ckpts.front()
                {
                    if round > ctx.round as u64 {
                        break;
                    }
                    self.replay_ckpts.pop_front();
                    if round < ctx.round as u64
                        || at_bits != ctx.now.to_bits()
                        || fin != ctx.finished as u64
                        || hash != self.completion_hash
                    {
                        self.fatal = Some(format!(
                            "replay divergence at journal checkpoint round \
                             {round}: journal (at={at_bits:016x} \
                             finished={fin} hash={hash:016x}) vs replayed \
                             round {} (at={:016x} finished={} hash={:016x}) \
                             — the journal was not produced by this \
                             configuration",
                            ctx.round,
                            ctx.now.to_bits(),
                            ctx.finished,
                            self.completion_hash,
                        ));
                        return;
                    }
                }
            }
            Mode::Live => {
                if let Err(e) = self.journal_append(&Record::Ckpt {
                    round: ctx.round as u64,
                    at_bits: ctx.now.to_bits(),
                    finished: ctx.finished as u64,
                    hash: self.completion_hash,
                }) {
                    self.fatal = Some(format!("journal append failed: {e}"));
                    return;
                }
                self.deploy_leases(ctx);
            }
        }
        if std::env::var_os("SYNERGY_DEPLOY_DEBUG").is_some() {
            eprintln!(
                "[leader] round={} now_sim={:.0} active={} grants={} \
                 finished={}",
                ctx.round,
                ctx.now,
                ctx.arena.n_active(),
                ctx.grants.len(),
                ctx.finished,
            );
        }
    }

    fn on_finished(&mut self, f: &FinishedJob, _now: f64) {
        self.completion_hash =
            fold_completion(self.completion_hash, f.id.0, f.jct_s.to_bits());
        let finish_bits = (f.arrival_s + f.jct_s).to_bits();
        if let Some((jct_bits, done_finish)) =
            self.replay_dones.remove(&f.id.0)
        {
            // The dead leader journaled this completion; the replayed
            // one must match bitwise.
            if jct_bits != f.jct_s.to_bits() || done_finish != finish_bits {
                self.fatal = Some(format!(
                    "replay divergence: job {} completed with \
                     jct={:016x}/finish={finish_bits:016x}, journal says \
                     jct={jct_bits:016x}/finish={done_finish:016x}",
                    f.id.0,
                    f.jct_s.to_bits(),
                ));
            }
            return;
        }
        if let Mode::Live = self.mode {
            if let Err(e) = self.journal_append(&Record::Done {
                id: f.id.0,
                jct_bits: f.jct_s.to_bits(),
                finish_bits,
            }) {
                self.fatal = Some(format!("journal append failed: {e}"));
                return;
            }
            if let Some(wid) = self.hosted_on.remove(&f.id.0) {
                let sent = match self.senders[wid].as_mut() {
                    Some(conn) => {
                        conn.send(&Message::Terminate { job_id: f.id.0 })
                    }
                    None => return,
                };
                if sent.is_err() {
                    self.note_worker_down(wid);
                }
            }
        }
    }

    fn advance(&mut self, now: f64, target: f64) -> Option<f64> {
        if self.fatal.is_some() {
            return None;
        }
        if let Mode::Replay { .. } = self.mode {
            return Some(target); // replay runs at memory speed
        }
        if self.senders.iter().all(|s| s.is_none()) {
            self.fatal = Some("all workers died".into());
            return None;
        }
        if self.run_start.elapsed().as_secs_f64() >= self.cfg.max_real_s {
            return None; // wall cap: normal (partial) stop
        }
        let _ = now;
        let sleep =
            self.grid.sleep_s(target, self.grid.start.elapsed().as_secs_f64());
        if sleep > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(sleep));
        }
        Some(target)
    }
}

/// Pull the next spec the cluster can ever host; oversized gangs are
/// dropped with a warning (the streaming analogue of the old up-front
/// `retain`).
fn pull_feasible(
    source: &mut dyn WorkloadSource,
    total_gpus: u32,
) -> Option<Job> {
    loop {
        let spec = source.next_spec()?;
        if spec.gpus <= total_gpus {
            return Some(spec.into_job());
        }
        eprintln!(
            "[leader] job {} demands {} GPUs > cluster capacity \
             {total_gpus}; dropped",
            spec.id.0, spec.gpus
        );
    }
}

#[cfg(test)]
mod tests {
    use super::{fold_completion, WallGrid};

    #[test]
    fn grid_subtracts_work_time_from_each_sleep() {
        let g = WallGrid::new(1.0);
        // Sim t=2.0 at scale 1 deadlines at wall 2.0 s; with 0.5 s of
        // work already elapsed, sleep only the remaining 1.5 s.
        assert!((g.sleep_s(2.0, 0.5) - 1.5).abs() < 1e-12);
        // Work ran until 2.3 s: the 4.0 s deadline needs 1.7 s — the
        // sleep does NOT reset to a full period.
        assert!((g.sleep_s(4.0, 2.3) - 1.7).abs() < 1e-12);
    }

    #[test]
    fn grid_absorbs_overruns_without_shifting() {
        let g = WallGrid::new(1.0);
        // Deadline already passed: no sleep...
        assert_eq!(g.sleep_s(1.0, 2.5), 0.0);
        assert_eq!(g.sleep_s(2.0, 2.6), 0.0);
        // ...and later deadlines are still the absolute marks — the
        // grid never drifts.
        assert!((g.sleep_s(3.0, 2.7) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn grid_deadlines_are_absolute_and_re_anchor_rescales() {
        let g = WallGrid::new(600.0);
        let mut elapsed = 0.0;
        for k in 1..=20 {
            // Each round does 0.01 s of "work" past the last boundary;
            // sim t = 150k at scale 600 must land at wall 0.25k exactly.
            elapsed += 0.01;
            elapsed += g.sleep_s(150.0 * k as f64, elapsed);
            assert!(
                (elapsed - 0.25 * k as f64).abs() < 1e-9,
                "round {k} must end on the absolute grid, not drift"
            );
        }
        // Recovery: re-anchor at sim 3000 — deadlines restart from the
        // new anchor, so sim 3600 is 1.0 wall second out.
        let mut g = g;
        g.re_anchor(3000.0);
        assert!((g.sleep_s(3600.0, 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn completion_hash_is_order_sensitive() {
        let h0 = super::journal::fnv1a(&[]);
        let a = fold_completion(fold_completion(h0, 1, 10), 2, 20);
        let b = fold_completion(fold_completion(h0, 2, 20), 1, 10);
        assert_ne!(a, b, "checkpoint hash must pin completion order");
        // Deterministic: same fold, same hash.
        let a2 = fold_completion(fold_completion(h0, 1, 10), 2, 20);
        assert_eq!(a, a2);
    }
}
