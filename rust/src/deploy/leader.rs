//! Leader: the live scheduler process (paper §4.3).
//!
//! Runs the exact same [`RoundPlanner`] as the simulator over a mirror
//! one-type [`Fleet`] built from worker registrations, and drives
//! workers with lease grant/renew/terminate messages each round.
//! Simulated time runs at `time_scale` × real time so a multi-hour
//! trace deploys in minutes (Table 5 compares deploy vs simulate on the
//! same trace).

use super::proto::{Conn, Message};
use crate::cluster::{Fleet, GpuGen, ServerSpec, TypeSpec};
use crate::coordinator::RoundPlanner;
use crate::job::{Job, JobId, JobState, TenantId};
use crate::mechanism::by_name as mechanism_by_name;
use crate::metrics::{per_tenant_stats, JctStats};
use crate::perf::PerfModel;
use crate::policy::by_name as policy_by_name;
use crate::profiler::{OptimisticProfiler, Sensitivity};
use crate::workload::{ReplaySource, TenantQuotas, WorkloadSource};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap};
use std::net::TcpListener;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Leader configuration.
pub struct LeaderConfig {
    pub bind: String,
    pub n_workers: usize,
    /// Real seconds per scheduling round.
    pub round_real_s: f64,
    /// Simulated seconds per real second.
    pub time_scale: f64,
    pub policy: String,
    pub mechanism: String,
    /// AOT variant workers should train.
    pub variant: String,
    /// Wall-clock cap for the whole run.
    pub max_real_s: f64,
    /// Tenant GPU quotas for the round planner (None = single tenant).
    pub quotas: Option<TenantQuotas>,
    /// Write a telemetry run profile (JSONL/CSV by extension) here —
    /// the same per-round/per-tenant series + plan-stage trace the
    /// simulator records, off the live round loop. `None` = no recorder
    /// (zero overhead, unchanged behaviour).
    pub telemetry: Option<String>,
    /// Record wall-clock milliseconds per round into the profile.
    /// Off by default: counter-only profiles stay deterministic in the
    /// round structure (sim-time stamps are nominal round multiples).
    pub telemetry_timing: bool,
}

impl Default for LeaderConfig {
    fn default() -> Self {
        LeaderConfig {
            bind: "127.0.0.1:0".into(),
            n_workers: 1,
            round_real_s: 2.0,
            time_scale: 600.0,
            policy: "srtf".into(),
            mechanism: "tune".into(),
            variant: "tiny".into(),
            max_real_s: 600.0,
            quotas: None,
            telemetry: None,
            telemetry_timing: false,
        }
    }
}

/// Outcome of a deploy run.
#[derive(Debug)]
pub struct LeaderReport {
    /// (job id, JCT in simulated seconds).
    pub jcts: Vec<(u64, f64)>,
    /// Owning tenant of every admitted job.
    pub tenant_of: BTreeMap<u64, TenantId>,
    /// Final reported training loss per job.
    pub losses: BTreeMap<u64, f64>,
    /// Total real train steps executed across workers.
    pub total_steps: u64,
    pub rounds: usize,
    pub makespan_sim_s: f64,
}

impl LeaderReport {
    pub fn jct_stats(&self) -> JctStats {
        let jcts: Vec<f64> = self.jcts.iter().map(|&(_, j)| j).collect();
        JctStats::from_jcts(&jcts)
    }

    /// Per-tenant JCT summaries.
    pub fn tenant_stats(&self) -> BTreeMap<TenantId, JctStats> {
        let pairs: Vec<(TenantId, f64)> = self
            .jcts
            .iter()
            .map(|&(id, jct)| {
                let t = self
                    .tenant_of
                    .get(&id)
                    .copied()
                    .unwrap_or(TenantId::DEFAULT);
                (t, jct)
            })
            .collect();
        per_tenant_stats(&pairs)
    }
}

/// Absolute-deadline round ticker. Round `k` ends at `k × period` from
/// the run's start rather than `period` after the round's *work*
/// finished — the old `sleep(period)`-after-planning accumulated every
/// round's planning/reconcile cost into the wall grid, so N rounds took
/// `N × period + Σ work` real seconds and drifted away from the nominal
/// sim-time stamps telemetry records. Pure arithmetic so the policy is
/// testable without a wall clock.
struct RoundTicker {
    period_s: f64,
    next_tick_s: f64,
}

impl RoundTicker {
    fn new(period_s: f64) -> RoundTicker {
        RoundTicker { period_s, next_tick_s: period_s }
    }

    /// Seconds to sleep at `elapsed_s` (time since run start) to reach
    /// the next round boundary, advancing the boundary one period. An
    /// overrunning round returns 0 — the grid is held, not shifted.
    fn sleep_s(&mut self, elapsed_s: f64) -> f64 {
        let s = (self.next_tick_s - elapsed_s).max(0.0);
        self.next_tick_s += self.period_s;
        s
    }
}

/// The leader process body.
pub struct Leader {
    pub cfg: LeaderConfig,
    /// Set after bind so tests can connect workers to an ephemeral port.
    pub addr: std::sync::Mutex<Option<std::net::SocketAddr>>,
}

impl Leader {
    pub fn new(cfg: LeaderConfig) -> Leader {
        Leader { cfg, addr: std::sync::Mutex::new(None) }
    }

    /// Bind, wait for `n_workers` registrations, run a batch trace, shut
    /// workers down, and report. Blocks. (Batch convenience wrapper over
    /// [`Leader::run_stream`].)
    pub fn run(&self, jobs: Vec<Job>) -> Result<LeaderReport> {
        self.run_stream(Box::new(ReplaySource::from_jobs(jobs)))
    }

    /// Like [`Leader::run`], but arrivals stream from a
    /// [`WorkloadSource`] instead of an up-front job list: the leader
    /// pulls the next spec lazily as simulated time passes it, so an
    /// unbounded or file-backed trace deploys without materialising the
    /// whole workload. The run ends when the source is exhausted and all
    /// admitted jobs finished (or at `max_real_s`).
    pub fn run_stream(
        &self,
        mut source: Box<dyn WorkloadSource>,
    ) -> Result<LeaderReport> {
        let listener = TcpListener::bind(&self.cfg.bind)?;
        *self.addr.lock().unwrap() = Some(listener.local_addr()?);

        // --- accept workers -------------------------------------------
        let mut conns: Vec<Conn> = Vec::new();
        let mut spec: Option<ServerSpec> = None;
        let mut fleet_gen: Option<GpuGen> = None;
        for server_id in 0..self.cfg.n_workers {
            let (stream, _) = listener.accept()?;
            let mut conn = Conn::new(stream)?;
            match conn.recv()? {
                Some(Message::Register { gpus, cpus, mem_gb, gen }) => {
                    let s = ServerSpec { gpus, cpus, mem_gb };
                    let g = GpuGen::by_name(&gen).ok_or_else(|| {
                        anyhow!("worker registered unknown gen {gen:?}")
                    })?;
                    if let Some(prev) = spec {
                        if prev != s {
                            return Err(anyhow!(
                                "heterogeneous workers unsupported"
                            ));
                        }
                    }
                    // Workers report their generation; the mirror fleet
                    // is still one-type, so a mixed registration is
                    // rejected up front rather than silently mis-modeled.
                    if fleet_gen.is_some_and(|prev| prev != g) {
                        return Err(anyhow!(
                            "mixed-generation workers unsupported: \
                             {gen:?} after {fleet_gen:?}"
                        ));
                    }
                    spec = Some(s);
                    fleet_gen = Some(g);
                    conn.send(&Message::RegisterAck { server_id })?;
                }
                other => return Err(anyhow!("expected register, got {other:?}")),
            }
            conns.push(conn);
        }
        let spec = spec.ok_or_else(|| anyhow!("no workers"))?;
        let gen = fleet_gen.ok_or_else(|| anyhow!("no workers"))?;

        // Reader threads funnel worker messages into one channel; `None`
        // signals the worker's connection is gone (crash/EOF) so the
        // leader can fail the worker over.
        let (tx, rx) = mpsc::channel::<(usize, Option<Message>)>();
        let mut senders: Vec<Conn> = Vec::new();
        for (wid, conn) in conns.into_iter().enumerate() {
            // Split: clone underlying stream for writing.
            let read_conn = conn;
            let tx = tx.clone();
            // Recreate a write-side Conn from the same socket.
            // (Conn::send uses its own cloned stream.)
            let write_conn = read_conn.try_clone_writer()?;
            senders.push(write_conn);
            std::thread::spawn(move || {
                let mut rc = read_conn;
                loop {
                    match rc.recv() {
                        Ok(Some(m)) => {
                            if tx.send((wid, Some(m))).is_err() {
                                break;
                            }
                        }
                        Ok(None) => break, // clean EOF
                        Err(e) => {
                            // A malformed frame is a protocol bug; losing
                            // the reader silently stalls every job on this
                            // worker, so shout before giving up.
                            eprintln!("[leader] worker {wid} recv: {e}");
                            break;
                        }
                    }
                }
                let _ = tx.send((wid, None));
            });
        }

        // --- scheduling state ------------------------------------------
        // Full-capacity mirror (admission + proportional shares); each
        // round replans over only the workers still alive. Workers are a
        // one-type fleet of whatever generation they registered
        // (heterogeneous workers register identical specs today; the
        // planner itself is fleet-generic).
        let fleet = Fleet::new(&[TypeSpec {
            gen,
            spec,
            machines: self.cfg.n_workers,
        }]);
        let mut alive = vec![true; self.cfg.n_workers];
        let world = PerfModel::with_gen(spec, gen);
        let profiler = OptimisticProfiler::noiseless_fleet(&fleet);
        let planner = RoundPlanner::with_quotas(
            policy_by_name(&self.cfg.policy)
                .ok_or_else(|| anyhow!("bad policy"))?,
            mechanism_by_name(&self.cfg.mechanism)
                .ok_or_else(|| anyhow!("bad mechanism"))?,
            self.cfg.quotas.clone(),
        );

        let total_gpus = fleet.total_gpus();
        // The streaming head: the next not-yet-arrived job, pulled from
        // the source only when simulated time reaches it.
        let mut next_job: Option<Job> =
            pull_feasible(source.as_mut(), total_gpus);
        let mut active: BTreeMap<JobId, Job> = BTreeMap::new();
        let mut contexts: BTreeMap<JobId, Sensitivity> = BTreeMap::new();
        let mut tenant_of: BTreeMap<u64, TenantId> = BTreeMap::new();
        // job -> worker currently hosting it.
        let mut hosted_on: HashMap<u64, usize> = HashMap::new();
        let mut losses: BTreeMap<u64, f64> = BTreeMap::new();
        let mut steps_total: BTreeMap<u64, u64> = BTreeMap::new();
        let mut jcts: Vec<(u64, f64)> = Vec::new();

        let start = Instant::now();
        let mut rounds = 0usize;
        let mut ticker = RoundTicker::new(self.cfg.round_real_s);
        // Same recorder as the simulator, fed by the live round loop.
        let mut recorder = self.cfg.telemetry.as_ref().map(|_| {
            crate::telemetry::TelemetryRecorder::new(
                crate::telemetry::TelemetryConfig {
                    timing: self.cfg.telemetry_timing,
                },
            )
        });
        while (next_job.is_some() || !active.is_empty())
            && start.elapsed().as_secs_f64() < self.cfg.max_real_s
        {
            let now_sim = start.elapsed().as_secs_f64() * self.cfg.time_scale;

            // Drain worker messages.
            while let Ok((wid, msg)) = rx.try_recv() {
                let Some(msg) = msg else {
                    // Worker `wid` died: fail it over. Its jobs return to
                    // the queue and resume from the leader's last
                    // progress view on the next round's lease.
                    if alive[wid] {
                        alive[wid] = false;
                        eprintln!(
                            "[leader] worker {wid} down; requeueing its jobs"
                        );
                        hosted_on.retain(|_, w| *w != wid);
                    }
                    continue;
                };
                match msg {
                    Message::Progress { job_id, samples_done, loss, steps } => {
                        if let Some(j) = active.get_mut(&JobId(job_id)) {
                            j.progress_samples =
                                samples_done.min(j.total_samples);
                        }
                        if loss.is_finite() {
                            losses.insert(job_id, loss);
                        }
                        steps_total.insert(job_id, steps);
                    }
                    Message::Finished { job_id } => {
                        if let Some(mut j) = active.remove(&JobId(job_id)) {
                            contexts.remove(&j.id);
                            j.state = JobState::Finished;
                            jcts.push((job_id, now_sim - j.arrival_s));
                            if let Some(wid) = hosted_on.remove(&job_id) {
                                let _ = senders[wid]
                                    .send(&Message::Terminate { job_id });
                            }
                        }
                    }
                    _ => {}
                }
            }

            // Admit arrivals (profile on arrival), pulling the stream
            // forward only as far as simulated time has reached.
            while next_job
                .as_ref()
                .is_some_and(|j| j.arrival_s <= now_sim)
            {
                let mut job = next_job.take().unwrap();
                let sens = profiler.profile(&job);
                job.total_samples =
                    job.duration_prop_s * sens.fair_throughput();
                tenant_of.insert(job.id.0, job.tenant);
                contexts.insert(job.id, sens);
                active.insert(job.id, job);
                next_job = pull_feasible(source.as_mut(), total_gpus);
            }

            // Plan the round over the alive workers only.
            let alive_ids: Vec<usize> = (0..alive.len())
                .filter(|&w| alive[w])
                .collect();
            if alive_ids.is_empty() {
                return Err(anyhow!("all workers died"));
            }
            let mut round_fleet =
                Fleet::with_server_ids_of(gen, spec, &alive_ids);
            let refs: Vec<(&Job, &Sensitivity)> =
                active.values().map(|j| (j, &contexts[&j.id])).collect();
            let planned_jobs = refs.len();
            let plan = planner.plan(&mut round_fleet, &refs, now_sim);

            // Reconcile leases with workers.
            let mut newly_hosted: HashMap<u64, usize> = HashMap::new();
            for (id, grant) in &plan.grants {
                // Primary worker: the server holding the most GPUs.
                let primary = grant
                    .placement
                    .shares
                    .iter()
                    .max_by_key(|(_, s)| s.gpus)
                    .map(|(&sid, _)| sid)
                    .unwrap_or(0);
                newly_hosted.insert(id.0, primary);
            }
            // Terminate moved/preempted jobs.
            let to_stop: Vec<u64> = hosted_on
                .iter()
                .filter(|(jid, wid)| newly_hosted.get(*jid) != Some(*wid))
                .map(|(&jid, _)| jid)
                .collect();
            for jid in to_stop {
                if let Some(wid) = hosted_on.remove(&jid) {
                    if senders[wid]
                        .send(&Message::Terminate { job_id: jid })
                        .is_err()
                    {
                        // Send failure == worker death; the reader thread
                        // will also report it, but react immediately.
                        alive[wid] = false;
                        hosted_on.retain(|_, w| *w != wid);
                    }
                }
            }
            // Grant/renew leases.
            for (id, grant) in &plan.grants {
                let job = &active[id];
                let wid = newly_hosted[&id.0];
                if !alive[wid] {
                    continue; // re-planned next round over survivors
                }
                let tput = world.throughput(
                    job.model,
                    job.gpus,
                    grant.demand.cpus,
                    grant.demand.mem_gb,
                );
                let sent = senders[wid].send(&Message::Lease {
                    job_id: id.0,
                    model: job.model.name().into(),
                    variant: self.cfg.variant.clone(),
                    gpus: job.gpus,
                    cpus: grant.demand.cpus,
                    mem_gb: grant.demand.mem_gb,
                    // Worker-side progress runs in real time.
                    target_tput: tput * self.cfg.time_scale,
                    round_s: self.cfg.round_real_s,
                    total_samples: job.total_samples,
                    done_samples: job.progress_samples,
                });
                if sent.is_err() {
                    alive[wid] = false;
                    hosted_on.retain(|_, w| *w != wid);
                    continue;
                }
                hosted_on.insert(id.0, wid);
            }
            for job in active.values_mut() {
                job.state = if plan.grants.contains_key(&job.id) {
                    JobState::Running
                } else {
                    JobState::Queued
                };
            }

            if let Some(rec) = recorder.as_mut() {
                use crate::telemetry as tm;
                // Counters only by default. Time stamps are *nominal*
                // (round index × round length × time_scale), not wall
                // clock, so the recorded round structure is a pure
                // function of the schedule; wall time goes into
                // `wall_ms` only under `telemetry_timing`.
                let nominal_s = rounds as f64
                    * self.cfg.round_real_s
                    * self.cfg.time_scale;
                let mut pools: Vec<tm::PoolCounters> = Vec::new();
                let mut fit_walk = 0u64;
                for p in &round_fleet.pools {
                    pools.push(tm::PoolCounters {
                        gen: p.gen,
                        free_gpus: p.cluster.free_gpus(),
                        total_gpus: p.cluster.total_gpus(),
                        free_cpus: p.cluster.free_cpus_gauge(),
                        total_cpus: p.cluster.total_cpus(),
                        free_mem_gb: p.cluster.free_mem_gb_gauge(),
                        total_mem_gb: p.cluster.total_mem_gb(),
                    });
                    fit_walk += p.cluster.take_fit_walk();
                }
                let mut tenants: BTreeMap<TenantId, tm::TenantCounters> =
                    BTreeMap::new();
                for job in active.values() {
                    let e = tenants.entry(job.tenant).or_insert(
                        tm::TenantCounters {
                            tenant: job.tenant,
                            running: 0,
                            pending: 0,
                            admitted_gpus: 0,
                            spilled_gpus: 0,
                        },
                    );
                    if job.state == JobState::Running {
                        e.running += 1;
                        e.admitted_gpus += job.gpus;
                    } else {
                        e.pending += 1;
                    }
                }
                // Gang counters off the planned grants (the mirror fleet
                // is flat today, so cross_rack stays 0 — the field keeps
                // the row layout identical to the simulator's).
                let mut gangs_placed = 0u32;
                let mut cross_rack_gangs = 0u32;
                for grant in plan.grants.values() {
                    if grant.placement.span() > 1 {
                        gangs_placed += 1;
                        if round_fleet.pool(grant.gen).is_some_and(|p| {
                            p.cluster.racks_spanned(&grant.placement) > 1
                        }) {
                            cross_rack_gangs += 1;
                        }
                    }
                }
                let running =
                    tenants.values().map(|t| t.running).sum::<u32>();
                let queued =
                    tenants.values().map(|t| t.pending).sum::<u32>();
                let admitted_gpus =
                    tenants.values().map(|t| t.admitted_gpus).sum::<u32>();
                rec.record_round(&tm::RoundSample {
                    round: rounds as u64,
                    time_ms: tm::milli(nominal_s),
                    queued,
                    running,
                    admitted_gpus,
                    spilled_gpus: 0,
                    free_gpus: pools.iter().map(|p| p.free_gpus).sum(),
                    total_gpus: pools.iter().map(|p| p.total_gpus).sum(),
                    free_cpus: pools.iter().map(|p| p.free_cpus).sum(),
                    total_cpus: pools.iter().map(|p| p.total_cpus).sum(),
                    free_mem_gb: pools
                        .iter()
                        .map(|p| p.free_mem_gb)
                        .sum(),
                    total_mem_gb: pools
                        .iter()
                        .map(|p| p.total_mem_gb)
                        .sum(),
                    gangs_placed,
                    cross_rack_gangs,
                    // The live leader replans over survivors instead of
                    // modelling churn events; the counters exist so the
                    // row layout matches the simulator's.
                    preemptions: 0,
                    servers_failed: 0,
                    servers_restored: 0,
                    wall_ms: start.elapsed().as_millis() as i64,
                    pools,
                    tenants: tenants.values().copied().collect(),
                });
                // The live planner replans from scratch every round:
                // always a full-tier plan over the active set.
                rec.record_plan(&tm::PlanEvent {
                    round: rounds as u64,
                    tier: tm::PlanTier::Full,
                    steps_total: planned_jobs as u64,
                    steps_reused: 0,
                    rollback_depth: 0,
                    fit_walk,
                    pools: Vec::new(),
                });
            }

            if std::env::var_os("SYNERGY_DEPLOY_DEBUG").is_some() {
                eprintln!(
                    "[leader] round={} now_sim={:.0} active={} grants={} \
                     finished={} remaining_hint={:?}",
                    rounds,
                    now_sim,
                    active.len(),
                    plan.grants.len(),
                    jcts.len(),
                    source.len_hint()
                );
            }
            rounds += 1;
            let sleep_s = ticker.sleep_s(start.elapsed().as_secs_f64());
            if sleep_s > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(sleep_s));
            }
        }

        // Shutdown.
        for s in &mut senders {
            let _ = s.send(&Message::Shutdown);
        }
        if let (Some(path), Some(rec)) = (&self.cfg.telemetry, &recorder) {
            crate::util::fsx::write_creating(
                std::path::Path::new(path),
                rec.render_for_path(path).as_bytes(),
            )
            .map_err(|e| anyhow!("telemetry: {e}"))?;
        }
        let makespan_sim_s =
            start.elapsed().as_secs_f64() * self.cfg.time_scale;
        Ok(LeaderReport {
            jcts,
            tenant_of,
            losses,
            total_steps: steps_total.values().sum(),
            rounds,
            makespan_sim_s,
        })
    }
}

/// Pull the next spec the cluster can ever host; oversized gangs are
/// dropped with a warning (the streaming analogue of the old up-front
/// `retain`).
fn pull_feasible(
    source: &mut dyn WorkloadSource,
    total_gpus: u32,
) -> Option<Job> {
    loop {
        let spec = source.next_spec()?;
        if spec.gpus <= total_gpus {
            return Some(spec.into_job());
        }
        eprintln!(
            "[leader] job {} demands {} GPUs > cluster capacity \
             {total_gpus}; dropped",
            spec.id.0, spec.gpus
        );
    }
}

#[cfg(test)]
mod tests {
    use super::RoundTicker;

    #[test]
    fn ticker_subtracts_work_time_from_each_sleep() {
        let mut t = RoundTicker::new(2.0);
        // Round 0's work took 0.5 s: sleep only the remaining 1.5 s so
        // the boundary lands at exactly 2.0 s.
        assert!((t.sleep_s(0.5) - 1.5).abs() < 1e-12);
        // Round 1's work ran until 2.3 s: the 4.0 s boundary needs 1.7 s
        // — the sleep does NOT reset to a full period.
        assert!((t.sleep_s(2.3) - 1.7).abs() < 1e-12);
    }

    #[test]
    fn ticker_absorbs_overruns_without_shifting_the_grid() {
        let mut t = RoundTicker::new(1.0);
        // Round 0 overran its whole budget: no sleep...
        assert_eq!(t.sleep_s(2.5), 0.0);
        // ...and the next boundary is still the absolute 2.0 s mark
        // (already passed), then 3.0 s — the grid never drifts.
        assert_eq!(t.sleep_s(2.6), 0.0);
        assert!((t.sleep_s(2.7) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn ticker_boundaries_are_absolute_multiples_of_the_period() {
        let mut t = RoundTicker::new(0.25);
        let mut elapsed = 0.0;
        for k in 1..=20 {
            // Each round does 0.01 s of "work" past the last boundary.
            elapsed += 0.01;
            elapsed += t.sleep_s(elapsed);
            assert!(
                (elapsed - 0.25 * k as f64).abs() < 1e-9,
                "round {k} must end on the absolute grid, not drift"
            );
        }
    }
}
