//! Wire protocol: newline-delimited JSON messages over TCP.
//!
//! Each frame is one JSON object terminated by '\n' with a `"type"`
//! discriminator. Encoding/decoding goes through [`crate::util::json`].

use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Control-plane messages between leader and workers.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// worker -> leader: join the cluster with this capacity. `gen` is
    /// the GPU generation name (mixed-generation fleets); senders that
    /// predate the field are decoded as `"v100"`.
    Register { gpus: u32, cpus: u32, mem_gb: f64, gen: String },
    /// leader -> worker: accepted; assigned server id.
    RegisterAck { server_id: usize },
    /// leader -> worker: start (or renew) a job lease for one round.
    Lease {
        job_id: u64,
        model: String,
        variant: String,
        gpus: u32,
        cpus: f64,
        mem_gb: f64,
        /// Target throughput (samples/s) the grant yields — the worker
        /// paces real train steps to this rate.
        target_tput: f64,
        round_s: f64,
        total_samples: f64,
        /// Samples already completed (leader's view) — a runner that is
        /// (re)started after migration or lease expiry resumes from here.
        done_samples: f64,
    },
    /// leader -> worker: terminate a job's lease (checkpoint + stop).
    Terminate { job_id: u64 },
    /// worker -> leader: progress report for a job.
    Progress { job_id: u64, samples_done: f64, loss: f64, steps: u64 },
    /// worker -> leader: job finished all its work.
    Finished { job_id: u64 },
    /// leader -> worker: experiment over, exit cleanly.
    Shutdown,
}

impl Message {
    pub fn encode(&self) -> String {
        let j = match self {
            Message::Register { gpus, cpus, mem_gb, gen } => Json::obj(vec![
                ("type", Json::str("register")),
                ("gpus", Json::num(*gpus as f64)),
                ("cpus", Json::num(*cpus as f64)),
                ("mem_gb", Json::num(*mem_gb)),
                ("gen", Json::str(gen.clone())),
            ]),
            Message::RegisterAck { server_id } => Json::obj(vec![
                ("type", Json::str("register_ack")),
                ("server_id", Json::num(*server_id as f64)),
            ]),
            Message::Lease {
                job_id,
                model,
                variant,
                gpus,
                cpus,
                mem_gb,
                target_tput,
                round_s,
                total_samples,
                done_samples,
            } => Json::obj(vec![
                ("type", Json::str("lease")),
                ("job_id", Json::num(*job_id as f64)),
                ("model", Json::str(model.clone())),
                ("variant", Json::str(variant.clone())),
                ("gpus", Json::num(*gpus as f64)),
                ("cpus", Json::num(*cpus)),
                ("mem_gb", Json::num(*mem_gb)),
                ("target_tput", Json::num(*target_tput)),
                ("round_s", Json::num(*round_s)),
                ("total_samples", Json::num(*total_samples)),
                ("done_samples", Json::num(*done_samples)),
            ]),
            Message::Terminate { job_id } => Json::obj(vec![
                ("type", Json::str("terminate")),
                ("job_id", Json::num(*job_id as f64)),
            ]),
            Message::Progress { job_id, samples_done, loss, steps } => {
                Json::obj(vec![
                    ("type", Json::str("progress")),
                    ("job_id", Json::num(*job_id as f64)),
                    ("samples_done", Json::num(*samples_done)),
                    ("loss", Json::num(*loss)),
                    ("steps", Json::num(*steps as f64)),
                ])
            }
            Message::Finished { job_id } => Json::obj(vec![
                ("type", Json::str("finished")),
                ("job_id", Json::num(*job_id as f64)),
            ]),
            Message::Shutdown => {
                Json::obj(vec![("type", Json::str("shutdown"))])
            }
        };
        j.encode()
    }

    pub fn decode(line: &str) -> Result<Message, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        let ty = j.get("type").as_str().ok_or("missing type")?;
        let num =
            |k: &str| j.get(k).as_f64().ok_or_else(|| format!("missing {k}"));
        let st = |k: &str| {
            j.get(k)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("missing {k}"))
        };
        Ok(match ty {
            "register" => Message::Register {
                gpus: num("gpus")? as u32,
                cpus: num("cpus")? as u32,
                mem_gb: num("mem_gb")?,
                // Pre-`gen` senders omit the field; default to the
                // homogeneous fleet's generation so old workers still
                // register.
                gen: st("gen").unwrap_or_else(|_| "v100".into()),
            },
            "register_ack" => {
                Message::RegisterAck { server_id: num("server_id")? as usize }
            }
            "lease" => Message::Lease {
                job_id: num("job_id")? as u64,
                model: st("model")?,
                variant: st("variant")?,
                gpus: num("gpus")? as u32,
                cpus: num("cpus")?,
                mem_gb: num("mem_gb")?,
                target_tput: num("target_tput")?,
                round_s: num("round_s")?,
                total_samples: num("total_samples")?,
                done_samples: num("done_samples").unwrap_or(0.0),
            },
            "terminate" => Message::Terminate { job_id: num("job_id")? as u64 },
            "progress" => Message::Progress {
                job_id: num("job_id")? as u64,
                samples_done: num("samples_done")?,
                // Loss is NaN until the first real train step completes;
                // non-finite numbers ride the wire as JSON null.
                loss: num("loss").unwrap_or(f64::NAN),
                steps: num("steps")? as u64,
            },
            "finished" => Message::Finished { job_id: num("job_id")? as u64 },
            "shutdown" => Message::Shutdown,
            other => return Err(format!("unknown message type {other:?}")),
        })
    }
}

/// Framed connection: one JSON message per line.
pub struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    pub fn new(stream: TcpStream) -> std::io::Result<Conn> {
        let writer = stream.try_clone()?;
        Ok(Conn { reader: BufReader::new(stream), writer })
    }

    pub fn send(&mut self, msg: &Message) -> std::io::Result<()> {
        let mut line = msg.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()
    }

    /// Blocking receive; None on clean EOF.
    pub fn recv(&mut self) -> std::io::Result<Option<Message>> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Ok(None);
        }
        Message::decode(line.trim_end()).map(Some).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e)
        })
    }

    /// A write-only handle to the same socket (leader keeps this while a
    /// reader thread owns the original `Conn`). Never call `recv` on the
    /// clone — both handles share the byte stream.
    pub fn try_clone_writer(&self) -> std::io::Result<Conn> {
        Ok(Conn {
            reader: BufReader::new(self.writer.try_clone()?),
            writer: self.writer.try_clone()?,
        })
    }

    pub fn set_read_timeout(
        &self,
        dur: Option<std::time::Duration>,
    ) -> std::io::Result<()> {
        self.reader.get_ref().set_read_timeout(dur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_messages_roundtrip() {
        let msgs = vec![
            Message::Register {
                gpus: 8,
                cpus: 24,
                mem_gb: 500.0,
                gen: "p100".into(),
            },
            Message::RegisterAck { server_id: 3 },
            Message::Lease {
                job_id: 7,
                model: "resnet18".into(),
                variant: "tiny".into(),
                gpus: 2,
                cpus: 7.5,
                mem_gb: 125.0,
                target_tput: 321.5,
                round_s: 5.0,
                total_samples: 1e6,
                done_samples: 2048.0,
            },
            Message::Terminate { job_id: 7 },
            Message::Progress {
                job_id: 7,
                samples_done: 123.0,
                loss: 5.25,
                steps: 42,
            },
            Message::Finished { job_id: 7 },
            Message::Shutdown,
        ];
        for m in msgs {
            let enc = m.encode();
            assert_eq!(Message::decode(&enc).unwrap(), m, "{enc}");
        }
    }

    #[test]
    fn register_without_gen_defaults_to_v100() {
        // A frame from a sender that predates the `gen` field must still
        // parse — mixed-generation registration is backwards compatible.
        let old =
            r#"{"type": "register", "gpus": 4, "cpus": 12, "mem_gb": 250}"#;
        assert_eq!(
            Message::decode(old).unwrap(),
            Message::Register {
                gpus: 4,
                cpus: 12,
                mem_gb: 250.0,
                gen: "v100".into(),
            }
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode("{}").is_err());
        assert!(Message::decode("not json").is_err());
        assert!(Message::decode(r#"{"type": "warp"}"#).is_err());
        assert!(Message::decode(r#"{"type": "lease"}"#).is_err());
    }

    #[test]
    fn conn_roundtrip_over_localhost() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Conn::new(s).unwrap();
            let m = conn.recv().unwrap().unwrap();
            conn.send(&m).unwrap(); // echo
        });
        let mut conn =
            Conn::new(TcpStream::connect(addr).unwrap()).unwrap();
        let msg = Message::Finished { job_id: 99 };
        conn.send(&msg).unwrap();
        let echoed = conn.recv().unwrap().unwrap();
        assert_eq!(echoed, msg);
        t.join().unwrap();
    }
}
