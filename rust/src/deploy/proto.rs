//! Wire protocol: newline-delimited JSON messages over TCP.
//!
//! Each frame is one JSON object terminated by '\n' with a `"type"`
//! discriminator. Encoding/decoding goes through [`crate::util::json`].

use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Control-plane messages between leader and workers.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// worker -> leader: join the cluster with this capacity. `gen` is
    /// the GPU generation name (mixed-generation fleets); senders that
    /// predate the field are decoded as `"v100"`.
    Register { gpus: u32, cpus: u32, mem_gb: f64, gen: String },
    /// leader -> worker: accepted; assigned server id. `heartbeat_s` is
    /// the lease period the leader enforces (0 = heartbeats disabled);
    /// senders that predate the field decode as 0.
    RegisterAck { server_id: usize, heartbeat_s: f64 },
    /// worker -> leader: lease renewal; proof of liveness.
    Heartbeat { server_id: usize },
    /// client -> leader: submit a job. Idempotent by client-supplied
    /// `job_id`; `arrival_s`/`duration_s` are sim-time seconds.
    Submit {
        job_id: u64,
        tenant: String,
        model: String,
        gpus: u32,
        arrival_s: f64,
        duration_s: f64,
    },
    /// leader -> client: submission journaled (durable). `duplicate`
    /// marks an identical resubmission that was already admitted.
    SubmitAck { job_id: u64, duplicate: bool },
    /// client -> leader: ask for run progress counters.
    QueryStatus,
    /// leader -> client: run progress counters.
    Status { submitted: u64, finished: u64, rounds: u64, recoveries: u64 },
    /// leader -> peer: typed rejection (duplicate registration,
    /// conflicting resubmission, malformed request). The connection
    /// stays usable unless the peer closes it.
    Error { reason: String },
    /// leader -> worker: start (or renew) a job lease for one round.
    Lease {
        job_id: u64,
        model: String,
        variant: String,
        gpus: u32,
        cpus: f64,
        mem_gb: f64,
        /// Target throughput (samples/s) the grant yields — the worker
        /// paces real train steps to this rate.
        target_tput: f64,
        round_s: f64,
        total_samples: f64,
        /// Samples already completed (leader's view) — a runner that is
        /// (re)started after migration or lease expiry resumes from here.
        done_samples: f64,
    },
    /// leader -> worker: terminate a job's lease (checkpoint + stop).
    Terminate { job_id: u64 },
    /// worker -> leader: progress report for a job.
    Progress { job_id: u64, samples_done: f64, loss: f64, steps: u64 },
    /// worker -> leader: job finished all its work.
    Finished { job_id: u64 },
    /// leader -> worker: experiment over, exit cleanly.
    Shutdown,
}

impl Message {
    pub fn encode(&self) -> String {
        let j = match self {
            Message::Register { gpus, cpus, mem_gb, gen } => Json::obj(vec![
                ("type", Json::str("register")),
                ("gpus", Json::num(*gpus as f64)),
                ("cpus", Json::num(*cpus as f64)),
                ("mem_gb", Json::num(*mem_gb)),
                ("gen", Json::str(gen.clone())),
            ]),
            Message::RegisterAck { server_id, heartbeat_s } => Json::obj(vec![
                ("type", Json::str("register_ack")),
                ("server_id", Json::num(*server_id as f64)),
                ("heartbeat_s", Json::num(*heartbeat_s)),
            ]),
            Message::Heartbeat { server_id } => Json::obj(vec![
                ("type", Json::str("heartbeat")),
                ("server_id", Json::num(*server_id as f64)),
            ]),
            Message::Submit {
                job_id,
                tenant,
                model,
                gpus,
                arrival_s,
                duration_s,
            } => Json::obj(vec![
                ("type", Json::str("submit")),
                ("job_id", Json::num(*job_id as f64)),
                ("tenant", Json::str(tenant.clone())),
                ("model", Json::str(model.clone())),
                ("gpus", Json::num(*gpus as f64)),
                ("arrival_s", Json::num(*arrival_s)),
                ("duration_s", Json::num(*duration_s)),
            ]),
            Message::SubmitAck { job_id, duplicate } => Json::obj(vec![
                ("type", Json::str("submit_ack")),
                ("job_id", Json::num(*job_id as f64)),
                ("duplicate", Json::Bool(*duplicate)),
            ]),
            Message::QueryStatus => {
                Json::obj(vec![("type", Json::str("query_status"))])
            }
            Message::Status { submitted, finished, rounds, recoveries } => {
                Json::obj(vec![
                    ("type", Json::str("status")),
                    ("submitted", Json::num(*submitted as f64)),
                    ("finished", Json::num(*finished as f64)),
                    ("rounds", Json::num(*rounds as f64)),
                    ("recoveries", Json::num(*recoveries as f64)),
                ])
            }
            Message::Error { reason } => Json::obj(vec![
                ("type", Json::str("error")),
                ("reason", Json::str(reason.clone())),
            ]),
            Message::Lease {
                job_id,
                model,
                variant,
                gpus,
                cpus,
                mem_gb,
                target_tput,
                round_s,
                total_samples,
                done_samples,
            } => Json::obj(vec![
                ("type", Json::str("lease")),
                ("job_id", Json::num(*job_id as f64)),
                ("model", Json::str(model.clone())),
                ("variant", Json::str(variant.clone())),
                ("gpus", Json::num(*gpus as f64)),
                ("cpus", Json::num(*cpus)),
                ("mem_gb", Json::num(*mem_gb)),
                ("target_tput", Json::num(*target_tput)),
                ("round_s", Json::num(*round_s)),
                ("total_samples", Json::num(*total_samples)),
                ("done_samples", Json::num(*done_samples)),
            ]),
            Message::Terminate { job_id } => Json::obj(vec![
                ("type", Json::str("terminate")),
                ("job_id", Json::num(*job_id as f64)),
            ]),
            Message::Progress { job_id, samples_done, loss, steps } => {
                Json::obj(vec![
                    ("type", Json::str("progress")),
                    ("job_id", Json::num(*job_id as f64)),
                    ("samples_done", Json::num(*samples_done)),
                    ("loss", Json::num(*loss)),
                    ("steps", Json::num(*steps as f64)),
                ])
            }
            Message::Finished { job_id } => Json::obj(vec![
                ("type", Json::str("finished")),
                ("job_id", Json::num(*job_id as f64)),
            ]),
            Message::Shutdown => {
                Json::obj(vec![("type", Json::str("shutdown"))])
            }
        };
        j.encode()
    }

    pub fn decode(line: &str) -> Result<Message, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        let ty = j.get("type").as_str().ok_or("missing type")?;
        let num =
            |k: &str| j.get(k).as_f64().ok_or_else(|| format!("missing {k}"));
        let st = |k: &str| {
            j.get(k)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("missing {k}"))
        };
        Ok(match ty {
            "register" => Message::Register {
                gpus: num("gpus")? as u32,
                cpus: num("cpus")? as u32,
                mem_gb: num("mem_gb")?,
                // Pre-`gen` senders omit the field; default to the
                // homogeneous fleet's generation so old workers still
                // register.
                gen: st("gen").unwrap_or_else(|_| "v100".into()),
            },
            "register_ack" => Message::RegisterAck {
                server_id: num("server_id")? as usize,
                // Pre-heartbeat leaders omit the field; 0 disables the
                // worker's heartbeat thread.
                heartbeat_s: num("heartbeat_s").unwrap_or(0.0),
            },
            "heartbeat" => {
                Message::Heartbeat { server_id: num("server_id")? as usize }
            }
            "submit" => Message::Submit {
                job_id: num("job_id")? as u64,
                tenant: st("tenant")?,
                model: st("model")?,
                gpus: num("gpus")? as u32,
                arrival_s: num("arrival_s")?,
                duration_s: num("duration_s")?,
            },
            "submit_ack" => Message::SubmitAck {
                job_id: num("job_id")? as u64,
                duplicate: j
                    .get("duplicate")
                    .as_bool()
                    .ok_or("missing duplicate")?,
            },
            "query_status" => Message::QueryStatus,
            "status" => Message::Status {
                submitted: num("submitted")? as u64,
                finished: num("finished")? as u64,
                rounds: num("rounds")? as u64,
                recoveries: num("recoveries")? as u64,
            },
            "error" => Message::Error { reason: st("reason")? },
            "lease" => Message::Lease {
                job_id: num("job_id")? as u64,
                model: st("model")?,
                variant: st("variant")?,
                gpus: num("gpus")? as u32,
                cpus: num("cpus")?,
                mem_gb: num("mem_gb")?,
                target_tput: num("target_tput")?,
                round_s: num("round_s")?,
                total_samples: num("total_samples")?,
                done_samples: num("done_samples").unwrap_or(0.0),
            },
            "terminate" => Message::Terminate { job_id: num("job_id")? as u64 },
            "progress" => Message::Progress {
                job_id: num("job_id")? as u64,
                samples_done: num("samples_done")?,
                // Loss is NaN until the first real train step completes;
                // non-finite numbers ride the wire as JSON null.
                loss: num("loss").unwrap_or(f64::NAN),
                steps: num("steps")? as u64,
            },
            "finished" => Message::Finished { job_id: num("job_id")? as u64 },
            "shutdown" => Message::Shutdown,
            other => return Err(format!("unknown message type {other:?}")),
        })
    }
}

/// Hard cap on one incoming frame. Every legitimate message is well
/// under 1 KiB; the cap bounds buffer growth against a peer that
/// streams bytes without ever sending '\n'.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Framed connection: one JSON message per line.
pub struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    pub fn new(stream: TcpStream) -> std::io::Result<Conn> {
        let writer = stream.try_clone()?;
        Ok(Conn { reader: BufReader::new(stream), writer })
    }

    pub fn send(&mut self, msg: &Message) -> std::io::Result<()> {
        let mut line = msg.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()
    }

    /// Blocking receive; None on clean EOF (including EOF mid-line — a
    /// peer that died mid-write is a disconnect, not a decode error).
    /// A line longer than [`MAX_LINE_BYTES`] is an `InvalidData` error:
    /// the buffer never grows past the cap, so a hostile or broken peer
    /// cannot balloon leader memory.
    pub fn recv(&mut self) -> std::io::Result<Option<Message>> {
        use std::io::Read;
        let mut line = String::new();
        let n = (&mut self.reader)
            .take(MAX_LINE_BYTES as u64 + 1)
            .read_line(&mut line)?;
        if n == 0 {
            return Ok(None);
        }
        if !line.ends_with('\n') {
            if n > MAX_LINE_BYTES {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("frame exceeds {MAX_LINE_BYTES} byte cap"),
                ));
            }
            // Mid-line EOF: the peer closed (or was killed) between
            // bytes of a frame. Nothing durable was promised for an
            // unterminated frame — treat it as a clean disconnect.
            return Ok(None);
        }
        Message::decode(line.trim_end()).map(Some).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e)
        })
    }

    /// A write-only handle to the same socket (leader keeps this while a
    /// reader thread owns the original `Conn`). Never call `recv` on the
    /// clone — both handles share the byte stream.
    pub fn try_clone_writer(&self) -> std::io::Result<Conn> {
        Ok(Conn {
            reader: BufReader::new(self.writer.try_clone()?),
            writer: self.writer.try_clone()?,
        })
    }

    pub fn set_read_timeout(
        &self,
        dur: Option<std::time::Duration>,
    ) -> std::io::Result<()> {
        self.reader.get_ref().set_read_timeout(dur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_messages_roundtrip() {
        let msgs = vec![
            Message::Register {
                gpus: 8,
                cpus: 24,
                mem_gb: 500.0,
                gen: "p100".into(),
            },
            Message::RegisterAck { server_id: 3, heartbeat_s: 1.5 },
            Message::Heartbeat { server_id: 3 },
            Message::Submit {
                job_id: 11,
                tenant: "ops".into(),
                model: "lstm".into(),
                gpus: 2,
                arrival_s: 60.0,
                duration_s: 1800.0,
            },
            Message::SubmitAck { job_id: 11, duplicate: true },
            Message::QueryStatus,
            Message::Status {
                submitted: 5,
                finished: 2,
                rounds: 9,
                recoveries: 1,
            },
            Message::Error { reason: "duplicate server".into() },
            Message::Lease {
                job_id: 7,
                model: "resnet18".into(),
                variant: "tiny".into(),
                gpus: 2,
                cpus: 7.5,
                mem_gb: 125.0,
                target_tput: 321.5,
                round_s: 5.0,
                total_samples: 1e6,
                done_samples: 2048.0,
            },
            Message::Terminate { job_id: 7 },
            Message::Progress {
                job_id: 7,
                samples_done: 123.0,
                loss: 5.25,
                steps: 42,
            },
            Message::Finished { job_id: 7 },
            Message::Shutdown,
        ];
        for m in msgs {
            let enc = m.encode();
            assert_eq!(Message::decode(&enc).unwrap(), m, "{enc}");
        }
    }

    #[test]
    fn register_without_gen_defaults_to_v100() {
        // A frame from a sender that predates the `gen` field must still
        // parse — mixed-generation registration is backwards compatible.
        let old =
            r#"{"type": "register", "gpus": 4, "cpus": 12, "mem_gb": 250}"#;
        assert_eq!(
            Message::decode(old).unwrap(),
            Message::Register {
                gpus: 4,
                cpus: 12,
                mem_gb: 250.0,
                gen: "v100".into(),
            }
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode("{}").is_err());
        assert!(Message::decode("not json").is_err());
        assert!(Message::decode(r#"{"type": "warp"}"#).is_err());
        assert!(Message::decode(r#"{"type": "lease"}"#).is_err());
    }

    #[test]
    fn register_ack_without_heartbeat_defaults_to_zero() {
        // Frames from a pre-heartbeat leader must still parse; 0
        // disables the worker-side heartbeat thread.
        let old = r#"{"type": "register_ack", "server_id": 2}"#;
        assert_eq!(
            Message::decode(old).unwrap(),
            Message::RegisterAck { server_id: 2, heartbeat_s: 0.0 }
        );
    }

    #[test]
    fn recv_caps_line_length() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // A frame body beyond the cap, never newline-terminated
            // from the reader's point of view until far too late.
            let junk = vec![b'x'; MAX_LINE_BYTES + 1024];
            s.write_all(&junk).unwrap();
            s.write_all(b"\n").unwrap();
        });
        let mut conn = Conn::new(TcpStream::connect(addr).unwrap()).unwrap();
        let err = conn.recv().expect_err("oversize frame must error");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("cap"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn recv_treats_mid_line_eof_as_clean_disconnect() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Die mid-frame: bytes but no terminating newline.
            s.write_all(b"{\"type\": \"finis").unwrap();
            // socket drops here
        });
        let mut conn = Conn::new(TcpStream::connect(addr).unwrap()).unwrap();
        t.join().unwrap();
        assert_eq!(
            conn.recv().expect("mid-line EOF is not an error"),
            None,
            "partial frame at EOF must read as a disconnect"
        );
    }

    #[test]
    fn conn_roundtrip_over_localhost() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut conn = Conn::new(s).unwrap();
            let m = conn.recv().unwrap().unwrap();
            conn.send(&m).unwrap(); // echo
        });
        let mut conn =
            Conn::new(TcpStream::connect(addr).unwrap()).unwrap();
        let msg = Message::Finished { job_id: 99 };
        conn.send(&msg).unwrap();
        let echoed = conn.recv().unwrap().unwrap();
        assert_eq!(echoed, msg);
        t.join().unwrap();
    }
}
