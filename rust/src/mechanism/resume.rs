//! Prefix-resumable round planning (ISSUE 5 tentpole).
//!
//! Every pool-decomposable mechanism plans a round as the same shape:
//!
//! 1. an **assignment fold** over the policy-ordered runnable sequence
//!    (A.2.2 type assignment — [`PlanSession`], driven job-by-job by
//!    [`super::Mechanism::step`]), then
//! 2. per pool, a **placement fold** over that pool's requests in a
//!    deterministic processing order ([`PoolAlg::order`] — sequence
//!    order for first-fit/proportional mechanisms, the §4.2 demand sort
//!    for TUNE), each step mutating only (pool cluster, pool grants),
//!    then
//! 3. an optional per-pool **finish pass** over the fold state (TUNE's
//!    §5.3.2 spare redistribution).
//!
//! Because the fleet starts every plan from the same round-reset state
//! and per-job context is fixed between arrival and completion, *the
//! fold state after any step prefix is a pure function of that prefix*.
//! That is the resume invariant: when the next round's processing order
//! shares a prefix with the cached plan's, [`plan_resumable`] rolls the
//! pool back to the end of the common prefix (cluster undo journal +
//! grant undo log, both O(changes)) and replays only the divergent
//! suffix — bit-identical to a full replan by construction, because
//! rollback restores recorded state by assignment (never arithmetic
//! inverses) and replay runs the exact same fold code.
//!
//! Step keys are [`JobId`]s: a job's gang size, sensitivity and per-pool
//! demands never change while it is active, so identical id sequences
//! imply identical step behaviour. A pool whose processing order is
//! *entirely* unchanged skips even its finish pass and reuses the
//! committed state and grants verbatim — the common case under SRTF/LAS,
//! where jobs reorder by remaining-time/service without changing the
//! demand-sorted pool order, which is exactly the workload the
//! exact-sequence memoizer of `sim/core.rs` almost never catches.
//!
//! Mechanisms with global programs (OPT's ILP spans all pools and jobs)
//! keep the default non-resumable [`super::Mechanism::plan`]: a full
//! replan from the round reset, still bit-identical, never resumed.

use super::{Grant, JobRequest, Mechanism, PoolGrant, PoolRequest};
use crate::cluster::{Cluster, Fleet, GpuGen, TypePool};
use crate::job::JobId;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The assignment fold: per-type free-GPU budgets consumed job-by-job in
/// sequence order, exactly as the batch A.2.2 assignment did. On a
/// one-pool fleet the fold is the no-op pass-through (every job maps to
/// the single type, unfiltered).
pub struct PlanSession<'a> {
    single: Option<GpuGen>,
    free: BTreeMap<GpuGen, u32>,
    jobs: Vec<(JobRequest<'a>, Option<GpuGen>)>,
}

impl<'a> PlanSession<'a> {
    /// A session over the fleet's *current* free capacity (the batch
    /// [`Mechanism::allocate`] contract: callers hand over the fleet in
    /// whatever state the round should plan against).
    pub fn from_fleet(fleet: &Fleet) -> PlanSession<'a> {
        let free = fleet
            .pools
            .iter()
            .map(|p| (p.gen, p.cluster.free_gpus()))
            .collect();
        PlanSession::with_budget(fleet, free)
    }

    /// A session over the fleet's *round-start* capacity (what the round
    /// reset restores). Used by the resume path, where the fleet still
    /// holds the previous plan's placements: a fresh replan would see
    /// the post-`evict_all` budgets, so the fold must too.
    pub fn at_round_start(fleet: &Fleet) -> PlanSession<'a> {
        let free = fleet
            .pools
            .iter()
            .map(|p| (p.gen, p.cluster.total_gpus()))
            .collect();
        PlanSession::with_budget(fleet, free)
    }

    fn with_budget(
        fleet: &Fleet,
        free: BTreeMap<GpuGen, u32>,
    ) -> PlanSession<'a> {
        let single = match &fleet.pools[..] {
            [pool] => Some(pool.gen),
            _ => None,
        };
        PlanSession { single, free, jobs: Vec::new() }
    }

    /// Fold the next job of the sequence with an explicit rank function
    /// (higher wins; only types whose remaining budget covers the gang
    /// are candidates; evaluated once per (job, candidate)). Identical
    /// tie-breaks to the pre-refactor batch assignment: candidates
    /// iterate in `GpuGen` order and `max_by` keeps the *last* maximum.
    pub fn assign_by(
        &mut self,
        job: JobRequest<'a>,
        rank: impl Fn(&JobRequest<'_>, GpuGen, u32) -> (f64, i64),
    ) {
        let gen = if let Some(g) = self.single {
            // One-type pass-through: never budget-filtered (the pool
            // algorithm handles GPU shortage, like the homogeneous cut).
            Some(g)
        } else {
            let best = self
                .free
                .iter()
                .filter(|(_, &f)| f >= job.gpus)
                .map(|(&g, &f)| (rank(&job, g, f), g))
                .max_by(|(ra, _), (rb, _)| ra.partial_cmp(rb).unwrap())
                .map(|(_, g)| g);
            if let Some(g) = best {
                *self.free.get_mut(&g).unwrap() -= job.gpus;
            }
            best
        };
        self.jobs.push((job, gen));
    }

    /// Type-blind capacity-weighted round robin (most free GPUs first,
    /// slowest generation on ties) — the default fold, what a
    /// heterogeneity-unaware scheduler does.
    pub fn assign_capacity_rr(&mut self, job: JobRequest<'a>) {
        self.assign_by(job, |_j, g, free| (free as f64, -(g as i64)));
    }

    /// Record the job without assigning a type (mechanisms whose global
    /// program makes its own type choice — OPT).
    pub fn push_unassigned(&mut self, job: JobRequest<'a>) {
        self.jobs.push((job, None));
    }

    /// Decompose into (sequence-ordered requests, assignment map).
    pub fn into_parts(
        self,
    ) -> (Vec<JobRequest<'a>>, BTreeMap<JobId, GpuGen>) {
        let mut assigned = BTreeMap::new();
        let mut jobs = Vec::with_capacity(self.jobs.len());
        for (j, g) in self.jobs {
            if let Some(g) = g {
                assigned.insert(j.id, g);
            }
            jobs.push(j);
        }
        (jobs, assigned)
    }
}

/// Undo entry for the per-pool grant map (parallel to the cluster's
/// journal): a fresh insert undoes to a removal, an overwrite undoes to
/// the stored previous grant.
#[derive(Debug)]
enum GrantUndo {
    Inserted(JobId),
    Replaced(JobId, PoolGrant),
}

/// One pool's journaled fold state: the grants plus their undo log. The
/// grant map is private so every mutation goes through
/// [`PoolPlan::insert`] — the undo log the resume rollback depends on
/// cannot be bypassed; pool algorithms read via [`PoolPlan::grants`].
#[derive(Debug, Default)]
pub struct PoolPlan {
    grants: BTreeMap<JobId, PoolGrant>,
    log: Vec<GrantUndo>,
}

impl PoolPlan {
    /// Insert or overwrite a grant, recording the inverse op.
    pub fn insert(&mut self, id: JobId, grant: PoolGrant) {
        match self.grants.insert(id, grant) {
            None => self.log.push(GrantUndo::Inserted(id)),
            Some(old) => self.log.push(GrantUndo::Replaced(id, old)),
        }
    }

    /// Read-only view of the granted jobs.
    pub fn grants(&self) -> &BTreeMap<JobId, PoolGrant> {
        &self.grants
    }

    /// Consume the plan, yielding the final grant map (batch path).
    pub fn into_grants(self) -> BTreeMap<JobId, PoolGrant> {
        self.grants
    }

    fn mark(&self) -> usize {
        self.log.len()
    }

    fn rollback_to(&mut self, mark: usize) {
        while self.log.len() > mark {
            match self.log.pop().expect("len checked") {
                GrantUndo::Inserted(id) => {
                    self.grants.remove(&id);
                }
                GrantUndo::Replaced(id, old) => {
                    self.grants.insert(id, old);
                }
            }
        }
    }
}

/// One mechanism's pool-level algorithm, expressed in the shape the
/// resume driver checkpoints: a deterministic processing order, a
/// per-job fold step, and an optional deferred global pass.
///
/// `Sync` because the sharded planner runs one pool's fold per worker
/// thread against the same algorithm value; implementations are plain
/// configuration data.
pub(crate) trait PoolAlg: Sync {
    /// Processing order as indices into `reqs`. Defaults to sequence
    /// (priority) order; TUNE overrides with the §4.2 demand sort.
    fn order(&self, reqs: &[PoolRequest<'_>]) -> Vec<usize> {
        (0..reqs.len()).collect()
    }

    /// Fold `reqs[idx]` into the pool state. May read/mutate earlier
    /// grants (TUNE's victim downgrades) — the fold state after a prefix
    /// stays a pure function of the prefix either way.
    fn place_step(
        &self,
        cluster: &mut Cluster,
        plan: &mut PoolPlan,
        reqs: &[PoolRequest<'_>],
        idx: usize,
    );

    /// Deferred global pass over the completed fold state (not part of
    /// any checkpoint; reruns whenever the pool replays).
    fn finish_pool(
        &self,
        cluster: &mut Cluster,
        plan: &mut PoolPlan,
        reqs: &[PoolRequest<'_>],
    ) {
        let _ = (cluster, plan, reqs);
    }
}

/// Run a pool algorithm to completion over one pool (the batch path —
/// no checkpointing; the grant log is simply discarded).
pub(crate) fn run_pool(
    alg: &dyn PoolAlg,
    cluster: &mut Cluster,
    reqs: &[PoolRequest<'_>],
) -> BTreeMap<JobId, PoolGrant> {
    let mut plan = PoolPlan::default();
    for idx in alg.order(reqs) {
        alg.place_step(cluster, &mut plan, reqs, idx);
    }
    alg.finish_pool(cluster, &mut plan, reqs);
    plan.into_grants()
}

/// Per-pool checkpoint: the processing-order step keys, the (cluster
/// journal, grant log) mark after each step, and the live fold state.
/// `marks[i]` is the state after `i` steps; `marks[0]` the pool's
/// round-reset base. Ops recorded past the last mark belong to the
/// finish pass and are undone first on any rollback.
struct PoolTrace {
    steps: Vec<JobId>,
    marks: Vec<(usize, usize)>,
    plan: PoolPlan,
}

/// Checkpointed state of one round plan, aligned with `fleet.pools`.
/// Returned by [`super::Mechanism::plan`] and handed back on the next
/// planning round; valid only while the fleet is untouched in between
/// (the simulation core guarantees that — memoized rounds do not mutate
/// the fleet).
pub struct PlanTrace {
    pools: Vec<PoolTrace>,
}

/// Per-pool resume accounting for one plan (telemetry: how much of each
/// pool's placement fold was served from the checkpoint vs replayed).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolPlanStats {
    /// Steps reused from the checkpointed prefix (the whole fold for an
    /// entirely unchanged pool).
    pub reused: usize,
    /// Steps replayed past the common prefix.
    pub replayed: usize,
}

/// The outcome of one planning round.
pub struct PlanOutcome {
    pub grants: BTreeMap<JobId, Grant>,
    /// Checkpoint for the next round (`None` from non-resumable
    /// mechanisms or when journaling is off).
    pub trace: Option<PlanTrace>,
    /// Per-job planning steps this plan comprised (all pools).
    pub steps_total: usize,
    /// Steps served from the checkpointed prefix instead of replayed.
    pub steps_reused: usize,
    /// Cluster undo-journal entries rolled back across pools to reach
    /// the common prefixes (0 on full replans and batch fallbacks).
    pub rollback_depth: usize,
    /// Per-pool reuse/replay split, aligned with `fleet.pools` (empty
    /// from non-resumable mechanisms and batch fallbacks).
    pub pool_stats: Vec<PoolPlanStats>,
}

fn common_prefix(a: &[JobId], b: &[JobId]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// One pool's contribution to a plan: the fresh checkpoint plus the
/// resume accounting that folds into [`PlanOutcome`].
struct PoolOutcome {
    trace: PoolTrace,
    stats: PoolPlanStats,
    steps_total: usize,
    steps_reused: usize,
    rollback_depth: usize,
}

/// Run one pool's placement fold (phases 2+3 for a single pool),
/// resuming from `prev_pool` where the processing-order prefix matches.
/// This touches only `pool` and its checkpoint — pools are disjoint
/// `Cluster`s, which is what lets the sharded driver run these
/// concurrently with no cross-pool synchronization.
fn plan_pool(
    alg: &dyn PoolAlg,
    pool: &mut TypePool,
    prev_pool: Option<PoolTrace>,
    sjobs: &[JobRequest<'_>],
    assigned: &BTreeMap<JobId, GpuGen>,
) -> PoolOutcome {
    let gen = pool.gen;
    let spec = pool.cluster.spec;
    let reqs = super::pool_requests(gen, spec, sjobs, assigned);
    let order = alg.order(&reqs);
    let new_steps: Vec<JobId> = order.iter().map(|&i| reqs[i].id).collect();
    let steps_total = new_steps.len();

    let cluster = &mut pool.cluster;
    let mut rollback_depth = 0usize;
    let (mut plan, mut marks, lcp) = match prev_pool {
        Some(t) if t.steps == new_steps => {
            // Unchanged pool plan: committed state, grants and finish
            // pass all reused verbatim (deterministic finish over an
            // identical fold state reproduces itself).
            return PoolOutcome {
                stats: PoolPlanStats { reused: t.steps.len(), replayed: 0 },
                steps_total,
                steps_reused: t.steps.len(),
                rollback_depth: 0,
                trace: t,
            };
        }
        Some(mut t) => {
            let lcp = common_prefix(&t.steps, &new_steps);
            let (cluster_mark, grant_mark) = t.marks[lcp];
            rollback_depth = cluster.journal_mark() - cluster_mark;
            cluster.rollback_journal_to(cluster_mark);
            t.plan.rollback_to(grant_mark);
            t.marks.truncate(lcp + 1);
            (t.plan, t.marks, lcp)
        }
        None => {
            (PoolPlan::default(), vec![(cluster.journal_mark(), 0)], 0)
        }
    };
    // Replay the divergent suffix, checkpointing after each step.
    for &idx in &order[lcp..] {
        alg.place_step(cluster, &mut plan, &reqs, idx);
        marks.push((cluster.journal_mark(), plan.mark()));
    }
    alg.finish_pool(cluster, &mut plan, &reqs);
    PoolOutcome {
        stats: PoolPlanStats { reused: lcp, replayed: steps_total - lcp },
        steps_total,
        steps_reused: lcp,
        rollback_depth,
        trace: PoolTrace { steps: new_steps, marks, plan },
    }
}

/// Fan the per-pool placement folds out over `shards` worker threads
/// (`std::thread::scope` — the sweep driver's no-new-deps pattern).
/// Each worker claims pools off a shared atomic counter and plans them
/// with its own checkpoint/journal; results land in per-pool slots and
/// are consumed in fixed pool order, so the assembled plan is
/// byte-identical to the serial loop for any shard count — scheduling
/// work is per-pool-deterministic and pools share no state.
fn plan_pools_sharded(
    alg: &dyn PoolAlg,
    fleet: &mut Fleet,
    prev_pools: Vec<Option<PoolTrace>>,
    sjobs: &[JobRequest<'_>],
    assigned: &BTreeMap<JobId, GpuGen>,
    shards: usize,
) -> Vec<PoolOutcome> {
    let work: Vec<Mutex<Option<(&mut TypePool, Option<PoolTrace>)>>> = fleet
        .pools
        .iter_mut()
        .zip(prev_pools)
        .map(|item| Mutex::new(Some(item)))
        .collect();
    let results: Vec<Mutex<Option<PoolOutcome>>> =
        work.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = shards.min(work.len());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let (pool, prev) =
                    work[i].lock().unwrap().take().expect("claimed once");
                let out = plan_pool(alg, pool, prev, sjobs, assigned);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every pool planned"))
        .collect()
}

/// Plan one round with longest-common-prefix resume against `prev`.
///
/// The assignment fold always recomputes in full (O(jobs × |K|) — the
/// cheap phase, and its budgets are the round-start constants either
/// way); the per-pool placement folds resume. Falls back to the batch
/// path when the fleet does not journal (deploy/test lifecycles that
/// never resume pay nothing).
pub(crate) fn plan_resumable<M: Mechanism + ?Sized>(
    mech: &M,
    alg: &dyn PoolAlg,
    fleet: &mut Fleet,
    jobs: &[JobRequest<'_>],
    prev: Option<PlanTrace>,
) -> PlanOutcome {
    if !fleet.journal_enabled() {
        fleet.evict_all();
        return PlanOutcome {
            grants: mech.allocate(fleet, jobs),
            trace: None,
            steps_total: 0,
            steps_reused: 0,
            rollback_depth: 0,
            pool_stats: Vec::new(),
        };
    }

    // Phase 1: the assignment fold, from round-start budgets (identical
    // to what a fresh replan sees right after `evict_all`).
    let mut session = PlanSession::at_round_start(fleet);
    for j in jobs {
        mech.step(&mut session, j.clone());
    }
    let (sjobs, assigned) = session.into_parts();

    // No valid checkpoint: hard-reset every pool and plan from scratch
    // (journals re-base at the reset).
    let n_pools = fleet.pools.len();
    let prev_pools: Vec<Option<PoolTrace>> = match prev {
        Some(t) if t.pools.len() == n_pools => {
            t.pools.into_iter().map(Some).collect()
        }
        _ => {
            fleet.evict_all();
            (0..n_pools).map(|_| None).collect()
        }
    };

    // Phase 2+3: per-pool placement folds, resumed where prefixes match.
    // Pools are disjoint, so with `--shards N > 1` on a multi-pool fleet
    // the folds fan out over worker threads; either way the outcomes are
    // consumed in fixed pool order, keeping the plan byte-identical for
    // any shard count.
    let shards = fleet.shards();
    let outcomes: Vec<PoolOutcome> = if shards <= 1 || n_pools <= 1 {
        fleet
            .pools
            .iter_mut()
            .zip(prev_pools)
            .map(|(pool, prev)| plan_pool(alg, pool, prev, &sjobs, &assigned))
            .collect()
    } else {
        plan_pools_sharded(alg, fleet, prev_pools, &sjobs, &assigned, shards)
    };
    let mut pools_out: Vec<PoolTrace> = Vec::with_capacity(n_pools);
    let mut pool_stats: Vec<PoolPlanStats> = Vec::with_capacity(n_pools);
    let mut steps_total = 0usize;
    let mut steps_reused = 0usize;
    let mut rollback_depth = 0usize;
    for o in outcomes {
        steps_total += o.steps_total;
        steps_reused += o.steps_reused;
        rollback_depth += o.rollback_depth;
        pool_stats.push(o.stats);
        pools_out.push(o.trace);
    }

    // Assemble the fleet-level grants from the per-pool fold states.
    let mut grants = BTreeMap::new();
    for (pool, t) in fleet.pools.iter().zip(&pools_out) {
        for (id, g) in &t.plan.grants {
            grants.insert(
                *id,
                Grant {
                    gen: pool.gen,
                    placement: g.placement.clone(),
                    demand: g.demand,
                },
            );
        }
    }
    PlanOutcome {
        grants,
        trace: Some(PlanTrace { pools: pools_out }),
        steps_total,
        steps_reused,
        rollback_depth,
        pool_stats,
    }
}
