//! Allocation mechanisms (paper §3.3 & §4): given the round's runnable
//! jobs (already priority-ordered by the policy) and their sensitivity
//! matrices, decide each job's fungible CPU/memory grant and its placement
//! onto servers.
//!
//! - [`proportional::Proportional`] — the baseline: CPU/mem strictly
//!   proportional to GPUs.
//! - [`greedy::Greedy`] — Synergy-GREEDY: first-fit with best-case
//!   demands; skips jobs that don't fit (fragments GPUs, §3.3).
//! - [`tune::Tune`] — Synergy-TUNE: best-fit packing with demand
//!   downgrade and victim reclamation (§4.2). Never skips a job whose GPU
//!   demand fits; never leaves a job below its proportional throughput.
//! - [`opt::Opt`] — Synergy-OPT: the two-LP upper bound (§4.1) solved
//!   with the in-crate simplex/ILP.
//! - [`fixed::Fixed`] — static best-case demands with first-fit, modeling
//!   DRF/Tetris-style big-data allocation (§5.7: "static allocations
//!   perform similar to greedy techniques").

pub mod fixed;
pub mod greedy;
pub mod opt;
pub mod proportional;
pub mod tune;

pub use fixed::Fixed;
pub use greedy::Greedy;
pub use opt::Opt;
pub use proportional::Proportional;
pub use tune::{PlacementStrategy, Tune, VictimStrategy};

use crate::cluster::{Cluster, Placement, Share};
use crate::job::{DemandVector, JobId};
use crate::profiler::SensitivityMatrix;
use std::collections::BTreeMap;

/// One runnable job as the mechanism sees it.
#[derive(Debug, Clone)]
pub struct JobRequest<'a> {
    pub id: JobId,
    pub gpus: u32,
    /// Best-case demand from the sensitivity matrix (§3.2).
    pub best: DemandVector,
    /// GPU-proportional demand (the fairness floor).
    pub prop: DemandVector,
    pub matrix: &'a SensitivityMatrix,
}

/// The outcome for one job: a placement and the demand it was granted.
#[derive(Debug, Clone)]
pub struct Grant {
    pub placement: Placement,
    pub demand: DemandVector,
}

/// Allocation mechanism interface.
pub trait Mechanism: Send + Sync {
    fn name(&self) -> &'static str;

    /// Place as many of `jobs` as the cluster allows; `jobs` arrive in
    /// policy priority order. The cluster must start the round empty of
    /// placements for these jobs. Returns the per-job grants.
    fn allocate(
        &self,
        cluster: &mut Cluster,
        jobs: &[JobRequest<'_>],
    ) -> BTreeMap<JobId, Grant>;
}

/// Look up a mechanism by CLI name. The `tune-*` variants expose the
/// design-choice knobs benchmarked by `ablation_design_choices`.
pub fn by_name(name: &str) -> Option<Box<dyn Mechanism>> {
    match name {
        "proportional" | "prop" => Some(Box::new(Proportional)),
        "greedy" => Some(Box::new(Greedy)),
        "tune" => Some(Box::new(Tune::default())),
        "tune-first-fit" => Some(Box::new(Tune {
            placement: PlacementStrategy::FirstFit,
            ..Tune::default()
        })),
        "tune-victim-first" => Some(Box::new(Tune {
            victim: VictimStrategy::FirstFound,
            ..Tune::default()
        })),
        "opt" => Some(Box::new(Opt::default())),
        "fixed" => Some(Box::new(Fixed)),
        _ => None,
    }
}

pub const ALL_MECHANISMS: [&str; 7] = [
    "proportional",
    "greedy",
    "tune",
    "tune-first-fit",
    "tune-victim-first",
    "opt",
    "fixed",
];

// ---------------------------------------------------------------------------
// Shared placement helpers
// ---------------------------------------------------------------------------

/// Split a demand proportionally over per-server GPU counts (paper §4.2:
/// "the CPU and memory allocations must be proportional to GPU allocations
/// across servers").
pub fn proportional_split(demand: &DemandVector, gpus_per_server: &[(usize, u32)])
    -> Placement
{
    let total: u32 = gpus_per_server.iter().map(|&(_, g)| g).sum();
    assert_eq!(total, demand.gpus, "split must cover the GPU demand");
    let mut p = Placement::default();
    for &(sid, g) in gpus_per_server {
        let frac = g as f64 / total as f64;
        p.shares.insert(
            sid,
            Share {
                gpus: g,
                cpus: demand.cpus * frac,
                mem_gb: demand.mem_gb * frac,
            },
        );
    }
    p
}

/// Best-fit placement of `demand`:
///
/// - if the job fits on a single server, pick the feasible server with the
///   least free resources (tight packing, §4.2);
/// - otherwise find the smallest set of servers with enough free GPUs,
///   splitting CPU/mem proportionally.
///
/// Does not mutate the cluster; returns the placement to commit.
pub fn best_fit(cluster: &Cluster, demand: &DemandVector) -> Option<Placement> {
    // Single-server attempt (consolidation preferred, §6).
    let share = Share {
        gpus: demand.gpus,
        cpus: demand.cpus,
        mem_gb: demand.mem_gb,
    };
    let mut best: Option<(f64, usize)> = None;
    for s in &cluster.servers {
        if s.fits(&share) {
            let score = s.free_score();
            if best.map(|(b, _)| score < b).unwrap_or(true) {
                best = Some((score, s.id));
            }
        }
    }
    if let Some((_, sid)) = best {
        return Some(Placement::single(sid, share));
    }

    // Multi-server split: greedily take GPUs from the fullest feasible
    // servers (minimizing the number of fragments).
    multi_server_fit(cluster, demand, |_s| true)
}

/// Multi-server placement honoring per-server proportional CPU/mem; the
/// `admit` filter restricts candidate servers (used by GPU-only search).
pub fn multi_server_fit(
    cluster: &Cluster,
    demand: &DemandVector,
    admit: impl Fn(&crate::cluster::Server) -> bool,
) -> Option<Placement> {
    let per_gpu_cpu = demand.cpus / demand.gpus as f64;
    let per_gpu_mem = demand.mem_gb / demand.gpus as f64;
    // Order candidate servers by free GPUs descending (fewest fragments),
    // then by fullness.
    let mut candidates: Vec<&crate::cluster::Server> = cluster
        .servers
        .iter()
        .filter(|s| s.free_gpus > 0 && admit(s))
        .collect();
    candidates.sort_by(|a, b| {
        b.free_gpus
            .cmp(&a.free_gpus)
            .then(a.free_score().partial_cmp(&b.free_score()).unwrap())
            .then(a.id.cmp(&b.id))
    });

    let mut remaining = demand.gpus;
    let mut picks: Vec<(usize, u32)> = Vec::new();
    for s in candidates {
        if remaining == 0 {
            break;
        }
        // How many GPUs can this server host given proportional CPU/mem?
        let by_cpu = if per_gpu_cpu > 0.0 {
            (s.free_cpus / per_gpu_cpu + 1e-9).floor() as u32
        } else {
            u32::MAX
        };
        let by_mem = if per_gpu_mem > 0.0 {
            (s.free_mem_gb / per_gpu_mem + 1e-9).floor() as u32
        } else {
            u32::MAX
        };
        let take = s.free_gpus.min(by_cpu).min(by_mem).min(remaining);
        if take > 0 {
            picks.push((s.id, take));
            remaining -= take;
        }
    }
    if remaining > 0 {
        return None;
    }
    Some(proportional_split(demand, &picks))
}

/// First-fit placement (Synergy-GREEDY / big-data style): the first
/// server, in id order, that satisfies the demand; multi-server split if
/// no single server fits.
pub fn first_fit(cluster: &Cluster, demand: &DemandVector) -> Option<Placement> {
    let share = Share {
        gpus: demand.gpus,
        cpus: demand.cpus,
        mem_gb: demand.mem_gb,
    };
    for s in &cluster.servers {
        if s.fits(&share) {
            return Some(Placement::single(s.id, share));
        }
    }
    multi_server_fit(cluster, demand, |_| true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;

    fn cluster(n: usize) -> Cluster {
        Cluster::homogeneous(ServerSpec::default(), n)
    }

    #[test]
    fn proportional_split_is_proportional() {
        let d = DemandVector::new(4, 12.0, 300.0);
        let p = proportional_split(&d, &[(0, 3), (1, 1)]);
        let s0 = p.shares[&0];
        let s1 = p.shares[&1];
        assert_eq!(s0.gpus, 3);
        assert!((s0.cpus - 9.0).abs() < 1e-9);
        assert!((s0.mem_gb - 225.0).abs() < 1e-9);
        assert_eq!(s1.gpus, 1);
        assert!((s1.cpus - 3.0).abs() < 1e-9);
    }

    #[test]
    fn best_fit_prefers_fuller_server() {
        let mut c = cluster(2);
        // Fill server 1 partially so it becomes the tighter fit.
        c.place(
            JobId(99),
            Placement::single(1, Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 }),
        );
        let d = DemandVector::new(2, 6.0, 100.0);
        let p = best_fit(&c, &d).unwrap();
        assert_eq!(p.span(), 1);
        assert!(p.shares.contains_key(&1), "should pack onto fuller server");
    }

    #[test]
    fn best_fit_splits_when_needed() {
        let c = cluster(2);
        let d = DemandVector::new(16, 48.0, 1000.0);
        let p = best_fit(&c, &d).unwrap();
        assert_eq!(p.span(), 2);
        assert_eq!(p.total().gpus, 16);
        assert!((p.total().cpus - 48.0).abs() < 1e-9);
    }

    #[test]
    fn best_fit_fails_when_no_capacity() {
        let mut c = cluster(1);
        c.place(
            JobId(1),
            Placement::single(0, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 }),
        );
        assert!(best_fit(&c, &DemandVector::new(1, 1.0, 10.0)).is_none());
    }

    #[test]
    fn multi_server_fit_respects_cpu_limits() {
        let mut c = cluster(2);
        // Soak CPUs on server 0: only 2 cores left.
        c.place(
            JobId(1),
            Placement::single(0, Share { gpus: 1, cpus: 22.0, mem_gb: 10.0 }),
        );
        // A 8-GPU job wanting 3 cpus/gpu can take at most 0 GPUs from
        // server 0 (2 cores < 3/gpu) — so all 8 must come from server 1.
        let d = DemandVector::new(8, 24.0, 80.0);
        let p = multi_server_fit(&c, &d, |_| true).unwrap();
        assert_eq!(p.shares.len(), 1);
        assert!(p.shares.contains_key(&1));
    }

    #[test]
    fn first_fit_takes_lowest_id() {
        let c = cluster(3);
        let d = DemandVector::new(1, 3.0, 62.5);
        let p = first_fit(&c, &d).unwrap();
        assert!(p.shares.contains_key(&0));
    }

    #[test]
    fn by_name_covers_all() {
        for n in ALL_MECHANISMS {
            assert!(by_name(n).is_some(), "{n}");
        }
    }
}
