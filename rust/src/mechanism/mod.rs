//! Allocation mechanisms (paper §3.3, §4 & A.2.2–A.2.3): given the
//! round's runnable jobs (already priority-ordered by the policy) and
//! their per-type sensitivities, decide each job's machine type, its
//! fungible CPU/memory grant, and its placement onto servers.
//!
//! There is exactly one mechanism stack, and it is type-generic: every
//! [`Mechanism`] allocates over a [`Fleet`] in two phases —
//!
//! 1. **Type assignment** (A.2.2): each job is pinned to one machine
//!    type for the round (jobs never span types). On a one-type fleet
//!    this phase is a no-op pass-through, which makes the homogeneous
//!    paper setting (§3.3, §4) the `|K| = 1` configuration of the same
//!    code, bit-for-bit.
//! 2. **Per-pool allocation**: inside each type pool the homogeneous
//!    §3.3/§4.2 algorithms run against that type's sensitivity matrix.
//!
//! Both phases are expressed as *resumable folds*: the [`Mechanism`]
//! trait is a per-job stepping API (`begin`/`step`/`finish`, with the
//! batch [`Mechanism::allocate`] as the driver loop), and the pool
//! algorithms implement the checkpointable fold shape in [`resume`] so
//! the simulation core can resume a changed round from the longest
//! common prefix of the previous plan instead of replanning from
//! scratch — bit-identically, by construction.
//!
//! The mechanisms:
//!
//! - [`proportional::Proportional`] — the baseline: type-blind
//!   (capacity-weighted round-robin) assignment, CPU/mem strictly
//!   proportional to GPUs.
//! - [`greedy::Greedy`] — Synergy-GREEDY: type-blind assignment,
//!   first-fit with best-case demands; skips jobs that don't fit
//!   (fragments GPUs, §3.3).
//! - [`tune::Tune`] — Synergy-TUNE: type-affine assignment (each job
//!   goes to the type that maximizes its normalized best-case
//!   throughput), then best-fit packing with demand downgrade and victim
//!   reclamation (§4.2). Never skips a job whose GPU demand fits; never
//!   leaves a job below the fairness floor `W_j^Fair`.
//! - [`opt::Opt`] — Synergy-OPT: the ILP upper bound. The A.2.3 program
//!   picks one `(c, m, type)` configuration per job; on a one-type fleet
//!   it degenerates to the paper's §4.1 LP1 over the idealized
//!   super-machine.
//! - [`fixed::Fixed`] — static best-case demands with first-fit, modeling
//!   DRF/Tetris-style big-data allocation (§5.7).
//!
//! **Fairness oracle.** A.2.2 assumes the per-job fair throughput
//! `W_j^Fair` is supplied by an oracle (a heterogeneity-aware fair
//! scheduler such as Gavel [44]). We implement the conservative oracle:
//! the GPU-proportional throughput on the *slowest* generation present
//! ([`Sensitivity::fair_throughput`]). Because throughput is monotone in
//! the GPU stage rate at fixed (c, m), a proportional allocation on any
//! type dominates this floor, so TUNE satisfies the constraint
//! structurally; on a one-type fleet the oracle coincides with the
//! homogeneous proportional floor `W_j[C_g, M_g]` (§4.1 constraint 5).

pub mod fixed;
pub mod greedy;
pub mod opt;
pub mod proportional;
pub mod resume;
pub mod tune;

pub use fixed::Fixed;
pub use greedy::Greedy;
pub use opt::{Opt, OptAllocation};
pub use proportional::Proportional;
pub use resume::{
    PlanOutcome, PlanSession, PlanTrace, PoolPlan, PoolPlanStats,
};
pub use tune::{PlacementStrategy, Tune, VictimStrategy};

pub(crate) use resume::{plan_resumable, run_pool, PoolAlg};

use crate::cluster::{Cluster, Fleet, GpuGen, Placement, ServerSpec, Share};
use crate::job::{DemandVector, JobId};
use crate::profiler::{Sensitivity, SensitivityMatrix};
use std::collections::BTreeMap;

/// One runnable job as the mechanisms see it: gang size plus the full
/// per-type sensitivity (`W_j[k][c, m]`).
#[derive(Debug, Clone)]
pub struct JobRequest<'a> {
    pub id: JobId,
    pub gpus: u32,
    pub sens: &'a Sensitivity,
}

/// The outcome for one job: the machine type, a placement inside that
/// type's pool, and the fungible demand it was granted.
#[derive(Debug, Clone)]
pub struct Grant {
    pub gen: GpuGen,
    pub placement: Placement,
    pub demand: DemandVector,
}

/// Allocation mechanism interface — the only one in the crate.
///
/// Planning is a resumable per-job stepping API: [`Mechanism::begin`]
/// opens a session, [`Mechanism::step`] folds the runnable sequence in
/// job-by-job (the A.2.2 type-assignment fold — intermediate state after
/// any prefix is a pure function of that prefix), and
/// [`Mechanism::finish`] runs the per-pool allocation plus any deferred
/// global passes. [`Mechanism::allocate`] is the batch driver loop over
/// exactly that API, and [`Mechanism::plan`] is the checkpointing entry
/// point the simulation core uses for longest-common-prefix resume (see
/// [`resume`]).
pub trait Mechanism: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether [`Mechanism::plan`] can return (and consume) checkpoints.
    /// Drivers use this to skip journaling entirely for mechanisms whose
    /// plans are global programs (OPT) — journaled ops would only ever
    /// be discarded.
    fn resumable(&self) -> bool {
        false
    }

    /// Open a planning session over the fleet's current free state.
    fn begin<'a>(&self, fleet: &Fleet) -> PlanSession<'a> {
        PlanSession::from_fleet(fleet)
    }

    /// Fold the next job of the policy-ordered runnable sequence into
    /// the session (type assignment, A.2.2). Default: type-blind
    /// capacity-weighted round robin — what a heterogeneity-unaware
    /// mechanism does; a no-op pass-through on one-type fleets.
    fn step<'a>(&self, session: &mut PlanSession<'a>, job: JobRequest<'a>) {
        session.assign_capacity_rr(job);
    }

    /// Complete the session: run the per-pool allocation algorithms (and
    /// any global passes) and return the grants. The fleet must be at
    /// the state `begin` observed.
    fn finish(
        &self,
        session: PlanSession<'_>,
        fleet: &mut Fleet,
    ) -> BTreeMap<JobId, Grant>;

    /// Place as many of `jobs` as the fleet allows; `jobs` arrive in
    /// policy priority order. The fleet must start the round empty of
    /// placements for these jobs. Returns the per-job grants. This is
    /// the driver loop over `begin`/`step`/`finish`.
    fn allocate(
        &self,
        fleet: &mut Fleet,
        jobs: &[JobRequest<'_>],
    ) -> BTreeMap<JobId, Grant> {
        let mut session = self.begin(fleet);
        for j in jobs {
            self.step(&mut session, j.clone());
        }
        self.finish(session, fleet)
    }

    /// Checkpointed planning with longest-common-prefix resume: plan
    /// `jobs` given the checkpoint of this mechanism's previous plan
    /// over the same (untouched-since) fleet. The default is the sound
    /// non-resumable fallback — hard-reset the fleet and replan from
    /// scratch (mechanisms whose program is global, like OPT's ILP,
    /// cannot reuse a prefix). Pool-decomposable mechanisms override via
    /// [`resume::plan_resumable`]. Bit-identical to `allocate` from a
    /// reset fleet in either case.
    fn plan(
        &self,
        fleet: &mut Fleet,
        jobs: &[JobRequest<'_>],
        prev: Option<PlanTrace>,
    ) -> PlanOutcome {
        let _ = prev;
        fleet.evict_all();
        PlanOutcome {
            grants: self.allocate(fleet, jobs),
            trace: None,
            steps_total: 0,
            steps_reused: 0,
            rollback_depth: 0,
            pool_stats: Vec::new(),
        }
    }
}

/// One job as a *pool-level* algorithm sees it: demands against a single
/// type's sensitivity matrix. This is the §3.3/§4.2 homogeneous request
/// shape; [`delegate_pools`] builds it per assigned type.
#[derive(Debug, Clone)]
pub struct PoolRequest<'a> {
    pub id: JobId,
    pub gpus: u32,
    /// Best-case demand from this type's sensitivity matrix (§3.2).
    pub best: DemandVector,
    /// GPU-proportional demand on this type (the fairness floor).
    pub prop: DemandVector,
    pub matrix: &'a SensitivityMatrix,
}

/// A pool-level grant: placement + demand inside one type pool.
#[derive(Debug, Clone)]
pub struct PoolGrant {
    pub placement: Placement,
    pub demand: DemandVector,
}

/// Look up a mechanism by CLI name. The `tune-*` variants expose the
/// design-choice knobs benchmarked by `ablation_design_choices`; the
/// `het-*` aliases are kept for pre-unification front-ends and configs.
pub fn by_name(name: &str) -> Option<Box<dyn Mechanism>> {
    match name {
        "proportional" | "prop" | "het-proportional" | "het-prop" => {
            Some(Box::new(Proportional))
        }
        "greedy" => Some(Box::new(Greedy)),
        "tune" | "het-tune" => Some(Box::new(Tune::default())),
        "tune-first-fit" => Some(Box::new(Tune {
            placement: PlacementStrategy::FirstFit,
            ..Tune::default()
        })),
        "tune-victim-first" => Some(Box::new(Tune {
            victim: VictimStrategy::FirstFound,
            ..Tune::default()
        })),
        "opt" | "het-opt" => Some(Box::new(Opt::default())),
        "fixed" => Some(Box::new(Fixed)),
        _ => None,
    }
}

pub const ALL_MECHANISMS: [&str; 7] = [
    "proportional",
    "greedy",
    "tune",
    "tune-first-fit",
    "tune-victim-first",
    "opt",
    "fixed",
];

// ---------------------------------------------------------------------------
// Type assignment + per-pool delegation
// ---------------------------------------------------------------------------

/// Sensitivity-aware assignment: `score` ranks the candidate types for
/// one job (higher wins, faster generation on ties). A batch driver over
/// [`PlanSession::assign_by`] — the per-job fold is the canonical code.
/// Production callers fold through `Mechanism::step` directly; this
/// batch form remains for the pass-through unit tests.
#[cfg(test)]
pub(crate) fn assign_types(
    fleet: &Fleet,
    jobs: &[JobRequest<'_>],
    score: impl Fn(&JobRequest<'_>, GpuGen) -> f64,
) -> BTreeMap<JobId, GpuGen> {
    let mut session = PlanSession::from_fleet(fleet);
    for j in jobs {
        session.assign_by(j.clone(), |j, g, _free| (score(j, g), g as i64));
    }
    session.into_parts().1
}

/// Type-blind assignment: jobs take types in capacity-weighted
/// round-robin order (whichever type has the most free GPUs, slowest
/// generation on ties), ignoring sensitivity — what a
/// heterogeneity-unaware scheduler does. Pass-through on one type.
pub(crate) fn assign_capacity_round_robin(
    fleet: &Fleet,
    jobs: &[JobRequest<'_>],
) -> BTreeMap<JobId, GpuGen> {
    let mut session = PlanSession::from_fleet(fleet);
    for j in jobs {
        session.assign_capacity_rr(j.clone());
    }
    session.into_parts().1
}

/// Build one pool's request list: the jobs assigned to `gen`, in
/// sequence order, with their demands derived against the pool's server
/// shape (best-case from the type's sensitivity matrix, proportional
/// floor from the spec ratios).
pub(crate) fn pool_requests<'a>(
    gen: GpuGen,
    spec: ServerSpec,
    jobs: &[JobRequest<'a>],
    assigned: &BTreeMap<JobId, GpuGen>,
) -> Vec<PoolRequest<'a>> {
    jobs.iter()
        .filter(|j| assigned.get(&j.id) == Some(&gen))
        .map(|j| {
            let matrix = j
                .sens
                .matrix(gen)
                .expect("job profiled on every type");
            PoolRequest {
                id: j.id,
                gpus: j.gpus,
                best: matrix.best_demand(),
                prop: DemandVector::proportional(
                    j.gpus,
                    spec.cpus as f64 / spec.gpus as f64,
                    spec.mem_gb / spec.gpus as f64,
                ),
                matrix,
            }
        })
        .collect()
}

/// Run a pool-level allocation algorithm inside each type pool over the
/// jobs assigned to it, wrapping the grants with their type.
pub(crate) fn delegate_pools(
    fleet: &mut Fleet,
    jobs: &[JobRequest<'_>],
    assigned: &BTreeMap<JobId, GpuGen>,
    alloc: impl Fn(
        &mut Cluster,
        &[PoolRequest<'_>],
    ) -> BTreeMap<JobId, PoolGrant>,
) -> BTreeMap<JobId, Grant> {
    let mut out = BTreeMap::new();
    for pool in &mut fleet.pools {
        let requests =
            pool_requests(pool.gen, pool.cluster.spec, jobs, assigned);
        for (id, g) in alloc(&mut pool.cluster, &requests) {
            out.insert(
                id,
                Grant {
                    gen: pool.gen,
                    placement: g.placement,
                    demand: g.demand,
                },
            );
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Shared placement helpers (pool-level)
// ---------------------------------------------------------------------------

/// Split a demand proportionally over per-server GPU counts (paper §4.2:
/// "the CPU and memory allocations must be proportional to GPU allocations
/// across servers").
pub fn proportional_split(demand: &DemandVector, gpus_per_server: &[(usize, u32)])
    -> Placement
{
    // An empty split would build an empty-share Placement — a "grant"
    // holding no resources that still counts as placed. No caller may
    // construct one (the zero-GPU guard in `multi_server_fit` returns
    // `None` instead); keep that loud.
    assert!(
        !gpus_per_server.is_empty() && demand.gpus > 0,
        "proportional_split of an empty pick set (zero-GPU demand?)"
    );
    let total: u32 = gpus_per_server.iter().map(|&(_, g)| g).sum();
    assert_eq!(total, demand.gpus, "split must cover the GPU demand");
    let mut p = Placement::default();
    for &(sid, g) in gpus_per_server {
        let frac = g as f64 / total as f64;
        p.shares.insert(
            sid,
            Share {
                gpus: g,
                cpus: demand.cpus * frac,
                mem_gb: demand.mem_gb * frac,
            },
        );
    }
    p
}

/// Best-fit placement of `demand` inside one pool:
///
/// - if the job fits on a single server, pick the feasible server with the
///   least free resources (tight packing, §4.2);
/// - otherwise find the smallest set of servers with enough free GPUs,
///   splitting CPU/mem proportionally.
///
/// Does not mutate the cluster; returns the placement to commit.
///
/// Server selection walks the pool's free-capacity index in ascending
/// `(free_score, scan position)` order, so the first CPU/mem-feasible
/// server *is* the linear scan's minimum — identical tie-breaks (the
/// scan's strict `<` kept the earliest minimal server), verified against
/// [`best_fit_scan`] by the index-equivalence property tests — without
/// touching the servers the GPU filter already excludes.
pub fn best_fit(cluster: &Cluster, demand: &DemandVector) -> Option<Placement> {
    // Single-server attempt (consolidation preferred, §6).
    let share = Share {
        gpus: demand.gpus,
        cpus: demand.cpus,
        mem_gb: demand.mem_gb,
    };
    for s in cluster.servers_by_fullness(demand.gpus) {
        cluster.note_fit_probe();
        if s.fits(&share) {
            return Some(Placement::single(s.id, share));
        }
    }

    // Multi-server split: greedily take GPUs from the fullest feasible
    // servers (minimizing the number of fragments).
    multi_server_fit(cluster, demand, |_s| true)
}

/// Reference implementation of [`best_fit`]'s single-server selection by
/// full linear scan — the pre-index hot path, kept as the ground truth
/// the index-equivalence property tests compare against.
pub fn best_fit_scan(
    cluster: &Cluster,
    demand: &DemandVector,
) -> Option<Placement> {
    let share = Share {
        gpus: demand.gpus,
        cpus: demand.cpus,
        mem_gb: demand.mem_gb,
    };
    let mut best: Option<(f64, usize)> = None;
    for s in &cluster.servers {
        if s.fits(&share) {
            let score = s.free_score();
            if best.map(|(b, _)| score < b).unwrap_or(true) {
                best = Some((score, s.id));
            }
        }
    }
    if let Some((_, sid)) = best {
        return Some(Placement::single(sid, share));
    }
    multi_server_fit(cluster, demand, |_s| true)
}

/// Rack preference for a candidate set: racks ranked by total free GPUs
/// among the candidates, descending (lower rack id on ties), so a gang
/// concentrates in the rack(s) able to host most of it. Returns `None`
/// on flat or locality-blind topologies — every server ranks equal and
/// callers keep the exact pre-topology order.
pub(crate) fn rack_ranks(
    cluster: &Cluster,
    candidates: &[&crate::cluster::Server],
) -> Option<Vec<u32>> {
    let topo = cluster.topology();
    if topo.is_flat() || !topo.placement_aware {
        return None;
    }
    let mut free_by_rack = vec![0u32; topo.racks as usize];
    for s in candidates {
        free_by_rack[cluster.rack_of(s.id) as usize] += s.free_gpus;
    }
    let mut order: Vec<u32> = (0..topo.racks).collect();
    order.sort_by(|&a, &b| {
        free_by_rack[b as usize]
            .cmp(&free_by_rack[a as usize])
            .then(a.cmp(&b))
    });
    let mut rank = vec![0u32; topo.racks as usize];
    for (i, r) in order.iter().enumerate() {
        rank[*r as usize] = i as u32;
    }
    Some(rank)
}

/// Multi-server placement honoring per-server proportional CPU/mem; the
/// `admit` filter restricts candidate servers (used by GPU-only search).
/// Candidates come from the free-capacity index (servers holding any
/// free GPU — at load a small fraction of the pool) and are then sorted
/// by the exact pre-index comparator, a total order, so the result is
/// byte-identical to the full-scan collection.
///
/// Under a rack topology (racks ≥ 2, placement-aware) a rack-rank key is
/// folded in *front* of the `(free_gpus desc, free_score, scan pos)`
/// packing key: candidates in the rack with the most free capacity among
/// the admitted set sort first, so a gang consolidates into as few racks
/// as possible before the per-server tie-breaks apply. On the flat
/// topology every server shares rank 0 and the order — and therefore
/// every schedule — is byte-identical to the pre-topology code
/// (golden-pinned).
pub fn multi_server_fit(
    cluster: &Cluster,
    demand: &DemandVector,
    admit: impl Fn(&crate::cluster::Server) -> bool,
) -> Option<Placement> {
    // A zero-GPU gang has no per-GPU proportional split (the divisions
    // below would be NaN) and would otherwise fall through to an
    // empty-picks "success"; it is not placeable by this helper.
    if demand.gpus == 0 {
        return None;
    }
    let per_gpu_cpu = demand.cpus / demand.gpus as f64;
    let per_gpu_mem = demand.mem_gb / demand.gpus as f64;
    // Order candidate servers by free GPUs descending (fewest fragments),
    // then by fullness.
    let mut candidates: Vec<&crate::cluster::Server> = cluster
        .servers_by_position(1)
        .filter(|s| admit(s))
        .collect();
    match rack_ranks(cluster, &candidates) {
        None => candidates.sort_by(|a, b| {
            b.free_gpus
                .cmp(&a.free_gpus)
                .then(a.free_score().total_cmp(&b.free_score()))
                .then(a.id.cmp(&b.id))
        }),
        Some(rank) => candidates.sort_by(|a, b| {
            rank[cluster.rack_of(a.id) as usize]
                .cmp(&rank[cluster.rack_of(b.id) as usize])
                .then(b.free_gpus.cmp(&a.free_gpus))
                .then(a.free_score().total_cmp(&b.free_score()))
                .then(a.id.cmp(&b.id))
        }),
    }

    let mut remaining = demand.gpus;
    let mut picks: Vec<(usize, u32)> = Vec::new();
    for s in candidates {
        if remaining == 0 {
            break;
        }
        cluster.note_fit_probe();
        // How many GPUs can this server host given proportional CPU/mem?
        let by_cpu = if per_gpu_cpu > 0.0 {
            (s.free_cpus / per_gpu_cpu + 1e-9).floor() as u32
        } else {
            u32::MAX
        };
        let by_mem = if per_gpu_mem > 0.0 {
            (s.free_mem_gb / per_gpu_mem + 1e-9).floor() as u32
        } else {
            u32::MAX
        };
        let take = s.free_gpus.min(by_cpu).min(by_mem).min(remaining);
        if take > 0 {
            picks.push((s.id, take));
            remaining -= take;
        }
    }
    if remaining > 0 {
        return None;
    }
    Some(proportional_split(demand, &picks))
}

/// First-fit placement (Synergy-GREEDY / big-data style): the first
/// server, in scan order, that satisfies the demand; multi-server split
/// if no single server fits. Walks the free-capacity index in scan
/// order, skipping servers the GPU filter already excludes — the first
/// feasible hit is identical to the linear scan's ([`first_fit_scan`],
/// pinned by the index-equivalence property tests).
pub fn first_fit(cluster: &Cluster, demand: &DemandVector) -> Option<Placement> {
    let share = Share {
        gpus: demand.gpus,
        cpus: demand.cpus,
        mem_gb: demand.mem_gb,
    };
    for s in cluster.servers_by_position(demand.gpus) {
        cluster.note_fit_probe();
        if s.fits(&share) {
            return Some(Placement::single(s.id, share));
        }
    }
    multi_server_fit(cluster, demand, |_| true)
}

/// Reference implementation of [`first_fit`] by full linear scan (the
/// pre-index hot path; ground truth for the equivalence property tests).
pub fn first_fit_scan(
    cluster: &Cluster,
    demand: &DemandVector,
) -> Option<Placement> {
    let share = Share {
        gpus: demand.gpus,
        cpus: demand.cpus,
        mem_gb: demand.mem_gb,
    };
    for s in &cluster.servers {
        if s.fits(&share) {
            return Some(Placement::single(s.id, share));
        }
    }
    multi_server_fit(cluster, demand, |_| true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;

    fn cluster(n: usize) -> Cluster {
        Cluster::homogeneous(ServerSpec::default(), n)
    }

    #[test]
    fn proportional_split_is_proportional() {
        let d = DemandVector::new(4, 12.0, 300.0);
        let p = proportional_split(&d, &[(0, 3), (1, 1)]);
        let s0 = p.shares[&0];
        let s1 = p.shares[&1];
        assert_eq!(s0.gpus, 3);
        assert!((s0.cpus - 9.0).abs() < 1e-9);
        assert!((s0.mem_gb - 225.0).abs() < 1e-9);
        assert_eq!(s1.gpus, 1);
        assert!((s1.cpus - 3.0).abs() < 1e-9);
    }

    #[test]
    fn best_fit_prefers_fuller_server() {
        let mut c = cluster(2);
        // Fill server 1 partially so it becomes the tighter fit.
        c.place(
            JobId(99),
            Placement::single(1, Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 }),
        );
        let d = DemandVector::new(2, 6.0, 100.0);
        let p = best_fit(&c, &d).unwrap();
        assert_eq!(p.span(), 1);
        assert!(p.shares.contains_key(&1), "should pack onto fuller server");
    }

    #[test]
    fn best_fit_splits_when_needed() {
        let c = cluster(2);
        let d = DemandVector::new(16, 48.0, 1000.0);
        let p = best_fit(&c, &d).unwrap();
        assert_eq!(p.span(), 2);
        assert_eq!(p.total().gpus, 16);
        assert!((p.total().cpus - 48.0).abs() < 1e-9);
    }

    #[test]
    fn best_fit_fails_when_no_capacity() {
        let mut c = cluster(1);
        c.place(
            JobId(1),
            Placement::single(0, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 }),
        );
        assert!(best_fit(&c, &DemandVector::new(1, 1.0, 10.0)).is_none());
    }

    #[test]
    fn multi_server_fit_respects_cpu_limits() {
        let mut c = cluster(2);
        // Soak CPUs on server 0: only 2 cores left.
        c.place(
            JobId(1),
            Placement::single(0, Share { gpus: 1, cpus: 22.0, mem_gb: 10.0 }),
        );
        // A 8-GPU job wanting 3 cpus/gpu can take at most 0 GPUs from
        // server 0 (2 cores < 3/gpu) — so all 8 must come from server 1.
        let d = DemandVector::new(8, 24.0, 80.0);
        let p = multi_server_fit(&c, &d, |_| true).unwrap();
        assert_eq!(p.shares.len(), 1);
        assert!(p.shares.contains_key(&1));
    }

    #[test]
    fn zero_gpu_demand_is_not_placeable() {
        // Regression (ISSUE 7): a zero-GPU demand used to come back as
        // Some(Placement) with *empty* shares (`remaining` started at 0,
        // the pick loop never ran) after computing NaN per-GPU CPU/mem.
        // DemandVector::new asserts gpus > 0, so build the degenerate
        // value the way a buggy caller would: by struct literal.
        let c = cluster(2);
        let d = DemandVector { gpus: 0, cpus: 4.0, mem_gb: 100.0 };
        assert!(multi_server_fit(&c, &d, |_| true).is_none());
        let d = DemandVector { gpus: 0, cpus: 0.0, mem_gb: 0.0 };
        assert!(multi_server_fit(&c, &d, |_| true).is_none());
    }

    #[test]
    #[should_panic(expected = "empty pick set")]
    fn proportional_split_rejects_empty_picks() {
        let d = DemandVector { gpus: 0, cpus: 4.0, mem_gb: 100.0 };
        proportional_split(&d, &[]);
    }

    /// Load the 4-server cluster so rack 0 (servers 0,1) holds 8 free
    /// GPUs split 7+1 and rack 1 (servers 2,3) holds 10 split 5+5.
    fn two_rack_loaded(topology: Option<crate::cluster::TopologySpec>) -> Cluster {
        let mut c = cluster(4);
        if let Some(spec) = topology {
            c.set_topology(spec.for_servers(4));
        }
        let mk = |g: u32| Share { gpus: g, cpus: g as f64, mem_gb: g as f64 * 10.0 };
        c.place(JobId(90), Placement::single(0, mk(1)));
        c.place(JobId(91), Placement::single(1, mk(7)));
        c.place(JobId(92), Placement::single(2, mk(3)));
        c.place(JobId(93), Placement::single(3, mk(3)));
        c
    }

    #[test]
    fn rack_aware_fit_consolidates_into_the_roomier_rack() {
        use crate::cluster::TopologySpec;
        let d = DemandVector::new(10, 10.0, 100.0);
        // Flat order is free-GPUs-descending: server 0 (7 free) first,
        // then server 2 — a placement straddling both racks.
        let flat = two_rack_loaded(None);
        let p = multi_server_fit(&flat, &d, |_| true).unwrap();
        assert!(p.shares.contains_key(&0) && p.shares.contains_key(&2));
        // Rack-aware: rack 1 has more aggregate free capacity (10 vs 8),
        // so its servers sort first and the gang lands entirely inside it.
        let aware = two_rack_loaded(Some(TopologySpec::racks(2)));
        let p = multi_server_fit(&aware, &d, |_| true).unwrap();
        let ids: Vec<usize> = p.shares.keys().copied().collect();
        assert_eq!(ids, vec![2, 3], "consolidated into rack 1");
        assert_eq!(aware.racks_spanned(&p), 1);
        // Locality-blind ablation arm: racks exist but the packing order
        // ignores them — byte-identical picks to the flat order.
        let blind = two_rack_loaded(Some(TopologySpec {
            placement_aware: false,
            ..TopologySpec::racks(2)
        }));
        let pb = multi_server_fit(&blind, &d, |_| true).unwrap();
        let pf = multi_server_fit(&flat, &d, |_| true).unwrap();
        assert_eq!(pb, pf);
        assert_eq!(blind.racks_spanned(&pb), 2);
    }

    #[test]
    fn flat_topology_fit_is_identity() {
        use crate::cluster::TopologySpec;
        // An explicit racks:1 spec must not change a single pick relative
        // to a cluster that never heard of topology.
        let plain = two_rack_loaded(None);
        let flat = two_rack_loaded(Some(TopologySpec::flat()));
        for gpus in 1..=10u32 {
            let d = DemandVector::new(gpus, gpus as f64, gpus as f64 * 10.0);
            assert_eq!(
                multi_server_fit(&plain, &d, |_| true),
                multi_server_fit(&flat, &d, |_| true),
                "{gpus} GPUs"
            );
            assert_eq!(best_fit(&plain, &d), best_fit(&flat, &d));
        }
    }

    #[test]
    fn first_fit_takes_lowest_id() {
        let c = cluster(3);
        let d = DemandVector::new(1, 3.0, 62.5);
        let p = first_fit(&c, &d).unwrap();
        assert!(p.shares.contains_key(&0));
    }

    #[test]
    fn by_name_covers_all_plus_het_aliases() {
        for n in ALL_MECHANISMS {
            assert!(by_name(n).is_some(), "{n}");
        }
        // Pre-unification front-end names resolve to the unified stack.
        assert_eq!(by_name("het-tune").unwrap().name(), "tune");
        assert_eq!(by_name("het-proportional").unwrap().name(), "proportional");
        assert_eq!(by_name("het-opt").unwrap().name(), "opt");
        assert!(by_name("warp-drive").is_none());
    }

    #[test]
    fn single_type_assignment_is_passthrough() {
        use crate::job::{Job, JobId, ModelKind};
        use crate::profiler::OptimisticProfiler;
        let fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let p = OptimisticProfiler::noiseless(ServerSpec::default());
        // More GPUs requested than exist: pass-through must *not* budget-
        // filter on a single type (the pool algorithm handles shortage).
        let jobs: Vec<Job> = (0..3)
            .map(|i| Job::new(JobId(i), ModelKind::Lstm, 8, 0.0, 60.0))
            .collect();
        let sens: Vec<_> = jobs.iter().map(|j| p.profile(j)).collect();
        let reqs: Vec<JobRequest> = jobs
            .iter()
            .zip(&sens)
            .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
            .collect();
        let assigned = assign_types(&fleet, &reqs, |_, _| 0.0);
        assert_eq!(assigned.len(), 3, "pass-through keeps every job");
        let rr = assign_capacity_round_robin(&fleet, &reqs);
        assert_eq!(rr.len(), 3);
    }
}
