//! Fixed-demand allocation: the big-data scheduler model (paper §5.7).
//!
//! DRF and Tetris "assume resources to be statically allocated throughout
//! the lifetime of a job" with demands encoded in the request. To compare
//! against them, Synergy's profiler supplies the best-case demand as that
//! static request, and the mechanism packs first-fit without any tuning —
//! which, as §5.7 observes, "performs similar to greedy techniques,
//! resulting in GPU fragmentation."
//!
//! The difference from [`super::Greedy`] is semantic, not mechanical: the
//! demand is *immutable* for the job's lifetime (re-used verbatim every
//! round), whereas GREEDY re-reads the profile and could in principle be
//! extended with tuning. Here both reduce to first-fit; `Fixed` exists so
//! the §5.7 benches name the baseline they model.

use super::{first_fit, Grant, JobRequest, Mechanism};
use crate::cluster::Cluster;
use crate::job::JobId;
use std::collections::BTreeMap;

/// Static best-case demands + first-fit (DRF/Tetris allocation model).
pub struct Fixed;

impl Mechanism for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn allocate(
        &self,
        cluster: &mut Cluster,
        jobs: &[JobRequest<'_>],
    ) -> BTreeMap<JobId, Grant> {
        let mut grants = BTreeMap::new();
        for job in jobs {
            if let Some(p) = first_fit(cluster, &job.best) {
                cluster.place(job.id, p.clone());
                grants.insert(job.id, Grant { placement: p, demand: job.best });
            }
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{DemandVector, Job, JobId, ModelKind};
    use crate::profiler::OptimisticProfiler;

    #[test]
    fn fixed_is_first_fit_on_best_demands() {
        let m = OptimisticProfiler::noiseless(ServerSpec::default())
            .profile(&Job::new(JobId(0), ModelKind::ShuffleNetV2, 1, 0.0, 60.0))
            .matrix;
        let mut cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| JobRequest {
                id: JobId(i),
                gpus: 1,
                best: m.best_demand(),
                prop: DemandVector::proportional(1, 3.0, 62.5),
                matrix: &m,
            })
            .collect();
        let grants = Fixed.allocate(&mut cluster, &reqs);
        // ShuffleNet wants ~16 cores: only one fits in 24 cores.
        assert!(grants.len() < 4);
        assert!(cluster.free_gpus() > 0, "fragmentation expected");
    }
}
