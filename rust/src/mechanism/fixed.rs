//! Fixed-demand allocation: the big-data scheduler model (paper §5.7).
//!
//! DRF and Tetris "assume resources to be statically allocated throughout
//! the lifetime of a job" with demands encoded in the request. To compare
//! against them, Synergy's profiler supplies the best-case demand as that
//! static request, and the mechanism packs first-fit without any tuning —
//! which, as §5.7 observes, "performs similar to greedy techniques,
//! resulting in GPU fragmentation." Type assignment is the same blind
//! round-robin as [`super::Proportional`].
//!
//! The difference from [`super::Greedy`] is semantic, not mechanical: the
//! demand is *immutable* for the job's lifetime (re-used verbatim every
//! round), whereas GREEDY re-reads the profile and could in principle be
//! extended with tuning. Here both reduce to first-fit; `Fixed` exists so
//! the §5.7 benches name the baseline they model.

use super::greedy::FirstFitBestAlg;
use super::{
    delegate_pools, plan_resumable, run_pool, Grant, JobRequest, Mechanism,
    PlanOutcome, PlanSession, PlanTrace, PoolGrant, PoolRequest,
};
use crate::cluster::{Cluster, Fleet};
use crate::job::JobId;
use std::collections::BTreeMap;

/// Static best-case demands + first-fit (DRF/Tetris allocation model).
pub struct Fixed;

impl Fixed {
    /// The §5.7 static-demand algorithm inside one pool (mechanically
    /// the GREEDY fold — see the module docs for why that is the point).
    pub fn allocate_pool(
        &self,
        cluster: &mut Cluster,
        jobs: &[PoolRequest<'_>],
    ) -> BTreeMap<JobId, PoolGrant> {
        run_pool(&FirstFitBestAlg, cluster, jobs)
    }
}

impl Mechanism for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn resumable(&self) -> bool {
        true
    }

    // step: default type-blind capacity round robin.

    fn finish(
        &self,
        session: PlanSession<'_>,
        fleet: &mut Fleet,
    ) -> BTreeMap<JobId, Grant> {
        let (jobs, assigned) = session.into_parts();
        delegate_pools(fleet, &jobs, &assigned, |cluster, reqs| {
            run_pool(&FirstFitBestAlg, cluster, reqs)
        })
    }

    fn plan(
        &self,
        fleet: &mut Fleet,
        jobs: &[JobRequest<'_>],
        prev: Option<PlanTrace>,
    ) -> PlanOutcome {
        plan_resumable(self, &FirstFitBestAlg, fleet, jobs, prev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{Job, JobId, ModelKind};
    use crate::profiler::OptimisticProfiler;

    #[test]
    fn fixed_is_first_fit_on_best_demands() {
        let s = OptimisticProfiler::noiseless(ServerSpec::default())
            .profile(&Job::new(JobId(0), ModelKind::ShuffleNetV2, 1, 0.0, 60.0));
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| JobRequest { id: JobId(i), gpus: 1, sens: &s })
            .collect();
        let grants = Fixed.allocate(&mut fleet, &reqs);
        // ShuffleNet wants ~16 cores: only one fits in 24 cores.
        assert!(grants.len() < 4);
        assert!(fleet.free_gpus() > 0, "fragmentation expected");
    }
}
