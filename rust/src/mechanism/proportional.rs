//! GPU-proportional allocation — the baseline every DNN scheduler uses
//! (paper §2): CPU and memory strictly proportional to the GPU grant.

use super::{best_fit, Grant, JobRequest, Mechanism};
use crate::cluster::Cluster;
use crate::job::JobId;
use std::collections::BTreeMap;

/// The GPU-proportional baseline mechanism.
pub struct Proportional;

impl Mechanism for Proportional {
    fn name(&self) -> &'static str {
        "proportional"
    }

    fn allocate(
        &self,
        cluster: &mut Cluster,
        jobs: &[JobRequest<'_>],
    ) -> BTreeMap<JobId, Grant> {
        let mut grants = BTreeMap::new();
        for job in jobs {
            // With proportional demands, any server with enough free GPUs
            // also has the proportional CPU/mem free (invariant of
            // proportional packing), so best_fit only fails on GPU
            // fragmentation across servers.
            if let Some(p) = best_fit(cluster, &job.prop) {
                cluster.place(job.id, p.clone());
                grants.insert(job.id, Grant { placement: p, demand: job.prop });
            }
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{DemandVector, Job, JobId, ModelKind};
    use crate::profiler::OptimisticProfiler;

    fn request(
        id: u64,
        gpus: u32,
        matrix: &crate::profiler::SensitivityMatrix,
    ) -> JobRequest<'_> {
        JobRequest {
            id: JobId(id),
            gpus,
            best: matrix.best_demand(),
            prop: DemandVector::proportional(gpus, 3.0, 62.5),
            matrix,
        }
    }

    #[test]
    fn proportional_fills_gpus_exactly() {
        let spec = ServerSpec::default();
        let profiler = OptimisticProfiler::noiseless(spec);
        let m = profiler
            .profile(&Job::new(JobId(0), ModelKind::ResNet18, 4, 0.0, 60.0))
            .matrix;
        let mut cluster = Cluster::homogeneous(spec, 2);
        let reqs: Vec<JobRequest> =
            (0..4).map(|i| request(i, 4, &m)).collect();
        let grants = Proportional.allocate(&mut cluster, &reqs);
        assert_eq!(grants.len(), 4);
        assert_eq!(cluster.free_gpus(), 0);
        // CPU/mem exactly proportional.
        for g in grants.values() {
            assert!((g.demand.cpus - 12.0).abs() < 1e-9);
            assert!((g.demand.mem_gb - 250.0).abs() < 1e-9);
        }
        assert!(cluster.check_consistency().is_ok());
    }

    #[test]
    fn leftover_jobs_not_granted() {
        let spec = ServerSpec::default();
        let profiler = OptimisticProfiler::noiseless(spec);
        let m = profiler
            .profile(&Job::new(JobId(0), ModelKind::Gnmt, 8, 0.0, 60.0))
            .matrix;
        let mut cluster = Cluster::homogeneous(spec, 1);
        let reqs: Vec<JobRequest> =
            (0..3).map(|i| request(i, 8, &m)).collect();
        let grants = Proportional.allocate(&mut cluster, &reqs);
        assert_eq!(grants.len(), 1);
        assert_eq!(cluster.free_gpus(), 0);
    }
}
