//! GPU-proportional allocation — the baseline every DNN scheduler uses
//! (paper §2): CPU and memory strictly proportional to the GPU grant,
//! type-blind across a mixed fleet (jobs take types in
//! capacity-weighted round-robin order, mirroring what a
//! heterogeneity-unaware cluster does).

use super::{
    best_fit, delegate_pools, plan_resumable, run_pool, Grant, JobRequest,
    Mechanism, PlanOutcome, PlanSession, PlanTrace, PoolAlg, PoolGrant,
    PoolPlan, PoolRequest,
};
use crate::cluster::{Cluster, Fleet};
use crate::job::JobId;
use std::collections::BTreeMap;

/// The GPU-proportional baseline mechanism.
pub struct Proportional;

/// Pool-level fold: sequence order, GPU-proportional demand, best-fit.
/// With proportional demands, any server with enough free GPUs also has
/// the proportional CPU/mem free (invariant of proportional packing), so
/// best_fit only fails on GPU fragmentation across servers.
pub(crate) struct ProportionalAlg;

impl PoolAlg for ProportionalAlg {
    fn place_step(
        &self,
        cluster: &mut Cluster,
        plan: &mut PoolPlan,
        reqs: &[PoolRequest<'_>],
        idx: usize,
    ) {
        let job = &reqs[idx];
        if let Some(p) = best_fit(cluster, &job.prop) {
            cluster.place(job.id, p.clone());
            plan.insert(job.id, PoolGrant { placement: p, demand: job.prop });
        }
    }
}

impl Proportional {
    /// The homogeneous §2 baseline inside one pool: every job gets the
    /// GPU-proportional demand, best-fit packed.
    pub fn allocate_pool(
        &self,
        cluster: &mut Cluster,
        jobs: &[PoolRequest<'_>],
    ) -> BTreeMap<JobId, PoolGrant> {
        run_pool(&ProportionalAlg, cluster, jobs)
    }
}

impl Mechanism for Proportional {
    fn name(&self) -> &'static str {
        "proportional"
    }

    fn resumable(&self) -> bool {
        true
    }

    // step: default type-blind capacity round robin.

    fn finish(
        &self,
        session: PlanSession<'_>,
        fleet: &mut Fleet,
    ) -> BTreeMap<JobId, Grant> {
        let (jobs, assigned) = session.into_parts();
        delegate_pools(fleet, &jobs, &assigned, |cluster, reqs| {
            run_pool(&ProportionalAlg, cluster, reqs)
        })
    }

    fn plan(
        &self,
        fleet: &mut Fleet,
        jobs: &[JobRequest<'_>],
        prev: Option<PlanTrace>,
    ) -> PlanOutcome {
        plan_resumable(self, &ProportionalAlg, fleet, jobs, prev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GpuGen, ServerSpec};
    use crate::job::{Job, JobId, ModelKind};
    use crate::profiler::{OptimisticProfiler, Sensitivity};

    fn profile(model: ModelKind, gpus: u32, fleet: &Fleet) -> Sensitivity {
        OptimisticProfiler::noiseless_fleet(fleet)
            .profile(&Job::new(JobId(0), model, gpus, 0.0, 60.0))
    }

    fn requests<'a>(
        ids: std::ops::Range<u64>,
        gpus: u32,
        s: &'a Sensitivity,
    ) -> Vec<JobRequest<'a>> {
        ids.map(|i| JobRequest { id: JobId(i), gpus, sens: s }).collect()
    }

    #[test]
    fn proportional_fills_gpus_exactly() {
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 2);
        let s = profile(ModelKind::ResNet18, 4, &fleet);
        let reqs = requests(0..4, 4, &s);
        let grants = Proportional.allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 4);
        assert_eq!(fleet.free_gpus(), 0);
        // CPU/mem exactly proportional; type = the single pool's.
        for g in grants.values() {
            assert_eq!(g.gen, GpuGen::V100);
            assert!((g.demand.cpus - 12.0).abs() < 1e-9);
            assert!((g.demand.mem_gb - 250.0).abs() < 1e-9);
        }
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn leftover_jobs_not_granted() {
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let s = profile(ModelKind::Gnmt, 8, &fleet);
        let reqs = requests(0..3, 8, &s);
        let grants = Proportional.allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 1);
        assert_eq!(fleet.free_gpus(), 0);
    }

    #[test]
    fn type_blind_round_robin_uses_both_pools() {
        // Two identical jobs, two identical-capacity types: both types
        // get used regardless of sensitivity.
        let mut fleet = Fleet::two_tier(1);
        let s = profile(ModelKind::Gnmt, 8, &fleet);
        let reqs = requests(0..2, 8, &s);
        let grants = Proportional.allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 2);
        let gens: Vec<GpuGen> = grants.values().map(|g| g.gen).collect();
        assert_ne!(gens[0], gens[1]);
    }
}
