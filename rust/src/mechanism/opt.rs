//! Synergy-OPT (paper §4.1 + Appendix A): the LP/ILP upper bound.
//!
//! Two programs, solved with the in-crate simplex ([`crate::lp`]):
//!
//! **LP1 (idealized super-machine)** — boolean `y_{c,m,j}` selects one
//! (CPU, memory) option per job to maximize Σ W_j[c,m]·y subject to
//! aggregate CPU and memory capacity. The paper's fairness constraint (5)
//! is enforced structurally: options are Pareto-pruned to those with
//! throughput ≥ W_j[C_g, M_g] (the proportional option itself is always
//! present), so every feasible selection honours the floor.
//!
//! **LP2 (placement)** — given (g_j, c*_j, m*_j), assign fractions x_{i,j}
//! of each job to machines, minimizing Σ x_{i,j} (each fragmented job
//! contributes ≥ 2, so this minimizes fragmentation; Theorem A.2 bounds
//! fragmented jobs by 3s).
//!
//! As in the paper (§4.1.3), OPT is a *simulation-only* upper bound: LP2's
//! fractional GPU assignments are not deployable; the simulator uses LP1's
//! allocations with a relaxed placement, and benches report LP1's
//! objective as the aspirational line.

use super::{best_fit, Grant, JobRequest, Mechanism};
use crate::cluster::{Cluster, Placement};
use crate::job::{DemandVector, JobId};
use crate::lp::{solve, solve_ilp, IlpOptions, Lp, Op};
use std::collections::BTreeMap;

/// Synergy-OPT.
#[derive(Default)]
pub struct Opt {
    /// If true, solve the LP relaxation only (faster; still an upper
    /// bound). Default solves the ILP.
    pub relax_only: bool,
}

/// The LP1 solution for one round.
#[derive(Debug, Clone)]
pub struct OptAllocation {
    /// Chosen (cpus, mem_gb, throughput) per job.
    pub chosen: BTreeMap<JobId, (f64, f64, f64)>,
    /// LP objective — aggregate throughput upper bound.
    pub objective: f64,
    /// Number of structural LP variables (for the §5.6 scaling bench).
    pub n_vars: usize,
}

impl Opt {
    /// Solve LP1 over the idealized super-machine (paper §4.1.1).
    pub fn solve_allocation(
        &self,
        cluster: &Cluster,
        jobs: &[JobRequest<'_>],
    ) -> Option<OptAllocation> {
        if jobs.is_empty() {
            return Some(OptAllocation {
                chosen: BTreeMap::new(),
                objective: 0.0,
                n_vars: 0,
            });
        }
        // Collect per-job option lists (Pareto-pruned, floor-filtered).
        let mut options: Vec<(JobId, Vec<(f64, f64, f64)>)> = Vec::new();
        for j in jobs {
            let mut opts = j.matrix.pareto_options();
            if opts.is_empty() {
                opts.push(j.matrix.proportional_option());
            }
            options.push((j.id, opts));
        }
        let n_vars: usize = options.iter().map(|(_, o)| o.len()).sum();
        let mut lp = Lp::new(n_vars);

        // Objective (1): maximize Σ W·y. Capacity (2)(3); choice (4).
        let mut cpu_row: Vec<(usize, f64)> = Vec::with_capacity(n_vars);
        let mut mem_row: Vec<(usize, f64)> = Vec::with_capacity(n_vars);
        let mut var = 0usize;
        let mut var_ranges: Vec<(JobId, usize, usize)> = Vec::new();
        for (id, opts) in &options {
            let start = var;
            for &(c, m, w) in opts {
                lp.set_objective(var, w);
                cpu_row.push((var, c));
                mem_row.push((var, m));
                var += 1;
            }
            var_ranges.push((*id, start, var));
        }
        lp.add(cpu_row, Op::Le, cluster.total_cpus());
        lp.add(mem_row, Op::Le, cluster.total_mem_gb());
        for &(_, start, end) in &var_ranges {
            let row: Vec<(usize, f64)> =
                (start..end).map(|v| (v, 1.0)).collect();
            lp.add(row, Op::Eq, 1.0);
        }

        let sol = if self.relax_only {
            solve(&lp).ok()?
        } else {
            let int_vars: Vec<usize> = (0..n_vars).collect();
            solve_ilp(&lp, &int_vars, IlpOptions::default()).ok()?
        };

        // Extract the chosen option per job (argmax y within the range).
        let mut chosen = BTreeMap::new();
        for &(id, start, end) in &var_ranges {
            let (_, opts) = options
                .iter()
                .find(|(oid, _)| *oid == id)
                .expect("job options");
            let best = (start..end)
                .max_by(|&a, &b| sol.x[a].partial_cmp(&sol.x[b]).unwrap())
                .unwrap();
            chosen.insert(id, opts[best - start]);
        }
        Some(OptAllocation { chosen, objective: sol.objective, n_vars })
    }

    /// Solve LP2 (paper §4.1.2): fractional placement of the LP1 demands
    /// onto machines, minimizing Σ x_{i,j}. Returns x[i][j] by (server,
    /// job index) plus the fragmented-job count.
    pub fn solve_placement(
        &self,
        cluster: &Cluster,
        jobs: &[JobRequest<'_>],
        alloc: &OptAllocation,
    ) -> Option<(Vec<Vec<f64>>, usize)> {
        let s = cluster.num_servers();
        let n = jobs.len();
        if n == 0 {
            return Some((vec![vec![]; s], 0));
        }
        let mut lp = Lp::new(s * n);
        let idx = |i: usize, j: usize| i * n + j;
        // Objective: minimize Σ x  (maximize -Σ x).
        for v in 0..s * n {
            lp.set_objective(v, -1.0);
        }
        // Capacity per machine (15)-(17).
        for i in 0..s {
            let gpu_row: Vec<(usize, f64)> = (0..n)
                .map(|j| (idx(i, j), jobs[j].gpus as f64))
                .collect();
            lp.add(gpu_row, Op::Le, cluster.spec.gpus as f64);
            let cpu_row: Vec<(usize, f64)> = (0..n)
                .map(|j| (idx(i, j), alloc.chosen[&jobs[j].id].0))
                .collect();
            lp.add(cpu_row, Op::Le, cluster.spec.cpus as f64);
            let mem_row: Vec<(usize, f64)> = (0..n)
                .map(|j| (idx(i, j), alloc.chosen[&jobs[j].id].1))
                .collect();
            lp.add(mem_row, Op::Le, cluster.spec.mem_gb);
        }
        // Full assignment (18).
        for j in 0..n {
            let row: Vec<(usize, f64)> =
                (0..s).map(|i| (idx(i, j), 1.0)).collect();
            lp.add(row, Op::Ge, 1.0);
        }
        let sol = solve(&lp).ok()?;
        let mut x = vec![vec![0.0; n]; s];
        let mut fragmented = 0usize;
        for j in 0..n {
            let mut pieces = 0;
            for i in 0..s {
                x[i][j] = sol.x[idx(i, j)];
                if sol.x[idx(i, j)] > 1e-6 {
                    pieces += 1;
                }
            }
            if pieces > 1 {
                fragmented += 1;
            }
        }
        Some((x, fragmented))
    }
}

impl Mechanism for Opt {
    fn name(&self) -> &'static str {
        "opt"
    }

    /// Simulation-mode OPT: LP1 chooses (c*, m*); jobs are then placed
    /// best-fit with those demands, falling back to the proportional
    /// demand if the ideal allocation can't be materialized (§4.1.3 —
    /// the gap between the idealized bound and deployable placements).
    fn allocate(
        &self,
        cluster: &mut Cluster,
        jobs: &[JobRequest<'_>],
    ) -> BTreeMap<JobId, Grant> {
        let mut grants = BTreeMap::new();
        let Some(alloc) = self.solve_allocation(cluster, jobs) else {
            return grants;
        };
        // Place big jobs first, like TUNE.
        let mut ordered: Vec<&JobRequest> = jobs.iter().collect();
        ordered.sort_by(|a, b| b.best.sort_key().cmp(&a.best.sort_key()));
        for job in ordered {
            let (c, m, _) = alloc.chosen[&job.id];
            let ideal = DemandVector::new(job.gpus, c, m);
            let placement: Option<Placement> = best_fit(cluster, &ideal)
                .or_else(|| best_fit(cluster, &job.prop));
            let demand = if placement.is_some()
                && best_fit(cluster, &ideal).is_some()
            {
                ideal
            } else {
                job.prop
            };
            if let Some(p) = placement {
                cluster.place(job.id, p.clone());
                grants.insert(job.id, Grant { placement: p, demand });
            }
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{Job, JobId, ModelKind};
    use crate::profiler::{OptimisticProfiler, SensitivityMatrix};

    fn matrix(model: ModelKind, gpus: u32) -> SensitivityMatrix {
        OptimisticProfiler::noiseless(ServerSpec::default())
            .profile(&Job::new(JobId(0), model, gpus, 0.0, 60.0))
            .matrix
    }

    fn request<'a>(id: u64, gpus: u32, m: &'a SensitivityMatrix) -> JobRequest<'a> {
        JobRequest {
            id: JobId(id),
            gpus,
            best: m.best_demand(),
            prop: DemandVector::proportional(gpus, 3.0, 62.5),
            matrix: m,
        }
    }

    #[test]
    fn opt_objective_upper_bounds_tune() {
        // Mixed workload on one server: OPT's LP objective must be >= the
        // aggregate throughput TUNE achieves.
        let img = matrix(ModelKind::AlexNet, 1);
        let lang = matrix(ModelKind::Gnmt, 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| request(i, 1, &img))
            .chain((4..8).map(|i| request(i, 1, &lang)))
            .collect();

        let mut c1 = Cluster::homogeneous(ServerSpec::default(), 1);
        let opt = Opt::default();
        let alloc = opt.solve_allocation(&c1, &reqs).unwrap();

        let grants = super::super::Tune::default().allocate(&mut c1, &reqs);
        let tune_total: f64 = reqs
            .iter()
            .map(|r| {
                let g = &grants[&r.id];
                r.matrix.throughput_at(g.demand.cpus, g.demand.mem_gb)
            })
            .sum();
        assert!(
            alloc.objective + 1e-6 >= tune_total,
            "opt {} < tune {}",
            alloc.objective,
            tune_total
        );
        // And TUNE should be within 10% of OPT (paper §5.6).
        assert!(
            tune_total >= alloc.objective * 0.9,
            "tune {} not within 10% of opt {}",
            tune_total,
            alloc.objective
        );
    }

    #[test]
    fn opt_respects_fairness_floor() {
        let img = matrix(ModelKind::ShuffleNetV2, 1);
        let speech = matrix(ModelKind::M5, 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| request(i, 1, &img))
            .chain((4..8).map(|i| request(i, 1, &speech)))
            .collect();
        let cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        let alloc = Opt::default().solve_allocation(&cluster, &reqs).unwrap();
        for r in &reqs {
            let (_, _, w) = alloc.chosen[&r.id];
            assert!(
                w + 1e-9 >= r.matrix.proportional_throughput(),
                "{:?} below floor",
                r.id
            );
        }
    }

    #[test]
    fn opt_capacity_respected() {
        let m = matrix(ModelKind::DeepSpeech, 1);
        let reqs: Vec<JobRequest> =
            (0..8).map(|i| request(i, 1, &m)).collect();
        let cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        let alloc = Opt::default().solve_allocation(&cluster, &reqs).unwrap();
        let cpus: f64 = alloc.chosen.values().map(|o| o.0).sum();
        let mem: f64 = alloc.chosen.values().map(|o| o.1).sum();
        assert!(cpus <= cluster.total_cpus() + 1e-6, "cpus={cpus}");
        assert!(mem <= cluster.total_mem_gb() + 1e-6, "mem={mem}");
    }

    #[test]
    fn lp2_placement_bounds_fragmentation() {
        let m = matrix(ModelKind::ResNet18, 2);
        let reqs: Vec<JobRequest> =
            (0..6).map(|i| request(i, 2, &m)).collect();
        let cluster = Cluster::homogeneous(ServerSpec::default(), 2);
        let opt = Opt::default();
        let alloc = opt.solve_allocation(&cluster, &reqs).unwrap();
        let (x, fragmented) =
            opt.solve_placement(&cluster, &reqs, &alloc).unwrap();
        // Theorem A.2: fragmented <= 3s.
        assert!(fragmented <= 3 * cluster.num_servers());
        // Every job fully assigned.
        for j in 0..reqs.len() {
            let total: f64 = (0..cluster.num_servers()).map(|i| x[i][j]).sum();
            assert!(total >= 1.0 - 1e-6, "job {j} assignment {total}");
        }
    }

    #[test]
    fn relaxation_at_least_ilp() {
        let img = matrix(ModelKind::AlexNet, 1);
        let reqs: Vec<JobRequest> =
            (0..6).map(|i| request(i, 1, &img)).collect();
        let cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        let ilp = Opt { relax_only: false }
            .solve_allocation(&cluster, &reqs)
            .unwrap();
        let lp = Opt { relax_only: true }
            .solve_allocation(&cluster, &reqs)
            .unwrap();
        assert!(lp.objective + 1e-6 >= ilp.objective);
    }

    #[test]
    fn opt_mechanism_places_jobs() {
        let img = matrix(ModelKind::AlexNet, 1);
        let lang = matrix(ModelKind::Lstm, 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| request(i, 1, &img))
            .chain((4..8).map(|i| request(i, 1, &lang)))
            .collect();
        let mut cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        let grants = Opt::default().allocate(&mut cluster, &reqs);
        assert_eq!(grants.len(), 8);
        assert_eq!(cluster.free_gpus(), 0);
        assert!(cluster.check_consistency().is_ok());
    }
}
