//! Synergy-OPT (paper §4.1, Appendix A & A.2.3): the LP/ILP upper bound,
//! type-generic.
//!
//! **Allocation program** — boolean `y_{c,m,i,j}` selects one (CPU,
//! memory, machine type) configuration per job to maximize
//! Σ `W_ij[c,m]`·y subject to per-type GPU/CPU/memory capacity
//! (A.2.3 constraints 23–24 plus the per-type GPU row that disjoint
//! pools require), one configuration per job (25), and the oracle
//! fairness floor `W_j^Fair` (26), enforced structurally: options per
//! (job, type) are Pareto-pruned to those meeting the floor, so every
//! feasible selection is fair by construction. On a one-type fleet the
//! type index collapses and this is the paper's §4.1 LP1 over the
//! idealized super-machine (the oracle floor coincides with the
//! homogeneous proportional floor, §4.1 constraint 5).
//!
//! **Placement program (LP2, §4.1.2)** — given (g_j, c*_j, m*_j) inside
//! one pool, assign fractions x_{i,j} of each job to machines,
//! minimizing Σ x_{i,j} (each fragmented job contributes ≥ 2, so this
//! minimizes fragmentation; Theorem A.2 bounds fragmented jobs by 3s).
//!
//! As in the paper (§4.1.3), OPT is a *simulation-only* upper bound:
//! LP2's fractional GPU assignments are not deployable; the simulator
//! uses the allocation program's choices with a relaxed placement, and
//! benches report the objective as the aspirational line.

use super::{
    assign_capacity_round_robin, best_fit, delegate_pools, Grant, JobRequest,
    Mechanism, PlanSession, Proportional,
};
use crate::cluster::{Cluster, Fleet, GpuGen};
use crate::job::{DemandVector, JobId};
use crate::lp::{solve, solve_ilp, IlpOptions, Lp, Op};
use std::collections::BTreeMap;

/// Synergy-OPT.
#[derive(Default)]
pub struct Opt {
    /// If true, solve the LP relaxation only (faster; still an upper
    /// bound). Default solves the ILP.
    pub relax_only: bool,
}

/// The allocation-program solution for one round.
#[derive(Debug, Clone)]
pub struct OptAllocation {
    /// Chosen (type, cpus, mem_gb, throughput) per job.
    pub chosen: BTreeMap<JobId, (GpuGen, f64, f64, f64)>,
    /// Objective — aggregate throughput upper bound.
    pub objective: f64,
    /// Number of structural variables (for the §5.6 scaling bench).
    pub n_vars: usize,
}

impl Opt {
    /// Solve the allocation program over the fleet (paper §4.1.1 /
    /// A.2.3). Options per (job, type) are Pareto-pruned and floored
    /// against the oracle `W_j^Fair`.
    pub fn solve_allocation(
        &self,
        fleet: &Fleet,
        jobs: &[JobRequest<'_>],
    ) -> Option<OptAllocation> {
        if jobs.is_empty() {
            return Some(OptAllocation {
                chosen: BTreeMap::new(),
                objective: 0.0,
                n_vars: 0,
            });
        }
        // (job, gen, options) — options only on types that could ever
        // host the job's gang (GPU capacity of the whole pool).
        struct Block {
            id: JobId,
            gpus: u32,
            gen: GpuGen,
            opts: Vec<(f64, f64, f64)>,
        }
        let mut blocks: Vec<Block> = Vec::new();
        for j in jobs {
            let fair = j.sens.fair_throughput();
            for pool in &fleet.pools {
                if pool.cluster.total_gpus() < j.gpus {
                    continue;
                }
                let m = j.sens.matrix(pool.gen).expect("profiled");
                let mut opts = m.pareto_options_with_floor(fair);
                if opts.is_empty() && m.proportional_throughput() >= fair {
                    opts.push(m.proportional_option());
                }
                if !opts.is_empty() {
                    blocks.push(Block {
                        id: j.id,
                        gpus: j.gpus,
                        gen: pool.gen,
                        opts,
                    });
                }
            }
        }

        let n_vars: usize = blocks.iter().map(|b| b.opts.len()).sum();
        let mut lp = Lp::new(n_vars);
        let mut var = 0usize;
        // Per-type capacity rows (constraints 23, 24 + the per-type GPU
        // capacity needed once types are disjoint pools).
        let mut cpu_rows: BTreeMap<GpuGen, Vec<(usize, f64)>> =
            BTreeMap::new();
        let mut mem_rows: BTreeMap<GpuGen, Vec<(usize, f64)>> =
            BTreeMap::new();
        let mut gpu_rows: BTreeMap<GpuGen, Vec<(usize, f64)>> =
            BTreeMap::new();
        // Per-job choice rows (constraint 25).
        let mut job_vars: BTreeMap<JobId, Vec<usize>> = BTreeMap::new();
        let mut var_map: Vec<(usize, usize)> = Vec::new(); // var -> (block, opt)
        for (bi, b) in blocks.iter().enumerate() {
            for (oi, &(c, m, w)) in b.opts.iter().enumerate() {
                lp.set_objective(var, w);
                cpu_rows.entry(b.gen).or_default().push((var, c));
                mem_rows.entry(b.gen).or_default().push((var, m));
                gpu_rows.entry(b.gen).or_default().push((var, b.gpus as f64));
                job_vars.entry(b.id).or_default().push(var);
                var_map.push((bi, oi));
                var += 1;
            }
        }
        for pool in &fleet.pools {
            if let Some(row) = cpu_rows.remove(&pool.gen) {
                lp.add(row, Op::Le, pool.cluster.total_cpus());
            }
            if let Some(row) = mem_rows.remove(&pool.gen) {
                lp.add(row, Op::Le, pool.cluster.total_mem_gb());
            }
            if let Some(row) = gpu_rows.remove(&pool.gen) {
                lp.add(row, Op::Le, pool.cluster.total_gpus() as f64);
            }
        }
        for vars in job_vars.values() {
            let row: Vec<(usize, f64)> =
                vars.iter().map(|&v| (v, 1.0)).collect();
            lp.add(row, Op::Eq, 1.0);
        }

        let sol = if self.relax_only {
            solve(&lp).ok()?
        } else {
            let int_vars: Vec<usize> = (0..n_vars).collect();
            solve_ilp(&lp, &int_vars, IlpOptions::default()).ok()?
        };

        // Extract the chosen option per job (argmax y within the job's
        // variables — exact for the ILP, rounding for the relaxation).
        let mut chosen = BTreeMap::new();
        for (id, vars) in &job_vars {
            let &best = vars
                .iter()
                .max_by(|&&a, &&b| sol.x[a].partial_cmp(&sol.x[b]).unwrap())
                .expect("every job row has a variable");
            let (bi, oi) = var_map[best];
            let b = &blocks[bi];
            let (c, m, w) = b.opts[oi];
            chosen.insert(*id, (b.gen, c, m, w));
        }
        Some(OptAllocation { chosen, objective: sol.objective, n_vars })
    }

    /// Solve LP2 (paper §4.1.2) inside one pool: fractional placement of
    /// the chosen demands onto that pool's machines, minimizing
    /// Σ x_{i,j}. `gangs` lists (job, gpus) and `demands` the chosen
    /// (cpus, mem_gb) per job. Returns x[i][j] by (server index, gang
    /// index) plus the fragmented-job count.
    pub fn solve_placement(
        &self,
        pool: &Cluster,
        gangs: &[(JobId, u32)],
        demands: &BTreeMap<JobId, (f64, f64)>,
    ) -> Option<(Vec<Vec<f64>>, usize)> {
        let s = pool.num_servers();
        let n = gangs.len();
        if n == 0 {
            return Some((vec![vec![]; s], 0));
        }
        let mut lp = Lp::new(s * n);
        let idx = |i: usize, j: usize| i * n + j;
        // Objective: minimize Σ x  (maximize -Σ x).
        for v in 0..s * n {
            lp.set_objective(v, -1.0);
        }
        // Capacity per machine (15)-(17).
        for i in 0..s {
            let gpu_row: Vec<(usize, f64)> = (0..n)
                .map(|j| (idx(i, j), gangs[j].1 as f64))
                .collect();
            lp.add(gpu_row, Op::Le, pool.spec.gpus as f64);
            let cpu_row: Vec<(usize, f64)> = (0..n)
                .map(|j| (idx(i, j), demands[&gangs[j].0].0))
                .collect();
            lp.add(cpu_row, Op::Le, pool.spec.cpus as f64);
            let mem_row: Vec<(usize, f64)> = (0..n)
                .map(|j| (idx(i, j), demands[&gangs[j].0].1))
                .collect();
            lp.add(mem_row, Op::Le, pool.spec.mem_gb);
        }
        // Full assignment (18).
        for j in 0..n {
            let row: Vec<(usize, f64)> =
                (0..s).map(|i| (idx(i, j), 1.0)).collect();
            lp.add(row, Op::Ge, 1.0);
        }
        let sol = solve(&lp).ok()?;
        let mut x = vec![vec![0.0; n]; s];
        let mut fragmented = 0usize;
        for j in 0..n {
            let mut pieces = 0;
            for i in 0..s {
                x[i][j] = sol.x[idx(i, j)];
                if sol.x[idx(i, j)] > 1e-6 {
                    pieces += 1;
                }
            }
            if pieces > 1 {
                fragmented += 1;
            }
        }
        Some((x, fragmented))
    }
}

impl Mechanism for Opt {
    fn name(&self) -> &'static str {
        "opt"
    }

    /// OPT's program is global (one ILP over every job and pool), so the
    /// stepping fold only records the sequence; everything happens in
    /// `finish`. Consequently OPT keeps the default non-resumable
    /// [`Mechanism::plan`]: a changed sequence always replans in full.
    fn step<'a>(&self, session: &mut PlanSession<'a>, job: JobRequest<'a>) {
        session.push_unassigned(job);
    }

    fn finish(
        &self,
        session: PlanSession<'_>,
        fleet: &mut Fleet,
    ) -> BTreeMap<JobId, Grant> {
        let (jobs, _) = session.into_parts();
        self.materialize(fleet, &jobs)
    }
}

impl Opt {
    /// Simulation-mode OPT: materialize the allocation program — place
    /// each job on its chosen type with the chosen demand via best-fit,
    /// falling back to the proportional demand on that type if packing
    /// fails (§4.1.3 — the gap between the idealized bound and
    /// deployable placements; the program ignores server boundaries).
    fn materialize(
        &self,
        fleet: &mut Fleet,
        jobs: &[JobRequest<'_>],
    ) -> BTreeMap<JobId, Grant> {
        let Some(alloc) = self.solve_allocation(fleet, jobs) else {
            // The per-job equality rows (25) can be unsatisfiable on a
            // multi-type fleet: admission caps aggregate GPUs, but the
            // admitted gangs may admit no per-type partition (e.g. three
            // 5-GPU jobs over two 8-GPU pools). Rather than idling the
            // whole round, degrade to type-blind proportional packing —
            // every job that fits still runs at its fairness floor.
            let assigned = assign_capacity_round_robin(fleet, jobs);
            return delegate_pools(fleet, jobs, &assigned, |cluster, reqs| {
                Proportional.allocate_pool(cluster, reqs)
            });
        };
        let mut out = BTreeMap::new();
        // Place big jobs first, like TUNE — ordered by the best-case
        // demand on the chosen type, which on a one-type fleet is
        // exactly the pre-unification homogeneous OPT placement order.
        let mut ordered: Vec<&JobRequest> = jobs.iter().collect();
        ordered.sort_by(|a, b| {
            let key = |j: &JobRequest| {
                alloc
                    .chosen
                    .get(&j.id)
                    .map(|&(gen, ..)| {
                        j.sens
                            .matrix(gen)
                            .expect("profiled")
                            .best_demand()
                            .sort_key()
                    })
                    .unwrap_or((j.gpus, 0, 0))
            };
            key(b).cmp(&key(a))
        });
        for j in ordered {
            let Some(&(gen, c, m, _)) = alloc.chosen.get(&j.id) else {
                continue;
            };
            let pool = fleet.pool_mut(gen).expect("chosen pool");
            let demand = DemandVector::new(j.gpus, c, m);
            let spec = pool.cluster.spec;
            let prop = DemandVector::proportional(
                j.gpus,
                spec.cpus as f64 / spec.gpus as f64,
                spec.mem_gb / spec.gpus as f64,
            );
            for d in [demand, prop] {
                if let Some(p) = best_fit(&pool.cluster, &d) {
                    pool.cluster.place(j.id, p.clone());
                    out.insert(
                        j.id,
                        Grant { gen, placement: p, demand: d },
                    );
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{Job, JobId, ModelKind};
    use crate::mechanism::Tune;
    use crate::profiler::{OptimisticProfiler, Sensitivity};

    fn sens(model: ModelKind, gpus: u32) -> Sensitivity {
        OptimisticProfiler::noiseless(ServerSpec::default())
            .profile(&Job::new(JobId(0), model, gpus, 0.0, 60.0))
    }

    fn request<'a>(id: u64, gpus: u32, s: &'a Sensitivity) -> JobRequest<'a> {
        JobRequest { id: JobId(id), gpus, sens: s }
    }

    #[test]
    fn opt_objective_upper_bounds_tune() {
        // Mixed workload on one server: OPT's objective must be >= the
        // aggregate throughput TUNE achieves.
        let img = sens(ModelKind::AlexNet, 1);
        let lang = sens(ModelKind::Gnmt, 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| request(i, 1, &img))
            .chain((4..8).map(|i| request(i, 1, &lang)))
            .collect();

        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let opt = Opt::default();
        let alloc = opt.solve_allocation(&fleet, &reqs).unwrap();

        let grants = Tune::default().allocate(&mut fleet, &reqs);
        let tune_total: f64 = reqs
            .iter()
            .map(|r| {
                let g = &grants[&r.id];
                r.sens
                    .matrix(g.gen)
                    .unwrap()
                    .throughput_at(g.demand.cpus, g.demand.mem_gb)
            })
            .sum();
        assert!(
            alloc.objective + 1e-6 >= tune_total,
            "opt {} < tune {}",
            alloc.objective,
            tune_total
        );
        // And TUNE should be within 10% of OPT (paper §5.6).
        assert!(
            tune_total >= alloc.objective * 0.9,
            "tune {} not within 10% of opt {}",
            tune_total,
            alloc.objective
        );
    }

    #[test]
    fn opt_respects_fairness_floor() {
        let img = sens(ModelKind::ShuffleNetV2, 1);
        let speech = sens(ModelKind::M5, 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| request(i, 1, &img))
            .chain((4..8).map(|i| request(i, 1, &speech)))
            .collect();
        let fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let alloc = Opt::default().solve_allocation(&fleet, &reqs).unwrap();
        for r in &reqs {
            let (_, _, _, w) = alloc.chosen[&r.id];
            assert!(
                w + 1e-9 >= r.sens.fair_throughput(),
                "{:?} below floor",
                r.id
            );
        }
    }

    #[test]
    fn opt_capacity_respected() {
        let s = sens(ModelKind::DeepSpeech, 1);
        let reqs: Vec<JobRequest> =
            (0..8).map(|i| request(i, 1, &s)).collect();
        let fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let alloc = Opt::default().solve_allocation(&fleet, &reqs).unwrap();
        let cpus: f64 = alloc.chosen.values().map(|o| o.1).sum();
        let mem: f64 = alloc.chosen.values().map(|o| o.2).sum();
        assert!(cpus <= fleet.total_cpus() + 1e-6, "cpus={cpus}");
        assert!(mem <= fleet.total_mem_gb() + 1e-6, "mem={mem}");
    }

    #[test]
    fn lp2_placement_bounds_fragmentation() {
        let s = sens(ModelKind::ResNet18, 2);
        let reqs: Vec<JobRequest> =
            (0..6).map(|i| request(i, 2, &s)).collect();
        let fleet = Fleet::homogeneous(ServerSpec::default(), 2);
        let opt = Opt::default();
        let alloc = opt.solve_allocation(&fleet, &reqs).unwrap();
        let gangs: Vec<(JobId, u32)> =
            reqs.iter().map(|r| (r.id, r.gpus)).collect();
        let demands: BTreeMap<JobId, (f64, f64)> = alloc
            .chosen
            .iter()
            .map(|(id, &(_, c, m, _))| (*id, (c, m)))
            .collect();
        let pool = &fleet.pools[0].cluster;
        let (x, fragmented) =
            opt.solve_placement(pool, &gangs, &demands).unwrap();
        // Theorem A.2: fragmented <= 3s.
        assert!(fragmented <= 3 * pool.num_servers());
        // Every job fully assigned.
        for j in 0..gangs.len() {
            let total: f64 = (0..pool.num_servers()).map(|i| x[i][j]).sum();
            assert!(total >= 1.0 - 1e-6, "job {j} assignment {total}");
        }
    }

    #[test]
    fn relaxation_at_least_ilp() {
        let img = sens(ModelKind::AlexNet, 1);
        let reqs: Vec<JobRequest> =
            (0..6).map(|i| request(i, 1, &img)).collect();
        let fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let ilp = Opt { relax_only: false }
            .solve_allocation(&fleet, &reqs)
            .unwrap();
        let lp = Opt { relax_only: true }
            .solve_allocation(&fleet, &reqs)
            .unwrap();
        assert!(lp.objective + 1e-6 >= ilp.objective);
    }

    #[test]
    fn opt_mechanism_places_jobs() {
        let img = sens(ModelKind::AlexNet, 1);
        let lang = sens(ModelKind::Lstm, 1);
        let reqs: Vec<JobRequest> = (0..4)
            .map(|i| request(i, 1, &img))
            .chain((4..8).map(|i| request(i, 1, &lang)))
            .collect();
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let grants = Opt::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 8);
        assert_eq!(fleet.free_gpus(), 0);
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn opt_degrades_gracefully_when_no_type_partition_exists() {
        // Three 5-GPU gangs over two 8-GPU pools: aggregate admission
        // passes (15 <= 16) but no per-type partition satisfies the
        // equality rows, so the ILP is infeasible. The mechanism must
        // still place the feasible subset instead of idling the round.
        let mut fleet = Fleet::two_tier(1);
        let p = OptimisticProfiler::noiseless_fleet(&fleet);
        let jobs: Vec<Job> = (0..3)
            .map(|i| Job::new(JobId(i), ModelKind::ResNet18, 5, 0.0, 3600.0))
            .collect();
        let sens: Vec<Sensitivity> =
            jobs.iter().map(|j| p.profile(j)).collect();
        let reqs: Vec<JobRequest> = jobs
            .iter()
            .zip(&sens)
            .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
            .collect();
        let grants = Opt::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 2, "two of three gangs fit the pools");
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn opt_upper_bounds_tune_on_mixed_fleet() {
        // The A.2.3 program must dominate het-TUNE's realized throughput.
        let mut fleet = Fleet::two_tier(1);
        let p = OptimisticProfiler::noiseless_fleet(&fleet);
        let jobs: Vec<Job> = [
            (0u64, ModelKind::ResNet18, 4u32),
            (1, ModelKind::Gnmt, 4),
            (2, ModelKind::AlexNet, 4),
            (3, ModelKind::Lstm, 4),
        ]
        .iter()
        .map(|&(id, m, g)| Job::new(JobId(id), m, g, 0.0, 3600.0))
        .collect();
        let sens: Vec<Sensitivity> =
            jobs.iter().map(|j| p.profile(j)).collect();
        let reqs: Vec<JobRequest> = jobs
            .iter()
            .zip(&sens)
            .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
            .collect();
        let opt = Opt::default().solve_allocation(&fleet, &reqs).expect("ilp");
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        let tune_tput: f64 = jobs
            .iter()
            .zip(&sens)
            .filter_map(|(j, s)| {
                grants.get(&j.id).map(|g| {
                    s.matrix(g.gen)
                        .unwrap()
                        .throughput_at(g.demand.cpus, g.demand.mem_gb)
                })
            })
            .sum();
        assert!(
            opt.objective + 1e-6 >= tune_tput,
            "OPT {} must dominate TUNE {}",
            opt.objective,
            tune_tput
        );
    }
}
