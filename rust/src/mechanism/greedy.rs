//! Synergy-GREEDY (paper §3.3): naive first-fit multi-dimensional packing
//! with best-case demands, type-blind across a mixed fleet.
//!
//! The strawman the paper builds Synergy-TUNE against. Two pathologies,
//! both reproduced by the §5.4 benches:
//!
//! 1. best-case CPU/memory demands exhaust auxiliary resources while GPUs
//!    sit free (GPU fragmentation);
//! 2. jobs whose demands don't fit are *skipped*, breaking the policy's
//!    fairness order.

use super::{
    delegate_pools, first_fit, plan_resumable, run_pool, Grant, JobRequest,
    Mechanism, PlanOutcome, PlanSession, PlanTrace, PoolAlg, PoolGrant,
    PoolPlan, PoolRequest,
};
use crate::cluster::{Cluster, Fleet};
use crate::job::JobId;
use std::collections::BTreeMap;

/// Synergy-GREEDY: first-fit with unmodified best-case demands.
pub struct Greedy;

/// Pool-level fold shared by GREEDY and [`super::Fixed`]: sequence
/// order, unmodified best-case demand, first-fit; jobs that don't fit
/// are skipped (the §3.3 fairness bug both baselines model).
pub(crate) struct FirstFitBestAlg;

impl PoolAlg for FirstFitBestAlg {
    fn place_step(
        &self,
        cluster: &mut Cluster,
        plan: &mut PoolPlan,
        reqs: &[PoolRequest<'_>],
        idx: usize,
    ) {
        let job = &reqs[idx];
        if let Some(p) = first_fit(cluster, &job.best) {
            cluster.place(job.id, p.clone());
            plan.insert(job.id, PoolGrant { placement: p, demand: job.best });
        }
        // else: skipped this round (the fairness bug, §3.3).
    }
}

impl Greedy {
    /// The §3.3 homogeneous algorithm inside one pool.
    pub fn allocate_pool(
        &self,
        cluster: &mut Cluster,
        jobs: &[PoolRequest<'_>],
    ) -> BTreeMap<JobId, PoolGrant> {
        run_pool(&FirstFitBestAlg, cluster, jobs)
    }
}

impl Mechanism for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn resumable(&self) -> bool {
        true
    }

    // step: default type-blind capacity round robin.

    fn finish(
        &self,
        session: PlanSession<'_>,
        fleet: &mut Fleet,
    ) -> BTreeMap<JobId, Grant> {
        let (jobs, assigned) = session.into_parts();
        delegate_pools(fleet, &jobs, &assigned, |cluster, reqs| {
            run_pool(&FirstFitBestAlg, cluster, reqs)
        })
    }

    fn plan(
        &self,
        fleet: &mut Fleet,
        jobs: &[JobRequest<'_>],
        prev: Option<PlanTrace>,
    ) -> PlanOutcome {
        plan_resumable(self, &FirstFitBestAlg, fleet, jobs, prev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{Job, JobId, ModelKind};
    use crate::profiler::{OptimisticProfiler, Sensitivity};

    fn profile(model: ModelKind, gpus: u32) -> Sensitivity {
        OptimisticProfiler::noiseless(ServerSpec::default())
            .profile(&Job::new(JobId(0), model, gpus, 0.0, 60.0))
    }

    #[test]
    fn greedy_grants_best_case_demands() {
        let s = profile(ModelKind::AlexNet, 1);
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let req = JobRequest { id: JobId(0), gpus: 1, sens: &s };
        let grants = Greedy.allocate(&mut fleet, &[req]);
        // AlexNet's knee is ~9.3 cores: the greedy grant exceeds prop.
        assert!(grants[&JobId(0)].demand.cpus > 3.0);
    }

    #[test]
    fn greedy_fragments_gpus_with_hungry_jobs() {
        // Five CPU-hungry 1-GPU jobs on one 24-core server: best-case
        // demands (~10+ cores each) exhaust CPU after 2 jobs, leaving
        // 6 GPUs stranded — the §3.3 pathology.
        let s = profile(ModelKind::M5, 1); // knee 10 cores, mem-hungry
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let reqs: Vec<JobRequest> = (0..5)
            .map(|i| JobRequest { id: JobId(i), gpus: 1, sens: &s })
            .collect();
        let grants = Greedy.allocate(&mut fleet, &reqs);
        assert!(grants.len() < 5, "greedy should fail to place all");
        assert!(fleet.free_gpus() > 0, "GPUs stranded");
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn greedy_skips_but_later_jobs_may_fit() {
        // A big job that doesn't fit is skipped; a small one after it fits
        // (the order-breaking behaviour).
        let s_big = profile(ModelKind::M5, 1);
        let s_small = profile(ModelKind::Lstm, 1);
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        // Soak memory so M5's best-case (huge mem) cannot fit.
        fleet.pools[0].cluster.place(
            JobId(99),
            crate::cluster::Placement::single(
                0,
                crate::cluster::Share { gpus: 1, cpus: 1.0, mem_gb: 450.0 },
            ),
        );
        let reqs = vec![
            JobRequest { id: JobId(0), gpus: 1, sens: &s_big },
            JobRequest { id: JobId(1), gpus: 1, sens: &s_small },
        ];
        let grants = Greedy.allocate(&mut fleet, &reqs);
        assert!(!grants.contains_key(&JobId(0)), "hungry job skipped");
        assert!(grants.contains_key(&JobId(1)), "small job jumped the queue");
    }
}
