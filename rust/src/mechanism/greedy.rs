//! Synergy-GREEDY (paper §3.3): naive first-fit multi-dimensional packing
//! with best-case demands.
//!
//! The strawman the paper builds Synergy-TUNE against. Two pathologies,
//! both reproduced by the §5.4 benches:
//!
//! 1. best-case CPU/memory demands exhaust auxiliary resources while GPUs
//!    sit free (GPU fragmentation);
//! 2. jobs whose demands don't fit are *skipped*, breaking the policy's
//!    fairness order.

use super::{first_fit, Grant, JobRequest, Mechanism};
use crate::cluster::Cluster;
use crate::job::JobId;
use std::collections::BTreeMap;

/// Synergy-GREEDY: first-fit with unmodified best-case demands.
pub struct Greedy;

impl Mechanism for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn allocate(
        &self,
        cluster: &mut Cluster,
        jobs: &[JobRequest<'_>],
    ) -> BTreeMap<JobId, Grant> {
        let mut grants = BTreeMap::new();
        for job in jobs {
            if let Some(p) = first_fit(cluster, &job.best) {
                cluster.place(job.id, p.clone());
                grants.insert(job.id, Grant { placement: p, demand: job.best });
            }
            // else: skipped this round (the fairness bug, §3.3).
        }
        grants
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::{DemandVector, Job, JobId, ModelKind};
    use crate::profiler::{OptimisticProfiler, SensitivityMatrix};

    fn matrix(model: ModelKind, gpus: u32) -> SensitivityMatrix {
        OptimisticProfiler::noiseless(ServerSpec::default())
            .profile(&Job::new(JobId(0), model, gpus, 0.0, 60.0))
            .matrix
    }

    #[test]
    fn greedy_grants_best_case_demands() {
        let m = matrix(ModelKind::AlexNet, 1);
        let mut cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        let req = JobRequest {
            id: JobId(0),
            gpus: 1,
            best: m.best_demand(),
            prop: DemandVector::proportional(1, 3.0, 62.5),
            matrix: &m,
        };
        let grants = Greedy.allocate(&mut cluster, &[req]);
        // AlexNet's knee is ~9.3 cores: the greedy grant exceeds prop.
        assert!(grants[&JobId(0)].demand.cpus > 3.0);
    }

    #[test]
    fn greedy_fragments_gpus_with_hungry_jobs() {
        // Five CPU-hungry 1-GPU jobs on one 24-core server: best-case
        // demands (~10+ cores each) exhaust CPU after 2 jobs, leaving
        // 6 GPUs stranded — the §3.3 pathology.
        let m = matrix(ModelKind::M5, 1); // knee 10 cores, mem-hungry
        let mut cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        let reqs: Vec<JobRequest> = (0..5)
            .map(|i| JobRequest {
                id: JobId(i),
                gpus: 1,
                best: m.best_demand(),
                prop: DemandVector::proportional(1, 3.0, 62.5),
                matrix: &m,
            })
            .collect();
        let grants = Greedy.allocate(&mut cluster, &reqs);
        assert!(grants.len() < 5, "greedy should fail to place all");
        assert!(cluster.free_gpus() > 0, "GPUs stranded");
        assert!(cluster.check_consistency().is_ok());
    }

    #[test]
    fn greedy_skips_but_later_jobs_may_fit() {
        // A big job that doesn't fit is skipped; a small one after it fits
        // (the order-breaking behaviour).
        let m_big = matrix(ModelKind::M5, 1);
        let m_small = matrix(ModelKind::Lstm, 1);
        let mut cluster = Cluster::homogeneous(ServerSpec::default(), 1);
        // Soak memory so M5's best-case (huge mem) cannot fit.
        cluster.place(
            JobId(99),
            crate::cluster::Placement::single(
                0,
                crate::cluster::Share { gpus: 1, cpus: 1.0, mem_gb: 450.0 },
            ),
        );
        let reqs = vec![
            JobRequest {
                id: JobId(0),
                gpus: 1,
                best: m_big.best_demand(),
                prop: DemandVector::proportional(1, 3.0, 62.5),
                matrix: &m_big,
            },
            JobRequest {
                id: JobId(1),
                gpus: 1,
                best: m_small.best_demand(),
                prop: DemandVector::proportional(1, 3.0, 62.5),
                matrix: &m_small,
            },
        ];
        let grants = Greedy.allocate(&mut cluster, &reqs);
        assert!(!grants.contains_key(&JobId(0)), "hungry job skipped");
        assert!(grants.contains_key(&JobId(1)), "small job jumped the queue");
    }
}
