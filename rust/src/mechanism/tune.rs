//! Synergy-TUNE (paper §4.2 + A.2.2): the practical near-optimal
//! mechanism, type-generic.
//!
//! Phase 1 — type assignment (A.2.2): each job is pinned to the machine
//! type that maximizes its best-case throughput *normalized by the
//! type's compute scale*, among types with free GPUs, so
//! compute-insensitive jobs defer fast GPUs to jobs that can exploit
//! them; jobs never span types in a round. On a one-type fleet this is a
//! no-op pass-through and the mechanism is exactly homogeneous
//! Synergy-TUNE.
//!
//! Phase 2 — per-pool §4.2 (verbatim), against the job's sensitivity
//! matrix *for its assigned type*:
//! 1. Sort runnable jobs by GPU, then CPU, then memory demand, descending.
//! 2. For each job, best-fit pack the best-case demand (single server if
//!    possible; otherwise minimal multi-server split with proportional
//!    per-server CPU/mem).
//! 3. If it doesn't fit and the demand exceeds proportional: retry at the
//!    GPU-proportional demand.
//! 4. If it still doesn't fit: find a GPU-feasible server (set) and
//!    downgrade resident jobs holding more than their proportional share
//!    until the job's proportional demand fits; by construction the
//!    reclaimed resources suffice.
//!
//! Properties (verified by unit + property tests):
//!
//! - **No GPU under-utilization at load**: a runnable job is only left
//!   unplaced if its GPU demand cannot be met anywhere — fungible demands
//!   never cause a skip (unlike GREEDY).
//! - **Fairness floor**: every placed job ends the round with at least
//!   its assigned type's GPU-proportional throughput, which dominates
//!   the oracle `W_j^Fair` (slowest-type proportional, A.2.2) — either
//!   it got its (≥ floor) best-case demand, or it (and/or victims) were
//!   downgraded *to* the proportional share, never below.

use super::{
    best_fit, delegate_pools, first_fit, plan_resumable, run_pool, Grant,
    JobRequest, Mechanism, PlanOutcome, PlanSession, PlanTrace, PoolAlg,
    PoolGrant, PoolPlan, PoolRequest,
};
use crate::cluster::{Cluster, Fleet, Placement, Share};
use crate::job::{DemandVector, JobId};
use std::collections::BTreeMap;

/// Server-selection strategy for packing (§4.2 uses best-fit; the
/// alternatives exist for the design-choice ablation bench).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementStrategy {
    /// Feasible server with the least free resources (tight packing —
    /// the paper's choice).
    #[default]
    BestFit,
    /// First feasible server in id order.
    FirstFit,
}

/// Victim-selection strategy for step 4's downgrades.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VictimStrategy {
    /// The victim holding the largest excess over proportional (fewest
    /// downgrades overall — the default).
    #[default]
    LargestExcess,
    /// The first over-proportional victim found (cheaper to compute,
    /// more downgrades).
    FirstFound,
}

/// Synergy-TUNE.
#[derive(Default)]
pub struct Tune {
    pub placement: PlacementStrategy,
    pub victim: VictimStrategy,
}

impl Tune {
    fn fit(&self, cluster: &Cluster, demand: &DemandVector) -> Option<Placement> {
        match self.placement {
            PlacementStrategy::BestFit => best_fit(cluster, demand),
            PlacementStrategy::FirstFit => first_fit(cluster, demand),
        }
    }

    /// The homogeneous §4.2 algorithm inside one pool. Public so the
    /// single-type pass-through property ("a one-type fleet reproduces
    /// the homogeneous grants bit-for-bit") is directly testable.
    pub fn allocate_pool(
        &self,
        cluster: &mut Cluster,
        jobs: &[PoolRequest<'_>],
    ) -> BTreeMap<JobId, PoolGrant> {
        run_pool(&TuneAlg(self), cluster, jobs)
    }
}

/// The §4.2 pool algorithm in resumable-fold shape: demand-sorted
/// processing order, a per-job step that may downgrade earlier victims,
/// and the §5.3.2 spare redistribution as the deferred finish pass.
/// Mutating earlier grants inside a step is fine for resume soundness —
/// the fold state after a step prefix is still a pure function of that
/// prefix.
struct TuneAlg<'m>(&'m Tune);

impl PoolAlg for TuneAlg<'_> {
    /// Step 1: sort by demand, descending (big rocks first). Stable, so
    /// demand ties keep the policy's sequence order.
    fn order(&self, reqs: &[PoolRequest<'_>]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..reqs.len()).collect();
        order.sort_by(|&a, &b| {
            reqs[b].best.sort_key().cmp(&reqs[a].best.sort_key())
        });
        order
    }

    fn place_step(
        &self,
        cluster: &mut Cluster,
        plan: &mut PoolPlan,
        reqs: &[PoolRequest<'_>],
        idx: usize,
    ) {
        let job = &reqs[idx];
        // Step 2: best-case demand.
        if let Some(p) = self.0.fit(cluster, &job.best) {
            cluster.place(job.id, p.clone());
            plan.insert(job.id, PoolGrant { placement: p, demand: job.best });
            return;
        }
        // Step 3: revert own demand to proportional.
        if job.best.exceeds(&job.prop) {
            if let Some(p) = self.0.fit(cluster, &job.prop) {
                cluster.place(job.id, p.clone());
                plan.insert(
                    job.id,
                    PoolGrant { placement: p, demand: job.prop },
                );
                return;
            }
        }
        // Step 4: reclaim from victims until the (floor) demand fits.
        // The floor is the element-wise min of best-case and
        // proportional: a job asking below proportional keeps its
        // small ask. Each iteration downgrades the most-over-allocated
        // victim on a GPU-feasible server; terminates because the
        // victim set is finite.
        let floor = job.best.clamp_to(&job.prop);
        let placed = loop {
            if let Some(p) = self.0.fit(cluster, &floor) {
                break Some(p);
            }
            if !downgrade_one_victim(cluster, plan, job, self.0.victim) {
                break None;
            }
        };
        match placed {
            Some(p) => {
                cluster.place(job.id, p.clone());
                plan.insert(job.id, PoolGrant { placement: p, demand: floor });
            }
            None => {
                // GPU demand itself cannot be met (only possible when
                // the coordinator over-admitted); leave unplaced.
            }
        }
    }

    /// Final pass: redistribute spare CPU/memory to placed jobs that
    /// still benefit (§5.3.2: "at low load ... the unallocated CPU and
    /// memory is assigned to the jobs that benefit from additional
    /// auxiliary resources").
    fn finish_pool(
        &self,
        cluster: &mut Cluster,
        plan: &mut PoolPlan,
        reqs: &[PoolRequest<'_>],
    ) {
        redistribute_spare(cluster, plan, reqs);
    }
}

impl Mechanism for Tune {
    fn name(&self) -> &'static str {
        "tune"
    }

    fn resumable(&self) -> bool {
        true
    }

    /// Affinity fold: the job's best-case throughput on the candidate
    /// type, normalized by the type's compute scale so
    /// compute-insensitive jobs defer fast GPUs to jobs that can exploit
    /// them.
    fn step<'a>(&self, session: &mut PlanSession<'a>, job: JobRequest<'a>) {
        session.assign_by(job, |j, gen, _free| {
            let m = j.sens.matrix(gen).expect("profiled");
            let peak = m.max_throughput();
            let scale = gen.compute_scale(m.model.task());
            (peak / scale, gen as i64)
        });
    }

    fn finish(
        &self,
        session: PlanSession<'_>,
        fleet: &mut Fleet,
    ) -> BTreeMap<JobId, Grant> {
        let (jobs, assigned) = session.into_parts();
        delegate_pools(fleet, &jobs, &assigned, |cluster, reqs| {
            run_pool(&TuneAlg(self), cluster, reqs)
        })
    }

    fn plan(
        &self,
        fleet: &mut Fleet,
        jobs: &[JobRequest<'_>],
        prev: Option<PlanTrace>,
    ) -> PlanOutcome {
        plan_resumable(self, &TuneAlg(self), fleet, jobs, prev)
    }
}

/// Grow granted demands toward their best-case values using whatever free
/// CPU/memory remains on the jobs' servers. Multi-server jobs grow
/// proportionally across their shares (per §4.2's proportional-split
/// rule). Jobs with the largest gap to best-case are served first.
fn redistribute_spare(
    cluster: &mut Cluster,
    plan: &mut PoolPlan,
    jobs: &[PoolRequest<'_>],
) {
    let best: BTreeMap<JobId, DemandVector> =
        jobs.iter().map(|j| (j.id, j.best)).collect();
    // Largest relative gap first.
    let mut order: Vec<JobId> = plan.grants().keys().copied().collect();
    order.sort_by(|a, b| {
        let gap = |id: &JobId| {
            let g = &plan.grants()[id];
            let bd = &best[id];
            (bd.cpus - g.demand.cpus).max(0.0)
                + (bd.mem_gb - g.demand.mem_gb).max(0.0) / 12.5
        };
        gap(b).partial_cmp(&gap(a)).unwrap().then(a.cmp(b))
    });

    for id in order {
        let bd = best[&id];
        // Early-out on the Copy demand alone — most jobs already hold
        // their best case, so don't touch the placement (let alone clone
        // the grant, as this loop once did) until a gap is established.
        let granted = plan.grants()[&id].demand;
        let want_cpu = (bd.cpus - granted.cpus).max(0.0);
        let want_mem = (bd.mem_gb - granted.mem_gb).max(0.0);
        if want_cpu <= 1e-9 && want_mem <= 1e-9 {
            continue;
        }
        let total_gpus = granted.gpus as f64;
        // Per-GPU headroom limited by the tightest server in the span.
        let mut cpu_per_gpu = f64::INFINITY;
        let mut mem_per_gpu = f64::INFINITY;
        for (&sid, share) in &plan.grants()[&id].placement.shares {
            let s = cluster.server(sid);
            cpu_per_gpu = cpu_per_gpu.min(s.free_cpus / share.gpus as f64);
            mem_per_gpu = mem_per_gpu.min(s.free_mem_gb / share.gpus as f64);
        }
        let add_cpu = want_cpu.min(cpu_per_gpu * total_gpus).max(0.0);
        let add_mem = want_mem.min(mem_per_gpu * total_gpus).max(0.0);
        if add_cpu <= 1e-9 && add_mem <= 1e-9 {
            continue;
        }
        let new_demand = DemandVector::new(
            granted.gpus,
            granted.cpus + add_cpu,
            granted.mem_gb + add_mem,
        );
        // Rebuild the placement on the same servers, proportional split.
        let old = cluster.evict(id).expect("granted job must be placed");
        let mut new_p = Placement::default();
        for (sid, share) in old.shares {
            let frac = share.gpus as f64 / total_gpus;
            new_p.shares.insert(
                sid,
                Share {
                    gpus: share.gpus,
                    cpus: new_demand.cpus * frac,
                    mem_gb: new_demand.mem_gb * frac,
                },
            );
        }
        cluster.place(id, new_p.clone());
        plan.insert(id, PoolGrant { placement: new_p, demand: new_demand });
    }
}

/// Downgrade the single best victim: a granted job holding more than its
/// proportional share on a server that could host (part of) `job`'s GPUs.
/// Returns false if no such victim exists.
///
/// Under a rack topology (racks ≥ 2, placement-aware) victims are ranked
/// first by the best rack rank among their touched candidate servers —
/// the same rack-preference order `multi_server_fit` packs by — so
/// reclaimed CPU/mem frees up in the rack the stuck gang would
/// consolidate into, and only then by largest excess. On the flat
/// topology every victim shares rank 0 and the selection reduces exactly
/// to the pre-topology largest-excess rule (first maximum kept).
///
/// A victim's proportional floor is recomputed from its granted gang
/// size and the pool's spec ratios — bit-identical to the request-list
/// values (same inputs, same expression), without carrying a side map
/// through the resumable fold.
fn downgrade_one_victim(
    cluster: &mut Cluster,
    plan: &mut PoolPlan,
    job: &PoolRequest<'_>,
    strategy: VictimStrategy,
) -> bool {
    // Candidate servers: those with any free GPUs (they could contribute
    // to the job's placement but lack CPU/mem). One boolean vec over
    // server ids, filled from the free-capacity index — the victim loop
    // below then probes it in O(span) per victim instead of the old
    // O(victims × candidate servers) `contains` scans.
    let mut candidate = vec![false; cluster.server_id_bound()];
    let mut candidates: Vec<&crate::cluster::Server> = Vec::new();
    for s in cluster.servers_by_position(1) {
        candidate[s.id] = true;
        candidates.push(s);
    }
    if candidates.is_empty() {
        return false;
    }
    // Per-candidate-server rack rank (None when flat/locality-blind —
    // all ranks 0 and the rack term vanishes from the victim key).
    let rack_rank_of: Vec<u32> = match super::rack_ranks(cluster, &candidates)
    {
        Some(rank) => {
            let mut by_id = vec![0u32; cluster.server_id_bound()];
            for s in &candidates {
                by_id[s.id] = rank[cluster.rack_of(s.id) as usize];
            }
            by_id
        }
        None => Vec::new(),
    };
    drop(candidates);
    let spec = cluster.spec;
    let prop_of = |gpus: u32| {
        DemandVector::proportional(
            gpus,
            spec.cpus as f64 / spec.gpus as f64,
            spec.mem_gb / spec.gpus as f64,
        )
    };

    // Find the best victim: preferred rack first (rank 0 when flat),
    // largest reclaimable excess within a rank.
    let mut best: Option<(JobId, u32, f64)> = None;
    for (&vid, grant) in plan.grants().iter() {
        if vid == job.id {
            continue;
        }
        let prop = prop_of(grant.demand.gpus);
        if !grant.demand.exceeds(&prop) {
            continue;
        }
        // Best (lowest) rack rank among the candidate servers this
        // victim touches; u32::MAX if it touches none.
        let mut vrank = u32::MAX;
        for sid in grant.placement.shares.keys() {
            if candidate[*sid] {
                if rack_rank_of.is_empty() {
                    vrank = 0;
                    break;
                }
                vrank = vrank.min(rack_rank_of[*sid]);
            }
        }
        if vrank == u32::MAX {
            continue; // touches no candidate server
        }
        // Normalized excess (CPU cores + memory units above proportional).
        let excess = (grant.demand.cpus - prop.cpus).max(0.0)
            + (grant.demand.mem_gb - prop.mem_gb).max(0.0) / 12.5;
        // Flat: ranks all equal, so this is exactly the pre-topology
        // strict largest-excess rule (first maximum kept).
        let better = best
            .map(|(_, br, be)| vrank < br || (vrank == br && excess > be))
            .unwrap_or(true);
        if better {
            best = Some((vid, vrank, excess));
        }
        if strategy == VictimStrategy::FirstFound && best.is_some() {
            break;
        }
    }
    let Some((vid, _, _)) = best else { return false };

    // Downgrade: shrink each per-server share to the element-wise min of
    // the current and proportional demand for the GPUs it holds there
    // (same servers — no migration; never grows a dimension).
    let victim_demand = plan.grants()[&vid].demand;
    let prop = victim_demand.clamp_to(&prop_of(victim_demand.gpus));
    let per_gpu_cpu = prop.cpus / prop.gpus as f64;
    let per_gpu_mem = prop.mem_gb / prop.gpus as f64;
    let old = cluster.evict(vid).expect("victim must be placed");
    let mut new_p = Placement::default();
    for (sid, share) in old.shares {
        new_p.shares.insert(
            sid,
            Share {
                gpus: share.gpus,
                cpus: per_gpu_cpu * share.gpus as f64,
                mem_gb: per_gpu_mem * share.gpus as f64,
            },
        );
    }
    cluster.place(vid, new_p.clone());
    plan.insert(vid, PoolGrant { placement: new_p, demand: prop });
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GpuGen, ServerSpec};
    use crate::job::{Job, JobId, ModelKind};
    use crate::profiler::{OptimisticProfiler, Sensitivity};

    fn sens(model: ModelKind, gpus: u32) -> Sensitivity {
        OptimisticProfiler::noiseless(ServerSpec::default())
            .profile(&Job::new(JobId(0), model, gpus, 0.0, 60.0))
    }

    fn request<'a>(id: u64, gpus: u32, s: &'a Sensitivity) -> JobRequest<'a> {
        JobRequest { id: JobId(id), gpus, sens: s }
    }

    #[test]
    fn tune_never_strands_gpus() {
        // The GREEDY pathology case: 8 CPU-hungry 1-GPU jobs, one server.
        let s = sens(ModelKind::M5, 1);
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let reqs: Vec<JobRequest> =
            (0..8).map(|i| request(i, 1, &s)).collect();
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 8, "all jobs must be placed");
        assert_eq!(fleet.free_gpus(), 0, "no stranded GPUs");
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn tune_grants_at_least_proportional_throughput() {
        let models = [
            ModelKind::ResNet18,
            ModelKind::M5,
            ModelKind::ShuffleNetV2,
            ModelKind::Gnmt,
            ModelKind::DeepSpeech,
            ModelKind::AlexNet,
            ModelKind::Lstm,
            ModelKind::MobileNetV2,
        ];
        let sensitivities: Vec<Sensitivity> =
            models.iter().map(|&k| sens(k, 1)).collect();
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let reqs: Vec<JobRequest> = sensitivities
            .iter()
            .enumerate()
            .map(|(i, s)| request(i as u64, 1, s))
            .collect();
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 8);
        for (req, s) in reqs.iter().zip(&sensitivities) {
            let g = &grants[&req.id];
            let m = s.matrix(g.gen).unwrap();
            let got = m.throughput_at(g.demand.cpus, g.demand.mem_gb);
            let floor = s.fair_throughput();
            assert!(
                got + 1e-9 >= floor,
                "{:?}: got {} < floor {}",
                req.id, got, floor
            );
        }
    }

    #[test]
    fn tune_gives_spare_resources_to_sensitive_jobs() {
        // 1 hungry image job + 7 language jobs: the image job should walk
        // away with more than proportional CPU.
        let img = sens(ModelKind::AlexNet, 1);
        let lang = sens(ModelKind::Gnmt, 1);
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let mut reqs = vec![request(0, 1, &img)];
        reqs.extend((1..8).map(|i| request(i, 1, &lang)));
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 8);
        let g = &grants[&JobId(0)];
        assert!(
            g.demand.cpus > 3.0,
            "sensitive job should exceed proportional CPU, got {}",
            g.demand.cpus
        );
    }

    #[test]
    fn tune_downgrades_victims_when_needed() {
        // Two hungry jobs land first (taking > proportional), then six
        // more hungry jobs force downgrades; everyone must still fit.
        let s = sens(ModelKind::DeepSpeech, 1);
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 1);
        let reqs: Vec<JobRequest> =
            (0..8).map(|i| request(i, 1, &s)).collect();
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 8);
        // Total CPU within capacity.
        let total_cpu: f64 = grants.values().map(|g| g.demand.cpus).sum();
        assert!(total_cpu <= 24.0 + 1e-6, "cpu oversubscribed: {total_cpu}");
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn tune_multi_gpu_split_is_proportional_per_server() {
        let s = sens(ModelKind::ResNet18, 16);
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 2);
        let reqs = vec![request(0, 16, &s)];
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        let g = &grants[&JobId(0)];
        assert_eq!(g.placement.span(), 2);
        for share in g.placement.shares.values() {
            let per_gpu_cpu = share.cpus / share.gpus as f64;
            let expect = g.demand.cpus / 16.0;
            assert!((per_gpu_cpu - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn tune_worst_case_degrades_to_proportional() {
        // All-sensitive split (paper Fig 11c): with every job hungry,
        // TUNE must still place everyone (at ~proportional), matching
        // the "never worse than GPU-proportional" guarantee.
        let m5 = sens(ModelKind::M5, 1);
        let shuffle = sens(ModelKind::ShuffleNetV2, 1);
        let mut fleet = Fleet::homogeneous(ServerSpec::default(), 2);
        let mut reqs = Vec::new();
        for i in 0..8 {
            reqs.push(request(i, 1, &m5));
        }
        for i in 8..16 {
            reqs.push(request(i, 1, &shuffle));
        }
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants.len(), 16);
        assert_eq!(fleet.free_gpus(), 0);
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn tune_sends_compute_bound_jobs_to_fast_type() {
        // One compute-bound language job + one input-bound image job on a
        // two-type fleet: the language job should land on the V100 pool.
        let mut fleet = Fleet::two_tier(1);
        let p = OptimisticProfiler::noiseless_fleet(&fleet);
        let jobs: Vec<Job> = [
            (0u64, ModelKind::Gnmt, 8u32),
            (1, ModelKind::ShuffleNetV2, 8),
        ]
        .iter()
        .map(|&(id, m, g)| Job::new(JobId(id), m, g, 0.0, 3600.0))
        .collect();
        let sens: Vec<Sensitivity> = jobs.iter().map(|j| p.profile(j)).collect();
        let reqs: Vec<JobRequest> = jobs
            .iter()
            .zip(&sens)
            .map(|(j, s)| JobRequest { id: j.id, gpus: j.gpus, sens: s })
            .collect();
        let grants = Tune::default().allocate(&mut fleet, &reqs);
        assert_eq!(grants[&JobId(0)].gen, GpuGen::V100, "gnmt on fast type");
        assert_eq!(grants[&JobId(1)].gen, GpuGen::P100);
    }
}
