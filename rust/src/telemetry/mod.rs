//! Deterministic run telemetry: delta-compressed per-round /
//! per-type-pool / per-tenant time series plus plan-stage trace events,
//! exportable as JSONL or CSV (`synergy sim --telemetry <path>`,
//! `synergy sweep --telemetry-dir <dir>`, leader `--telemetry`).
//!
//! Design rules (standing invariants — see ROADMAP):
//!
//! - **Default-off and schedule-inert.** Recording reads O(1) gauges the
//!   free-capacity index already maintains and never feeds a value back
//!   into planning, so enabling telemetry changes zero scheduled bytes
//!   and zero golden payload bytes.
//! - **Counters only in deterministic mode.** Emitted files carry sim
//!   time and counters — no wall-clock — so `synergy sweep` telemetry is
//!   byte-identical for any `--threads`. Wall time appears only behind
//!   [`TelemetryConfig::timing`] (`--telemetry-timing`), which CI never
//!   diffs.
//! - **Arena-friendly storage.** Samples are flattened into one
//!   delta-compressed byte arena per stream ([`DeltaLog`]: zigzag +
//!   varint over a row-prefix delta) instead of a Vec-of-structs, so
//!   long runs cost a few bytes per row: counters move slowly round to
//!   round, and small deltas are 1-byte varints. Float gauges are
//!   quantized to milli-units before encoding (exact integer
//!   round-trip at 1e-3 resolution, matching the goldens' 1 ms
//!   rounding).
//!
//! Row layouts (field-by-field; also documented in
//! `tests/golden/README.md`):
//!
//! - **Round row** — delta-encoded prefix
//!   `[round, time_ms, queued, running, admitted_gpus, spilled_gpus,
//!     free_gpus, total_gpus, free_cpus_milli, total_cpus_milli,
//!     free_mem_milli, total_mem_milli, gangs_placed, cross_rack_gangs,
//!     preemptions, servers_failed, servers_restored]`
//!   (+ `wall_ms` when timing is on), then 6 fields per type pool
//!   `[free_gpus, total_gpus, free_cpus_milli, total_cpus_milli,
//!     free_mem_milli, total_mem_milli]`, then an absolute tail
//!   `[n_tenants, (tenant_id, running, pending, admitted_gpus,
//!     spilled_gpus)…]` (tenant sets change round to round, so the tail
//!   is not delta-friendly).
//! - **Plan event** — delta-encoded prefix
//!   `[round, tier, steps_total, steps_reused, rollback_depth,
//!     fit_walk]` (tier: 0 = full, 1 = memoized, 2 = resumed), then an
//!   absolute tail `[n_pools, (reused, replayed)…]`.

use crate::cluster::GpuGen;
use crate::job::TenantId;
use crate::util::json::Json;

/// Fixed per-round core fields before the optional `wall_ms` and the
/// per-pool blocks (see module docs for the layout).
const ROUND_CORE: usize = 17;
/// Fields per type pool in a round row.
const POOL_FIELDS: usize = 6;
/// Fields per tenant in a round row's absolute tail.
const TENANT_FIELDS: usize = 5;
/// Delta-encoded prefix of a plan event.
const PLAN_PREFIX: usize = 6;
/// Schema version stamped into the JSONL `meta` line.
pub const SCHEMA_VERSION: u64 = 1;

/// Quantize a float gauge to milli-units for exact integer round-trips
/// (1e-3 resolution — the same granularity the golden metrics use).
pub fn milli(x: f64) -> i64 {
    (x * 1000.0).round() as i64
}

/// Inverse of [`milli`].
pub fn from_milli(v: i64) -> f64 {
    v as f64 / 1000.0
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

fn write_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

fn read_varint(buf: &[u8], pos: &mut usize) -> u64 {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = buf[*pos];
        *pos += 1;
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return v;
        }
        shift += 7;
    }
}

/// Append-only delta-compressed log of integer rows backed by one flat
/// byte arena.
///
/// Each row is written as `varint(len)` followed by one zigzag varint
/// per field; the first `prefix` fields are encoded as deltas against
/// the previous row (absolute when there is no previous row or it was
/// shorter), the rest absolute. [`DeltaLog::decode`] replays the same
/// rule, so `decode(push(rows)) == rows` exactly for any rows.
#[derive(Debug, Clone, Default)]
pub struct DeltaLog {
    prefix: usize,
    buf: Vec<u8>,
    prev: Vec<i64>,
    rows: usize,
}

impl DeltaLog {
    /// A log whose first `prefix` fields per row are delta-encoded.
    pub fn new(prefix: usize) -> DeltaLog {
        DeltaLog { prefix, ..DeltaLog::default() }
    }

    /// Append one row.
    pub fn push(&mut self, fields: &[i64]) {
        write_varint(&mut self.buf, fields.len() as u64);
        for (i, &v) in fields.iter().enumerate() {
            let enc = if i < self.prefix && i < self.prev.len() {
                v.wrapping_sub(self.prev[i])
            } else {
                v
            };
            write_varint(&mut self.buf, zigzag(enc));
        }
        self.prev.clear();
        self.prev.extend_from_slice(fields);
        self.rows += 1;
    }

    /// Decode every row back out (exact inverse of the pushes).
    pub fn decode(&self) -> Vec<Vec<i64>> {
        let mut out = Vec::with_capacity(self.rows);
        let mut prev: Vec<i64> = Vec::new();
        let mut pos = 0usize;
        while pos < self.buf.len() {
            let n = read_varint(&self.buf, &mut pos) as usize;
            let mut row = Vec::with_capacity(n);
            for i in 0..n {
                let raw = unzigzag(read_varint(&self.buf, &mut pos));
                let v = if i < self.prefix && i < prev.len() {
                    prev[i].wrapping_add(raw)
                } else {
                    raw
                };
                row.push(v);
            }
            prev.clear();
            prev.extend_from_slice(&row);
            out.push(row);
        }
        out
    }

    /// Number of rows pushed.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Encoded size in bytes (the compression evidence).
    pub fn encoded_bytes(&self) -> usize {
        self.buf.len()
    }
}

/// Per-type-pool counter snapshot, read off the free-capacity index in
/// O(1) — never a fresh server scan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolCounters {
    pub gen: GpuGen,
    pub free_gpus: u32,
    pub total_gpus: u32,
    pub free_cpus: f64,
    pub total_cpus: f64,
    pub free_mem_gb: f64,
    pub total_mem_gb: f64,
}

/// Per-tenant per-round counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantCounters {
    pub tenant: TenantId,
    /// Jobs of this tenant currently holding a placement.
    pub running: u32,
    /// Jobs of this tenant queued without a placement.
    pub pending: u32,
    /// GPUs admitted for this tenant at the last admission pass.
    pub admitted_gpus: u32,
    /// GPUs this tenant received only via the work-conserving spill
    /// pass at the last admission (0 with quotas off).
    pub spilled_gpus: u32,
}

/// One sampled scheduling round.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RoundSample {
    pub round: u64,
    /// Deterministic sim time in ms (never wall clock).
    pub time_ms: i64,
    pub queued: u32,
    pub running: u32,
    /// Total GPUs admitted at the last admission pass.
    pub admitted_gpus: u32,
    /// Total GPUs admitted only via quota spill at the last admission.
    pub spilled_gpus: u32,
    pub free_gpus: u32,
    pub total_gpus: u32,
    pub free_cpus: f64,
    pub total_cpus: f64,
    pub free_mem_gb: f64,
    pub total_mem_gb: f64,
    /// Multi-server gangs deployed this round (the carried plan's count
    /// on memoized/fast-forwarded rounds — placements stay committed).
    pub gangs_placed: u32,
    /// Of `gangs_placed`, the gangs straddling a rack boundary under
    /// the fleet's topology. Always 0 on a flat topology.
    pub cross_rack_gangs: u32,
    /// Jobs preempted by host failures *this round* (instantaneous —
    /// unlike the admission/gang gauges above, churn tallies are not
    /// carried across fast-forwarded rounds; a quiet round reads 0).
    pub preemptions: u32,
    /// Servers taken offline by churn this round (instantaneous).
    pub servers_failed: u32,
    /// Servers restored or added by churn this round (instantaneous).
    pub servers_restored: u32,
    /// Wall-clock ms — recorded/emitted only when timing is enabled.
    pub wall_ms: i64,
    pub pools: Vec<PoolCounters>,
    pub tenants: Vec<TenantCounters>,
}

/// Which planning tier served a round (the three-tier stack:
/// full replan / exact-sequence memoized / prefix-resumed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanTier {
    Full,
    Memoized,
    Resumed,
}

impl PlanTier {
    fn code(self) -> i64 {
        match self {
            PlanTier::Full => 0,
            PlanTier::Memoized => 1,
            PlanTier::Resumed => 2,
        }
    }

    fn from_code(c: i64) -> PlanTier {
        match c {
            0 => PlanTier::Full,
            1 => PlanTier::Memoized,
            _ => PlanTier::Resumed,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanTier::Full => "full",
            PlanTier::Memoized => "memoized",
            PlanTier::Resumed => "resumed",
        }
    }
}

/// One plan-stage trace event (one per round).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanEvent {
    pub round: u64,
    pub tier: PlanTier,
    /// Per-job planning steps executed or reused this round.
    pub steps_total: u64,
    /// Steps served from a checkpointed prefix instead of replayed.
    pub steps_reused: u64,
    /// Undo-journal entries rolled back across pools (prefix resume).
    pub rollback_depth: u64,
    /// Candidate servers examined by the free-capacity index walks.
    pub fit_walk: u64,
    /// Per-pool `(reused, replayed)` step counts (empty on memoized
    /// rounds — no planner ran).
    pub pools: Vec<(u64, u64)>,
}

/// Recorder knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct TelemetryConfig {
    /// Emit wall-clock fields. Off = deterministic counters-only mode.
    pub timing: bool,
}

/// Live-service lifecycle counters (deploy leader only): how the run
/// *started* and how the fleet *degraded*, as opposed to what was
/// scheduled. Counters only — no wall clock — so counters-only profiles
/// stay deterministic; note these describe the service process, not the
/// schedule (a recovered run records `recoveries: 1` while producing a
/// schedule byte-identical to an unkilled run's).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceCounters {
    /// 1 when the leader warm-started from a write-ahead journal.
    pub recoveries: u64,
    /// Journal records replayed during warm start.
    pub journal_records_replayed: u64,
    /// Workers failed over because their heartbeat lease expired.
    pub heartbeat_expiries: u64,
}

/// The run recorder: two [`DeltaLog`] arenas (round samples, plan
/// events) plus the fixed pool shape captured at the first sample.
#[derive(Debug, Clone, Default)]
pub struct TelemetryRecorder {
    cfg: TelemetryConfig,
    rounds: Option<DeltaLog>,
    plans: DeltaLog,
    pool_gens: Vec<GpuGen>,
    scratch: Vec<i64>,
    service: Option<ServiceCounters>,
}

impl TelemetryRecorder {
    pub fn new(cfg: TelemetryConfig) -> TelemetryRecorder {
        TelemetryRecorder {
            cfg,
            rounds: None,
            plans: DeltaLog::new(PLAN_PREFIX),
            pool_gens: Vec::new(),
            scratch: Vec::new(),
            service: None,
        }
    }

    /// Attach the service-lifecycle counters (deploy leader). Absent
    /// from simulator profiles; at most one `service` line per export.
    pub fn record_service(&mut self, c: ServiceCounters) {
        self.service = Some(c);
    }

    /// The recorded service counters, if any.
    pub fn service(&self) -> Option<ServiceCounters> {
        self.service
    }

    pub fn config(&self) -> TelemetryConfig {
        self.cfg
    }

    /// Record one round sample. The pool set is fixed by the first
    /// sample (fleets do not change shape mid-run).
    pub fn record_round(&mut self, s: &RoundSample) {
        if self.rounds.is_none() {
            self.pool_gens = s.pools.iter().map(|p| p.gen).collect();
            let prefix = ROUND_CORE
                + usize::from(self.cfg.timing)
                + POOL_FIELDS * s.pools.len();
            self.rounds = Some(DeltaLog::new(prefix));
        }
        assert_eq!(
            s.pools.len(),
            self.pool_gens.len(),
            "telemetry: pool count changed mid-run"
        );
        let mut row = std::mem::take(&mut self.scratch);
        row.clear();
        row.extend_from_slice(&[
            s.round as i64,
            s.time_ms,
            i64::from(s.queued),
            i64::from(s.running),
            i64::from(s.admitted_gpus),
            i64::from(s.spilled_gpus),
            i64::from(s.free_gpus),
            i64::from(s.total_gpus),
            milli(s.free_cpus),
            milli(s.total_cpus),
            milli(s.free_mem_gb),
            milli(s.total_mem_gb),
            i64::from(s.gangs_placed),
            i64::from(s.cross_rack_gangs),
            i64::from(s.preemptions),
            i64::from(s.servers_failed),
            i64::from(s.servers_restored),
        ]);
        if self.cfg.timing {
            row.push(s.wall_ms);
        }
        for p in &s.pools {
            row.extend_from_slice(&[
                i64::from(p.free_gpus),
                i64::from(p.total_gpus),
                milli(p.free_cpus),
                milli(p.total_cpus),
                milli(p.free_mem_gb),
                milli(p.total_mem_gb),
            ]);
        }
        row.push(s.tenants.len() as i64);
        for t in &s.tenants {
            row.extend_from_slice(&[
                i64::from(t.tenant.0),
                i64::from(t.running),
                i64::from(t.pending),
                i64::from(t.admitted_gpus),
                i64::from(t.spilled_gpus),
            ]);
        }
        self.rounds.as_mut().expect("initialized above").push(&row);
        self.scratch = row;
    }

    /// Record one plan-stage trace event.
    pub fn record_plan(&mut self, e: &PlanEvent) {
        let mut row = std::mem::take(&mut self.scratch);
        row.clear();
        row.extend_from_slice(&[
            e.round as i64,
            e.tier.code(),
            e.steps_total as i64,
            e.steps_reused as i64,
            e.rollback_depth as i64,
            e.fit_walk as i64,
        ]);
        row.push(e.pools.len() as i64);
        for &(reused, replayed) in &e.pools {
            row.push(reused as i64);
            row.push(replayed as i64);
        }
        self.plans.push(&row);
        self.scratch = row;
    }

    pub fn n_rounds(&self) -> usize {
        self.rounds.as_ref().map_or(0, DeltaLog::rows)
    }

    pub fn n_plan_events(&self) -> usize {
        self.plans.rows()
    }

    /// Total encoded arena size in bytes.
    pub fn encoded_bytes(&self) -> usize {
        self.rounds.as_ref().map_or(0, DeltaLog::encoded_bytes)
            + self.plans.encoded_bytes()
    }

    /// Decode all round samples back out (exact inverse of
    /// [`TelemetryRecorder::record_round`] up to milli quantization of
    /// the float gauges, which the recorder applies on entry).
    pub fn rounds(&self) -> Vec<RoundSample> {
        let Some(log) = &self.rounds else {
            return Vec::new();
        };
        log.decode().iter().map(|row| self.decode_round(row)).collect()
    }

    fn decode_round(&self, row: &[i64]) -> RoundSample {
        let mut i = ROUND_CORE;
        let wall_ms = if self.cfg.timing {
            let w = row[i];
            i += 1;
            w
        } else {
            0
        };
        let mut pools = Vec::with_capacity(self.pool_gens.len());
        for &gen in &self.pool_gens {
            pools.push(PoolCounters {
                gen,
                free_gpus: row[i] as u32,
                total_gpus: row[i + 1] as u32,
                free_cpus: from_milli(row[i + 2]),
                total_cpus: from_milli(row[i + 3]),
                free_mem_gb: from_milli(row[i + 4]),
                total_mem_gb: from_milli(row[i + 5]),
            });
            i += POOL_FIELDS;
        }
        let n_tenants = row[i] as usize;
        i += 1;
        let mut tenants = Vec::with_capacity(n_tenants);
        for _ in 0..n_tenants {
            tenants.push(TenantCounters {
                tenant: TenantId(row[i] as u32),
                running: row[i + 1] as u32,
                pending: row[i + 2] as u32,
                admitted_gpus: row[i + 3] as u32,
                spilled_gpus: row[i + 4] as u32,
            });
            i += TENANT_FIELDS;
        }
        RoundSample {
            round: row[0] as u64,
            time_ms: row[1],
            queued: row[2] as u32,
            running: row[3] as u32,
            admitted_gpus: row[4] as u32,
            spilled_gpus: row[5] as u32,
            free_gpus: row[6] as u32,
            total_gpus: row[7] as u32,
            free_cpus: from_milli(row[8]),
            total_cpus: from_milli(row[9]),
            free_mem_gb: from_milli(row[10]),
            total_mem_gb: from_milli(row[11]),
            gangs_placed: row[12] as u32,
            cross_rack_gangs: row[13] as u32,
            preemptions: row[14] as u32,
            servers_failed: row[15] as u32,
            servers_restored: row[16] as u32,
            wall_ms,
            pools,
            tenants,
        }
    }

    /// Decode all plan events back out.
    pub fn plan_events(&self) -> Vec<PlanEvent> {
        self.plans
            .decode()
            .iter()
            .map(|row| {
                let n_pools = row[PLAN_PREFIX] as usize;
                let mut pools = Vec::with_capacity(n_pools);
                for p in 0..n_pools {
                    let base = PLAN_PREFIX + 1 + 2 * p;
                    pools.push((row[base] as u64, row[base + 1] as u64));
                }
                PlanEvent {
                    round: row[0] as u64,
                    tier: PlanTier::from_code(row[1]),
                    steps_total: row[2] as u64,
                    steps_reused: row[3] as u64,
                    rollback_depth: row[4] as u64,
                    fit_walk: row[5] as u64,
                    pools,
                }
            })
            .collect()
    }

    fn pool_json(p: &PoolCounters) -> Json {
        Json::obj(vec![
            ("gen", Json::str(p.gen.name())),
            ("free_gpus", Json::num(f64::from(p.free_gpus))),
            ("total_gpus", Json::num(f64::from(p.total_gpus))),
            ("free_cpus", Json::num(p.free_cpus)),
            ("total_cpus", Json::num(p.total_cpus)),
            ("free_mem_gb", Json::num(p.free_mem_gb)),
            ("total_mem_gb", Json::num(p.total_mem_gb)),
        ])
    }

    fn tenant_json(t: &TenantCounters) -> Json {
        Json::obj(vec![
            ("tenant", Json::num(f64::from(t.tenant.0))),
            ("running", Json::num(f64::from(t.running))),
            ("pending", Json::num(f64::from(t.pending))),
            ("admitted_gpus", Json::num(f64::from(t.admitted_gpus))),
            ("spilled_gpus", Json::num(f64::from(t.spilled_gpus))),
        ])
    }

    fn round_json(&self, s: &RoundSample) -> Json {
        let mut fields = vec![
            ("kind", Json::str("round")),
            ("round", Json::num(s.round as f64)),
            ("time_ms", Json::num(s.time_ms as f64)),
            ("queued", Json::num(f64::from(s.queued))),
            ("running", Json::num(f64::from(s.running))),
            ("admitted_gpus", Json::num(f64::from(s.admitted_gpus))),
            ("spilled_gpus", Json::num(f64::from(s.spilled_gpus))),
            ("free_gpus", Json::num(f64::from(s.free_gpus))),
            ("total_gpus", Json::num(f64::from(s.total_gpus))),
            ("free_cpus", Json::num(s.free_cpus)),
            ("total_cpus", Json::num(s.total_cpus)),
            ("free_mem_gb", Json::num(s.free_mem_gb)),
            ("total_mem_gb", Json::num(s.total_mem_gb)),
            ("gangs_placed", Json::num(f64::from(s.gangs_placed))),
            (
                "cross_rack_gangs",
                Json::num(f64::from(s.cross_rack_gangs)),
            ),
            ("preemptions", Json::num(f64::from(s.preemptions))),
            ("servers_failed", Json::num(f64::from(s.servers_failed))),
            (
                "servers_restored",
                Json::num(f64::from(s.servers_restored)),
            ),
        ];
        if self.cfg.timing {
            fields.push(("wall_ms", Json::num(s.wall_ms as f64)));
        }
        fields.push((
            "pools",
            Json::arr(s.pools.iter().map(Self::pool_json).collect()),
        ));
        fields.push((
            "tenants",
            Json::arr(s.tenants.iter().map(Self::tenant_json).collect()),
        ));
        Json::obj(fields)
    }

    fn plan_json(e: &PlanEvent) -> Json {
        Json::obj(vec![
            ("kind", Json::str("plan")),
            ("round", Json::num(e.round as f64)),
            ("tier", Json::str(e.tier.name())),
            ("steps_total", Json::num(e.steps_total as f64)),
            ("steps_reused", Json::num(e.steps_reused as f64)),
            ("rollback_depth", Json::num(e.rollback_depth as f64)),
            ("fit_walk", Json::num(e.fit_walk as f64)),
            (
                "pools",
                Json::arr(
                    e.pools
                        .iter()
                        .map(|&(reused, replayed)| {
                            Json::obj(vec![
                                ("reused", Json::num(reused as f64)),
                                ("replayed", Json::num(replayed as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Full JSONL export: one `meta` line, then `round` lines, then
    /// `plan` lines. Byte-deterministic in counters-only mode.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let meta = Json::obj(vec![
            ("kind", Json::str("meta")),
            ("schema", Json::num(SCHEMA_VERSION as f64)),
            ("counters_only", Json::Bool(!self.cfg.timing)),
            (
                "pools",
                Json::arr(
                    self.pool_gens
                        .iter()
                        .map(|g| Json::str(g.name()))
                        .collect(),
                ),
            ),
            ("rounds", Json::num(self.n_rounds() as f64)),
            ("plan_events", Json::num(self.n_plan_events() as f64)),
            ("encoded_bytes", Json::num(self.encoded_bytes() as f64)),
        ]);
        out.push_str(&meta.encode());
        out.push('\n');
        for s in self.rounds() {
            out.push_str(&self.round_json(&s).encode());
            out.push('\n');
        }
        for e in self.plan_events() {
            out.push_str(&Self::plan_json(&e).encode());
            out.push('\n');
        }
        if let Some(c) = self.service {
            let line = Json::obj(vec![
                ("kind", Json::str("service")),
                ("recoveries", Json::num(c.recoveries as f64)),
                (
                    "journal_records_replayed",
                    Json::num(c.journal_records_replayed as f64),
                ),
                (
                    "heartbeat_expiries",
                    Json::num(c.heartbeat_expiries as f64),
                ),
            ]);
            out.push_str(&line.encode());
            out.push('\n');
        }
        out
    }

    /// CSV export of the round series only (fixed columns: core prefix,
    /// optional `wall_ms`, then 6 columns per pool). Per-tenant tails
    /// and plan events are variable-shape and JSONL-only.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "round,time_ms,queued,running,admitted_gpus,spilled_gpus,\
             free_gpus,total_gpus,free_cpus,total_cpus,free_mem_gb,\
             total_mem_gb,gangs_placed,cross_rack_gangs,preemptions,\
             servers_failed,servers_restored",
        );
        if self.cfg.timing {
            out.push_str(",wall_ms");
        }
        for g in &self.pool_gens {
            let n = g.name();
            for col in [
                "free_gpus",
                "total_gpus",
                "free_cpus",
                "total_cpus",
                "free_mem_gb",
                "total_mem_gb",
            ] {
                out.push_str(&format!(",{n}_{col}"));
            }
        }
        out.push('\n');
        for s in self.rounds() {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                s.round,
                s.time_ms,
                s.queued,
                s.running,
                s.admitted_gpus,
                s.spilled_gpus,
                s.free_gpus,
                s.total_gpus,
                s.free_cpus,
                s.total_cpus,
                s.free_mem_gb,
                s.total_mem_gb,
                s.gangs_placed,
                s.cross_rack_gangs,
                s.preemptions,
                s.servers_failed,
                s.servers_restored,
            ));
            if self.cfg.timing {
                out.push_str(&format!(",{}", s.wall_ms));
            }
            for p in &s.pools {
                out.push_str(&format!(
                    ",{},{},{},{},{},{}",
                    p.free_gpus,
                    p.total_gpus,
                    p.free_cpus,
                    p.total_cpus,
                    p.free_mem_gb,
                    p.total_mem_gb,
                ));
            }
            out.push('\n');
        }
        out
    }

    /// Render for `path`: `.csv` extension selects CSV, anything else
    /// JSONL.
    pub fn render_for_path(&self, path: &str) -> String {
        if path.ends_with(".csv") {
            self.to_csv()
        } else {
            self.to_jsonl()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_varint_roundtrip() {
        let cases = [
            0i64,
            1,
            -1,
            2,
            -2,
            63,
            -64,
            64,
            127,
            128,
            -129,
            1 << 20,
            -(1 << 20),
            i64::MAX,
            i64::MIN,
        ];
        for &v in &cases {
            assert_eq!(unzigzag(zigzag(v)), v, "zigzag({v})");
            let mut buf = Vec::new();
            write_varint(&mut buf, zigzag(v));
            let mut pos = 0;
            assert_eq!(unzigzag(read_varint(&buf, &mut pos)), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn small_deltas_encode_to_one_byte() {
        // The compression claim: slowly-moving counters cost ~1 byte
        // per field per row after the first.
        let mut log = DeltaLog::new(3);
        log.push(&[1_000_000, 500_000, 123_456]);
        let first = log.encoded_bytes();
        log.push(&[1_000_001, 500_000, 123_457]);
        // 1 len byte + 3 single-byte deltas.
        assert_eq!(log.encoded_bytes() - first, 4);
    }

    #[test]
    fn deltalog_roundtrip_mixed_row_lengths() {
        let rows: Vec<Vec<i64>> = vec![
            vec![0, 10, -5, 7, 2, 0, 0],
            vec![1, 12, -5, 7, 3, 1, 99, 4, -4],
            vec![2, 9, 40],
            vec![3, 9, 40, 0, 0, 0, 0, 0, 0, 0],
            vec![],
            vec![i64::MAX, i64::MIN, 0],
        ];
        for prefix in [0usize, 2, 5, 64] {
            let mut log = DeltaLog::new(prefix);
            for r in &rows {
                log.push(r);
            }
            assert_eq!(log.decode(), rows, "prefix {prefix}");
            assert_eq!(log.rows(), rows.len());
        }
    }

    fn sample(round: u64, tenants: usize) -> RoundSample {
        RoundSample {
            round,
            time_ms: 300_000 * round as i64,
            queued: 5 + round as u32,
            running: 3,
            admitted_gpus: 8,
            spilled_gpus: 2,
            free_gpus: 1,
            total_gpus: 16,
            free_cpus: 10.5,
            total_cpus: 48.0,
            free_mem_gb: 171.25,
            total_mem_gb: 1000.0,
            gangs_placed: 3,
            cross_rack_gangs: 1 + round as u32 % 2,
            preemptions: round as u32 % 3,
            servers_failed: u32::from(round % 4 == 1),
            servers_restored: u32::from(round % 4 == 2),
            wall_ms: 7 * round as i64,
            pools: vec![
                PoolCounters {
                    gen: GpuGen::P100,
                    free_gpus: 1,
                    total_gpus: 8,
                    free_cpus: 4.5,
                    total_cpus: 24.0,
                    free_mem_gb: 21.25,
                    total_mem_gb: 500.0,
                },
                PoolCounters {
                    gen: GpuGen::V100,
                    free_gpus: 0,
                    total_gpus: 8,
                    free_cpus: 6.0,
                    total_cpus: 24.0,
                    free_mem_gb: 150.0,
                    total_mem_gb: 500.0,
                },
            ],
            tenants: (0..tenants)
                .map(|t| TenantCounters {
                    tenant: TenantId(t as u32),
                    running: 1 + t as u32,
                    pending: 2,
                    admitted_gpus: 4,
                    spilled_gpus: t as u32,
                })
                .collect(),
        }
    }

    #[test]
    fn recorder_roundtrips_samples_and_plans() {
        let mut rec =
            TelemetryRecorder::new(TelemetryConfig { timing: false });
        let samples = vec![sample(0, 2), sample(1, 1), sample(2, 3)];
        for s in &samples {
            rec.record_round(s);
        }
        let plans = vec![
            PlanEvent {
                round: 0,
                tier: PlanTier::Full,
                steps_total: 12,
                steps_reused: 0,
                rollback_depth: 0,
                fit_walk: 31,
                pools: vec![(0, 7), (0, 5)],
            },
            PlanEvent {
                round: 1,
                tier: PlanTier::Resumed,
                steps_total: 12,
                steps_reused: 9,
                rollback_depth: 3,
                fit_walk: 6,
                pools: vec![(7, 0), (2, 3)],
            },
            PlanEvent {
                round: 2,
                tier: PlanTier::Memoized,
                steps_total: 0,
                steps_reused: 0,
                rollback_depth: 0,
                fit_walk: 0,
                pools: vec![],
            },
        ];
        for e in &plans {
            rec.record_plan(e);
        }
        // Counters-only mode drops wall_ms: decoded samples match the
        // inputs with wall_ms zeroed.
        let expect: Vec<RoundSample> = samples
            .iter()
            .map(|s| RoundSample { wall_ms: 0, ..s.clone() })
            .collect();
        assert_eq!(rec.rounds(), expect);
        assert_eq!(rec.plan_events(), plans);
        assert_eq!(rec.n_rounds(), 3);
        assert_eq!(rec.n_plan_events(), 3);
    }

    #[test]
    fn timing_mode_preserves_wall_ms() {
        let mut rec =
            TelemetryRecorder::new(TelemetryConfig { timing: true });
        let samples = vec![sample(0, 1), sample(1, 1)];
        for s in &samples {
            rec.record_round(s);
        }
        assert_eq!(rec.rounds(), samples);
        assert!(rec.to_jsonl().contains("\"wall_ms\""));
        assert!(rec.to_csv().lines().next().unwrap().contains("wall_ms"));
    }

    #[test]
    fn counters_only_export_has_no_wall_clock() {
        let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
        rec.record_round(&sample(0, 1));
        rec.record_plan(&PlanEvent {
            round: 0,
            tier: PlanTier::Full,
            steps_total: 1,
            steps_reused: 0,
            rollback_depth: 0,
            fit_walk: 2,
            pools: vec![(0, 1)],
        });
        let jsonl = rec.to_jsonl();
        assert!(!jsonl.contains("wall_ms"));
        assert!(jsonl.contains("\"counters_only\":true"));
        assert!(!rec.to_csv().contains("wall_ms"));
        // Export is a pure function of recorded state.
        assert_eq!(jsonl, rec.to_jsonl());
    }

    #[test]
    fn service_counters_are_optional_and_counters_only() {
        let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
        rec.record_round(&sample(0, 1));
        // Simulator profiles carry no service line at all.
        assert!(rec.service().is_none());
        assert!(!rec.to_jsonl().contains("\"kind\":\"service\""));
        rec.record_service(ServiceCounters {
            recoveries: 1,
            journal_records_replayed: 42,
            heartbeat_expiries: 2,
        });
        let jsonl = rec.to_jsonl();
        let line = jsonl
            .lines()
            .find(|l| l.contains("\"kind\":\"service\""))
            .expect("service line");
        assert!(line.contains("\"recoveries\":1"));
        assert!(line.contains("\"journal_records_replayed\":42"));
        assert!(line.contains("\"heartbeat_expiries\":2"));
        // Still counters-only: no wall clock sneaks in via the service
        // line, and CSV shape is untouched.
        assert!(!jsonl.contains("wall_ms"));
        assert!(!rec.to_csv().contains("service"));
    }

    #[test]
    fn render_for_path_picks_format_by_extension() {
        let mut rec = TelemetryRecorder::new(TelemetryConfig::default());
        rec.record_round(&sample(0, 0));
        assert!(rec
            .render_for_path("out/telemetry.csv")
            .starts_with("round,time_ms"));
        assert!(rec
            .render_for_path("out/telemetry.jsonl")
            .starts_with("{\"counters_only\""));
    }

    #[test]
    fn milli_quantization_is_exact_at_1e_minus_3() {
        for v in [0.0, 0.001, -0.001, 10.5, 171.25, 123456.789] {
            assert_eq!(from_milli(milli(v)), v);
        }
    }
}
