//! Synergy CLI: the Layer-3 leader binary.
//!
//! Subcommands:
//!
//! ```text
//! synergy sim       --policy srtf --mechanism tune --servers 16 \
//!                   --jobs 1000 --load 8 --split 20,70,10 [--multi-gpu]
//!                   [--tenants a:2,b:1]
//!                   [--topology racks:2]  # rack-aware gang placement +
//!                   # per-rack link cost; default flat = pre-topology
//!                   # schedules, byte-identical
//!                   [--telemetry run.jsonl|run.csv] [--telemetry-timing]
//!                   # per-round/per-pool/per-tenant series + plan trace;
//!                   # counters only unless --telemetry-timing
//!                   [--faults mtbf:24,mttr:2,seed:7 | --faults faults.json]
//!                   # deterministic host churn (fail/restore events);
//!                   # absent = byte-identical to pre-fault builds
//! synergy sim       --trace trace.csv --format philly|alibaba|google \
//!                   [--load-scale 2 --duration-min 60 --duration-max 1e5]
//!                   [--gpu-cap 16 --max-jobs 500 --keep-failed]
//!                   [--cpu-multiplier 8]  # google: normalized-CPU -> GPUs
//!                   [--shards 4]  # fan per-pool planning over N threads;
//!                   # schedule-invisible, byte-identical for any N
//! synergy sweep     --policies fifo,srtf --mechanisms proportional,tune \
//!                   --threads 8 [--out report.txt] [--plan-stats]
//!                   [--telemetry-dir telem/]  # one <policy>_<mechanism>.jsonl per cell
//!                   # deterministic parallel grid; byte-identical to --threads 1
//! synergy compare   --policies fifo,srtf --mechanisms proportional,tune ...
//! synergy profile   --model resnet18 --gpus 1
//! synergy models    # print the model zoo + CPU knees (Fig 2 data)
//! synergy trace     --jobs 100 --load 8 --out trace.json
//! synergy leader    --workers 2 --port 7331 --variant tiny ...
//!                   [--journal wal/ [--recover]]  # write-ahead state
//!                   # journal; --recover warm-starts bit-exactly
//!                   [--report out.json]  # deterministic schedule report
//!                   [--expect-jobs N]    # gate the round loop on N
//!                   # admissions (source + network submissions)
//!                   [--heartbeat S]      # worker lease period; silent
//!                   # for 3S => fail over via preempt-and-requeue
//!                   [--port-file f]      # write bound IP:PORT here
//! synergy worker    --leader 127.0.0.1:7331 --artifacts artifacts
//! synergy submit    --leader 127.0.0.1:7331 --id 7 --model resnet18 \
//!                   --gpus 2 --duration 3600 [--tenant team-a]
//!                   [--arrival S] | --status   # query run progress
//! synergy config    --file experiment.json   # run from a config file
//! ```
//!
//! (`simulate` is an alias of `sim`.) See the [`synergy::workload`] docs
//! for trace formats and the `--tenants name:weight,...` spec syntax.

use synergy::cluster::{ServerSpec, TopologySpec};
use synergy::config::ExperimentConfig;
use synergy::deploy::{Leader, LeaderConfig, Worker, WorkerConfig};
use synergy::job::{Job, JobId, ModelKind, ALL_MODELS};
use synergy::metrics::jains_index;
use synergy::perf::PerfModel;
use synergy::profiler::OptimisticProfiler;
use synergy::sim::{FaultSpec, SimConfig, Simulator};
use synergy::telemetry::{TelemetryConfig, TelemetryRecorder};
use synergy::trace::{generate, Split, TraceConfig};
use synergy::util::cli::Args;
use synergy::util::fsx;
use synergy::workload::{
    AlibabaTraceConfig, AlibabaTraceSource, GoogleTraceConfig,
    GoogleTraceSource, PhillyTraceConfig, PhillyTraceSource,
    SyntheticSource, TenantQuotas, TenantSpec, WorkloadSource,
};

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("sim") | Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("compare") => cmd_compare(&args),
        Some("profile") => cmd_profile(&args),
        Some("models") => cmd_models(),
        Some("trace") => cmd_trace(&args),
        Some("leader") => cmd_leader(&args),
        Some("worker") => cmd_worker(&args),
        Some("submit") => cmd_submit(&args),
        Some("config") => cmd_config(&args),
        Some("hetero") => cmd_hetero(&args),
        Some("version") => println!("synergy {}", synergy::VERSION),
        _ => {
            eprintln!("usage: synergy <sim|sweep|compare|profile|models|trace|leader|worker|submit|config|hetero> [--flags]");
            eprintln!("see README.md for the full flag reference");
            std::process::exit(2);
        }
    }
}

fn parse_split(s: &str) -> Split {
    let parts: Vec<u32> = s
        .split(',')
        .map(|p| p.trim().parse().expect("split must be like 20,70,10"))
        .collect();
    assert_eq!(parts.len(), 3, "split must have three components");
    Split::new(parts[0], parts[1], parts[2])
}

fn trace_from_args(args: &Args) -> TraceConfig {
    let load = args.f64("load", 8.0);
    TraceConfig {
        n_jobs: args.usize("jobs", 1000),
        split: parse_split(args.get_or("split", "20,70,10")),
        multi_gpu: args.flag("multi-gpu"),
        jobs_per_hour: if args.flag("static") || load <= 0.0 {
            None
        } else {
            Some(load)
        },
        seed: args.u64("seed", 1),
    }
}

/// `--topology flat|racks:R` (shared by `sim`, `sweep`, `compare`,
/// `hetero`); absent = flat, the byte-identical pre-topology behaviour.
fn topology_from_args(args: &Args) -> TopologySpec {
    match args.get("topology") {
        Some(s) => TopologySpec::parse(s)
            .unwrap_or_else(|e| panic!("--topology: {e}")),
        None => TopologySpec::default(),
    }
}

/// `--faults mtbf:<h>,mttr:<h>[,seed:S]` or `--faults <file.json>`
/// (shared by `sim`, `sweep`, `compare`, `hetero`, and config files);
/// absent = no churn, the byte-identical pre-fault behaviour.
fn faults_from_args(args: &Args) -> Option<FaultSpec> {
    args.get("faults").map(|s| {
        FaultSpec::parse(s).unwrap_or_else(|e| panic!("--faults: {e}"))
    })
}

fn tenant_spec_from_args(args: &Args) -> Option<TenantSpec> {
    args.get("tenants").map(|s| {
        TenantSpec::parse(s).unwrap_or_else(|e| panic!("--tenants: {e}"))
    })
}

/// A fully built workload: jobs + tenant metadata.
struct WorkloadBundle {
    jobs: Vec<Job>,
    quotas: Option<TenantQuotas>,
    tenant_names: Vec<String>,
}

/// Batch form of [`workload_source_from_args`]: drain the source into a
/// job list (simulator & converter paths).
fn workload_from_args(args: &Args) -> WorkloadBundle {
    let (mut source, quotas, tenant_names) = workload_source_from_args(args);
    WorkloadBundle { jobs: source.drain_jobs(), quotas, tenant_names }
}

/// Build the workload *source* from `--trace <path> --format
/// philly|alibaba|google` (file traces; `google` takes a trace
/// directory or an instance-events CSV) or the synthetic generator
/// flags, with optional `--tenants name:weight,...` quotas (see
/// [`synergy::workload`]). Streaming consumers (the deploy leader) take
/// the source as-is; batch consumers use [`workload_from_args`].
#[allow(clippy::type_complexity)]
fn workload_source_from_args(
    args: &Args,
) -> (Box<dyn WorkloadSource>, Option<TenantQuotas>, Vec<String>) {
    let spec = tenant_spec_from_args(args);
    let max_jobs = {
        let n = args.usize("max-jobs", 0);
        (n > 0).then_some(n)
    };
    match args.get("trace") {
        Some(path) => {
            let source: Box<dyn WorkloadSource> =
                match args.get_or("format", "philly") {
                    "philly" => Box::new(
                        PhillyTraceSource::new(PhillyTraceConfig {
                            path: path.to_string(),
                            load_scale: args.f64("load-scale", 1.0),
                            duration_min_s: args.f64("duration-min", 1.0),
                            duration_max_s: args
                                .f64("duration-max", f64::INFINITY),
                            gpu_cap: args.usize("gpu-cap", 16) as u32,
                            max_jobs,
                            split: parse_split(
                                args.get_or("split", "20,70,10"),
                            ),
                            seed: args.u64("seed", 1),
                            keep_failed: args.flag("keep-failed"),
                        })
                        .unwrap_or_else(|e| panic!("--trace {path}: {e}")),
                    ),
                    "alibaba" => Box::new(
                        AlibabaTraceSource::new(AlibabaTraceConfig {
                            path: path.to_string(),
                            load_scale: args.f64("load-scale", 1.0),
                            cpu_heavy_pct: args.f64("cpu-heavy", 60.0),
                            mem_heavy_pct: args.f64("mem-heavy", 60.0),
                            max_jobs,
                            seed: args.u64("seed", 1),
                        })
                        .unwrap_or_else(|e| panic!("--trace {path}: {e}")),
                    ),
                    "google" => Box::new(
                        GoogleTraceSource::new(GoogleTraceConfig {
                            path: path.to_string(),
                            load_scale: args.f64("load-scale", 1.0),
                            cpu_multiplier: args.f64("cpu-multiplier", 8.0),
                            gpu_cap: args.usize("gpu-cap", 16) as u32,
                            max_jobs,
                            split: parse_split(
                                args.get_or("split", "20,70,10"),
                            ),
                            seed: args.u64("seed", 1),
                            keep_failed: args.flag("keep-failed"),
                            duration_min_s: args.f64("duration-min", 1.0),
                            duration_max_s: args
                                .f64("duration-max", f64::INFINITY),
                        })
                        .unwrap_or_else(|e| panic!("--trace {path}: {e}")),
                    ),
                    other => panic!(
                        "unknown --format '{other}' \
                         (expected philly|alibaba|google)"
                    ),
                };
            let tenant_names = source.tenant_names();
            let quotas = spec.map(|s| {
                for name in &s.names {
                    if !tenant_names.contains(name) {
                        eprintln!(
                            "warning: --tenants name '{name}' matches no \
                             tenant in the trace (trace tenants: \
                             {tenant_names:?}); its weight is ignored"
                        );
                    }
                }
                s.quotas_for(&tenant_names)
            });
            (source, quotas, tenant_names)
        }
        None => {
            let cfg = trace_from_args(args);
            match spec {
                Some(s) => {
                    let source =
                        SyntheticSource::new(cfg).with_tenants(s.clone());
                    let tenant_names = source.tenant_names();
                    (Box::new(source), Some(s.quotas()), tenant_names)
                }
                None => (
                    Box::new(SyntheticSource::new(cfg)),
                    None,
                    vec!["default".to_string()],
                ),
            }
        }
    }
}

/// Print the per-tenant JCT table + Jain's fairness index.
fn print_tenant_stats(
    by: &std::collections::BTreeMap<synergy::job::TenantId, synergy::metrics::JctStats>,
    tenant_names: &[String],
) {
    println!("\nper-tenant JCT:");
    println!(
        "{:<16} {:>6} {:>10} {:>10} {:>10}",
        "tenant", "jobs", "avg_jct_h", "p50_jct_h", "p99_jct_h"
    );
    for (t, s) in by {
        let name = tenant_names
            .get(t.0 as usize)
            .cloned()
            .unwrap_or_else(|| format!("t{}", t.0));
        println!(
            "{:<16} {:>6} {:>10.2} {:>10.2} {:>10.2}",
            name,
            s.n,
            s.avg_hrs(),
            s.p50_s / 3600.0,
            s.p99_hrs()
        );
    }
    let avgs: Vec<f64> = by.values().map(|s| s.avg_s).collect();
    println!("jain_fairness(avg_jct) = {:.3}", jains_index(&avgs));
}

fn sim_config(args: &Args, mechanism: &str, policy: &str) -> SimConfig {
    SimConfig {
        spec: ServerSpec {
            gpus: args.usize("gpus-per-server", 8) as u32,
            cpus: args.usize("cpus-per-server", 24) as u32,
            mem_gb: args.f64("mem-per-server", 500.0),
        },
        n_servers: args.usize("servers", 16),
        round_s: args.f64("round", 300.0),
        policy: policy.into(),
        mechanism: mechanism.into(),
        profile_noise: args.f64("noise", 0.0),
        max_sim_s: args.f64("max-sim-days", 400.0) * 86_400.0,
        span_factor: args.usize("span-factor", 1),
        network_penalty: args.f64("network-penalty", 0.0),
        reference_spec: None,
        types: None,
        force_replan: args.flag("force-replan"),
        no_resume: args.flag("no-resume"),
        topology: topology_from_args(args),
        shards: args.usize("shards", 1).max(1),
        faults: faults_from_args(args),
    }
}

fn cmd_simulate(args: &Args) {
    let policy = args.get_or("policy", "fifo").to_string();
    let mechanism = args.get_or("mechanism", "tune").to_string();
    let workload = workload_from_args(args);
    let sim = Simulator::with_quotas(
        sim_config(args, &mechanism, &policy),
        workload.quotas.clone(),
    );
    // Telemetry is strictly opt-in: without --telemetry no recorder
    // exists and the run is byte-for-byte the pre-telemetry one.
    let telemetry_path = args.get("telemetry").map(str::to_string);
    let mut recorder = telemetry_path.as_ref().map(|_| {
        TelemetryRecorder::new(TelemetryConfig {
            timing: args.flag("telemetry-timing"),
        })
    });
    let t0 = std::time::Instant::now();
    let result = sim.run_with_telemetry(workload.jobs, recorder.as_mut());
    if let (Some(path), Some(rec)) = (&telemetry_path, &recorder) {
        fsx::write_or_exit(path, &rec.render_for_path(path), "telemetry");
        eprintln!(
            "telemetry: {} rounds, {} plan events -> {path}",
            rec.n_rounds(),
            rec.n_plan_events()
        );
    }
    if args.flag("json") {
        // Canonical metrics document; plan stats are opt-in and fault
        // stats appear exactly when --faults is given, so the default
        // payload matches the golden scenario shape exactly.
        println!(
            "{}",
            result.metrics_json(
                args.flag("plan-stats"),
                args.get("faults").is_some(),
            )
        );
        return;
    }
    let stats = result.jct_stats();
    println!(
        "policy={policy} mechanism={mechanism} jobs={} rounds={} \
         planned={} resumed={} wall={:?}",
        stats.n,
        result.rounds,
        result.planned_rounds,
        result.resumed_rounds,
        t0.elapsed()
    );
    println!(
        "avg_jct={:.2}h p50={:.2}h p95={:.2}h p99={:.2}h makespan={:.2}h",
        stats.avg_hrs(),
        stats.p50_s / 3600.0,
        stats.p95_s / 3600.0,
        stats.p99_hrs(),
        result.makespan_s / 3600.0
    );
    println!(
        "mean_gpu_util={:.1}% mean_cpu_util={:.1}% profiling={:.0}min",
        result.utilization.mean_gpu_util() * 100.0,
        result.utilization.mean_cpu_util() * 100.0,
        result.profiling_minutes
    );
    if workload.tenant_names.len() > 1 || workload.quotas.is_some() {
        print_tenant_stats(&result.tenant_stats(), &workload.tenant_names);
    }
}

/// `synergy sweep` — deterministic parallel scenario-grid driver.
///
/// Runs the {policies} × {mechanisms} grid over one shared workload
/// (synthetic flags or `--trace`/`--format`, with optional `--tenants`
/// quotas), one independent `Simulator` per cell, fanned out over
/// `--threads` OS threads (`std::thread::scope`, no work queue beyond an
/// atomic cell counter). Each cell is a deterministic simulation and the
/// report is assembled in fixed grid order after every worker joins, so
/// the output is **byte-identical for any thread count** — `--threads 1`
/// is the serial reference CI diffs the parallel run against. Timing is
/// deliberately excluded from the report (it would break byte parity);
/// `--plan-stats` appends the planning split per cell.
fn cmd_sweep(args: &Args) {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    let policies: Vec<String> = args
        .get_or("policies", "fifo,srtf")
        .split(',')
        .map(str::to_string)
        .collect();
    let mechanisms: Vec<String> = args
        .get_or("mechanisms", "proportional,tune")
        .split(',')
        .map(str::to_string)
        .collect();
    let workload = workload_from_args(args);
    let plan_stats = args.flag("plan-stats");
    let fault_stats = args.get("faults").is_some();
    // Per-cell telemetry profiles: each cell records independently, so
    // the files — like the report — are byte-identical for any thread
    // count (counters only; --telemetry-timing adds wall-clock, which
    // CI never diffs).
    let telemetry_dir = args.get("telemetry-dir").map(str::to_string);
    let telemetry_timing = args.flag("telemetry-timing");

    struct CellSpec {
        policy: String,
        mechanism: String,
    }
    let cells: Vec<CellSpec> = policies
        .iter()
        .flat_map(|p| {
            mechanisms.iter().map(move |m| CellSpec {
                policy: p.clone(),
                mechanism: m.clone(),
            })
        })
        .collect();
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = args.usize("threads", default_threads).max(1).min(cells.len().max(1));

    let t0 = std::time::Instant::now();
    let next = AtomicUsize::new(0);
    // Per cell: (metrics line, rendered telemetry profile if requested).
    let results: Vec<Mutex<Option<(String, Option<String>)>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let cell = &cells[i];
                let sim = Simulator::with_quotas(
                    sim_config(args, &cell.mechanism, &cell.policy),
                    workload.quotas.clone(),
                );
                let mut recorder = telemetry_dir.as_ref().map(|_| {
                    TelemetryRecorder::new(TelemetryConfig {
                        timing: telemetry_timing,
                    })
                });
                let r = sim.run_with_telemetry(
                    workload.jobs.clone(),
                    recorder.as_mut(),
                );
                *results[i].lock().unwrap() = Some((
                    r.metrics_json(plan_stats, fault_stats),
                    recorder.map(|rec| rec.to_jsonl()),
                ));
            });
        }
    });

    // Fixed grid order, no timing inside the report: byte-identical to a
    // serial run regardless of completion order. All workers have
    // joined, so the slots unwrap without locking.
    let mut report = String::new();
    report.push_str(&format!("sweep cells={}\n", cells.len()));
    for (cell, slot) in cells.iter().zip(results) {
        let (metrics, telemetry) = slot
            .into_inner()
            .unwrap()
            .expect("every sweep cell produces a result");
        report.push_str(&format!(
            "cell policy={} mechanism={} {metrics}\n",
            cell.policy, cell.mechanism
        ));
        if let (Some(dir), Some(profile)) = (&telemetry_dir, telemetry) {
            // Fixed cell order + deterministic recorder contents: the
            // per-cell files are diffable across thread counts.
            let path =
                format!("{dir}/{}_{}.jsonl", cell.policy, cell.mechanism);
            fsx::write_or_exit(&path, &profile, "sweep telemetry");
        }
    }
    match args.get("out") {
        Some(path) => {
            fsx::write_or_exit(path, &report, "sweep report");
            eprintln!(
                "wrote {} cells to {path} ({} threads, {:?})",
                cells.len(),
                threads,
                t0.elapsed()
            );
        }
        None => {
            print!("{report}");
            eprintln!("({} threads, {:?})", threads, t0.elapsed());
        }
    }
}

fn cmd_compare(args: &Args) {
    let policies: Vec<String> = args
        .get_or("policies", "fifo,srtf,las,ftf")
        .split(',')
        .map(str::to_string)
        .collect();
    let mechanisms: Vec<String> = args
        .get_or("mechanisms", "proportional,tune")
        .split(',')
        .map(str::to_string)
        .collect();
    let trace_cfg = trace_from_args(args);
    let jobs = generate(&trace_cfg);
    println!(
        "{:<8} {:<14} {:>10} {:>10} {:>10}",
        "policy", "mechanism", "avg_jct_h", "p99_jct_h", "makespan_h"
    );
    for p in &policies {
        for m in &mechanisms {
            let sim = Simulator::new(sim_config(args, m, p));
            let r = sim.run(jobs.clone());
            let s = r.jct_stats();
            println!(
                "{:<8} {:<14} {:>10.2} {:>10.2} {:>10.2}",
                p,
                m,
                s.avg_hrs(),
                s.p99_hrs(),
                r.makespan_s / 3600.0
            );
        }
    }
}

fn cmd_profile(args: &Args) {
    let model = ModelKind::from_name(args.get_or("model", "resnet18"))
        .expect("unknown model; run `synergy models`");
    let gpus = args.usize("gpus", 1) as u32;
    let spec = ServerSpec::default();
    let profiler = OptimisticProfiler::new(spec);
    let job = Job::new(JobId(0), model, gpus, 0.0, 3600.0);
    let out = profiler.profile(&job);
    println!(
        "model={} gpus={gpus} empirical_points={} cost={:.0}min",
        model.name(),
        out.empirical_points,
        out.cost_minutes
    );
    let matrix = out.primary();
    let d = matrix.best_demand();
    println!(
        "best_demand: cpus={} mem={}GB  (proportional: cpus={} mem={}GB)",
        d.cpus, d.mem_gb, matrix.prop_cpus, matrix.prop_mem_gb
    );
    println!(
        "throughput: best={:.0} prop={:.0} samples/s",
        matrix.max_throughput(),
        matrix.proportional_throughput()
    );
    // CPU sensitivity curve at full memory (the Fig-2 row).
    let full_mem = *matrix.mem_points.last().unwrap();
    print!("cpu curve @ full mem:");
    for &c in &matrix.cpu_points {
        print!(" {:.0}", matrix.throughput_at(c, full_mem));
    }
    println!();
}

fn cmd_models() {
    let world = PerfModel::new(ServerSpec::default());
    println!(
        "{:<16} {:<9} {:>9} {:>10} {:>11} {:>11} {:>12}",
        "model", "task", "cpu_knee", "gpu_tput", "dataset_gb", "prop_tput", "max_tput(1g)"
    );
    for m in ALL_MODELS {
        let co = m.coeffs();
        println!(
            "{:<16} {:<9} {:>9.1} {:>10.0} {:>11.0} {:>11.0} {:>12.0}",
            m.name(),
            format!("{:?}", m.task()).to_lowercase(),
            co.cpu_knee(),
            co.gpu_tput,
            co.dataset_gb,
            world.proportional_throughput(m, 1),
            world.max_throughput(m, 1),
        );
    }
}

/// Heterogeneous-cluster simulation (paper Appendix A.2).
///
/// `synergy hetero --mechanism het-tune --policy srtf --machines 8 \
///     --jobs 500 --load 6 --split 30,50,20 [--multi-gpu]
///     [--types k80:4,p100:8,v100:8] [--topology racks:2]
///     [--trace x.csv --format philly|alibaba] [--tenants a:2,b:1]
///     [--json [--plan-stats]]`
///
/// Builds a mixed-generation fleet — `--types gen:count,...` for an
/// arbitrary mix, or the default two-generation split (`--machines`
/// P100 servers + `--machines` V100 servers) — and runs the workload
/// through the one engine behind `synergy sim`: `hetero` is a fleet
/// description, not a second code path. Trace files and tenant quotas
/// work exactly as in `synergy sim`.
fn cmd_hetero(args: &Args) {
    use synergy::hetero::{GpuGen, HeteroSimConfig, HeteroSimulator, TypeSpec};
    let spec = ServerSpec {
        gpus: args.usize("gpus-per-server", 8) as u32,
        cpus: args.usize("cpus-per-server", 24) as u32,
        mem_gb: args.f64("mem-per-server", 500.0),
    };
    let machines = args.usize("machines", 8);
    let types: Vec<TypeSpec> = match args.get("types") {
        Some(s) => s
            .split(',')
            .map(|part| {
                let (name, count) = part
                    .split_once(':')
                    .unwrap_or_else(|| panic!("--types: '{part}' is not gen:count"));
                let machines: usize = count
                    .trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--types: bad count '{count}'"));
                assert!(
                    machines > 0,
                    "--types: machine count must be positive in '{part}'"
                );
                TypeSpec {
                    gen: GpuGen::by_name(name.trim()).unwrap_or_else(|| {
                        panic!("--types: unknown generation '{name}'")
                    }),
                    spec,
                    machines,
                }
            })
            .collect(),
        None => vec![
            TypeSpec { gen: GpuGen::P100, spec, machines },
            TypeSpec { gen: GpuGen::V100, spec, machines },
        ],
    };
    let mechanism = args.get_or("mechanism", "het-tune").to_string();
    let policy = args.get_or("policy", "srtf").to_string();
    let workload = workload_from_args(args);
    let sim = HeteroSimulator::with_quotas(
        HeteroSimConfig {
            types,
            round_s: args.f64("round", 300.0),
            policy,
            mechanism: mechanism.clone(),
            profile_noise: args.f64("noise", 0.0),
            max_sim_s: args.f64("max-sim-days", 400.0) * 86_400.0,
            topology: topology_from_args(args),
            faults: faults_from_args(args),
        },
        workload.quotas.clone(),
    );
    let t0 = std::time::Instant::now();
    let r = sim.run(workload.jobs);
    if args.flag("json") {
        // Same canonical payload as `synergy sim --json` (plan stats
        // opt-in via --plan-stats, fault stats on exactly when --faults
        // is given — exactly like the homogeneous path).
        println!(
            "{}",
            r.metrics_json(
                args.flag("plan-stats"),
                args.get("faults").is_some(),
            )
        );
        return;
    }
    let s = r.jct_stats();
    println!(
        "{mechanism}: jobs={} avg_jct={:.2}h p99={:.2}h makespan={:.2}h \
         rounds={} profiling={:.0}min wall={:.1}s",
        r.jcts.len(),
        s.avg_hrs(),
        s.p99_hrs(),
        r.makespan_s / 3600.0,
        r.rounds,
        r.profiling_minutes,
        t0.elapsed().as_secs_f64()
    );
    if workload.tenant_names.len() > 1 || workload.quotas.is_some() {
        print_tenant_stats(&r.tenant_stats(), &workload.tenant_names);
    }
}

fn cmd_trace(args: &Args) {
    use synergy::util::json::Json;
    // Works for synthetic *and* file workloads, so this doubles as a
    // trace converter: `synergy trace --trace x.csv --format alibaba`.
    let workload = workload_from_args(args);
    let arr: Vec<Json> = workload
        .jobs
        .iter()
        .map(|j| {
            Json::obj(vec![
                ("id", Json::num(j.id.0 as f64)),
                ("tenant", Json::num(j.tenant.0 as f64)),
                ("model", Json::str(j.model.name())),
                ("gpus", Json::num(j.gpus as f64)),
                ("arrival_s", Json::num(j.arrival_s)),
                ("duration_s", Json::num(j.duration_prop_s)),
            ])
        })
        .collect();
    let doc = Json::arr(arr).encode();
    match args.get("out") {
        Some(path) => {
            fsx::write_or_exit(path, &doc, "trace");
            println!("wrote {} jobs to {path}", workload.jobs.len());
        }
        None => println!("{doc}"),
    }
}

fn cmd_leader(args: &Args) {
    // Streaming arrival path: the leader pulls jobs from the source as
    // their (scaled) arrival times pass — the trace is never
    // materialised up front.
    let (source, quotas, tenant_names) = workload_source_from_args(args);
    let cfg = LeaderConfig {
        bind: format!("0.0.0.0:{}", args.usize("port", 7331)),
        n_workers: args.usize("workers", 1),
        round_real_s: args.f64("round-real", 2.0),
        time_scale: args.f64("time-scale", 600.0),
        policy: args.get_or("policy", "srtf").into(),
        mechanism: args.get_or("mechanism", "tune").into(),
        variant: args.get_or("variant", "tiny").into(),
        max_real_s: args.f64("max-real", 600.0),
        quotas,
        telemetry: args.get("telemetry").map(str::to_string),
        telemetry_timing: args.flag("telemetry-timing"),
        journal_dir: args.get("journal").map(str::to_string),
        recover: args.flag("recover"),
        report_path: args.get("report").map(str::to_string),
        expect_jobs: args.usize("expect-jobs", 0),
        heartbeat_s: args.f64("heartbeat", 0.0),
        port_file: args.get("port-file").map(str::to_string),
    };
    let leader = Leader::new(cfg);
    match leader.run_stream(source) {
        Ok(report) => {
            let s = report.jct_stats();
            println!(
                "deploy done: jobs={} rounds={} steps={} avg_jct={:.2}h p99={:.2}h",
                s.n,
                report.rounds,
                report.total_steps,
                s.avg_hrs(),
                s.p99_hrs()
            );
            if tenant_names.len() > 1 {
                for (t, ts) in report.tenant_stats() {
                    let name = tenant_names
                        .get(t.0 as usize)
                        .cloned()
                        .unwrap_or_else(|| format!("t{}", t.0));
                    println!(
                        "tenant {:<16} jobs={} avg_jct={:.2}h",
                        name,
                        ts.n,
                        ts.avg_hrs()
                    );
                }
            }
        }
        Err(e) => {
            eprintln!("leader failed: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_worker(args: &Args) {
    let cfg = WorkerConfig {
        leader_addr: args.get_or("leader", "127.0.0.1:7331").into(),
        artifacts_dir: args.get_or("artifacts", "artifacts").into(),
        gpus: args.usize("gpus", 8) as u32,
        cpus: args.usize("cpus", 24) as u32,
        mem_gb: args.f64("mem", 500.0),
        gen: args.get_or("gen", "v100").into(),
        real_compute: !args.flag("no-compute"),
        fail_after_s: {
            let t = args.f64("fail-after", 0.0);
            (t > 0.0).then_some(t)
        },
    };
    match Worker::run(cfg) {
        Ok(n) => println!("worker done; ran {n} jobs"),
        Err(e) => {
            eprintln!("worker failed: {e}");
            std::process::exit(1);
        }
    }
}

/// Network job-submission client: one `Submit` (idempotent by
/// `--id` — re-running the same command is acked as a duplicate, never
/// double-admitted) or one `QueryStatus` (`--status`), then print the
/// leader's reply.
fn cmd_submit(args: &Args) {
    use synergy::deploy::proto::Conn;
    use synergy::deploy::Message;
    let addr = args.get_or("leader", "127.0.0.1:7331");
    let stream = std::net::TcpStream::connect(addr)
        .unwrap_or_else(|e| panic!("connect {addr}: {e}"));
    let mut conn = Conn::new(stream).expect("clone stream");
    conn.set_read_timeout(Some(std::time::Duration::from_secs(30)))
        .expect("set timeout");
    let req = if args.flag("status") {
        Message::QueryStatus
    } else {
        Message::Submit {
            job_id: args.u64("id", u64::MAX),
            tenant: args.get_or("tenant", "default").into(),
            model: args
                .get("model")
                .expect("--model <name> required (see `synergy models`)")
                .into(),
            gpus: args.usize("gpus", 1) as u32,
            arrival_s: args.f64("arrival", 0.0),
            duration_s: args.f64("duration", 0.0),
        }
    };
    if let Message::Submit { job_id, duration_s, .. } = &req {
        assert!(*job_id != u64::MAX, "--id <job id> required");
        assert!(*duration_s > 0.0, "--duration <seconds> required");
    }
    conn.send(&req).expect("send");
    match conn.recv() {
        Ok(Some(Message::SubmitAck { job_id, duplicate })) => {
            println!(
                "accepted job {job_id}{}",
                if duplicate { " (duplicate: already admitted)" } else { "" }
            );
        }
        Ok(Some(Message::Status { submitted, finished, rounds, recoveries })) => {
            println!(
                "submitted={submitted} finished={finished} rounds={rounds} \
                 recoveries={recoveries}"
            );
        }
        Ok(Some(Message::Error { reason })) => {
            eprintln!("rejected: {reason}");
            std::process::exit(1);
        }
        other => {
            eprintln!("unexpected reply: {other:?}");
            std::process::exit(1);
        }
    }
}

fn cmd_config(args: &Args) {
    let path = args.get("file").expect("--file <config.json> required");
    let cfg = ExperimentConfig::from_file(path).expect("bad config");
    println!("running experiment '{}'", cfg.name);
    // Config files reach the same workload readers as the CLI flags:
    // `trace`/`format` select a file source, `tenants` turns on quotas.
    let (jobs, quotas, tenant_names) =
        cfg.workload().expect("bad workload in config");
    // A `hetero` section turns the same engine into a mixed fleet.
    let sim = Simulator::with_quotas(
        SimConfig {
            spec: cfg.spec,
            n_servers: cfg.n_servers,
            round_s: cfg.round_s,
            policy: cfg.policy.clone(),
            mechanism: cfg.mechanism.clone(),
            profile_noise: cfg.profile_noise,
            max_sim_s: 400.0 * 86_400.0,
            span_factor: 1,
            network_penalty: 0.0,
            reference_spec: None,
            types: cfg.types(),
            force_replan: false,
            no_resume: false,
            topology: cfg.topology,
            shards: cfg.shards,
            faults: cfg.faults.as_deref().map(|f| {
                FaultSpec::parse(f).expect("validated at config load")
            }),
        },
        quotas.clone(),
    );
    let r = sim.run(jobs);
    let s = r.jct_stats();
    println!(
        "{}: avg_jct={:.2}h p99={:.2}h makespan={:.2}h rounds={}",
        cfg.name,
        s.avg_hrs(),
        s.p99_hrs(),
        r.makespan_s / 3600.0,
        r.rounds
    );
    if tenant_names.len() > 1 || quotas.is_some() {
        print_tenant_stats(&r.tenant_stats(), &tenant_names);
    }
}
