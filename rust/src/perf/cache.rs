//! MinIO cache model (paper §3.1, reference [41]).
//!
//! MinIO is a DNN-aware, application-level cache with two properties the
//! paper leans on:
//!
//! 1. **Fixed per-epoch hit rate**: MinIO caches a fixed subset of the
//!    dataset and never evicts during an epoch, so exactly
//!    `cached_fraction` of accesses hit, every epoch, regardless of access
//!    order. This is what makes job throughput *predictable* in the memory
//!    dimension and enables optimistic profiling.
//! 2. **Isolation**: each job's cache is carved out of its own memory
//!    allocation; co-located jobs cannot thrash each other (unlike the OS
//!    page cache).

/// MinIO cache state for one job: dataset size vs cache capacity.
#[derive(Debug, Clone, Copy)]
pub struct MinIoCache {
    pub dataset_gb: f64,
    pub cache_gb: f64,
}

impl MinIoCache {
    /// `cache_gb` is clamped at 0 (callers may pass mem-minus-working-set).
    pub fn new(dataset_gb: f64, cache_gb: f64) -> MinIoCache {
        assert!(dataset_gb > 0.0, "empty dataset");
        MinIoCache { dataset_gb, cache_gb: cache_gb.max(0.0) }
    }

    /// Fraction of the dataset resident in cache, in [0, 1].
    pub fn cached_fraction(&self) -> f64 {
        (self.cache_gb / self.dataset_gb).min(1.0)
    }

    /// Per-epoch miss fraction (MinIO property 1).
    pub fn miss_fraction(&self) -> f64 {
        1.0 - self.cached_fraction()
    }

    /// Bytes fetched from storage per epoch, GB.
    pub fn fetch_gb_per_epoch(&self) -> f64 {
        self.miss_fraction() * self.dataset_gb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_cached_never_misses() {
        let c = MinIoCache::new(100.0, 100.0);
        assert_eq!(c.miss_fraction(), 0.0);
        let c2 = MinIoCache::new(100.0, 250.0);
        assert_eq!(c2.miss_fraction(), 0.0);
    }

    #[test]
    fn zero_cache_always_misses() {
        let c = MinIoCache::new(100.0, 0.0);
        assert_eq!(c.miss_fraction(), 1.0);
        assert_eq!(c.fetch_gb_per_epoch(), 100.0);
    }

    #[test]
    fn partial_cache_is_linear() {
        let c = MinIoCache::new(200.0, 50.0);
        assert_eq!(c.cached_fraction(), 0.25);
        assert_eq!(c.miss_fraction(), 0.75);
        assert_eq!(c.fetch_gb_per_epoch(), 150.0);
    }

    #[test]
    fn negative_cache_clamps_to_zero() {
        let c = MinIoCache::new(100.0, -5.0);
        assert_eq!(c.cache_gb, 0.0);
        assert_eq!(c.miss_fraction(), 1.0);
    }
}
