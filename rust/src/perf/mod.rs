//! Ground-truth performance model: what a job's throughput *actually* is
//! for a given (GPU, CPU, memory) allocation.
//!
//! This module plays the role of the physical hardware in the paper's
//! experiments. The DNN input pipeline is modeled as three overlapped
//! stages (the standard data-stall model of [41, 42]):
//!
//! ```text
//!   storage --fetch--> DRAM cache --preprocess(CPU)--> GPU compute
//! ```
//!
//! In steady state the pipeline runs at the rate of its slowest stage:
//!
//! ```text
//!   tput(g, c, m) = min( g * gpu_tput,             -- GPU stage
//!                        c * cpu_prep_rate,        -- CPU stage
//!                        fetch_rate(g, m) )        -- storage stage
//! ```
//!
//! The storage stage uses the MinIO cache model ([`cache`]): with `m` GB of
//! cache over a `dataset_gb` dataset, a fixed fraction `1 - m/dataset` of
//! accesses per epoch miss and must be fetched at the per-GPU storage
//! bandwidth (MinIO guarantees exactly this hit rate; paper §3.1).
//!
//! There is exactly one ground-truth model for every machine type: a
//! [`PerfModel`] carries the server shape *and* the GPU generation
//! ([`crate::cluster::GpuGen`], paper A.2.1), and only the GPU stage is
//! scaled by the generation factor — CPU pre-processing and storage
//! fetch are host-side and do not change with GPU generation. The V100
//! basis scales by exactly 1, so [`PerfModel::new`] reproduces the
//! paper's homogeneous testbed bit-for-bit; a mixed fleet simply holds
//! one `PerfModel` per generation present (`W_ij`, A.2.1).
//!
//! The calibration tests at the bottom pin the module to the published
//! Fig-2 facts (knees, speedups) — see `job/zoo.rs`.

pub mod cache;

use crate::cluster::{GpuGen, ServerSpec};
use crate::job::{ModelKind, PerfCoeffs};
use cache::MinIoCache;

/// Per-GPU storage bandwidth, MB/s. Models each GPU worker's fair share of
/// the shared storage path (remote store / disks), the regime in which the
/// data-stall studies [41, 62] operate.
pub const STORAGE_BW_MB_PER_GPU: f64 = 25.0;

/// Charge the rack-topology link cost on a gang's round rate: a gang
/// spanning `racks_spanned` racks runs at
/// `rate / (1 + link_cost × (racks_spanned − 1))` — each rack boundary
/// adds one `link_cost` of interconnect contention on top of the
/// per-server network penalty the engine already charges (the Philly
/// analysis' locality effect, arXiv:1901.05758).
///
/// Single-rack gangs return `rate` unchanged — an early return, not a
/// division by 1.0 — so flat-topology schedules stay bit-identical to
/// pre-topology ones (golden-pinned).
pub fn link_adjusted_rate(rate: f64, racks_spanned: u32, link_cost: f64) -> f64 {
    if racks_spanned <= 1 || link_cost == 0.0 {
        return rate;
    }
    rate / (1.0 + link_cost * (racks_spanned - 1) as f64)
}

/// The ground-truth world model handed to simulators and the profiler:
/// one per machine type (server shape × GPU generation).
#[derive(Debug, Clone, Copy)]
pub struct PerfModel {
    pub spec: ServerSpec,
    /// GPU generation of this machine type; scales the GPU stage only.
    pub gen: GpuGen,
}

impl PerfModel {
    /// Ground truth for the V100 calibration basis (scale exactly 1 —
    /// the paper's homogeneous testbed).
    pub fn new(spec: ServerSpec) -> PerfModel {
        PerfModel { spec, gen: GpuGen::default() }
    }

    /// Ground truth for an explicit machine type (`W_ij`, paper A.2.1).
    pub fn with_gen(spec: ServerSpec, gen: GpuGen) -> PerfModel {
        PerfModel { spec, gen }
    }

    /// Steady-state training throughput in samples/second for `model`
    /// running on `gpus` GPUs of this generation with `cpus` cores and
    /// `mem_gb` GB of cache:
    /// `min(scale_i · g · gpu_tput, c · prep_rate, fetch_rate)`.
    ///
    /// Memory below the model's working-set floor pins throughput to ~0
    /// (the job thrashes); the scheduler never allocates below the floor
    /// because the sensitivity matrix reports it as useless.
    pub fn throughput(
        &self,
        model: ModelKind,
        gpus: u32,
        cpus: f64,
        mem_gb: f64,
    ) -> f64 {
        let co = model.coeffs();
        if mem_gb < co.min_mem_gb {
            return 0.0;
        }
        let scale = self.gen.compute_scale(model.task());
        let gpu_rate = gpus as f64 * co.gpu_tput * scale;
        let cpu_rate = cpus * co.cpu_prep_rate;
        let fetch_rate = self.fetch_rate(&co, gpus, mem_gb);
        gpu_rate.min(cpu_rate).min(fetch_rate)
    }

    /// Storage-stage rate: misses-per-sample × sample size must flow
    /// through the job's aggregate storage bandwidth.
    fn fetch_rate(&self, co: &PerfCoeffs, gpus: u32, mem_gb: f64) -> f64 {
        let cache = MinIoCache::new(co.dataset_gb, mem_gb - co.min_mem_gb);
        let miss = cache.miss_fraction();
        if miss <= 0.0 {
            return f64::INFINITY;
        }
        let bw_kb = STORAGE_BW_MB_PER_GPU * 1024.0 * gpus as f64;
        bw_kb / (miss * co.sample_kb)
    }

    /// Per-epoch time in seconds (dataset pass at the steady-state rate).
    /// This is what Fig 2 plots.
    pub fn epoch_time_s(
        &self,
        model: ModelKind,
        gpus: u32,
        cpus: f64,
        mem_gb: f64,
        samples_per_epoch: f64,
    ) -> f64 {
        let t = self.throughput(model, gpus, cpus, mem_gb);
        if t <= 0.0 {
            f64::INFINITY
        } else {
            samples_per_epoch / t
        }
    }

    /// Throughput under GPU-proportional allocation — the fairness floor
    /// W[C_g, M_g] (paper §4.1).
    pub fn proportional_throughput(&self, model: ModelKind, gpus: u32) -> f64 {
        let c = self.spec.cpus as f64 / self.spec.gpus as f64 * gpus as f64;
        let m = self.spec.mem_gb / self.spec.gpus as f64 * gpus as f64;
        self.throughput(model, gpus, c, m)
    }

    /// Max achievable throughput for the job if granted an entire
    /// server-span worth of CPU/memory.
    pub fn max_throughput(&self, model: ModelKind, gpus: u32) -> f64 {
        let span = (gpus as f64 / self.spec.gpus as f64).ceil().max(1.0);
        self.throughput(
            model,
            gpus,
            self.spec.cpus as f64 * span,
            self.spec.mem_gb * span,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::ModelKind::*;

    fn world() -> PerfModel {
        PerfModel::new(ServerSpec::default())
    }

    /// Fully-cached throughput at c CPUs (the Fig-2a setting).
    fn cached_tput(m: ModelKind, c: f64) -> f64 {
        world().throughput(m, 1, c, 1000.0)
    }

    #[test]
    fn calibration_alexnet_3_to_12_cpus_is_3_1x() {
        let s = cached_tput(AlexNet, 12.0) / cached_tput(AlexNet, 3.0);
        assert!((s - 3.1).abs() < 0.1, "speedup={s}");
    }

    #[test]
    fn calibration_resnet18_3_to_9_cpus_is_2_3x() {
        let s = cached_tput(ResNet18, 9.0) / cached_tput(ResNet18, 3.0);
        assert!((s - 2.3).abs() < 0.1, "speedup={s}");
    }

    #[test]
    fn calibration_shufflenet_needs_more_than_12_cores() {
        assert!(cached_tput(ShuffleNetV2, 13.0) > cached_tput(ShuffleNetV2, 12.0));
    }

    #[test]
    fn calibration_language_models_flat_beyond_1_cpu() {
        for m in [Gnmt, Lstm, TransformerXl] {
            let t1 = cached_tput(m, 1.0);
            let t12 = cached_tput(m, 12.0);
            assert!((t12 - t1) / t1 < 0.01, "{m:?} not CPU-insensitive");
        }
    }

    #[test]
    fn calibration_resnet18_memory_2x() {
        // §2.1: ResNet18 (OpenImages) with memory swept from the 62.5 GB
        // GPU-proportional share to the 500 GB server max speeds up ~2x.
        let w = world();
        let lo = w.throughput(ResNet18, 1, 3.0, 62.5);
        let hi = w.throughput(ResNet18, 1, 3.0, 500.0);
        let s = hi / lo;
        assert!((1.7..2.4).contains(&s), "memory speedup={s}");
    }

    #[test]
    fn calibration_gnmt_memory_insensitive_at_20gb() {
        let w = world();
        let lo = w.throughput(Gnmt, 1, 3.0, 20.0);
        let hi = w.throughput(Gnmt, 1, 3.0, 500.0);
        assert!((hi - lo).abs() / hi < 1e-9, "GNMT should be flat: {lo} vs {hi}");
    }

    #[test]
    fn below_working_set_is_zero() {
        assert_eq!(world().throughput(Gnmt, 1, 3.0, 10.0), 0.0);
    }

    #[test]
    fn throughput_monotone_in_all_dims() {
        let w = world();
        for m in crate::job::ALL_MODELS {
            let base = w.throughput(m, 1, 3.0, 62.5);
            assert!(w.throughput(m, 2, 6.0, 125.0) >= base * 1.99);
            assert!(w.throughput(m, 1, 6.0, 62.5) >= base);
            assert!(w.throughput(m, 1, 3.0, 125.0) >= base);
        }
    }

    #[test]
    fn proportional_floor_below_max() {
        let w = world();
        for m in crate::job::ALL_MODELS {
            let prop = w.proportional_throughput(m, 1);
            let max = w.max_throughput(m, 1);
            assert!(prop > 0.0, "{m:?}");
            assert!(max >= prop, "{m:?}: prop={prop} max={max}");
        }
    }

    #[test]
    fn epoch_time_is_inverse_throughput() {
        let w = world();
        let t = w.throughput(ResNet50, 1, 3.0, 62.5);
        let e = w.epoch_time_s(ResNet50, 1, 3.0, 62.5, t * 60.0);
        assert!((e - 60.0).abs() < 1e-9);
    }

    fn model_on(gen: GpuGen) -> PerfModel {
        PerfModel::with_gen(ServerSpec::default(), gen)
    }

    #[test]
    fn v100_scale_is_exactly_the_homogeneous_ground_truth() {
        // The one-type special case must be bit-for-bit the calibration
        // basis: with_gen(V100) and new() agree everywhere.
        let het = model_on(GpuGen::V100);
        let hom = world();
        for m in crate::job::ALL_MODELS {
            for (c, mem) in [(3.0, 62.5), (12.0, 500.0), (1.0, 30.0)] {
                assert_eq!(
                    het.throughput(m, 1, c, mem),
                    hom.throughput(m, 1, c, mem),
                    "{m:?} at ({c}, {mem})"
                );
            }
        }
    }

    #[test]
    fn faster_generation_never_slower() {
        for m in crate::job::ALL_MODELS {
            for (c, mem) in [(3.0, 62.5), (24.0, 500.0)] {
                let k80 = model_on(GpuGen::K80).throughput(m, 1, c, mem);
                let v100 = model_on(GpuGen::V100).throughput(m, 1, c, mem);
                let a100 = model_on(GpuGen::A100).throughput(m, 1, c, mem);
                assert!(k80 <= v100 && v100 <= a100, "{m:?} ({c},{mem})");
            }
        }
    }

    #[test]
    fn input_bound_jobs_gain_little_from_faster_gpus() {
        // ShuffleNet at 3 CPUs is CPU-bound: generation barely matters.
        let lo = model_on(GpuGen::K80).throughput(ShuffleNetV2, 1, 3.0, 500.0);
        let hi = model_on(GpuGen::A100).throughput(ShuffleNetV2, 1, 3.0, 500.0);
        assert!(
            hi / lo < 1.05,
            "input-bound job should not scale with GPU gen: {lo} -> {hi}"
        );
        // ...while a compute-bound language model scales with generation.
        let lo = model_on(GpuGen::K80).throughput(Gnmt, 1, 3.0, 62.5);
        let hi = model_on(GpuGen::A100).throughput(Gnmt, 1, 3.0, 62.5);
        assert!(hi / lo > 5.0, "compute-bound job must scale: {lo} -> {hi}");
    }

    #[test]
    fn below_working_set_is_zero_on_all_gens() {
        for gen in crate::cluster::ALL_GENS {
            assert_eq!(model_on(gen).throughput(Gnmt, 1, 3.0, 10.0), 0.0);
        }
    }

    #[test]
    fn link_cost_charges_per_rack_boundary_and_is_identity_at_one() {
        let rate = 123.456789;
        // Bit-exact identity for single-rack gangs and zero link cost —
        // the flat-topology byte-identity invariant rests on this.
        assert_eq!(link_adjusted_rate(rate, 0, 0.15).to_bits(), rate.to_bits());
        assert_eq!(link_adjusted_rate(rate, 1, 0.15).to_bits(), rate.to_bits());
        assert_eq!(link_adjusted_rate(rate, 4, 0.0).to_bits(), rate.to_bits());
        // Each additional rack adds one link_cost to the divisor.
        assert!((link_adjusted_rate(100.0, 2, 0.25) - 80.0).abs() < 1e-9);
        assert!((link_adjusted_rate(100.0, 3, 0.25) - 100.0 / 1.5).abs() < 1e-9);
        assert!(link_adjusted_rate(100.0, 3, 0.25) < link_adjusted_rate(100.0, 2, 0.25));
    }

    #[test]
    fn speech_models_are_fetch_bound_at_proportional_share() {
        // M5's large dataset makes fetch the bottleneck at 62.5 GB.
        let w = world();
        let co = M5.coeffs();
        let prop = w.proportional_throughput(M5, 1);
        assert!(prop < co.gpu_tput * 0.2, "M5 prop tput too high: {prop}");
        // ...and memory relieves it substantially.
        let hi = w.throughput(M5, 1, 3.0, 500.0);
        assert!(hi / prop > 2.0);
    }
}
