//! Cluster metrics: JCT statistics, makespan, utilization timeseries
//! (everything the paper's evaluation section reports), plus per-tenant
//! JCT/fairness accounting for the multi-tenant workloads.

use crate::job::TenantId;
use crate::util::stats::{cdf, mean, percentile};
use std::collections::BTreeMap;

/// JCT summary for a set of finished jobs.
#[derive(Debug, Clone)]
pub struct JctStats {
    pub n: usize,
    pub avg_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl JctStats {
    pub fn from_jcts(jcts: &[f64]) -> JctStats {
        JctStats {
            n: jcts.len(),
            avg_s: mean(jcts),
            p50_s: percentile(jcts, 50.0),
            p95_s: percentile(jcts, 95.0),
            p99_s: percentile(jcts, 99.0),
            max_s: jcts.iter().cloned().fold(0.0, f64::max),
        }
    }

    pub fn avg_hrs(&self) -> f64 {
        self.avg_s / 3600.0
    }

    pub fn p99_hrs(&self) -> f64 {
        self.p99_s / 3600.0
    }
}

/// Short/long split (paper §5.3.1 uses a 4-hour boundary).
pub const SHORT_JOB_BOUNDARY_S: f64 = 4.0 * 3600.0;

/// Split JCTs into (short, long) by the paper's 4-hour boundary on the
/// *baseline duration* of the job.
pub fn split_short_long(
    jcts_and_durations: &[(f64, f64)],
) -> (Vec<f64>, Vec<f64>) {
    let mut short = Vec::new();
    let mut long = Vec::new();
    for &(jct, dur) in jcts_and_durations {
        if dur < SHORT_JOB_BOUNDARY_S {
            short.push(jct);
        } else {
            long.push(jct);
        }
    }
    (short, long)
}

/// One utilization sample (per scheduling round).
#[derive(Debug, Clone, Copy)]
pub struct UtilSample {
    pub time_s: f64,
    pub gpu_util: f64,
    /// CPU *allocation* fraction (cores granted to jobs).
    pub cpu_util: f64,
    /// CPU *usage* fraction: cores actively pre-processing, i.e.
    /// Σ_j progress_rate / prep_rate. This is the quantity Fig 10b plots —
    /// proportional allocation grants cores that stalled jobs cannot use.
    pub cpu_used: f64,
    pub mem_util: f64,
    pub queued_jobs: usize,
    pub running_jobs: usize,
}

/// Rolling recorder for per-round cluster state (Fig 10).
#[derive(Debug, Clone, Default)]
pub struct UtilizationLog {
    pub samples: Vec<UtilSample>,
}

impl UtilizationLog {
    pub fn record(&mut self, s: UtilSample) {
        self.samples.push(s);
    }

    pub fn mean_gpu_util(&self) -> f64 {
        mean(&self.samples.iter().map(|s| s.gpu_util).collect::<Vec<_>>())
    }

    pub fn mean_cpu_util(&self) -> f64 {
        mean(&self.samples.iter().map(|s| s.cpu_util).collect::<Vec<_>>())
    }

    /// Mean CPU *usage* over the samples where the cluster had running
    /// jobs (the paper's Fig-10b metric; idle tail excluded so mechanisms
    /// with shorter makespans are not penalized).
    pub fn mean_cpu_used_busy(&self) -> f64 {
        let busy: Vec<f64> = self
            .samples
            .iter()
            .filter(|s| s.running_jobs > 0)
            .map(|s| s.cpu_used)
            .collect();
        mean(&busy)
    }

    /// Mean GPU allocation over busy samples.
    pub fn mean_gpu_util_busy(&self) -> f64 {
        let busy: Vec<f64> = self
            .samples
            .iter()
            .filter(|s| s.running_jobs > 0)
            .map(|s| s.gpu_util)
            .collect();
        mean(&busy)
    }
}

/// Round-planning split (memoization + prefix-resume accounting), for
/// the optional plan-stats section of [`metrics_json`].
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanSummary {
    pub planned_rounds: usize,
    pub resumed_rounds: usize,
    /// Planning steps served from checkpointed prefixes.
    pub reused_steps: usize,
    /// All planning steps across planned rounds.
    pub total_steps: usize,
}

/// Fault-injection accounting (host churn under `--faults`), for the
/// optional fault-stats section of [`metrics_json`].
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultSummary {
    /// Running jobs preempted back into the queue by host failures.
    pub preemptions: u64,
    /// Lost partial-round work, in GPU·rounds: each preemption charges
    /// the victim's GPU count (the round's progress was not yet
    /// credited).
    pub preempted_gpu_rounds_lost: u64,
    pub servers_failed: u64,
    pub servers_restored: u64,
}

/// The canonical metrics document: JCT summary + Jain fairness over the
/// per-tenant average JCTs (+ the per-tenant table). This is the exact
/// payload the golden scenario matrix pins (`tests/scenarios.rs`), so
/// its default shape must stay byte-stable; `plan` and `faults` (both
/// default `None` everywhere golden-relevant) append their sections as
/// *additional* keys without touching the existing ones. Values are
/// rounded to 1 ms so goldens survive libm ulp differences across hosts
/// while still pinning the schedule.
pub fn metrics_json(
    stats: &JctStats,
    by_tenant: &BTreeMap<TenantId, JctStats>,
    makespan_s: f64,
    rounds: usize,
    plan: Option<&PlanSummary>,
    faults: Option<&FaultSummary>,
) -> String {
    use crate::util::json::Json;
    let r3 = |x: f64| (x * 1000.0).round() / 1000.0;
    let tenant_avgs: Vec<f64> = by_tenant.values().map(|s| s.avg_s).collect();
    let tenants: Vec<Json> = by_tenant
        .iter()
        .map(|(t, s)| {
            Json::obj(vec![
                ("tenant", Json::num(t.0 as f64)),
                ("jobs", Json::num(s.n as f64)),
                ("avg_jct_s", Json::num(r3(s.avg_s))),
                ("p99_jct_s", Json::num(r3(s.p99_s))),
            ])
        })
        .collect();
    let mut fields = vec![
        ("jobs", Json::num(stats.n as f64)),
        ("avg_jct_s", Json::num(r3(stats.avg_s))),
        ("p50_jct_s", Json::num(r3(stats.p50_s))),
        ("p99_jct_s", Json::num(r3(stats.p99_s))),
        ("makespan_s", Json::num(r3(makespan_s))),
        ("rounds", Json::num(rounds as f64)),
        ("jain_fairness", Json::num(r3(jains_index(&tenant_avgs)))),
        ("per_tenant", Json::arr(tenants)),
    ];
    if let Some(p) = plan {
        fields.push(("planned_rounds", Json::num(p.planned_rounds as f64)));
        fields.push(("resumed_rounds", Json::num(p.resumed_rounds as f64)));
        fields.push(("reused_steps", Json::num(p.reused_steps as f64)));
        fields.push(("total_steps", Json::num(p.total_steps as f64)));
    }
    if let Some(f) = faults {
        fields.push(("preemptions", Json::num(f.preemptions as f64)));
        fields.push((
            "preempted_gpu_rounds_lost",
            Json::num(f.preempted_gpu_rounds_lost as f64),
        ));
        fields.push(("servers_failed", Json::num(f.servers_failed as f64)));
        fields.push(("servers_restored", Json::num(f.servers_restored as f64)));
    }
    Json::obj(fields).encode()
}

/// Per-tenant JCT summaries from `(tenant, jct)` pairs.
pub fn per_tenant_stats(
    jcts: &[(TenantId, f64)],
) -> BTreeMap<TenantId, JctStats> {
    let mut grouped: BTreeMap<TenantId, Vec<f64>> = BTreeMap::new();
    for &(t, jct) in jcts {
        grouped.entry(t).or_default().push(jct);
    }
    grouped
        .into_iter()
        .map(|(t, xs)| (t, JctStats::from_jcts(&xs)))
        .collect()
}

/// Jain's fairness index over a set of per-tenant quantities:
/// `(Σx)² / (n·Σx²)`, in `(0, 1]` with 1 = perfectly even. Returns 1.0
/// for empty or all-zero input (nothing to be unfair about).
pub fn jains_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// Per-job speedup of mechanism A over B (Fig 6c): jct_b / jct_a per job.
pub fn per_job_speedups(jct_a: &[f64], jct_b: &[f64]) -> Vec<f64> {
    assert_eq!(jct_a.len(), jct_b.len());
    jct_a
        .iter()
        .zip(jct_b)
        .map(|(&a, &b)| if a > 0.0 { b / a } else { 1.0 })
        .collect()
}

/// CDF helper re-exported for the figure benches.
pub fn jct_cdf(jcts: &[f64], points: usize) -> Vec<(f64, f64)> {
    cdf(jcts, points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jct_stats_basic() {
        let s = JctStats::from_jcts(&[3600.0, 7200.0, 10800.0]);
        assert_eq!(s.n, 3);
        assert!((s.avg_hrs() - 2.0).abs() < 1e-9);
        assert_eq!(s.max_s, 10800.0);
        assert!(s.p99_s <= s.max_s);
    }

    #[test]
    fn short_long_split_at_4h() {
        let data = vec![
            (1000.0, 3599.0 * 4.0), // short (just under 4h baseline)
            (9999.0, 4.1 * 3600.0), // long
        ];
        let (short, long) = split_short_long(&data);
        assert_eq!(short, vec![1000.0]);
        assert_eq!(long, vec![9999.0]);
    }

    #[test]
    fn speedups_elementwise() {
        let sp = per_job_speedups(&[1.0, 2.0], &[3.0, 2.0]);
        assert_eq!(sp, vec![3.0, 1.0]);
    }

    #[test]
    fn per_tenant_grouping() {
        let jcts = vec![
            (TenantId(0), 100.0),
            (TenantId(1), 400.0),
            (TenantId(0), 300.0),
        ];
        let by = per_tenant_stats(&jcts);
        assert_eq!(by.len(), 2);
        assert_eq!(by[&TenantId(0)].n, 2);
        assert_eq!(by[&TenantId(0)].avg_s, 200.0);
        assert_eq!(by[&TenantId(1)].avg_s, 400.0);
    }

    #[test]
    fn jains_index_bounds() {
        assert_eq!(jains_index(&[]), 1.0);
        assert_eq!(jains_index(&[0.0, 0.0]), 1.0);
        assert!((jains_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // One tenant hogging everything → 1/n.
        let skewed = jains_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skewed - 0.25).abs() < 1e-12);
        let mid = jains_index(&[2.0, 1.0]);
        assert!(mid > 0.25 && mid < 1.0);
    }

    #[test]
    fn utilization_log_means() {
        let mut log = UtilizationLog::default();
        for (g, c, used, running) in
            [(1.0, 0.5, 0.4, 2), (0.5, 0.7, 0.6, 1), (0.0, 0.0, 0.0, 0)]
        {
            log.record(UtilSample {
                time_s: 0.0,
                gpu_util: g,
                cpu_util: c,
                cpu_used: used,
                mem_util: 0.0,
                queued_jobs: 0,
                running_jobs: running,
            });
        }
        assert!((log.mean_gpu_util() - 0.5).abs() < 1e-9);
        assert!((log.mean_cpu_util() - 0.4).abs() < 1e-9);
        // Busy means exclude the idle third sample.
        assert!((log.mean_cpu_used_busy() - 0.5).abs() < 1e-9);
        assert!((log.mean_gpu_util_busy() - 0.75).abs() < 1e-9);
    }
}
