//! Deterministic fault injection (ISSUE 9): seeded host churn.
//!
//! A [`FaultSpec`] describes when servers leave and rejoin the fleet:
//!
//! - **scripted** — `--faults <file>`, a JSON array of
//!   `{"at": seconds, "pool": i, "count": n, "action": "fail"|"add"}`
//!   entries (`pool` defaults to 0, `count` to 1, `action` to `"fail"`);
//! - **generated** — `--faults mtbf:<hours>,mttr:<hours>[,seed:S]`, a
//!   seeded Poisson process: exponential inter-failure gaps with the
//!   given mean-time-between-failures, each failure paired with a
//!   restore `mttr` later, victim pool drawn uniformly.
//!
//! Both forms materialize, via [`FaultSpec::schedule`], into one flat
//! sorted `Vec<FaultEntry>` that the event core enqueues up front — the
//! whole churn timeline is a pure function of (spec, horizon, pool
//! count), so replay is exact and byte-identical across runs, hosts,
//! `--threads`, and `--shards`. Entries sort by `(at, kind, insertion
//! order)` with failures before additions at equal times, matching the
//! event queue's tie-break (failure < addition < arrival < lease).

use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// What happens to a server: it fails (goes offline, running gangs
/// preempted) or is added (an offline server restored, or the pool
/// grown by a fresh machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// Take one server offline (failures sort before additions).
    Fail,
    /// Bring one server back online (or grow the pool).
    Add,
}

/// One materialized churn event: a single server in `pool` fails or is
/// added at simulated time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEntry {
    pub at: f64,
    pub pool: usize,
    pub kind: FaultKind,
}

/// One line of a scripted fault file, before `count` expansion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScriptFault {
    pub at: f64,
    pub pool: usize,
    pub count: u32,
    pub kind: FaultKind,
}

/// Safety cap on generator output: a pathological mtbf cannot flood the
/// event heap (16k churn events is far past any realistic schedule).
const MAX_GENERATED: usize = 16_384;

/// A fault-injection description (see module docs for the two forms).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultSpec {
    /// Explicit scripted schedule from a JSON file.
    Script(Vec<ScriptFault>),
    /// Seeded MTBF/MTTR generator (times in seconds).
    Generator { mtbf_s: f64, mttr_s: f64, seed: u64 },
}

impl FaultSpec {
    /// Parse the CLI form: `mtbf:<hours>,mttr:<hours>[,seed:S]` for the
    /// generator, anything else is a path to a scripted JSON file
    /// (loaded eagerly so a bad file fails at config time, not mid-run).
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        if s.starts_with("mtbf:") {
            Self::parse_generator(s)
        } else {
            let text = std::fs::read_to_string(s)
                .map_err(|e| format!("faults '{s}': cannot read file: {e}"))?;
            Self::script_from_json(&text)
                .map_err(|e| format!("faults '{s}': {e}"))
        }
    }

    fn parse_generator(s: &str) -> Result<FaultSpec, String> {
        let mut mtbf_s = None;
        let mut mttr_s = None;
        let mut seed = 1u64;
        for part in s.split(',') {
            let (key, val) = part
                .split_once(':')
                .ok_or_else(|| format!("faults '{s}': expected key:value, got '{part}'"))?;
            match key {
                "mtbf" => {
                    let h: f64 = val.parse().map_err(|_| {
                        format!("faults '{s}': mtbf expects hours, got '{val}'")
                    })?;
                    mtbf_s = Some(h * 3600.0);
                }
                "mttr" => {
                    let h: f64 = val.parse().map_err(|_| {
                        format!("faults '{s}': mttr expects hours, got '{val}'")
                    })?;
                    mttr_s = Some(h * 3600.0);
                }
                "seed" => {
                    seed = val.parse().map_err(|_| {
                        format!("faults '{s}': seed expects an integer, got '{val}'")
                    })?;
                }
                other => {
                    return Err(format!("faults '{s}': unknown key '{other}'"));
                }
            }
        }
        let mtbf_s = mtbf_s.ok_or_else(|| format!("faults '{s}': missing mtbf"))?;
        let mttr_s = mttr_s.ok_or_else(|| format!("faults '{s}': missing mttr"))?;
        if !(mtbf_s > 0.0 && mtbf_s.is_finite()) {
            return Err(format!("faults '{s}': mtbf must be finite and > 0"));
        }
        if !(mttr_s >= 0.0 && mttr_s.is_finite()) {
            return Err(format!("faults '{s}': mttr must be finite and >= 0"));
        }
        Ok(FaultSpec::Generator { mtbf_s, mttr_s, seed })
    }

    /// Parse a scripted fault document (the contents of a `--faults`
    /// file): a JSON array of `{at, pool, count, action}` objects.
    pub fn script_from_json(text: &str) -> Result<FaultSpec, String> {
        let doc = Json::parse(text).map_err(|e| format!("bad JSON: {e:?}"))?;
        let arr = doc
            .as_arr()
            .ok_or_else(|| "expected a top-level JSON array".to_string())?;
        let mut entries = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let at = e
                .get("at")
                .as_f64()
                .ok_or_else(|| format!("entry {i}: missing numeric 'at' (seconds)"))?;
            if !(at >= 0.0 && at.is_finite()) {
                return Err(format!("entry {i}: 'at' must be finite and >= 0"));
            }
            let pool = e.get("pool").as_f64().unwrap_or(0.0);
            if pool < 0.0 || pool.fract() != 0.0 {
                return Err(format!("entry {i}: 'pool' must be a non-negative integer"));
            }
            let count = e.get("count").as_f64().unwrap_or(1.0);
            if !(count >= 1.0) || count.fract() != 0.0 {
                return Err(format!("entry {i}: 'count' must be a positive integer"));
            }
            let kind = match e.get("action").as_str().unwrap_or("fail") {
                "fail" | "remove" => FaultKind::Fail,
                "add" => FaultKind::Add,
                other => {
                    return Err(format!(
                        "entry {i}: action must be 'fail'|'remove'|'add', got '{other}'"
                    ));
                }
            };
            entries.push(ScriptFault { at, pool: pool as usize, count: count as u32, kind });
        }
        Ok(FaultSpec::Script(entries))
    }

    /// Materialize the churn timeline for one run: every single-server
    /// event before `max_sim_s`, sorted by `(at, kind, insertion
    /// order)`. Script pools past the fleet clamp to the last pool (the
    /// mapping stays total and deterministic for any fleet shape);
    /// generator pools are drawn uniformly from the seeded stream.
    pub fn schedule(&self, max_sim_s: f64, n_pools: usize) -> Vec<FaultEntry> {
        assert!(n_pools > 0, "fault schedule needs at least one pool");
        let mut out = Vec::new();
        match self {
            FaultSpec::Script(entries) => {
                for e in entries {
                    if e.at >= max_sim_s {
                        continue;
                    }
                    let pool = e.pool.min(n_pools - 1);
                    for _ in 0..e.count {
                        out.push(FaultEntry { at: e.at, pool, kind: e.kind });
                    }
                }
            }
            FaultSpec::Generator { mtbf_s, mttr_s, seed } => {
                let mut rng = Pcg64::new(*seed, 0xFA117);
                let lambda = 1.0 / mtbf_s;
                let mut t = 0.0;
                while out.len() + 2 <= MAX_GENERATED {
                    t += rng.exponential(lambda);
                    if t >= max_sim_s {
                        break;
                    }
                    let pool = rng.below(n_pools as u64) as usize;
                    out.push(FaultEntry { at: t, pool, kind: FaultKind::Fail });
                    let back = t + mttr_s;
                    if back < max_sim_s {
                        out.push(FaultEntry { at: back, pool, kind: FaultKind::Add });
                    }
                }
            }
        }
        // Stable sort: equal (at, kind) pairs keep insertion order, so
        // the timeline is reproducible down to the last tie.
        out.sort_by(|a, b| a.at.total_cmp(&b.at).then(a.kind.cmp(&b.kind)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_form_parses_and_rejects_garbage() {
        let g = FaultSpec::parse("mtbf:12,mttr:0.5,seed:7").unwrap();
        assert_eq!(
            g,
            FaultSpec::Generator { mtbf_s: 12.0 * 3600.0, mttr_s: 1800.0, seed: 7 }
        );
        // Seed defaults to 1.
        let g = FaultSpec::parse("mtbf:1,mttr:1").unwrap();
        assert!(matches!(g, FaultSpec::Generator { seed: 1, .. }));
        assert!(FaultSpec::parse("mtbf:12").is_err()); // missing mttr
        assert!(FaultSpec::parse("mtbf:x,mttr:1").is_err());
        assert!(FaultSpec::parse("mtbf:0,mttr:1").is_err());
        assert!(FaultSpec::parse("mtbf:1,mttr:-1").is_err());
        assert!(FaultSpec::parse("mtbf:1,mttr:1,foo:2").is_err());
        assert!(FaultSpec::parse("/no/such/fault/file.json").is_err());
    }

    #[test]
    fn script_parses_defaults_and_rejects_bad_entries() {
        let s = FaultSpec::script_from_json(
            r#"[{"at": 600, "pool": 1, "count": 2, "action": "fail"},
                {"at": 1200, "action": "add"},
                {"at": 300}]"#,
        )
        .unwrap();
        let FaultSpec::Script(entries) = &s else { panic!("expected script") };
        assert_eq!(entries.len(), 3);
        assert_eq!(
            entries[0],
            ScriptFault { at: 600.0, pool: 1, count: 2, kind: FaultKind::Fail }
        );
        // Defaults: pool 0, count 1, action fail.
        assert_eq!(
            entries[1],
            ScriptFault { at: 1200.0, pool: 0, count: 1, kind: FaultKind::Add }
        );
        assert_eq!(entries[2].kind, FaultKind::Fail);
        assert!(FaultSpec::script_from_json("{}").is_err());
        assert!(FaultSpec::script_from_json(r#"[{"pool": 0}]"#).is_err());
        assert!(FaultSpec::script_from_json(r#"[{"at": -1}]"#).is_err());
        assert!(FaultSpec::script_from_json(r#"[{"at": 1, "count": 0}]"#).is_err());
        assert!(FaultSpec::script_from_json(r#"[{"at": 1, "action": "explode"}]"#).is_err());
    }

    #[test]
    fn script_schedule_expands_counts_clamps_pools_and_sorts() {
        let s = FaultSpec::Script(vec![
            ScriptFault { at: 900.0, pool: 9, count: 1, kind: FaultKind::Add },
            ScriptFault { at: 900.0, pool: 0, count: 2, kind: FaultKind::Fail },
            ScriptFault { at: 300.0, pool: 1, count: 1, kind: FaultKind::Fail },
            ScriptFault { at: 1e12, pool: 0, count: 1, kind: FaultKind::Fail },
        ]);
        let plan = s.schedule(3600.0, 2);
        // Past-horizon entry dropped; count expanded; fail before add
        // at the shared t=900 instant; pool 9 clamps to the last pool.
        assert_eq!(plan.len(), 4);
        assert_eq!(plan[0], FaultEntry { at: 300.0, pool: 1, kind: FaultKind::Fail });
        assert_eq!(plan[1].kind, FaultKind::Fail);
        assert_eq!(plan[2].kind, FaultKind::Fail);
        assert_eq!(plan[3], FaultEntry { at: 900.0, pool: 1, kind: FaultKind::Add });
    }

    #[test]
    fn generator_schedule_is_deterministic_and_pairs_restores() {
        let g = FaultSpec::Generator { mtbf_s: 4.0 * 3600.0, mttr_s: 1800.0, seed: 3 };
        let a = g.schedule(86_400.0, 3);
        let b = g.schedule(86_400.0, 3);
        assert_eq!(a, b, "same spec must replay byte-identically");
        assert!(!a.is_empty(), "a day at 4h MTBF should produce churn");
        let fails = a.iter().filter(|e| e.kind == FaultKind::Fail).count();
        let adds = a.iter().filter(|e| e.kind == FaultKind::Add).count();
        // Every restore pairs with an earlier failure (some failures
        // near the horizon may lose their restore past it).
        assert!(adds <= fails);
        assert!(a.iter().all(|e| e.pool < 3 && e.at < 86_400.0));
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at, "schedule must be time-sorted");
        }
        // A different seed moves the timeline.
        let c = FaultSpec::Generator { mtbf_s: 4.0 * 3600.0, mttr_s: 1800.0, seed: 4 }
            .schedule(86_400.0, 3);
        assert_ne!(a, c);
    }

    #[test]
    fn generator_output_is_capped() {
        // An absurd mtbf (sub-second) hits the cap instead of flooding.
        let g = FaultSpec::Generator { mtbf_s: 0.001, mttr_s: 0.0, seed: 1 };
        let plan = g.schedule(1e9, 1);
        assert!(plan.len() <= MAX_GENERATED);
    }
}
