//! The simulation engine.

use crate::cluster::{Cluster, ServerSpec};
use crate::coordinator::{JobContext, RoundPlanner};
use crate::job::{Job, JobId, JobState, TenantId};
use crate::mechanism::{by_name as mechanism_by_name, Grant};
use crate::metrics::{per_tenant_stats, JctStats, UtilSample, UtilizationLog};
use crate::perf::PerfModel;
use crate::policy::by_name as policy_by_name;
use crate::profiler::OptimisticProfiler;
use crate::workload::TenantQuotas;
use std::collections::BTreeMap;

/// Simulator configuration.
pub struct SimConfig {
    pub spec: ServerSpec,
    pub n_servers: usize,
    /// Scheduling round length, seconds (paper uses ~5 minutes).
    pub round_s: f64,
    pub policy: String,
    pub mechanism: String,
    /// Profiler measurement noise (0.0 for exact).
    pub profile_noise: f64,
    /// Stop after this much simulated time (safety valve).
    pub max_sim_s: f64,
    /// Profiler grid widening for multi-GPU jobs (§6 consolidation-vs-
    /// allocation ablation). 1 = paper's consolidation-strict default.
    pub span_factor: usize,
    /// Per-extra-server throughput penalty for fragmented placements:
    /// `rate /= 1 + penalty × (span − 1)`. 0 = the paper's main-body
    /// assumption (no modeled network cost).
    pub network_penalty: f64,
    /// Server shape that job *durations* are defined against (paper §5.1:
    /// trace durations assume GPU-proportional allocation on the study's
    /// ratio-3 servers). Defaults to `spec`; the Fig-12 CPU:GPU-ratio
    /// sweep pins it to ratio 3 so richer servers genuinely speed the
    /// baseline up instead of re-normalizing the work away.
    pub reference_spec: Option<ServerSpec>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            spec: ServerSpec::default(),
            n_servers: 16,
            round_s: 300.0,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            profile_noise: 0.0,
            max_sim_s: 400.0 * 24.0 * 3600.0,
            span_factor: 1,
            network_penalty: 0.0,
            reference_spec: None,
        }
    }
}

/// Simulation output.
#[derive(Debug)]
pub struct SimResult {
    /// Finished jobs in arrival order (id, model, gpus, arrival, baseline
    /// duration, JCT seconds).
    pub finished: Vec<FinishedJob>,
    pub makespan_s: f64,
    pub rounds: usize,
    pub utilization: UtilizationLog,
    /// Total profiling cost across all jobs, minutes (§3.1 accounting).
    pub profiling_minutes: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct FinishedJob {
    pub id: JobId,
    pub tenant: TenantId,
    pub gpus: u32,
    pub arrival_s: f64,
    pub duration_prop_s: f64,
    pub jct_s: f64,
}

impl SimResult {
    pub fn jcts(&self) -> Vec<f64> {
        self.finished.iter().map(|f| f.jct_s).collect()
    }

    pub fn jct_stats(&self) -> JctStats {
        JctStats::from_jcts(&self.jcts())
    }

    /// Per-tenant JCT summaries (multi-tenant workloads).
    pub fn tenant_stats(&self) -> BTreeMap<TenantId, JctStats> {
        let pairs: Vec<(TenantId, f64)> =
            self.finished.iter().map(|f| (f.tenant, f.jct_s)).collect();
        per_tenant_stats(&pairs)
    }

    /// JCTs of a monitored subrange of jobs (steady-state window, §5.1).
    pub fn jcts_in_window(&self, from_idx: usize, n: usize) -> Vec<f64> {
        self.finished
            .iter()
            .filter(|f| {
                (f.id.0 as usize) >= from_idx && (f.id.0 as usize) < from_idx + n
            })
            .map(|f| f.jct_s)
            .collect()
    }
}

/// The simulator.
pub struct Simulator {
    cfg: SimConfig,
    world: PerfModel,
    quotas: Option<TenantQuotas>,
}

impl Simulator {
    pub fn new(cfg: SimConfig) -> Simulator {
        let world = PerfModel::new(cfg.spec);
        Simulator { cfg, world, quotas: None }
    }

    /// A simulator whose coordinator enforces tenant GPU quotas.
    pub fn with_quotas(
        cfg: SimConfig,
        quotas: Option<TenantQuotas>,
    ) -> Simulator {
        let mut sim = Simulator::new(cfg);
        sim.quotas = quotas;
        sim
    }

    /// Run a trace to completion (or `max_sim_s`).
    pub fn run(&self, mut jobs: Vec<Job>) -> SimResult {
        let planner = RoundPlanner::with_quotas(
            policy_by_name(&self.cfg.policy)
                .unwrap_or_else(|| panic!("unknown policy {}", self.cfg.policy)),
            mechanism_by_name(&self.cfg.mechanism).unwrap_or_else(|| {
                panic!("unknown mechanism {}", self.cfg.mechanism)
            }),
            self.quotas.clone(),
        );
        let mut cluster =
            Cluster::homogeneous(self.cfg.spec, self.cfg.n_servers);
        let profiler = OptimisticProfiler {
            noise_sd: self.cfg.profile_noise,
            span_factor: self.cfg.span_factor,
            ..OptimisticProfiler::new(self.cfg.spec)
        };

        jobs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        // Reject jobs that can never fit.
        jobs.retain(|j| j.gpus <= cluster.total_gpus());

        let mut contexts: BTreeMap<JobId, JobContext> = BTreeMap::new();
        let mut profiling_minutes = 0.0;
        let mut active: BTreeMap<JobId, Job> = BTreeMap::new();
        let mut finished: Vec<FinishedJob> = Vec::new();
        let mut util = UtilizationLog::default();

        let mut next_arrival = 0usize; // index into jobs
        let mut now = 0.0f64;
        let mut rounds = 0usize;
        let mut last_set_changed = true;
        let n_total = jobs.len();

        while (finished.len() < n_total) && now < self.cfg.max_sim_s {
            // Admit arrivals up to `now` (profiling happens on arrival).
            while next_arrival < jobs.len()
                && jobs[next_arrival].arrival_s <= now + 1e-9
            {
                let mut job = jobs[next_arrival].clone();
                let outcome = profiler.profile(&job);
                profiling_minutes += outcome.cost_minutes;
                let ctx = JobContext::new(outcome.matrix, &cluster);
                // Total work from the baseline duration (paper §5.1),
                // against the reference server shape.
                let ref_tput = match self.cfg.reference_spec {
                    Some(rs) => PerfModel::new(rs)
                        .proportional_throughput(job.model, job.gpus),
                    None => ctx.prop_tput,
                };
                job.total_samples = job.duration_prop_s * ref_tput;
                contexts.insert(job.id, ctx);
                active.insert(job.id, job);
                next_arrival += 1;
                last_set_changed = true;
            }

            // Fast-forward when nothing can change the plan: all active
            // jobs running, queue empty, set unchanged.
            if !last_set_changed && active.values().all(|j| j.state == JobState::Running)
            {
                // keep current placements; jobs keep progressing below.
            } else {
                // Re-plan the round.
                cluster.evict_all();
                let refs: Vec<(&Job, &JobContext)> = active
                    .values()
                    .map(|j| (j, &contexts[&j.id]))
                    .collect();
                let plan = planner.plan(&mut cluster, &refs, now);
                // Update job states from grants.
                let granted: BTreeMap<JobId, Grant> = plan.grants;
                for job in active.values_mut() {
                    job.state = if granted.contains_key(&job.id) {
                        JobState::Running
                    } else {
                        JobState::Queued
                    };
                }
                self.deploy_round(&granted, &mut active, &contexts);
                last_set_changed = false;
            }

            // Determine the horizon of this round: next arrival or round
            // boundary, whichever first.
            let round_end = now + self.cfg.round_s;
            let horizon = if next_arrival < jobs.len() {
                round_end.min(jobs[next_arrival].arrival_s.max(now + 1e-6))
            } else {
                round_end
            };
            let dt = horizon - now;

            // Progress running jobs; record exact finish times.
            let mut any_finished = false;
            for job in active.values_mut() {
                if job.state != JobState::Running {
                    continue;
                }
                let tput = job.progress_rate;
                if tput <= 0.0 {
                    continue;
                }
                let need = job.remaining_samples() / tput;
                if need <= dt {
                    job.finish_s = now + need;
                    job.attained_service_s += need;
                    job.progress_samples = job.total_samples;
                    job.state = JobState::Finished;
                    any_finished = true;
                } else {
                    job.progress_samples += tput * dt;
                    job.attained_service_s += dt;
                }
            }
            if any_finished {
                last_set_changed = true;
                let done: Vec<JobId> = active
                    .values()
                    .filter(|j| j.state == JobState::Finished)
                    .map(|j| j.id)
                    .collect();
                for id in done {
                    let j = active.remove(&id).unwrap();
                    contexts.remove(&id);
                    finished.push(FinishedJob {
                        id: j.id,
                        tenant: j.tenant,
                        gpus: j.gpus,
                        arrival_s: j.arrival_s,
                        duration_prop_s: j.duration_prop_s,
                        jct_s: j.finish_s - j.arrival_s,
                    });
                }
            }

            // Sample utilization once per executed round.
            // Actual CPU usage: cores actively pre-processing across
            // running jobs (rate / per-core prep rate).
            let cpu_used: f64 = active
                .values()
                .filter(|j| j.state == JobState::Running)
                .map(|j| j.progress_rate / j.model.coeffs().cpu_prep_rate)
                .sum::<f64>()
                / cluster.total_cpus();
            util.record(UtilSample {
                time_s: now,
                gpu_util: cluster.gpu_utilization(),
                cpu_util: cluster.cpu_utilization(),
                cpu_used,
                mem_util: 1.0
                    - cluster.free_mem_gb() / cluster.total_mem_gb(),
                queued_jobs: active
                    .values()
                    .filter(|j| j.state == JobState::Queued)
                    .count(),
                running_jobs: active
                    .values()
                    .filter(|j| j.state == JobState::Running)
                    .count(),
            });

            rounds += 1;
            // Jump straight to the next interesting instant when idle.
            if active.is_empty() && next_arrival < jobs.len() {
                now = jobs[next_arrival].arrival_s;
            } else {
                now = horizon;
            }
        }

        let makespan_s = finished
            .iter()
            .map(|f| f.arrival_s + f.jct_s)
            .fold(0.0, f64::max);
        SimResult { finished, makespan_s, rounds, utilization: util, profiling_minutes }
    }

    /// Deploy: fix each granted job's progress rate for the round from the
    /// ground-truth model at its granted (c, m).
    fn deploy_round(
        &self,
        grants: &BTreeMap<JobId, Grant>,
        active: &mut BTreeMap<JobId, Job>,
        _contexts: &BTreeMap<JobId, JobContext>,
    ) {
        for (id, grant) in grants {
            if let Some(job) = active.get_mut(id) {
                let rate = self.world.throughput(
                    job.model,
                    job.gpus,
                    grant.demand.cpus,
                    grant.demand.mem_gb,
                );
                // Fragmented placements pay the data-parallel sync cost
                // (§6 consolidation tradeoff; 0 in the paper's main body).
                let span = grant.placement.span().max(1) as f64;
                job.progress_rate = rate
                    / (1.0 + self.cfg.network_penalty * (span - 1.0));
            }
        }
        // Queued jobs make no progress.
        for job in active.values_mut() {
            if job.state != JobState::Running {
                job.progress_rate = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::ModelKind;
    use crate::trace::{generate, Split, TraceConfig};

    fn small_cfg(policy: &str, mechanism: &str) -> SimConfig {
        SimConfig {
            n_servers: 2,
            policy: policy.into(),
            mechanism: mechanism.into(),
            ..Default::default()
        }
    }

    fn small_trace(n: usize, seed: u64) -> Vec<Job> {
        generate(&TraceConfig {
            n_jobs: n,
            split: Split::new(30, 60, 10),
            multi_gpu: true,
            jobs_per_hour: Some(6.0),
            seed,
        })
    }

    #[test]
    fn all_jobs_finish() {
        let sim = Simulator::new(small_cfg("fifo", "tune"));
        let result = sim.run(small_trace(30, 1));
        assert_eq!(result.finished.len(), 30);
        assert!(result.makespan_s > 0.0);
        assert!(result.rounds > 0);
    }

    #[test]
    fn tune_beats_proportional_on_sensitive_mix() {
        let trace = generate(&TraceConfig {
            n_jobs: 40,
            split: Split::new(60, 30, 10), // image-heavy: CPU-sensitive
            multi_gpu: false,
            jobs_per_hour: None, // static: full contention
            seed: 7,
        });
        let prop = Simulator::new(small_cfg("fifo", "proportional"))
            .run(trace.clone());
        let tune =
            Simulator::new(small_cfg("fifo", "tune")).run(trace);
        let a = prop.jct_stats().avg_s;
        let b = tune.jct_stats().avg_s;
        assert!(
            b < a * 0.95,
            "tune ({b}) should beat proportional ({a})"
        );
    }

    #[test]
    fn no_job_slower_than_proportional_baseline() {
        // Fairness: per-job JCT under TUNE <= (1+eps) x JCT under
        // proportional for a static trace with identical arrival order.
        let trace = generate(&TraceConfig {
            n_jobs: 16,
            split: Split::new(50, 0, 50),
            multi_gpu: false,
            jobs_per_hour: None,
            seed: 3,
        });
        let prop = Simulator::new(small_cfg("fifo", "proportional"))
            .run(trace.clone());
        let tune = Simulator::new(small_cfg("fifo", "tune")).run(trace);
        let by_id = |r: &SimResult| {
            let mut m: BTreeMap<u64, f64> = BTreeMap::new();
            for f in &r.finished {
                m.insert(f.id.0, f.jct_s);
            }
            m
        };
        let p = by_id(&prop);
        let t = by_id(&tune);
        for (id, &jt) in &t {
            let jp = p[id];
            assert!(
                jt <= jp * 1.05 + self_round_slack(),
                "job {id}: tune {jt} vs prop {jp}"
            );
        }
    }

    fn self_round_slack() -> f64 {
        // One round of slack: round-boundary quantization.
        301.0
    }

    #[test]
    fn tenant_tags_flow_into_results_and_quotas_apply() {
        use crate::workload::{SyntheticSource, TenantSpec, WorkloadSource};
        let spec = TenantSpec::parse("a:1,b:1").unwrap();
        let jobs = SyntheticSource::new(TraceConfig {
            n_jobs: 24,
            split: Split::new(0, 100, 0),
            multi_gpu: false,
            jobs_per_hour: None,
            seed: 13,
        })
        .with_tenants(spec.clone())
        .drain_jobs();
        let sim =
            Simulator::with_quotas(small_cfg("fifo", "tune"), Some(spec.quotas()));
        let r = sim.run(jobs.clone());
        assert_eq!(r.finished.len(), 24);
        let by = r.tenant_stats();
        // Both tenants appear with the right job counts.
        let n_a = jobs.iter().filter(|j| j.tenant.0 == 0).count();
        assert_eq!(by[&TenantId(0)].n, n_a);
        assert_eq!(by[&TenantId(1)].n, 24 - n_a);
    }

    #[test]
    fn deterministic_runs() {
        let trace = small_trace(20, 11);
        let a = Simulator::new(small_cfg("srtf", "tune")).run(trace.clone());
        let b = Simulator::new(small_cfg("srtf", "tune")).run(trace);
        assert_eq!(a.jcts(), b.jcts());
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn static_trace_makespan_equals_last_finish() {
        let trace = generate(&TraceConfig {
            n_jobs: 10,
            jobs_per_hour: None,
            ..Default::default()
        });
        let r = Simulator::new(small_cfg("fifo", "proportional")).run(trace);
        let max_finish = r
            .finished
            .iter()
            .map(|f| f.jct_s)
            .fold(0.0, f64::max);
        assert!((r.makespan_s - max_finish).abs() < 1e-6);
    }

    #[test]
    fn single_long_job_runs_at_expected_speed() {
        // A single GNMT job alone in the cluster: JCT should equal its
        // baseline duration (it is GPU-bound; extra resources don't help).
        let mut j = Job::new(JobId(0), ModelKind::Gnmt, 1, 0.0, 7200.0);
        j.rng_stream = 0;
        let r = Simulator::new(small_cfg("fifo", "tune")).run(vec![j]);
        let jct = r.finished[0].jct_s;
        assert!(
            (jct - 7200.0).abs() < 60.0,
            "GNMT solo JCT {jct} should be ~7200"
        );
    }

    #[test]
    fn sensitive_solo_job_finishes_faster_than_baseline() {
        // An AlexNet job alone under TUNE gets ~9.3 cores instead of 3:
        // JCT ~ 1/3 of baseline duration.
        let j = Job::new(JobId(0), ModelKind::AlexNet, 1, 0.0, 7200.0);
        let r = Simulator::new(small_cfg("fifo", "tune")).run(vec![j]);
        let jct = r.finished[0].jct_s;
        assert!(jct < 7200.0 * 0.45, "JCT {jct} should be ~2400");
    }
}
