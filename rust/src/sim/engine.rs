//! The one simulation engine: a [`FleetModel`] — the single
//! [`ClusterModel`] implementation — parameterized by a fleet
//! description, driven by the shared event core ([`crate::sim::core`]).
//!
//! [`Simulator`] wires a [`Fleet`] (one V100 pool by default; any mix of
//! generations via [`SimConfig::types`]), the optimistic profiler, one
//! ground-truth [`PerfModel`] per generation, and a [`Mechanism`] into a
//! [`FleetModel`] and hands the loop itself to
//! [`run_events_with_faults`]. Policy
//! ordering, tenant-quota admission, progress, and metrics all live in
//! the core. The heterogeneous front-end ([`crate::hetero`]) is nothing
//! but a `SimConfig` with `types` set — there is no second engine.

use super::core::{
    run_events_with_faults, utilization_sample, ClusterModel, CoreConfig,
    DeployedGrant, PlanStats, RoundRates, SimResult,
};
use super::faults::{FaultKind, FaultSpec};
use crate::cluster::{Fleet, GpuGen, ServerSpec, TopologySpec, TypeSpec};
use crate::coordinator::{policy_view_with_free, round_start_free};
use crate::job::{Job, JobArena};
use crate::mechanism::{
    by_name as mechanism_by_name, JobRequest, Mechanism, PlanTrace,
};
use crate::metrics::UtilSample;
use crate::perf::PerfModel;
use crate::policy::{by_name as policy_by_name, PolicyJobView};
use crate::profiler::{OptimisticProfiler, Sensitivity};
use crate::workload::TenantQuotas;
use std::collections::BTreeMap;

/// Simulator configuration.
pub struct SimConfig {
    pub spec: ServerSpec,
    pub n_servers: usize,
    /// Scheduling round length, seconds (paper uses ~5 minutes).
    pub round_s: f64,
    pub policy: String,
    pub mechanism: String,
    /// Profiler measurement noise (0.0 for exact).
    pub profile_noise: f64,
    /// Stop after this much simulated time (safety valve).
    pub max_sim_s: f64,
    /// Profiler grid widening for multi-GPU jobs (§6 consolidation-vs-
    /// allocation ablation). 1 = paper's consolidation-strict default.
    pub span_factor: usize,
    /// Per-extra-server throughput penalty for fragmented placements:
    /// `rate /= 1 + penalty × (span − 1)`. 0 = the paper's main-body
    /// assumption (no modeled network cost).
    pub network_penalty: f64,
    /// Server shape that job *durations* are defined against (paper §5.1:
    /// trace durations assume GPU-proportional allocation on the study's
    /// ratio-3 servers). Defaults to the fleet's fairness oracle; the
    /// Fig-12 CPU:GPU-ratio sweep pins it to ratio 3 so richer servers
    /// genuinely speed the baseline up instead of re-normalizing the
    /// work away.
    pub reference_spec: Option<ServerSpec>,
    /// Mixed-fleet description (paper A.2): one entry per machine type.
    /// `None` = the homogeneous special case, `n_servers` V100 machines
    /// of `spec` (when set, `spec`/`n_servers` are ignored).
    pub types: Option<Vec<TypeSpec>>,
    /// Disable round-plan memoization (rerun the mechanism on every
    /// non-fast-forwardable round — the pre-memoization hot path).
    /// Schedules are bit-identical either way; exists for the
    /// memo-parity harness and A/B perf measurement. Implies
    /// `no_resume`.
    pub force_replan: bool,
    /// Disable the prefix-resume planning tier only (exact-sequence
    /// memoization stays on): every replan runs the mechanism from a
    /// hard fleet reset. Schedules are bit-identical either way; exists
    /// for the three-arm parity harness and `synergy sim --no-resume`.
    pub no_resume: bool,
    /// Rack topology over each pool's scan order (`--topology racks:R`).
    /// The default flat spec reproduces pre-topology schedules
    /// byte-identically: one rack class means the consolidation-aware
    /// candidate order degenerates to the plain packing key, and
    /// single-rack gangs never enter the link-cost division.
    pub topology: TopologySpec,
    /// Planning fan-out width (`--shards N`): the resumable planner
    /// spreads the per-pool placement folds over up to N worker threads.
    /// Schedule-invisible — results merge in fixed pool order, so every
    /// `SimResult`, golden payload and telemetry profile is
    /// byte-identical for any value. 1 (default) = serial.
    pub shards: usize,
    /// Deterministic host churn (`--faults`): a scripted or seeded
    /// schedule of server failures/restores, materialized once per run
    /// via [`FaultSpec::schedule`] and injected as `ServerFailed` /
    /// `ServerAdded` events. `None` (default) = no churn, byte-identical
    /// to pre-fault builds.
    pub faults: Option<FaultSpec>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            spec: ServerSpec::default(),
            n_servers: 16,
            round_s: 300.0,
            policy: "fifo".into(),
            mechanism: "tune".into(),
            profile_noise: 0.0,
            max_sim_s: 400.0 * 24.0 * 3600.0,
            span_factor: 1,
            network_penalty: 0.0,
            reference_spec: None,
            types: None,
            force_replan: false,
            no_resume: false,
            topology: TopologySpec::default(),
            shards: 1,
            faults: None,
        }
    }
}

/// The topology behind the shared core — the only [`ClusterModel`]: one
/// [`Fleet`], one ground-truth [`PerfModel`] per generation present,
/// per-job [`Sensitivity`] contexts from the one optimistic profiler,
/// and one allocation [`Mechanism`]. A one-pool fleet *is* the paper's
/// homogeneous simulator; more pools *is* the A.2 heterogeneous one.
pub struct FleetModel {
    fleet: Fleet,
    worlds: BTreeMap<GpuGen, PerfModel>,
    profiler: OptimisticProfiler,
    mechanism: Box<dyn Mechanism>,
    /// Per-job scheduling context, arena-indexed (dense slab — the
    /// per-round `BTreeMap` lookups were a hot-path cost at scale).
    /// Boxed so a retired job's slot collapses to one machine word after
    /// [`ClusterModel::forget`]: resident memory tracks *running* jobs,
    /// not total arrivals (the million-job-scale requirement).
    sens: Vec<Option<Box<Sensitivity>>>,
    reference_spec: Option<ServerSpec>,
    network_penalty: f64,
    /// Largest single pool, GPUs — the gang-fit bound (A.2.2: no
    /// cross-type spans).
    max_pool_gpus: u32,
    /// Prefix-resume enabled: the fleet journals its mutations and the
    /// previous plan's checkpoint is retained between planning rounds.
    resume: bool,
    /// Checkpoint of the previous plan (valid while the fleet is
    /// untouched, which the core guarantees between plans).
    trace: Option<PlanTrace>,
    /// Capture each plan's committed placements as [`DeployedGrant`]s
    /// for a live round driver. Off (and cost-free) on pure simulation
    /// paths.
    capture_grants: bool,
    /// The last planned round's grants (valid across memoized rounds —
    /// placements stay committed until the next replan).
    last_grants: Vec<DeployedGrant>,
}

impl FleetModel {
    /// Build the model a [`SimConfig`] describes.
    pub fn from_config(cfg: &SimConfig) -> FleetModel {
        let mut fleet = match &cfg.types {
            Some(types) => Fleet::new(types),
            None => Fleet::homogeneous(cfg.spec, cfg.n_servers),
        };
        cfg.topology
            .validate()
            .unwrap_or_else(|e| panic!("invalid topology: {e}"));
        fleet.set_topology(cfg.topology);
        fleet.set_shards(cfg.shards.max(1));
        let mechanism = mechanism_by_name(&cfg.mechanism).unwrap_or_else(|| {
            panic!("unknown mechanism {}", cfg.mechanism)
        });
        // Journal (and retain checkpoints) only when the mechanism can
        // actually resume — OPT's global program would journal ops every
        // round just to discard them.
        let resume =
            !cfg.force_replan && !cfg.no_resume && mechanism.resumable();
        if resume {
            fleet.enable_journal();
        }
        let worlds: BTreeMap<GpuGen, PerfModel> = fleet
            .pools
            .iter()
            .map(|p| (p.gen, PerfModel::with_gen(p.cluster.spec, p.gen)))
            .collect();
        let profiler = OptimisticProfiler {
            noise_sd: cfg.profile_noise,
            span_factor: cfg.span_factor,
            ..OptimisticProfiler::for_fleet(&fleet)
        };
        let max_pool_gpus = fleet.max_pool_gpus();
        FleetModel {
            fleet,
            worlds,
            profiler,
            mechanism,
            sens: Vec::new(),
            reference_spec: cfg.reference_spec,
            network_penalty: cfg.network_penalty,
            max_pool_gpus,
            resume,
            trace: None,
            capture_grants: false,
            last_grants: Vec::new(),
        }
    }

    /// Turn on per-plan grant capture (the deploy leader's driver needs
    /// server assignments; simulation paths never pay for them).
    pub fn enable_grant_capture(&mut self) {
        self.capture_grants = true;
    }

    fn sens(&self, idx: usize) -> &Sensitivity {
        self.sens[idx].as_deref().expect("job profiled on arrival")
    }
}

impl ClusterModel for FleetModel {
    fn fits(&self, job: &Job) -> bool {
        job.gpus <= self.max_pool_gpus
    }

    fn total_gpus(&self) -> u32 {
        self.fleet.total_gpus()
    }

    fn profile_arrival(&mut self, idx: usize, job: &mut Job) -> f64 {
        // Profiled on every machine type present (A.2's `W_ij`; one
        // sweep on a one-type fleet).
        let s = self.profiler.profile(job);
        // Total work from the baseline duration (paper §5.1), against
        // the reference server shape or the fleet's fairness oracle
        // (slowest-type proportional; on one type, the homogeneous
        // proportional throughput).
        let ref_tput = match self.reference_spec {
            Some(rs) => PerfModel::new(rs)
                .proportional_throughput(job.model, job.gpus),
            None => s.fair_throughput(),
        };
        job.total_samples = job.duration_prop_s * ref_tput;
        let cost = s.cost_minutes;
        if self.sens.len() <= idx {
            self.sens.resize_with(idx + 1, || None);
        }
        self.sens[idx] = Some(Box::new(s));
        cost
    }

    fn forget(&mut self, idx: usize) {
        self.sens[idx] = None;
    }

    fn policy_views(&self, arena: &JobArena, out: &mut Vec<PolicyJobView>) {
        // One round-start free tuple for the whole pass: each view is
        // O(1) instead of rescanning the fleet per job.
        let free = round_start_free(&self.fleet);
        out.extend(arena.active_with_indices().map(|(idx, j)| {
            policy_view_with_free(&self.fleet, free, j, self.sens(idx))
        }));
    }

    fn place_round(
        &mut self,
        runnable: &[u32],
        arena: &JobArena,
        rates: &mut RoundRates,
    ) -> PlanStats {
        let requests: Vec<JobRequest<'_>> = runnable
            .iter()
            .map(|&idx| {
                let j = arena.job(idx as usize);
                JobRequest {
                    id: j.id,
                    gpus: j.gpus,
                    sens: self.sens[idx as usize]
                        .as_deref()
                        .expect("job profiled on arrival"),
                }
            })
            .collect();
        // Plan with prefix resume when enabled: hand the mechanism the
        // previous plan's checkpoint (the fleet is untouched since —
        // memoized rounds never mutate it). Mechanisms reset or roll
        // back the fleet themselves; disabled paths take the hard-reset
        // batch route inside `plan`'s default.
        let prev = if self.resume { self.trace.take() } else { None };
        let outcome = self.mechanism.plan(&mut self.fleet, &requests, prev);
        debug_assert!(self.fleet.check_consistency().is_ok());
        if self.resume {
            self.trace = outcome.trace;
        }
        let grants = outcome.grants;
        // Deploy: fix each granted job's progress rate for the round from
        // its assigned type's ground truth at the granted (c, m).
        // Fragmented placements pay the data-parallel sync cost (§6
        // consolidation tradeoff; 0 in the paper's main body), and gangs
        // straddling a rack boundary additionally pay the topology's
        // per-level link cost. Flat topologies never enter that branch,
        // so their rates stay bit-identical to pre-topology builds.
        let mut gangs_placed = 0u32;
        let mut cross_rack_gangs = 0u32;
        if self.capture_grants {
            self.last_grants.clear();
        }
        for &idx in runnable {
            let job = arena.job(idx as usize);
            if let Some(grant) = grants.get(&job.id) {
                let base = self.worlds[&grant.gen].throughput(
                    job.model,
                    job.gpus,
                    grant.demand.cpus,
                    grant.demand.mem_gb,
                );
                let span = grant.placement.span().max(1) as f64;
                let mut rate =
                    base / (1.0 + self.network_penalty * (span - 1.0));
                if grant.placement.span() > 1 {
                    gangs_placed += 1;
                    let pool = self
                        .fleet
                        .pool(grant.gen)
                        .expect("grant references an existing pool");
                    let racks = pool.cluster.racks_spanned(&grant.placement);
                    if racks > 1 {
                        cross_rack_gangs += 1;
                        rate = crate::perf::link_adjusted_rate(
                            rate,
                            racks,
                            pool.cluster.topology().link_cost,
                        );
                    }
                }
                rates.set(idx as usize, rate);
                if self.capture_grants {
                    // Primary host: the share holding the most GPUs,
                    // lowest server id on ties — deterministic.
                    let server = grant
                        .placement
                        .shares
                        .iter()
                        .max_by(|(ia, a), (ib, b)| {
                            a.gpus.cmp(&b.gpus).then(ib.cmp(ia))
                        })
                        .map(|(&sid, _)| sid)
                        .expect("grant has at least one share");
                    self.last_grants.push(DeployedGrant {
                        id: job.id,
                        server,
                        gpus: job.gpus,
                        cpus: grant.demand.cpus,
                        mem_gb: grant.demand.mem_gb,
                    });
                }
            }
        }
        // Drain the per-pool fit-walk counters unconditionally so the
        // cluster state is identical whether or not a telemetry recorder
        // consumes the figure.
        let fit_walk: u64 = self
            .fleet
            .pools
            .iter()
            .map(|p| p.cluster.take_fit_walk())
            .sum();
        PlanStats {
            resumed: outcome.steps_reused > 0,
            steps_total: outcome.steps_total,
            steps_reused: outcome.steps_reused,
            rollback_depth: outcome.rollback_depth,
            fit_walk: fit_walk as usize,
            pool_stats: outcome.pool_stats,
            gangs_placed,
            cross_rack_gangs,
        }
    }

    fn apply_fault(
        &mut self,
        kind: FaultKind,
        pool: usize,
        arena: &JobArena,
        preempted: &mut Vec<u32>,
    ) -> bool {
        // Either direction changes fleet membership, so the previous
        // plan's checkpoint is unsound: the journal was re-based by the
        // cluster and the fold state references the old server set. Drop
        // it — the next replan takes the hard-reset batch route.
        match kind {
            FaultKind::Fail => {
                let Some(victims) = self.fleet.fail_server(pool) else {
                    return false; // pool already fully offline: no-op
                };
                for id in victims {
                    let idx = arena.index_of(id);
                    // Placements of jobs that finished mid-round stay
                    // committed until the next replan; losing the host
                    // under them preempts nothing.
                    if arena.job(idx).state == crate::job::JobState::Running {
                        preempted.push(idx as u32);
                    }
                }
            }
            FaultKind::Add => {
                if !self.fleet.add_server(pool) {
                    return false;
                }
            }
        }
        // `max_pool_gpus` (the admission gate in `fits`) deliberately
        // stays at its construction-time value: admissibility is decided
        // once per job against the nominal fleet, so transient churn
        // never flips a job between admitted and rejected — that would
        // make "no job lost" depend on fault timing.
        self.trace = None;
        true
    }

    fn utilization(&self, now: f64, arena: &JobArena) -> UtilSample {
        let total_mem = self.fleet.total_mem_gb();
        let mem_util = if total_mem == 0.0 {
            0.0
        } else {
            1.0 - self.fleet.free_mem_gb() / total_mem
        };
        utilization_sample(
            now,
            arena,
            self.fleet.gpu_utilization(),
            self.fleet.cpu_utilization(),
            mem_util,
            self.fleet.total_cpus(),
        )
    }

    fn deployed_grants(&self, out: &mut Vec<DeployedGrant>) {
        out.clear();
        if self.capture_grants {
            out.extend(self.last_grants.iter().cloned());
        }
    }

    fn pool_counters(
        &self,
        out: &mut Vec<crate::telemetry::PoolCounters>,
    ) {
        // O(pools): free figures come from the incrementally-maintained
        // index (GPU count + CPU/mem gauges), totals from the spec
        // arithmetic. No per-server scan — telemetry sampling must not
        // change the hot path's complexity.
        out.clear();
        for p in &self.fleet.pools {
            out.push(crate::telemetry::PoolCounters {
                gen: p.gen,
                free_gpus: p.cluster.free_gpus(),
                total_gpus: p.cluster.total_gpus(),
                free_cpus: p.cluster.free_cpus_gauge(),
                total_cpus: p.cluster.total_cpus(),
                free_mem_gb: p.cluster.free_mem_gb_gauge(),
                total_mem_gb: p.cluster.total_mem_gb(),
            });
        }
    }
}

/// Pre-unification name for the engine model, kept as an alias: the
/// "homogeneous model" is the same [`FleetModel`] with one pool.
pub type HomoModel = FleetModel;

/// The simulator.
pub struct Simulator {
    cfg: SimConfig,
    quotas: Option<TenantQuotas>,
}

impl Simulator {
    pub fn new(cfg: SimConfig) -> Simulator {
        Simulator { cfg, quotas: None }
    }

    /// A simulator whose admission enforces tenant GPU quotas.
    pub fn with_quotas(
        cfg: SimConfig,
        quotas: Option<TenantQuotas>,
    ) -> Simulator {
        let mut sim = Simulator::new(cfg);
        sim.quotas = quotas;
        sim
    }

    /// Run a trace to completion (or `max_sim_s`) through the shared
    /// event-driven core.
    pub fn run(&self, jobs: Vec<Job>) -> SimResult {
        self.run_with_telemetry(jobs, None)
    }

    /// [`Simulator::run`] with an optional telemetry recorder attached
    /// (per-round/per-pool/per-tenant series + plan-stage trace). The
    /// schedule is bit-identical with the recorder on or off.
    pub fn run_with_telemetry(
        &self,
        jobs: Vec<Job>,
        telemetry: Option<&mut crate::telemetry::TelemetryRecorder>,
    ) -> SimResult {
        let policy = policy_by_name(&self.cfg.policy)
            .unwrap_or_else(|| panic!("unknown policy {}", self.cfg.policy));
        let mut model = FleetModel::from_config(&self.cfg);
        // Materialize the churn schedule once, against the *nominal*
        // pool count — the same spec always yields the same event list,
        // independent of shards, threads, or planning tier.
        let n_pools = model.fleet.n_types();
        let faults = self
            .cfg
            .faults
            .as_ref()
            .map(|s| s.schedule(self.cfg.max_sim_s, n_pools))
            .unwrap_or_default();
        run_events_with_faults(
            &mut model,
            policy.as_ref(),
            self.quotas.as_ref(),
            &CoreConfig {
                round_s: self.cfg.round_s,
                max_sim_s: self.cfg.max_sim_s,
                force_replan: self.cfg.force_replan,
            },
            jobs,
            telemetry,
            &faults,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobId, ModelKind};
    use crate::sim::core::run_events;
    use crate::trace::{generate, Split, TraceConfig};

    fn small_cfg(policy: &str, mechanism: &str) -> SimConfig {
        SimConfig {
            n_servers: 2,
            policy: policy.into(),
            mechanism: mechanism.into(),
            ..Default::default()
        }
    }

    fn small_trace(n: usize, seed: u64) -> Vec<Job> {
        generate(&TraceConfig {
            n_jobs: n,
            split: Split::new(30, 60, 10),
            multi_gpu: true,
            jobs_per_hour: Some(6.0),
            seed,
        })
    }

    #[test]
    fn all_jobs_finish() {
        let sim = Simulator::new(small_cfg("fifo", "tune"));
        let result = sim.run(small_trace(30, 1));
        assert_eq!(result.finished.len(), 30);
        assert!(result.makespan_s > 0.0);
        assert!(result.rounds > 0);
    }

    #[test]
    fn tune_beats_proportional_on_sensitive_mix() {
        let trace = generate(&TraceConfig {
            n_jobs: 40,
            split: Split::new(60, 30, 10), // image-heavy: CPU-sensitive
            multi_gpu: false,
            jobs_per_hour: None, // static: full contention
            seed: 7,
        });
        let prop = Simulator::new(small_cfg("fifo", "proportional"))
            .run(trace.clone());
        let tune =
            Simulator::new(small_cfg("fifo", "tune")).run(trace);
        let a = prop.jct_stats().avg_s;
        let b = tune.jct_stats().avg_s;
        assert!(
            b < a * 0.95,
            "tune ({b}) should beat proportional ({a})"
        );
    }

    #[test]
    fn no_job_slower_than_proportional_baseline() {
        // Fairness: per-job JCT under TUNE <= (1+eps) x JCT under
        // proportional for a static trace with identical arrival order.
        let trace = generate(&TraceConfig {
            n_jobs: 16,
            split: Split::new(50, 0, 50),
            multi_gpu: false,
            jobs_per_hour: None,
            seed: 3,
        });
        let prop = Simulator::new(small_cfg("fifo", "proportional"))
            .run(trace.clone());
        let tune = Simulator::new(small_cfg("fifo", "tune")).run(trace);
        let by_id = |r: &SimResult| {
            let mut m: BTreeMap<u64, f64> = BTreeMap::new();
            for f in &r.finished {
                m.insert(f.id.0, f.jct_s);
            }
            m
        };
        let p = by_id(&prop);
        let t = by_id(&tune);
        for (id, &jt) in &t {
            let jp = p[id];
            assert!(
                jt <= jp * 1.05 + self_round_slack(),
                "job {id}: tune {jt} vs prop {jp}"
            );
        }
    }

    fn self_round_slack() -> f64 {
        // One round of slack: round-boundary quantization.
        301.0
    }

    #[test]
    fn tenant_tags_flow_into_results_and_quotas_apply() {
        use crate::job::TenantId;
        use crate::workload::{SyntheticSource, TenantSpec, WorkloadSource};
        let spec = TenantSpec::parse("a:1,b:1").unwrap();
        let jobs = SyntheticSource::new(TraceConfig {
            n_jobs: 24,
            split: Split::new(0, 100, 0),
            multi_gpu: false,
            jobs_per_hour: None,
            seed: 13,
        })
        .with_tenants(spec.clone())
        .drain_jobs();
        let sim =
            Simulator::with_quotas(small_cfg("fifo", "tune"), Some(spec.quotas()));
        let r = sim.run(jobs.clone());
        assert_eq!(r.finished.len(), 24);
        let by = r.tenant_stats();
        // Both tenants appear with the right job counts.
        let n_a = jobs.iter().filter(|j| j.tenant.0 == 0).count();
        assert_eq!(by[&TenantId(0)].n, n_a);
        assert_eq!(by[&TenantId(1)].n, 24 - n_a);
    }

    #[test]
    fn memoization_preserves_schedule_and_bounds_planning() {
        // The memoized path must reproduce the forced-replan schedule
        // bit-for-bit, while planning at most once per set change under
        // a time-stable policy (FIFO keys never move between events).
        let trace = small_trace(30, 17);
        let memo = Simulator::new(small_cfg("fifo", "tune")).run(trace.clone());
        let forced = Simulator::new(SimConfig {
            force_replan: true,
            ..small_cfg("fifo", "tune")
        })
        .run(trace);
        let bits = |r: &SimResult| -> Vec<(u64, u64)> {
            r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect()
        };
        assert_eq!(bits(&memo), bits(&forced));
        assert_eq!(memo.rounds, forced.rounds);
        assert!(
            memo.planned_rounds <= forced.planned_rounds,
            "memoization may only remove mechanism runs"
        );
        assert!(
            memo.planned_rounds <= 2 * 30 + 1,
            "fifo planned rounds {} exceed arrivals+completions+1",
            memo.planned_rounds
        );
        assert!(memo.planned_rounds <= memo.rounds);
    }

    #[test]
    fn deterministic_runs() {
        let trace = small_trace(20, 11);
        let a = Simulator::new(small_cfg("srtf", "tune")).run(trace.clone());
        let b = Simulator::new(small_cfg("srtf", "tune")).run(trace);
        assert_eq!(a.jcts(), b.jcts());
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn simulator_and_bare_core_agree() {
        // The Simulator entry point is nothing but configuration: driving
        // the core directly with an equivalent FleetModel must reproduce
        // the schedule bit-for-bit.
        let trace = small_trace(24, 9);
        let cfg = small_cfg("srtf", "tune");
        let via_sim = Simulator::new(cfg).run(trace.clone());
        let cfg = small_cfg("srtf", "tune");
        let mut model = FleetModel::from_config(&cfg);
        let via_core = run_events(
            &mut model,
            policy_by_name("srtf").unwrap().as_ref(),
            None,
            &CoreConfig {
                round_s: cfg.round_s,
                max_sim_s: cfg.max_sim_s,
                ..CoreConfig::default()
            },
            trace,
        );
        assert_eq!(via_sim.rounds, via_core.rounds);
        let bits = |r: &SimResult| -> Vec<(u64, u64)> {
            r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect()
        };
        assert_eq!(bits(&via_sim), bits(&via_core));
    }

    #[test]
    fn static_trace_makespan_equals_last_finish() {
        let trace = generate(&TraceConfig {
            n_jobs: 10,
            jobs_per_hour: None,
            ..Default::default()
        });
        let r = Simulator::new(small_cfg("fifo", "proportional")).run(trace);
        let max_finish = r
            .finished
            .iter()
            .map(|f| f.jct_s)
            .fold(0.0, f64::max);
        assert!((r.makespan_s - max_finish).abs() < 1e-6);
    }

    #[test]
    fn single_long_job_runs_at_expected_speed() {
        // A single GNMT job alone in the cluster: JCT should equal its
        // baseline duration (it is GPU-bound; extra resources don't help).
        let mut j = Job::new(JobId(0), ModelKind::Gnmt, 1, 0.0, 7200.0);
        j.rng_stream = 0;
        let r = Simulator::new(small_cfg("fifo", "tune")).run(vec![j]);
        let jct = r.finished[0].jct_s;
        assert!(
            (jct - 7200.0).abs() < 60.0,
            "GNMT solo JCT {jct} should be ~7200"
        );
    }

    #[test]
    fn sensitive_solo_job_finishes_faster_than_baseline() {
        // An AlexNet job alone under TUNE gets ~9.3 cores instead of 3:
        // JCT ~ 1/3 of baseline duration.
        let j = Job::new(JobId(0), ModelKind::AlexNet, 1, 0.0, 7200.0);
        let r = Simulator::new(small_cfg("fifo", "tune")).run(vec![j]);
        let jct = r.finished[0].jct_s;
        assert!(jct < 7200.0 * 0.45, "JCT {jct} should be ~2400");
    }

    #[test]
    fn mixed_fleet_runs_through_the_same_simulator() {
        // `types` turns the same Simulator into the A.2 heterogeneous
        // engine — no separate code path.
        let types = vec![
            TypeSpec {
                gen: GpuGen::P100,
                spec: ServerSpec::default(),
                machines: 1,
            },
            TypeSpec {
                gen: GpuGen::V100,
                spec: ServerSpec::default(),
                machines: 1,
            },
        ];
        let sim = Simulator::new(SimConfig {
            types: Some(types),
            policy: "fifo".into(),
            mechanism: "tune".into(),
            ..Default::default()
        });
        let r = sim.run(small_trace(20, 5));
        assert_eq!(r.finished.len(), 20);
        assert!(r.jcts().iter().all(|&j| j > 0.0 && j.is_finite()));
    }

    #[test]
    fn explicit_flat_topology_is_bitwise_identity() {
        // `--topology flat` must be indistinguishable from not passing
        // the flag at all — the flat pass-through the goldens rest on.
        let trace = small_trace(24, 21);
        let base = Simulator::new(small_cfg("srtf", "tune")).run(trace.clone());
        let flat = Simulator::new(SimConfig {
            topology: TopologySpec::flat(),
            ..small_cfg("srtf", "tune")
        })
        .run(trace);
        let bits = |r: &SimResult| -> Vec<(u64, u64)> {
            r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect()
        };
        assert_eq!(bits(&base), bits(&flat));
        assert_eq!(base.rounds, flat.rounds);
        assert_eq!(base.gangs_placed, flat.gangs_placed);
        assert_eq!(base.cross_rack_gangs, 0, "flat never counts cross-rack");
        assert_eq!(flat.cross_rack_gangs, 0);
    }

    #[test]
    fn no_faults_spec_is_absent_by_default_and_runs_are_identical() {
        // `faults: None` must be byte-identical to a run from a build
        // that never heard of faults — the no-fault identity invariant,
        // checked here at the engine level (goldens pin it end-to-end).
        let trace = small_trace(24, 31);
        let base = Simulator::new(small_cfg("srtf", "tune")).run(trace.clone());
        // An empty script is the degenerate fault spec: zero events.
        let faulted = Simulator::new(SimConfig {
            faults: Some(FaultSpec::Script(vec![])),
            ..small_cfg("srtf", "tune")
        })
        .run(trace);
        let bits = |r: &SimResult| -> Vec<(u64, u64)> {
            r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect()
        };
        assert_eq!(bits(&base), bits(&faulted));
        assert_eq!(base.preemptions, 0);
        assert_eq!(faulted.servers_failed, 0);
    }

    #[test]
    fn churn_preempts_and_every_job_still_finishes() {
        // Aggressive churn on a 2-server pool: hosts fail and return
        // every few simulated hours. Preempted jobs must re-enter the
        // queue and complete — no job lost.
        let trace = small_trace(20, 43);
        let spec = FaultSpec::parse("mtbf:6,mttr:2,seed:5").unwrap();
        let r = Simulator::new(SimConfig {
            faults: Some(spec),
            ..small_cfg("fifo", "tune")
        })
        .run(trace);
        assert_eq!(r.finished.len(), 20, "every admitted job completes");
        assert!(r.servers_failed > 0, "churn actually fired");
        assert!(r.servers_restored > 0);
        assert!(
            r.preemptions == 0 || r.preempted_gpu_rounds_lost > 0,
            "lost work is charged whenever jobs were preempted"
        );
        assert!(r.jcts().iter().all(|&j| j > 0.0 && j.is_finite()));
    }

    #[test]
    fn faulted_runs_are_deterministic_and_tier_invariant() {
        // Same spec, same trace: bitwise-equal results — including
        // across the forced-replan tier (the fleet-epoch memo key must
        // not desynchronize the tiers under churn).
        let trace = small_trace(18, 51);
        let cfg = || SimConfig {
            faults: Some(FaultSpec::parse("mtbf:12,mttr:3").unwrap()),
            ..small_cfg("srtf", "tune")
        };
        let a = Simulator::new(cfg()).run(trace.clone());
        let b = Simulator::new(cfg()).run(trace.clone());
        let forced = Simulator::new(SimConfig {
            force_replan: true,
            ..cfg()
        })
        .run(trace);
        let bits = |r: &SimResult| -> Vec<(u64, u64)> {
            r.finished.iter().map(|f| (f.id.0, f.jct_s.to_bits())).collect()
        };
        assert_eq!(bits(&a), bits(&b));
        assert_eq!(bits(&a), bits(&forced));
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.preemptions, forced.preemptions);
        assert_eq!(a.servers_failed, forced.servers_failed);
    }

    #[test]
    fn racked_topology_counts_gangs_and_still_finishes() {
        // 2 servers × 8 GPUs under racks:2 (one server per rack): any
        // multi-server gang is cross-rack by construction, so the two
        // counters must agree, and the link cost only slows jobs down —
        // everything still completes.
        let trace = small_trace(30, 1);
        let flat = Simulator::new(small_cfg("fifo", "tune")).run(trace.clone());
        let racked = Simulator::new(SimConfig {
            topology: TopologySpec::racks(2),
            ..small_cfg("fifo", "tune")
        })
        .run(trace);
        assert_eq!(racked.finished.len(), 30);
        assert_eq!(
            racked.gangs_placed, racked.cross_rack_gangs,
            "one server per rack: every gang spans racks"
        );
        assert_eq!(flat.cross_rack_gangs, 0);
        assert!(racked.cross_rack_fraction() <= 1.0);
        if racked.cross_rack_gangs > 0 {
            // The link cost can only delay completion.
            assert!(racked.makespan_s >= flat.makespan_s - 1e-9);
        }
    }
}
