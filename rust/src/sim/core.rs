//! The shared event-driven simulation core.
//!
//! The split of responsibilities:
//!
//! - **The core** ([`run_events`]) owns the event queue and everything
//!   workload- and tenant-related: arrival admission + profiling hooks,
//!   policy ordering, weighted-quota admission with work-conserving
//!   spill ([`crate::workload::admission`]), job progress, exact
//!   completion recording, per-round utilization sampling, and metrics.
//! - **The [`ClusterModel`]** owns everything topology-related: how a
//!   job is profiled, how the policy view is derived from its context,
//!   and how the runnable set is allocated and what throughput each
//!   grant yields. Since the one-resource-model unification there is a
//!   single implementation — [`crate::sim::FleetModel`] — parameterized
//!   by the fleet description (one type pool = the paper's homogeneous
//!   setting; several = the A.2 heterogeneous one), delegating to the
//!   type-generic [`crate::mechanism`] stack.
//!
//! Because policy ordering, quota admission, progress arithmetic, and
//! metric accounting live here, a scenario (trace × quotas × policy)
//! behaves identically modulo the fleet description — same seed + same
//! scenario ⇒ identical schedule from either front-end (golden-tested
//! in `tests/scenarios.rs`, which also pins a single-type V100 fleet
//! driven through the hetero front-end to the homogeneous front-end
//! bit-for-bit).
//!
//! ## Events
//!
//! The queue carries four event kinds:
//!
//! - [`SimEvent::ServerFailed`] / [`SimEvent::ServerAdded`] — host churn
//!   from a deterministic [`crate::sim::faults::FaultSpec`] timeline:
//!   one server leaves (running gangs preempted and requeued, work
//!   preserved) or rejoins/grows the fleet. Ordered *before* arrivals
//!   at equal times so replay is exact.
//! - [`SimEvent::Arrival`] — a job arrives (profiled on arrival, §3.1).
//! - [`SimEvent::LeaseExpiry`] — the current round's resource leases end
//!   (round-based scheduling, §3.2). Lease events are lazily invalidated
//!   by round number: replanning earlier (an arrival) supersedes the
//!   outstanding lease, exactly like a real round-based scheduler
//!   preempting on queue change.
//!
//! Placements and completions are *derived*, not queued: a completion
//! instant is fully determined by the round's grants, so the core
//! records it exactly mid-round while the resources release at the next
//! lease expiry (the paper's semantics — JCT is exact, reclamation is
//! round-granular).
//!
//! ## Round-plan memoization
//!
//! The round plan is a pure function of the *ordered runnable set*: the
//! fleet starts every round from the same reset state, per-job
//! scheduling context is fixed between arrival and completion, and the
//! mechanisms are deterministic. So the core replans — runs the
//! allocation mechanism — only when that ordered runnable sequence
//! differs from the last planned round's ("replan iff observable inputs
//! changed"; the goldens are the proof). Otherwise the cached rates and
//! the still-committed placements are reused verbatim. Two tiers:
//!
//! - **Fast-forward** (pre-memoization behaviour, kept): an unchanged,
//!   fully-running active set skips even the policy/admission pass.
//! - **Memoized round**: with queued jobs present (the common at-load
//!   steady state), the cheap O(n log n) policy + admission pass runs,
//!   and only an actually-changed runnable sequence triggers the
//!   O(jobs × fit-attempts) mechanism. Under time-stable policies
//!   (FIFO) the sequence only changes on arrival/completion, so the
//!   planned-round count is bounded by `arrivals + completions + 1`
//!   (asserted by the `sim_scale` bench); time-varying keys (SRTF/LAS)
//!   replan exactly when their order genuinely shifts the runnable set.
//!
//! - **Prefix-resumed round** (the third tier, below the exact-match
//!   memoizer): when the sequence *did* change, the model's
//!   [`ClusterModel::place_round`] may resume the mechanism from a
//!   checkpoint of the previous plan instead of replanning from scratch
//!   — the per-pool fold state after a step prefix is a pure function
//!   of that prefix (see [`crate::mechanism::resume`]), so only the
//!   divergent suffix replays. Time-varying policies (SRTF/LAS), whose
//!   sequences shift almost every round and so defeat the exact-match
//!   tier, land here: reorders that leave the demand-sorted pool order
//!   intact reuse the whole plan, and arrivals/completions reuse the
//!   undisturbed prefix. [`SimResult::resumed_rounds`] and the
//!   reused-step totals report the split.
//!
//! [`CoreConfig::force_replan`] disables the memoized tier (every
//! non-fast-forward round replans — the pre-memoization hot path);
//! `tests/memo_parity.rs` pins all planning tiers to bit-identical
//! schedules (forced vs memoized vs prefix-resumed). This plus
//! arena-backed job state is what keeps 512-GPU × 8000-job traces
//! tractable (`benches/sim_scale.rs` → `BENCH_sim.json`).

use crate::job::{Job, JobArena, JobId, JobState, TenantId};
use crate::metrics::{per_tenant_stats, JctStats, UtilSample, UtilizationLog};
use crate::policy::{PolicyJobView, SchedulingPolicy};
use crate::sim::faults::{FaultEntry, FaultKind};
use crate::telemetry::{
    milli, PlanEvent, PlanTier, PoolCounters, RoundSample,
    TelemetryRecorder, TenantCounters,
};
use crate::workload::{admission, AdmissionJob, TenantQuotas};
use std::collections::{BTreeMap, BinaryHeap};

/// Core loop knobs shared by every topology.
#[derive(Debug, Clone, Copy)]
pub struct CoreConfig {
    /// Scheduling round length, seconds (paper uses ~5 minutes).
    pub round_s: f64,
    /// Stop after this much simulated time (safety valve).
    pub max_sim_s: f64,
    /// Disable round-plan memoization: rerun the mechanism on every
    /// round with a non-fast-forwardable active set (the pre-memoization
    /// behaviour). Exists for the memo-parity harness; schedules must be
    /// bit-identical either way.
    pub force_replan: bool,
}

impl Default for CoreConfig {
    fn default() -> Self {
        CoreConfig {
            round_s: 300.0,
            max_sim_s: 400.0 * 24.0 * 3600.0,
            force_replan: false,
        }
    }
}

/// Arena-aligned per-round progress rates — the deployed plan's output,
/// reused across rounds (memoized rounds read the previous plan's
/// entries verbatim).
#[derive(Debug)]
pub struct RoundRates {
    rates: Vec<f64>,
    placed: Vec<bool>,
}

impl RoundRates {
    pub fn new(n_jobs: usize) -> RoundRates {
        RoundRates { rates: vec![0.0; n_jobs], placed: vec![false; n_jobs] }
    }

    /// Drop every entry (start of a replanned round).
    pub fn clear(&mut self) {
        self.placed.fill(false);
    }

    /// Record a placed job's progress rate for the round.
    pub fn set(&mut self, idx: usize, rate: f64) {
        self.rates[idx] = rate;
        self.placed[idx] = true;
    }

    /// The rate granted to arena job `idx`, or `None` if unplaced.
    pub fn get(&self, idx: usize) -> Option<f64> {
        self.placed[idx].then(|| self.rates[idx])
    }

    /// Extend the slots to cover `n_jobs` arena entries (mid-run job
    /// injection via a [`RoundDriver`]); existing entries are untouched.
    pub fn grow(&mut self, n_jobs: usize) {
        if n_jobs > self.rates.len() {
            self.rates.resize(n_jobs, 0.0);
            self.placed.resize(n_jobs, false);
        }
    }
}

/// Statistics of one planning round, as reported by
/// [`ClusterModel::place_round`] and aggregated into [`SimResult`]
/// (and, when a [`crate::telemetry::TelemetryRecorder`] is attached,
/// into one plan-stage trace event per round).
#[derive(Debug, Clone, Default)]
pub struct PlanStats {
    /// Whether any planning step was served from the previous plan's
    /// checkpoint instead of replayed (prefix resume engaged).
    pub resumed: bool,
    /// Per-job planning steps this plan comprised (0 when the mechanism
    /// does not report step accounting — non-resumable paths).
    pub steps_total: usize,
    /// Steps reused from the checkpointed prefix.
    pub steps_reused: usize,
    /// Cluster undo-journal entries rolled back to reach the reused
    /// prefixes (0 on full replans, batch fallbacks, and memoized
    /// rounds).
    pub rollback_depth: usize,
    /// Fit-index probes the mechanism walked for this plan (drained
    /// from the per-pool cluster counters; 0 when the topology does not
    /// track them).
    pub fit_walk: usize,
    /// Per-pool (reused, replayed) step split, pool order (empty from
    /// non-resumable mechanisms and batch fallbacks).
    pub pool_stats: Vec<crate::mechanism::PoolPlanStats>,
    /// Multi-server gangs this plan committed (placements spanning more
    /// than one server).
    pub gangs_placed: u32,
    /// Of [`PlanStats::gangs_placed`], the gangs whose servers straddle
    /// a rack boundary under the fleet's [`crate::cluster::Topology`].
    /// Always 0 on a flat topology.
    pub cross_rack_gangs: u32,
}

/// What a topology must provide to the core loop. Implementations keep
/// per-job scheduling context (sensitivity matrices) internally, keyed
/// by the dense arena index the core hands them.
pub trait ClusterModel {
    /// Can this job's gang ever be placed (one pool must fit it)?
    fn fits(&self, job: &Job) -> bool;

    /// Cluster-wide GPU capacity (the admission budget).
    fn total_gpus(&self) -> u32;

    /// Profile an arriving job: derive its total work (`total_samples`)
    /// and cache its scheduling context under arena index `idx`. Returns
    /// the profiling cost in minutes (§3.1 accounting).
    fn profile_arrival(&mut self, idx: usize, job: &mut Job) -> f64;

    /// Drop the context of a departed job.
    fn forget(&mut self, idx: usize);

    /// Append policy views for the active set (id order) to `out`; the
    /// core orders them with the scheduling policy. Views are defined
    /// against the round-start (reset) fleet regardless of when they are
    /// evaluated.
    fn policy_views(&self, arena: &JobArena, out: &mut Vec<PolicyJobView>);

    /// Plan the round: restore the fleet to its round-start state (§3.2:
    /// placements are recomputed from scratch every round — either a
    /// hard reset or a checkpoint rollback to the reused prefix),
    /// allocate + place the admitted runnable set (policy order, arena
    /// indices), and record each placed job's progress rate (samples/s)
    /// for the round into `rates` (cleared by the core beforehand). Jobs
    /// left unset stay queued. Called only when the round actually
    /// replans — memoized rounds keep the committed placements, which
    /// are identical to what a replan would recommit. Returns the plan's
    /// resume statistics.
    fn place_round(
        &mut self,
        runnable: &[u32],
        arena: &JobArena,
        rates: &mut RoundRates,
    ) -> PlanStats;

    /// Apply one churn event to type pool `pool`: on
    /// [`FaultKind::Fail`], take one server (deterministic
    /// scan-position rule) offline, evict every placement touching it,
    /// and append the arena indices of the preempted jobs to
    /// `preempted`; on [`FaultKind::Add`], restore an offline server or
    /// grow the pool by a fresh one. Returns whether a server actually
    /// changed state (a `Fail` against an all-offline pool is a no-op).
    /// The caller owns all replan/metrics bookkeeping — an applied
    /// fault must force a replan (the fleet epoch) because committed
    /// placements and plan checkpoints are unsound across a membership
    /// change. The default ignores faults (models without churn
    /// support).
    fn apply_fault(
        &mut self,
        kind: FaultKind,
        pool: usize,
        arena: &JobArena,
        preempted: &mut Vec<u32>,
    ) -> bool {
        let _ = (kind, pool, arena, preempted);
        false
    }

    /// One utilization sample of the deployed round.
    fn utilization(&self, now: f64, arena: &JobArena) -> UtilSample;

    /// Append one O(1) counter snapshot per type pool to `out`
    /// (telemetry only — must read incremental aggregates, never fresh
    /// scans, and must never influence scheduling). The default reports
    /// no pools; called only when a recorder is attached.
    fn pool_counters(&self, out: &mut Vec<crate::telemetry::PoolCounters>) {
        out.clear();
    }

    /// Snapshot the currently committed placements as deployable grants
    /// (primary-server assignment per placed job) into `out`, for a
    /// [`RoundDriver`] that executes the plan on real workers. Read-only
    /// on the schedule; called only when the driver asks for grants
    /// ([`RoundDriver::wants_grants`]). The default reports none.
    fn deployed_grants(&self, out: &mut Vec<DeployedGrant>) {
        out.clear();
    }
}

/// One committed placement, as a live driver deploys it: which server
/// primarily hosts the gang and what the grant's demand vector is.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployedGrant {
    pub id: JobId,
    /// Primary hosting server (the share with the most GPUs; lowest
    /// server id on ties — deterministic).
    pub server: usize,
    pub gpus: u32,
    pub cpus: f64,
    pub mem_gb: f64,
}

/// Work or churn injected into a running core loop by a
/// [`RoundDriver`].
#[derive(Debug)]
pub enum DriverEvent {
    /// A job submitted mid-run. The core admits it through the normal
    /// arrival path (profiling included) at
    /// `max(job.arrival_s, now)`; jobs no pool can ever fit are
    /// dropped, mirroring the up-front `fits` retain — drivers
    /// validate before injecting.
    Submit(Job),
    /// Churn on type pool `pool` at the current sim time, routed
    /// through the same [`ClusterModel::apply_fault`] preempt-and-
    /// requeue path as a scripted fault timeline.
    Churn { kind: FaultKind, pool: usize },
}

/// Hook surface that lets an external round executor (the live deploy
/// leader) ride the event-driven core: the core remains the single
/// owner of planning, admission, progress arithmetic, and completion
/// accounting, while the driver feeds submissions/churn in and carries
/// grants out to real workers. [`NullDriver`] implements every hook as
/// a no-op, and `run_events_with_faults` runs through it — pure
/// simulation paths are byte-identical to the pre-driver core.
pub trait RoundDriver {
    /// `true` while more work may still arrive: the loop keeps ticking
    /// rounds even when every admitted job has finished.
    fn stream_open(&self) -> bool {
        false
    }

    /// Collect externally injected events at the top of a round
    /// iteration. `now` is the current sim time; push into `inbox`.
    fn poll(&mut self, now: f64, inbox: &mut Vec<DriverEvent>) {
        let _ = (now, inbox);
    }

    /// Whether [`RoundDriver::on_round`] needs the committed grants
    /// snapshot (skipped when `false`, so simulation paths never pay
    /// for it).
    fn wants_grants(&self) -> bool {
        false
    }

    /// Observe one executed round after the plan is deployed and the
    /// round's completions are folded.
    fn on_round(&mut self, ctx: &RoundCtx) {
        let _ = ctx;
    }

    /// Observe one exact completion, in completion order.
    fn on_finished(&mut self, f: &FinishedJob, now: f64) {
        let _ = (f, now);
    }

    /// Advance sim time toward `target` (the next event horizon). A
    /// real-time driver sleeps the scaled wall interval and returns
    /// `Some(target)`; returning `None` stops the loop (wall deadline
    /// reached). The returned time must equal `target` whenever the
    /// run is to stay byte-identical to a pure simulation.
    fn advance(&mut self, now: f64, target: f64) -> Option<f64> {
        let _ = now;
        Some(target)
    }
}

/// The inert driver behind every pure-simulation entry point.
pub struct NullDriver;

impl RoundDriver for NullDriver {}

/// What [`RoundDriver::on_round`] sees of an executed round.
pub struct RoundCtx<'a> {
    /// Round counter (0-based, pre-increment).
    pub round: usize,
    /// Round start, sim seconds.
    pub now: f64,
    /// Round end: the earliest of lease expiry and the next event.
    pub horizon: f64,
    pub arena: &'a JobArena,
    /// Committed placements (empty unless
    /// [`RoundDriver::wants_grants`]).
    pub grants: &'a [DeployedGrant],
    /// Completions folded so far, run total.
    pub finished: usize,
    /// Jobs admitted so far, run total.
    pub n_total: usize,
}

/// An event in the simulation queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SimEvent {
    /// Churn: one server in the fault timeline's entry `seq` fails at
    /// `at` (entry into the run's materialized
    /// [`crate::sim::faults::FaultEntry`] slice).
    ServerFailed { at: f64, seq: usize },
    /// Churn: one server is restored/added per timeline entry `seq`.
    ServerAdded { at: f64, seq: usize },
    /// Job `idx` (index into the arrival-sorted trace) arrives at `at`.
    Arrival { at: f64, idx: usize },
    /// Round `round`'s resource leases expire at `at`. Stale when the
    /// core has moved past `round` (lazy invalidation).
    LeaseExpiry { at: f64, round: usize },
}

impl SimEvent {
    fn at(&self) -> f64 {
        match *self {
            SimEvent::ServerFailed { at, .. }
            | SimEvent::ServerAdded { at, .. }
            | SimEvent::Arrival { at, .. }
            | SimEvent::LeaseExpiry { at, .. } => at,
        }
    }

    /// (time, kind, seq): failures before additions before arrivals
    /// before lease expiries at equal times, then FIFO by index within
    /// a kind — a deterministic total order, so faulted replay is
    /// exact. The relative order of arrivals and lease expiries is
    /// unchanged from the pre-fault core, which keeps fault-free runs
    /// byte-identical.
    fn order_key(&self) -> (f64, u8, usize) {
        match *self {
            SimEvent::ServerFailed { at, seq } => (at, 0, seq),
            SimEvent::ServerAdded { at, seq } => (at, 1, seq),
            SimEvent::Arrival { at, idx } => (at, 2, idx),
            SimEvent::LeaseExpiry { at, round } => (at, 3, round),
        }
    }
}

/// Max-heap entry ordered so the *earliest* event pops first.
struct HeapEntry(SimEvent);

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        let (ta, ka, ia) = self.0.order_key();
        let (tb, kb, ib) = other.0.order_key();
        // Reversed: BinaryHeap pops the maximum, we want the minimum.
        tb.total_cmp(&ta).then(kb.cmp(&ka)).then(ib.cmp(&ia))
    }
}

/// The simulation event queue.
///
/// Lease expiries are invalidated lazily (a new round supersedes the old
/// round's expiry without removing it), so stale entries buried under
/// far-future arrivals would otherwise accumulate without bound — one
/// per round over a million-job trace. `drop_stale` therefore compacts
/// the heap whenever it exceeds twice the live-event count, keeping the
/// heap O(pending arrivals) while staying amortized O(1) per round.
struct EventQueue {
    heap: BinaryHeap<HeapEntry>,
    /// Queued (not yet popped) arrivals — the live-event lower bound the
    /// compaction threshold is measured against.
    arrivals: usize,
    /// Queued (not yet popped) churn events — like arrivals, live until
    /// popped, so compaction must keep them and count them live.
    churn: usize,
}

impl EventQueue {
    fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new(), arrivals: 0, churn: 0 }
    }

    fn push(&mut self, e: SimEvent) {
        match e {
            SimEvent::Arrival { .. } => self.arrivals += 1,
            SimEvent::ServerFailed { .. } | SimEvent::ServerAdded { .. } => {
                self.churn += 1
            }
            SimEvent::LeaseExpiry { .. } => {}
        }
        self.heap.push(HeapEntry(e));
    }

    /// Total queued entries, stale lease expiries included (the
    /// compaction regression test bounds this).
    #[cfg(test)]
    fn len(&self) -> usize {
        self.heap.len()
    }

    /// Drop lease events from rounds other than `round` off the top,
    /// then compact buried stale leases once they dominate the heap.
    fn drop_stale(&mut self, round: usize) {
        while matches!(
            self.heap.peek(),
            Some(HeapEntry(SimEvent::LeaseExpiry { round: r, .. })) if *r != round
        ) {
            self.heap.pop();
        }
        // Live events: every queued arrival and churn event plus at
        // most one current lease expiry. Rebuilding preserves pop order
        // exactly — it is a pure function of `order_key`'s total order,
        // so dropping never-poppable stale entries is
        // schedule-invisible.
        let live = self.arrivals + self.churn + 1;
        if self.heap.len() > 2 * live {
            self.heap = std::mem::take(&mut self.heap)
                .into_iter()
                .filter(|HeapEntry(e)| match e {
                    SimEvent::Arrival { .. }
                    | SimEvent::ServerFailed { .. }
                    | SimEvent::ServerAdded { .. } => true,
                    SimEvent::LeaseExpiry { round: r, .. } => *r == round,
                })
                .collect();
        }
    }

    /// Pop the next arrival due at or before `deadline`, if it is the
    /// earliest live event.
    fn pop_arrival_due(&mut self, deadline: f64, round: usize) -> Option<usize> {
        self.drop_stale(round);
        if let Some(HeapEntry(SimEvent::Arrival { at, idx })) = self.heap.peek() {
            if *at <= deadline {
                let idx = *idx;
                self.heap.pop();
                self.arrivals -= 1;
                return Some(idx);
            }
        }
        None
    }

    /// Pop the next churn event due at or before `deadline`, if it is
    /// the earliest live event. Returns the fault-timeline entry index
    /// and kind; failures pop before additions at equal times
    /// (`order_key`).
    fn pop_churn_due(
        &mut self,
        deadline: f64,
        round: usize,
    ) -> Option<(usize, FaultKind)> {
        self.drop_stale(round);
        let (seq, kind) = match self.heap.peek() {
            Some(HeapEntry(SimEvent::ServerFailed { at, seq }))
                if *at <= deadline =>
            {
                (*seq, FaultKind::Fail)
            }
            Some(HeapEntry(SimEvent::ServerAdded { at, seq }))
                if *at <= deadline =>
            {
                (*seq, FaultKind::Add)
            }
            _ => return None,
        };
        self.heap.pop();
        self.churn -= 1;
        Some((seq, kind))
    }

    /// Time of the earliest live event.
    fn next_at(&mut self, round: usize) -> Option<f64> {
        self.drop_stale(round);
        self.heap.peek().map(|e| e.0.at())
    }

    /// Time of the earliest queued arrival or churn event (used for the
    /// idle fast-forward jump). Called between rounds, when every lease
    /// event still in the heap is stale — so after
    /// [`EventQueue::drop_stale`] the top is the next wake event (or
    /// the queue is drained), keeping this O(log n) rather than a heap
    /// scan.
    fn next_wake_at(&mut self, round: usize) -> Option<f64> {
        self.drop_stale(round);
        match self.heap.peek() {
            Some(HeapEntry(SimEvent::LeaseExpiry { .. })) | None => None,
            Some(HeapEntry(e)) => Some(e.at()),
        }
    }
}

/// Assemble one round's utilization sample from a topology's resource
/// ratios plus the core-owned active-set accounting. Shared by both
/// [`ClusterModel`] implementations so the metrics (notably the
/// `cpu_used` Fig-10b quantity: Σ rate / per-core prep rate) cannot
/// drift apart between engines.
pub fn utilization_sample(
    now: f64,
    arena: &JobArena,
    gpu_util: f64,
    cpu_util: f64,
    mem_util: f64,
    total_cpus: f64,
) -> UtilSample {
    // A fully-offline fleet has zero capacity; report 0.0 usage rather
    // than 0/0 = NaN (nothing can be Running then anyway).
    let cpu_used: f64 = if total_cpus == 0.0 {
        0.0
    } else {
        arena
            .active_jobs()
            .filter(|j| j.state == JobState::Running)
            .map(|j| j.progress_rate / j.model.coeffs().cpu_prep_rate)
            .sum::<f64>()
            / total_cpus
    };
    UtilSample {
        time_s: now,
        gpu_util,
        cpu_util,
        cpu_used,
        mem_util,
        queued_jobs: arena
            .active_jobs()
            .filter(|j| j.state == JobState::Queued)
            .count(),
        running_jobs: arena
            .active_jobs()
            .filter(|j| j.state == JobState::Running)
            .count(),
    }
}

/// Simulation output (shared by both engines).
#[derive(Debug)]
pub struct SimResult {
    /// Finished jobs in completion order (id, tenant, gpus, arrival,
    /// baseline duration, JCT seconds).
    pub finished: Vec<FinishedJob>,
    pub makespan_s: f64,
    pub rounds: usize,
    /// Rounds that actually ran the allocation mechanism; the rest were
    /// fast-forwarded or served from the memoized plan. Under
    /// time-stable policies this is bounded by
    /// `arrivals + completions + 1`.
    pub planned_rounds: usize,
    /// Planned rounds that resumed from the previous plan's checkpoint
    /// (some planning steps reused instead of replayed) — the third
    /// planning tier, below the exact-sequence memoizer. Always
    /// `<= planned_rounds`; 0 under `force_replan`/`no_resume` or
    /// non-resumable mechanisms.
    pub resumed_rounds: usize,
    /// Total per-job planning steps across all planned rounds (resume
    /// accounting; 0 when the mechanism does not report steps).
    pub plan_steps_total: usize,
    /// Of [`SimResult::plan_steps_total`], the steps served from
    /// checkpointed prefixes. `reused / total` is the mean reused-prefix
    /// fraction the `sim_scale` bench reports.
    pub plan_steps_reused: usize,
    pub utilization: UtilizationLog,
    /// Total profiling cost across all jobs, minutes (§3.1 accounting).
    pub profiling_minutes: f64,
    /// Multi-server gangs deployed, summed over executed rounds (a gang
    /// running N rounds counts N times — round-weighted exposure, the
    /// denominator for [`SimResult::cross_rack_fraction`]). Memoized and
    /// fast-forwarded rounds re-count the carried plan's gangs, since
    /// those placements stay committed.
    pub gangs_placed: u64,
    /// Of [`SimResult::gangs_placed`], the round-weighted count whose
    /// placements straddled a rack boundary. Always 0 on a flat
    /// topology.
    pub cross_rack_gangs: u64,
    /// Running jobs preempted by server failures and requeued (work
    /// preserved). 0 without fault injection.
    pub preemptions: u64,
    /// GPU-rounds of in-flight lease lost to preemptions: each victim
    /// charges its gang width once (round-quantized progress means the
    /// *completed* rounds are preserved exactly; what a failure kills
    /// is the round in flight).
    pub preempted_gpu_rounds_lost: u64,
    /// Servers taken offline by the fault timeline (no-op failures
    /// against an empty pool excluded).
    pub servers_failed: u64,
    /// Servers restored or added by the fault timeline.
    pub servers_restored: u64,
}

#[derive(Debug, Clone, Copy)]
pub struct FinishedJob {
    pub id: JobId,
    pub tenant: TenantId,
    pub gpus: u32,
    pub arrival_s: f64,
    pub duration_prop_s: f64,
    pub jct_s: f64,
}

impl SimResult {
    pub fn jcts(&self) -> Vec<f64> {
        self.finished.iter().map(|f| f.jct_s).collect()
    }

    pub fn jct_stats(&self) -> JctStats {
        JctStats::from_jcts(&self.jcts())
    }

    /// Per-tenant JCT summaries (multi-tenant workloads).
    pub fn tenant_stats(&self) -> BTreeMap<TenantId, JctStats> {
        let pairs: Vec<(TenantId, f64)> =
            self.finished.iter().map(|f| (f.tenant, f.jct_s)).collect();
        per_tenant_stats(&pairs)
    }

    /// JCTs of a monitored subrange of jobs (steady-state window, §5.1).
    pub fn jcts_in_window(&self, from_idx: usize, n: usize) -> Vec<f64> {
        self.finished
            .iter()
            .filter(|f| {
                (f.id.0 as usize) >= from_idx && (f.id.0 as usize) < from_idx + n
            })
            .map(|f| f.jct_s)
            .collect()
    }

    /// Fraction of round-weighted gang exposure that ran cross-rack
    /// (`cross_rack_gangs / gangs_placed`; 0.0 when no gangs ran). The
    /// consolidation ablation's headline locality figure.
    pub fn cross_rack_fraction(&self) -> f64 {
        if self.gangs_placed == 0 {
            return 0.0;
        }
        self.cross_rack_gangs as f64 / self.gangs_placed as f64
    }

    /// Round-planning summary (memoized/resumed tier accounting).
    pub fn plan_summary(&self) -> crate::metrics::PlanSummary {
        crate::metrics::PlanSummary {
            planned_rounds: self.planned_rounds,
            resumed_rounds: self.resumed_rounds,
            reused_steps: self.plan_steps_reused,
            total_steps: self.plan_steps_total,
        }
    }

    /// Churn/preemption summary (fault-injection accounting).
    pub fn fault_summary(&self) -> crate::metrics::FaultSummary {
        crate::metrics::FaultSummary {
            preemptions: self.preemptions,
            preempted_gpu_rounds_lost: self.preempted_gpu_rounds_lost,
            servers_failed: self.servers_failed,
            servers_restored: self.servers_restored,
        }
    }

    /// The canonical metrics document ([`crate::metrics::metrics_json`]).
    /// `plan_stats` and `fault_stats` (both default **off** — golden
    /// files must not change) append the round-planning split and the
    /// churn/preemption counters respectively (the CLI turns
    /// `fault_stats` on exactly when `--faults` is given).
    pub fn metrics_json(&self, plan_stats: bool, fault_stats: bool) -> String {
        let summary = self.plan_summary();
        let faults = self.fault_summary();
        crate::metrics::metrics_json(
            &self.jct_stats(),
            &self.tenant_stats(),
            self.makespan_s,
            self.rounds,
            plan_stats.then_some(&summary),
            fault_stats.then_some(&faults),
        )
    }
}

/// Run a trace to completion (or `cfg.max_sim_s`) over `model`.
///
/// The one scheduling loop behind both simulators: arrivals are profiled
/// as their events fire, the policy orders the active set, quota
/// admission cuts the runnable set ([`admission::admit`] — byte-identical
/// to plain gang backfill when `quotas` is `None`), the model allocates,
/// and jobs progress at their granted rates until the next event.
pub fn run_events<M: ClusterModel + ?Sized>(
    model: &mut M,
    policy: &dyn SchedulingPolicy,
    quotas: Option<&TenantQuotas>,
    cfg: &CoreConfig,
    jobs: Vec<Job>,
) -> SimResult {
    run_events_with_faults(model, policy, quotas, cfg, jobs, None, &[])
}

/// One [`TenantCounters`] slot per tenant, keyed deterministically.
fn tenant_entry(
    map: &mut BTreeMap<TenantId, TenantCounters>,
    t: TenantId,
) -> &mut TenantCounters {
    map.entry(t).or_insert(TenantCounters {
        tenant: t,
        running: 0,
        pending: 0,
        admitted_gpus: 0,
        spilled_gpus: 0,
    })
}

/// [`run_events`] with an optional [`TelemetryRecorder`] attached.
///
/// With `telemetry: None` this *is* `run_events`. With a recorder, every
/// executed round appends one [`RoundSample`] (cluster-wide + per-pool +
/// per-tenant counters) and one [`PlanEvent`] (which planning tier served
/// the round, step/rollback/fit-walk accounting). Recording is strictly
/// read-only on the schedule: it samples incremental aggregates after
/// the round is deployed, so the returned [`SimResult`] is bit-identical
/// with the recorder on or off (pinned by `tests/telemetry.rs`).
/// Wall-clock time is sampled only when the recorder was built with
/// [`crate::telemetry::TelemetryConfig::timing`] — deterministic runs
/// carry counters and sim-time only.
pub fn run_events_recorded<M: ClusterModel + ?Sized>(
    model: &mut M,
    policy: &dyn SchedulingPolicy,
    quotas: Option<&TenantQuotas>,
    cfg: &CoreConfig,
    jobs: Vec<Job>,
    telemetry: Option<&mut TelemetryRecorder>,
) -> SimResult {
    run_events_with_faults(model, policy, quotas, cfg, jobs, telemetry, &[])
}

/// [`run_events_recorded`] plus a materialized fault timeline
/// ([`crate::sim::faults::FaultSpec::schedule`]).
///
/// Churn events are enqueued up front and fire *before* arrivals at
/// equal times (see [`SimEvent`]'s order key). On a failure the model
/// preempts every gang touching the victim server: the jobs re-enter
/// the runnable queue with their completed round-quantized work
/// preserved, the in-flight lease is charged to
/// [`SimResult::preempted_gpu_rounds_lost`], and the fleet epoch bumps
/// so the next plan cannot be served from the memoized plan or a
/// now-unsound resume checkpoint. With `faults` empty this *is*
/// [`run_events_recorded`] — fault-free runs are byte-identical to the
/// pre-fault core (golden-pinned).
pub fn run_events_with_faults<M: ClusterModel + ?Sized>(
    model: &mut M,
    policy: &dyn SchedulingPolicy,
    quotas: Option<&TenantQuotas>,
    cfg: &CoreConfig,
    jobs: Vec<Job>,
    telemetry: Option<&mut TelemetryRecorder>,
    faults: &[FaultEntry],
) -> SimResult {
    run_events_driven(
        model,
        policy,
        quotas,
        cfg,
        jobs,
        telemetry,
        faults,
        &mut NullDriver,
    )
}

/// [`run_events_with_faults`] with a [`RoundDriver`] attached — the
/// full core loop. The driver can hold the stream open past the last
/// known job, inject submissions and churn mid-run, read each round's
/// committed grants, observe exact completions, and pace (or stop) the
/// advance of sim time. Every pure-simulation entry point runs through
/// [`NullDriver`], whose hooks are all no-ops — those paths are
/// byte-identical to the pre-driver core. The live deploy leader is
/// the real driver: it shares this exact planning/accounting code
/// path with the simulator, which is what makes a recovered leader's
/// replay byte-identical to the run it resumes.
#[allow(clippy::too_many_arguments)]
pub fn run_events_driven<M: ClusterModel + ?Sized, D: RoundDriver>(
    model: &mut M,
    policy: &dyn SchedulingPolicy,
    quotas: Option<&TenantQuotas>,
    cfg: &CoreConfig,
    mut jobs: Vec<Job>,
    mut telemetry: Option<&mut TelemetryRecorder>,
    faults: &[FaultEntry],
    driver: &mut D,
) -> SimResult {
    jobs.sort_by(|a, b| {
        a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id))
    });
    // Reject jobs that can never fit.
    jobs.retain(|j| model.fits(j));
    let mut n_total = jobs.len();

    let mut queue = EventQueue::new();
    for (idx, j) in jobs.iter().enumerate() {
        queue.push(SimEvent::Arrival { at: j.arrival_s, idx });
    }
    // The scripted churn timeline is known up front (it is a pure
    // function of the fault spec) — enqueue it; `seq` indexes into
    // `fault_log`, which grows past the scripted entries when a driver
    // injects live churn.
    let mut fault_log: Vec<FaultEntry> = faults.to_vec();
    for (seq, f) in fault_log.iter().enumerate() {
        queue.push(match f.kind {
            FaultKind::Fail => SimEvent::ServerFailed { at: f.at, seq },
            FaultKind::Add => SimEvent::ServerAdded { at: f.at, seq },
        });
    }
    let mut arena = JobArena::new(jobs);

    let mut profiling_minutes = 0.0;
    let mut finished: Vec<FinishedJob> = Vec::new();
    let mut util = UtilizationLog::default();
    let mut now = 0.0f64;
    let mut rounds = 0usize;
    let mut planned_rounds = 0usize;
    let mut resumed_rounds = 0usize;
    let mut plan_steps_total = 0usize;
    let mut plan_steps_reused = 0usize;
    let mut last_set_changed = true;
    // Fleet-membership epoch: bumped by every applied fault. The memo
    // key is (epoch, runnable sequence) — a plan computed against a
    // different fleet must never be served, even if the sequence
    // matches.
    let mut fleet_epoch = 0u64;
    let mut planned_epoch = 0u64;
    let mut preemptions = 0u64;
    let mut preempted_gpu_rounds_lost = 0u64;
    let mut servers_failed = 0u64;
    let mut servers_restored = 0u64;
    let mut preempted_buf: Vec<u32> = Vec::new();

    // Round-scoped buffers, reused across rounds (the per-round
    // allocations were a measurable slice of the hot loop).
    let mut views: Vec<PolicyJobView> = Vec::new();
    let mut ordered: Vec<AdmissionJob> = Vec::new();
    let mut ordered_idx: Vec<u32> = Vec::new();
    let mut rates = RoundRates::new(n_total);
    let mut runnable: Vec<u32> = Vec::new();
    // The runnable sequence the cached plan was computed from.
    let mut planned_runnable: Vec<u32> = Vec::new();
    let mut have_plan = false;
    let mut done: Vec<u32> = Vec::new();
    let mut inbox: Vec<DriverEvent> = Vec::new();
    let mut grants_buf: Vec<DeployedGrant> = Vec::new();

    // Telemetry state. Zero-cost when no recorder is attached: the
    // buffers stay empty and every recording block is skipped.
    let recording = telemetry.is_some();
    let wall_start = telemetry
        .as_ref()
        .filter(|r| r.config().timing)
        .map(|_| std::time::Instant::now());
    let mut pools_buf: Vec<PoolCounters> = Vec::new();
    let mut tenants_buf: BTreeMap<TenantId, TenantCounters> = BTreeMap::new();
    // Admission counters carry across fast-forwarded rounds (no fresh
    // admission pass ran, so the deployed split is the last computed one).
    let mut last_admitted: BTreeMap<TenantId, u32> = BTreeMap::new();
    let mut last_spilled: BTreeMap<TenantId, u32> = BTreeMap::new();
    let mut last_plan_steps = 0usize;
    // Gang counters carry like the admission split: memoized and
    // fast-forwarded rounds keep the last plan's committed placements,
    // so the deployed gang exposure is the last planned one.
    let mut last_gangs = 0u32;
    let mut last_cross_rack = 0u32;
    let mut gangs_placed_total = 0u64;
    let mut cross_rack_total = 0u64;

    while (finished.len() < n_total || driver.stream_open())
        && now < cfg.max_sim_s
    {
        // Externally injected work and churn first, so events injected
        // "now" fire inside this round's event drain (churn before
        // arrivals at equal times, as always).
        driver.poll(now, &mut inbox);
        for ev in inbox.drain(..) {
            match ev {
                DriverEvent::Submit(job) => {
                    if !model.fits(&job) {
                        continue; // mirrors the up-front `fits` retain
                    }
                    let at = job.arrival_s.max(now);
                    let idx = arena.push(job);
                    rates.grow(arena.n_jobs());
                    queue.push(SimEvent::Arrival { at, idx });
                    n_total += 1;
                }
                DriverEvent::Churn { kind, pool } => {
                    let seq = fault_log.len();
                    fault_log.push(FaultEntry { at: now, pool, kind });
                    queue.push(match kind {
                        FaultKind::Fail => {
                            SimEvent::ServerFailed { at: now, seq }
                        }
                        FaultKind::Add => {
                            SimEvent::ServerAdded { at: now, seq }
                        }
                    });
                }
            }
        }
        let mut planned_this_round: Option<PlanStats> = None;
        // Per-round churn telemetry tallies (events are instantaneous,
        // so unlike the admission/gang gauges nothing carries across
        // fast-forwarded rounds).
        let mut round_preemptions = 0u32;
        let mut round_failed = 0u32;
        let mut round_restored = 0u32;
        // Fire due events in exact heap order: churn before arrivals at
        // equal times (each pop helper only fires when its kind tops
        // the heap, so interleaved timelines drain in `order_key`
        // order). Profiling happens on arrival.
        loop {
            if let Some((seq, kind)) = queue.pop_churn_due(now + 1e-9, rounds)
            {
                preempted_buf.clear();
                if model.apply_fault(
                    kind,
                    fault_log[seq].pool,
                    &arena,
                    &mut preempted_buf,
                ) {
                    match kind {
                        FaultKind::Fail => {
                            servers_failed += 1;
                            round_failed += 1;
                        }
                        FaultKind::Add => {
                            servers_restored += 1;
                            round_restored += 1;
                        }
                    }
                    fleet_epoch += 1;
                    last_set_changed = true;
                }
                for &idx in &preempted_buf {
                    let job = arena.job_mut(idx as usize);
                    // Requeue with completed work preserved: the
                    // round-quantized `progress_samples` already
                    // credited stays; what the failure kills is the
                    // lease in flight, charged below.
                    job.state = JobState::Queued;
                    job.progress_rate = 0.0;
                    preempted_gpu_rounds_lost += job.gpus as u64;
                }
                preemptions += preempted_buf.len() as u64;
                round_preemptions += preempted_buf.len() as u32;
                continue;
            }
            if let Some(idx) = queue.pop_arrival_due(now + 1e-9, rounds) {
                profiling_minutes +=
                    model.profile_arrival(idx, arena.job_mut(idx));
                arena.activate(idx);
                last_set_changed = true;
                continue;
            }
            break;
        }

        // Fast-forward when nothing can change the schedule: set
        // unchanged and every active job already running. Otherwise run
        // the cheap policy + admission pass and replan only if the
        // ordered runnable sequence differs from the cached plan's (the
        // plan is a pure function of that sequence — see module docs).
        if last_set_changed
            || arena.active_jobs().any(|j| j.state != JobState::Running)
        {
            views.clear();
            model.policy_views(&arena, &mut views);
            policy.order(&mut views, now);
            // One id → arena-index translation per view; admission
            // reports positions into `ordered`, so the runnable set maps
            // back through `ordered_idx` without further lookups.
            ordered.clear();
            ordered_idx.clear();
            for v in &views {
                let idx = arena.index_of(v.id);
                ordered_idx.push(idx as u32);
                let j = arena.job(idx);
                ordered.push(AdmissionJob {
                    id: j.id,
                    tenant: j.tenant,
                    gpus: j.gpus,
                });
            }
            let outcome =
                admission::admit(&ordered, model.total_gpus(), quotas);
            runnable.clear();
            runnable.extend(
                outcome.positions.iter().map(|&p| ordered_idx[p]),
            );
            if recording {
                // The quota-free fast path skips per-tenant bookkeeping;
                // rebuild the admitted split here so the hot loop never
                // pays for it when telemetry is off.
                if quotas.is_some() {
                    last_admitted.clone_from(&outcome.gpus_by_tenant);
                } else {
                    last_admitted.clear();
                    for &p in &outcome.positions {
                        *last_admitted
                            .entry(ordered[p].tenant)
                            .or_insert(0) += ordered[p].gpus;
                    }
                }
                last_spilled.clone_from(&outcome.spilled_gpus_by_tenant);
            }

            if cfg.force_replan
                || !have_plan
                || planned_epoch != fleet_epoch
                || runnable != planned_runnable
            {
                rates.clear();
                let stats = model.place_round(&runnable, &arena, &mut rates);
                std::mem::swap(&mut planned_runnable, &mut runnable);
                have_plan = true;
                planned_epoch = fleet_epoch;
                planned_rounds += 1;
                if stats.resumed {
                    resumed_rounds += 1;
                }
                plan_steps_total += stats.steps_total;
                plan_steps_reused += stats.steps_reused;
                last_plan_steps = stats.steps_total;
                last_gangs = stats.gangs_placed;
                last_cross_rack = stats.cross_rack_gangs;
                planned_this_round = Some(stats);
            }
            // Deploy the (possibly memoized) plan. Idempotent: memoized
            // rounds re-apply the identical rates.
            for k in 0..arena.n_active() {
                let idx = arena.active_indices()[k] as usize;
                let job = arena.job_mut(idx);
                match rates.get(idx) {
                    Some(rate) => {
                        job.state = JobState::Running;
                        job.progress_rate = rate;
                    }
                    None => {
                        job.state = JobState::Queued;
                        job.progress_rate = 0.0;
                    }
                }
            }
            last_set_changed = false;
        }

        // Horizon: the earliest of this round's lease expiry and the next
        // arrival event.
        queue.push(SimEvent::LeaseExpiry { at: now + cfg.round_s, round: rounds });
        let horizon = queue
            .next_at(rounds)
            .expect("lease event just pushed")
            .max(now + 1e-6);
        let dt = horizon - now;

        // Progress running jobs; record exact finish times.
        let mut any_finished = false;
        for k in 0..arena.n_active() {
            let idx = arena.active_indices()[k] as usize;
            let job = arena.job_mut(idx);
            if job.state != JobState::Running {
                continue;
            }
            let tput = job.progress_rate;
            if tput <= 0.0 {
                continue;
            }
            let need = job.remaining_samples() / tput;
            if need <= dt {
                job.finish_s = now + need;
                job.attained_service_s += need;
                job.progress_samples = job.total_samples;
                job.state = JobState::Finished;
                any_finished = true;
            } else {
                job.progress_samples += tput * dt;
                job.attained_service_s += dt;
            }
        }
        if any_finished {
            last_set_changed = true;
            done.clear();
            done.extend(
                arena
                    .active_with_indices()
                    .filter(|(_, j)| j.state == JobState::Finished)
                    .map(|(idx, _)| idx as u32),
            );
            for &idx in &done {
                let idx = idx as usize;
                arena.deactivate(idx);
                model.forget(idx);
                let j = arena.job(idx);
                let fj = FinishedJob {
                    id: j.id,
                    tenant: j.tenant,
                    gpus: j.gpus,
                    arrival_s: j.arrival_s,
                    duration_prop_s: j.duration_prop_s,
                    jct_s: j.finish_s - j.arrival_s,
                };
                finished.push(fj);
                driver.on_finished(&fj, now);
            }
        }

        // Hand the executed round to the driver (lease deployment on
        // real workers, journal checkpointing). Strictly read-only on
        // the schedule; a no-op for [`NullDriver`].
        if driver.wants_grants() {
            model.deployed_grants(&mut grants_buf);
        }
        driver.on_round(&RoundCtx {
            round: rounds,
            now,
            horizon,
            arena: &arena,
            grants: &grants_buf,
            finished: finished.len(),
            n_total,
        });

        // Sample utilization once per executed round.
        let sample = model.utilization(now, &arena);
        if let Some(rec) = telemetry.as_deref_mut() {
            // Per-pool counters off the incremental aggregates (O(pools),
            // no fresh scans); fleet-wide figures are their sums.
            model.pool_counters(&mut pools_buf);
            let mut free_gpus = 0u32;
            let mut total_gpus = 0u32;
            let mut free_cpus = 0.0f64;
            let mut total_cpus = 0.0f64;
            let mut free_mem_gb = 0.0f64;
            let mut total_mem_gb = 0.0f64;
            for p in &pools_buf {
                free_gpus += p.free_gpus;
                total_gpus += p.total_gpus;
                free_cpus += p.free_cpus;
                total_cpus += p.total_cpus;
                free_mem_gb += p.free_mem_gb;
                total_mem_gb += p.total_mem_gb;
            }
            tenants_buf.clear();
            for j in arena.active_jobs() {
                let e = tenant_entry(&mut tenants_buf, j.tenant);
                if j.state == JobState::Running {
                    e.running += 1;
                } else {
                    e.pending += 1;
                }
            }
            for (&t, &g) in &last_admitted {
                tenant_entry(&mut tenants_buf, t).admitted_gpus = g;
            }
            for (&t, &g) in &last_spilled {
                tenant_entry(&mut tenants_buf, t).spilled_gpus = g;
            }
            let round_sample = RoundSample {
                round: rounds as u64,
                time_ms: milli(now),
                queued: sample.queued_jobs as u32,
                running: sample.running_jobs as u32,
                admitted_gpus: last_admitted.values().sum(),
                spilled_gpus: last_spilled.values().sum(),
                free_gpus,
                total_gpus,
                free_cpus,
                total_cpus,
                free_mem_gb,
                total_mem_gb,
                wall_ms: wall_start
                    .map_or(0, |s| s.elapsed().as_millis() as i64),
                gangs_placed: last_gangs,
                cross_rack_gangs: last_cross_rack,
                preemptions: round_preemptions,
                servers_failed: round_failed,
                servers_restored: round_restored,
                pools: std::mem::take(&mut pools_buf),
                tenants: tenants_buf.values().copied().collect(),
            };
            rec.record_round(&round_sample);
            pools_buf = round_sample.pools;

            // One plan-stage event per round: which tier served it.
            let ev = match planned_this_round.take() {
                Some(stats) => PlanEvent {
                    round: rounds as u64,
                    tier: if stats.resumed {
                        PlanTier::Resumed
                    } else {
                        PlanTier::Full
                    },
                    steps_total: stats.steps_total as u64,
                    steps_reused: stats.steps_reused as u64,
                    rollback_depth: stats.rollback_depth as u64,
                    fit_walk: stats.fit_walk as u64,
                    pools: stats
                        .pool_stats
                        .iter()
                        .map(|p| (p.reused as u64, p.replayed as u64))
                        .collect(),
                },
                // No mechanism run this round: served verbatim from the
                // memoized plan (or fast-forwarded past planning) — the
                // whole cached plan is the reused prefix.
                None => PlanEvent {
                    round: rounds as u64,
                    tier: PlanTier::Memoized,
                    steps_total: last_plan_steps as u64,
                    steps_reused: last_plan_steps as u64,
                    rollback_depth: 0,
                    fit_walk: 0,
                    pools: Vec::new(),
                },
            };
            rec.record_plan(&ev);
        }
        util.record(sample);

        gangs_placed_total += last_gangs as u64;
        cross_rack_total += last_cross_rack as u64;
        rounds += 1;
        // Jump straight to the next arrival or churn event when idle.
        // The round counter just advanced, so this round's lease is
        // already stale. The driver paces the advance (a real-time
        // driver sleeps the scaled interval; `None` = wall deadline,
        // stop). NullDriver advances instantly to the target.
        let target = if arena.n_active() == 0 {
            queue.next_wake_at(rounds).unwrap_or(horizon)
        } else {
            horizon
        };
        now = match driver.advance(now, target) {
            Some(t) => t,
            None => break,
        };
    }

    let makespan_s = finished
        .iter()
        .map(|f| f.arrival_s + f.jct_s)
        .fold(0.0, f64::max);
    SimResult {
        finished,
        makespan_s,
        rounds,
        planned_rounds,
        resumed_rounds,
        plan_steps_total,
        plan_steps_reused,
        utilization: util,
        profiling_minutes,
        gangs_placed: gangs_placed_total,
        cross_rack_gangs: cross_rack_total,
        preemptions,
        preempted_gpu_rounds_lost,
        servers_failed,
        servers_restored,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_kind() {
        let mut q = EventQueue::new();
        q.push(SimEvent::LeaseExpiry { at: 10.0, round: 0 });
        q.push(SimEvent::Arrival { at: 10.0, idx: 1 });
        q.push(SimEvent::Arrival { at: 5.0, idx: 0 });
        // Earliest first; at equal time, arrivals before lease expiries.
        assert_eq!(q.pop_arrival_due(20.0, 0), Some(0));
        assert_eq!(q.pop_arrival_due(20.0, 0), Some(1));
        assert_eq!(q.next_at(0), Some(10.0));
        assert_eq!(q.pop_arrival_due(20.0, 0), None);
    }

    #[test]
    fn stale_lease_events_are_skipped() {
        let mut q = EventQueue::new();
        q.push(SimEvent::LeaseExpiry { at: 3.0, round: 0 });
        q.push(SimEvent::LeaseExpiry { at: 7.0, round: 2 });
        q.push(SimEvent::Arrival { at: 5.0, idx: 4 });
        // Round 2: the round-0 lease is stale; arrival at 5 wins.
        assert_eq!(q.next_at(2), Some(5.0));
        assert_eq!(q.pop_arrival_due(5.0, 2), Some(4));
        assert_eq!(q.next_at(2), Some(7.0));
    }

    #[test]
    fn next_wake_skips_stale_lease_events() {
        let mut q = EventQueue::new();
        // A lease from round 0 is stale once the loop reaches round 1.
        q.push(SimEvent::LeaseExpiry { at: 1.0, round: 0 });
        assert_eq!(q.next_wake_at(1), None);
        q.push(SimEvent::Arrival { at: 9.0, idx: 0 });
        q.push(SimEvent::Arrival { at: 4.0, idx: 1 });
        q.push(SimEvent::LeaseExpiry { at: 2.0, round: 0 });
        assert_eq!(q.next_wake_at(1), Some(4.0));
        // An earlier churn event wakes the idle loop before the arrival.
        q.push(SimEvent::ServerAdded { at: 3.0, seq: 0 });
        assert_eq!(q.next_wake_at(1), Some(3.0));
    }

    #[test]
    fn churn_orders_before_arrivals_and_leases_with_stable_seq() {
        let mut q = EventQueue::new();
        // Everything at t=10: the full tie-break is failure < addition
        // < arrival < lease expiry, FIFO by seq within a kind.
        q.push(SimEvent::LeaseExpiry { at: 10.0, round: 0 });
        q.push(SimEvent::Arrival { at: 10.0, idx: 5 });
        q.push(SimEvent::ServerAdded { at: 10.0, seq: 3 });
        q.push(SimEvent::ServerFailed { at: 10.0, seq: 2 });
        q.push(SimEvent::ServerFailed { at: 10.0, seq: 1 });
        // An earlier failure still pops first regardless of kind rank.
        q.push(SimEvent::Arrival { at: 4.0, idx: 9 });
        assert_eq!(q.pop_churn_due(20.0, 0), None); // arrival at 4 tops
        assert_eq!(q.pop_arrival_due(20.0, 0), Some(9));
        assert_eq!(q.pop_churn_due(20.0, 0), Some((1, FaultKind::Fail)));
        assert_eq!(q.pop_churn_due(20.0, 0), Some((2, FaultKind::Fail)));
        assert_eq!(q.pop_churn_due(20.0, 0), Some((3, FaultKind::Add)));
        // Churn drained: the arrival tops the heap, lease after it.
        assert_eq!(q.pop_churn_due(20.0, 0), None);
        assert_eq!(q.pop_arrival_due(20.0, 0), Some(5));
        assert_eq!(q.next_at(0), Some(10.0));
        // A due deadline gates churn pops like arrivals.
        q.push(SimEvent::ServerFailed { at: 30.0, seq: 4 });
        assert_eq!(q.pop_churn_due(20.0, 0), None);
        assert_eq!(q.pop_churn_due(30.0, 0), Some((4, FaultKind::Fail)));
    }

    #[test]
    fn buried_stale_leases_are_compacted() {
        // Each round's lease lands *after* every pending arrival, so it
        // is buried below the heap top when the next round supersedes
        // it — the shape lazy top-popping alone never reclaims, and the
        // heap would grow by one dead entry per round for the whole run.
        let n = 1_000;
        let mut q = EventQueue::new();
        for i in 0..n {
            q.push(SimEvent::Arrival { at: i as f64, idx: i });
        }
        for round in 0..n {
            q.push(SimEvent::LeaseExpiry {
                at: 1e6 + round as f64,
                round,
            });
            // Compaction is pop-order invisible: arrivals still pop in
            // arrival order.
            assert_eq!(q.pop_arrival_due(f64::INFINITY, round), Some(round));
            assert!(
                q.len() <= 2 * (n - round + 1),
                "round {round}: stale leases accumulate, len = {}",
                q.len()
            );
        }
        // Drained of arrivals, the queue holds the live lease alone
        // (plus at most one not-yet-compacted stale entry).
        assert_eq!(q.next_at(n - 1), Some(1e6 + (n - 1) as f64));
        assert!(q.len() <= 2, "len = {}", q.len());
        assert_eq!(q.next_wake_at(n), None);
    }

    #[test]
    fn compaction_preserves_buried_churn_events() {
        // Same stale-lease-burying shape as above, with two far-future
        // churn events pushed first: compaction rebuilds must keep them
        // live (and count them toward the live bound) even while
        // thousands of stale leases are reclaimed around them.
        let n = 1_000;
        let mut q = EventQueue::new();
        q.push(SimEvent::ServerFailed { at: 2e6, seq: 0 });
        q.push(SimEvent::ServerAdded { at: 3e6, seq: 1 });
        for i in 0..n {
            q.push(SimEvent::Arrival { at: i as f64, idx: i });
        }
        for round in 0..n {
            q.push(SimEvent::LeaseExpiry { at: 1e6 + round as f64, round });
            assert_eq!(q.pop_arrival_due(f64::INFINITY, round), Some(round));
            assert!(
                q.len() <= 2 * (n - round + 3),
                "round {round}: stale leases accumulate, len = {}",
                q.len()
            );
        }
        // The churn events survived every compaction, in order.
        assert_eq!(q.next_wake_at(n), Some(2e6));
        assert_eq!(q.pop_churn_due(f64::INFINITY, n), Some((0, FaultKind::Fail)));
        assert_eq!(q.pop_churn_due(f64::INFINITY, n), Some((1, FaultKind::Add)));
        assert_eq!(q.pop_churn_due(f64::INFINITY, n), None);
    }
}
