//! Event-driven cluster simulator (paper §4.3).
//!
//! Faithful to the paper's implementation: a global event queue carries
//! job arrivals and schedule events; each schedule event runs the round
//! planner ([`crate::coordinator`]) over the runnable jobs, deploys the
//! allocations, and jobs progress at the throughput their (c, m) grant
//! yields under the ground-truth [`PerfModel`]. A job finishing releases
//! its lease at the next round boundary (round-based scheduling), but its
//! JCT is recorded at the exact finish instant.
//!
//! Performance: rounds with an unchanged runnable set and an empty queue
//! fast-forward to the next arrival/finish event (the schedule would be
//! recomputed identically), which is what makes 512-GPU × 8000-job traces
//! tractable (see EXPERIMENTS.md §Perf).

mod engine;

pub use engine::{FinishedJob, SimConfig, SimResult, Simulator};
