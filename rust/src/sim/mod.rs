//! Event-driven cluster simulation (paper §4.3).
//!
//! Since the core unification, `sim` hosts the *shared* event-driven
//! scheduling loop ([`core`]) plus its homogeneous configuration
//! ([`engine`]). A global event queue carries job arrivals and round
//! lease expiries; each planning pass runs the scheduling policy, the
//! tenant-quota admission ([`crate::workload::admission`]), and the
//! topology's allocation mechanism over the runnable jobs, then jobs
//! progress at the throughput their (c, m) grant yields under the ground
//! truth. A job finishing releases its lease at the next round boundary
//! (round-based scheduling), but its JCT is recorded at the exact finish
//! instant.
//!
//! The heterogeneous simulator ([`crate::hetero::sim`]) is the other
//! configuration of the same core — same loop, same admission, same
//! accounting, different [`ClusterModel`].
//!
//! Performance: rounds with an unchanged runnable set and an empty queue
//! fast-forward to the next arrival/finish event (the schedule would be
//! recomputed identically), which is what makes 512-GPU × 8000-job traces
//! tractable (see EXPERIMENTS.md §Perf).

mod core;
mod engine;

pub use self::core::{
    run_events, utilization_sample, ClusterModel, CoreConfig, FinishedJob,
    SimEvent, SimResult,
};
pub use engine::{HomoModel, SimConfig, Simulator};
