//! Event-driven cluster simulation (paper §4.3).
//!
//! `sim` hosts the *shared* event-driven scheduling loop ([`core`]) plus
//! the one topology behind it ([`engine::FleetModel`] — the single
//! [`ClusterModel`] implementation, parameterized by a fleet
//! description). A global event queue carries job arrivals, round lease
//! expiries, and — under a [`FaultSpec`] ([`faults`]) — deterministic
//! host churn (`ServerFailed`/`ServerAdded`); each planning pass runs
//! the scheduling policy, the tenant-quota admission
//! ([`crate::workload::admission`]), and the allocation mechanism over
//! the runnable jobs, then jobs progress at the throughput their
//! (type, c, m) grant yields under that type's ground truth. A job
//! finishing releases its lease at the next round boundary (round-based
//! scheduling), but its JCT is recorded at the exact finish instant. A
//! host failure preempts the gangs placed on it back into the queue
//! with completed work preserved — no job is ever lost to churn.
//!
//! There is one engine with two front-ends: [`Simulator`] (homogeneous
//! defaults: `n_servers` V100 machines) and the heterogeneous
//! [`crate::hetero::HeteroSimulator`] (a `SimConfig` with
//! [`SimConfig::types`] set). A one-pool fleet reproduces the
//! pre-unification homogeneous schedule bit-for-bit (golden-tested in
//! `tests/scenarios.rs`).
//!
//! Performance: the core memoizes the round plan — the mechanism reruns
//! only when the policy-ordered, admission-cut runnable sequence
//! actually changed (see [`core`]'s module docs for the invariant) —
//! and when it does rerun, pool-decomposable mechanisms *resume* from a
//! checkpoint of the previous plan, replaying only the steps past the
//! longest common prefix ([`crate::mechanism::resume`]); jobs live in a
//! dense [`crate::job::JobArena`] instead of per-round `BTreeMap`s, and
//! packing walks the clusters' free-capacity indices. That combination
//! is what makes 512-GPU × 8000-job traces tractable
//! (`benches/sim_scale.rs` → `BENCH_sim.json`).

mod core;
mod engine;
mod faults;

pub use self::core::{
    run_events, run_events_driven, run_events_recorded,
    run_events_with_faults, utilization_sample, ClusterModel, CoreConfig,
    DeployedGrant, DriverEvent, FinishedJob, NullDriver, PlanStats,
    RoundCtx, RoundDriver, RoundRates, SimEvent, SimResult,
};
pub use engine::{FleetModel, HomoModel, SimConfig, Simulator};
pub use faults::{FaultEntry, FaultKind, FaultSpec, ScriptFault};
