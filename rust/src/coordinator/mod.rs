//! The round planner: glue between policy, profiler output, and mechanism
//! (paper §3.2 "Scheduling mechanism"), type-generic.
//!
//! Every round the coordinator:
//! 1. builds policy views for all queued+running jobs over the fleet,
//! 2. orders them with the scheduling policy,
//! 3. admits the top jobs whose aggregate GPU demand fits the fleet
//!    ("runnable set", §4.2 — admission ignores fungible resources);
//!    with tenant quotas configured ([`RoundPlanner::with_quotas`]) the
//!    admission walks the ordered queue under per-tenant GPU caps with a
//!    work-conserving spill pass (see [`crate::workload::admission`]),
//! 4. hands the runnable set to the mechanism for type assignment,
//!    allocation and placement — via the batch
//!    [`crate::mechanism::Mechanism::allocate`] driver, which itself
//!    folds the sequence through the resumable `begin`/`step`/`finish`
//!    session API (the simulation core additionally exploits that API's
//!    checkpoints for prefix-resumed replanning; the wall-clock deploy
//!    round is long enough that the leader just replans).
//!
//! Both the simulator ([`crate::sim`]) and the live deploy mode
//! ([`crate::deploy`]) drive the same pipeline over the same
//! [`crate::cluster::Fleet`] representation, so scheduling behaviour is
//! identical in the two (Table 5's fidelity comparison): the deploy
//! leader calls [`RoundPlanner::plan`] on a one-type fleet of its
//! workers, while the simulation core ([`crate::sim::run_events`])
//! composes the same shared pieces — [`policy_view`] for step 1, the
//! policy's `order` for step 2, and
//! [`crate::workload::admission::admit`] for step 3 — around the
//! fleet-generic [`crate::sim::ClusterModel`].

use crate::cluster::Fleet;
use crate::job::{Job, JobId};
use crate::mechanism::{Grant, JobRequest, Mechanism};
use crate::policy::{PolicyJobView, SchedulingPolicy};
use crate::profiler::Sensitivity;
use crate::workload::{admission, AdmissionJob, TenantQuotas};
use std::collections::BTreeMap;

/// The plan for one round.
#[derive(Debug)]
pub struct RoundPlan {
    /// Grants (type + placement + fungible demand) per placed job.
    pub grants: BTreeMap<JobId, Grant>,
    /// Jobs admitted to the runnable set but left unplaced by the
    /// mechanism (GREEDY skips; TUNE only on true GPU shortage).
    pub unplaced: Vec<JobId>,
}

/// Round planner: policy + mechanism + admission.
pub struct RoundPlanner {
    pub policy: Box<dyn SchedulingPolicy>,
    pub mechanism: Box<dyn Mechanism>,
    /// Per-tenant weights for quota admission; `None` = single-tenant
    /// behaviour (plain GPU-capacity admission).
    pub quotas: Option<TenantQuotas>,
}

impl RoundPlanner {
    pub fn new(
        policy: Box<dyn SchedulingPolicy>,
        mechanism: Box<dyn Mechanism>,
    ) -> RoundPlanner {
        Self::with_quotas(policy, mechanism, None)
    }

    /// A planner with tenant-aware weighted-quota admission.
    pub fn with_quotas(
        policy: Box<dyn SchedulingPolicy>,
        mechanism: Box<dyn Mechanism>,
        quotas: Option<TenantQuotas>,
    ) -> RoundPlanner {
        RoundPlanner { policy, mechanism, quotas }
    }

    /// Plan one round. `fleet` must have no placements (the round reset
    /// evicts everything first); `jobs` are all arrived unfinished jobs
    /// with their sensitivities (the per-job scheduling context — the
    /// same [`Sensitivity`] the simulation engine keeps per job).
    pub fn plan(
        &self,
        fleet: &mut Fleet,
        jobs: &[(&Job, &Sensitivity)],
        now: f64,
    ) -> RoundPlan {
        assert!(
            fleet.pools.iter().all(|p| p.cluster.placements().is_empty()),
            "round must start empty"
        );

        // 1-2: policy views, ordered (one round-start free tuple for the
        // whole pass).
        let free = round_start_free(fleet);
        let mut views: Vec<PolicyJobView> = jobs
            .iter()
            .map(|(job, sens)| policy_view_with_free(fleet, free, job, sens))
            .collect();
        self.policy.order(&mut views, now);

        // 3: admit while aggregate GPU demand fits (fungible dims
        // ignored). With quotas, per-tenant GPU caps apply first and
        // stranded capacity spills work-conservingly; without quotas this
        // is the standard gang-scheduling backfill at GPU granularity.
        let total_gpus = fleet.total_gpus();
        let by_id: BTreeMap<JobId, (&Job, &Sensitivity)> =
            jobs.iter().map(|(j, c)| (j.id, (*j, *c))).collect();
        let ordered: Vec<AdmissionJob> = views
            .iter()
            .map(|v| {
                let (job, _) = by_id[&v.id];
                AdmissionJob { id: job.id, tenant: job.tenant, gpus: job.gpus }
            })
            .collect();
        let runnable =
            admission::admit(&ordered, total_gpus, self.quotas.as_ref())
                .admitted;

        // 4: mechanism allocation in policy order.
        let requests: Vec<JobRequest> = runnable
            .iter()
            .map(|id| {
                let (job, sens) = by_id[id];
                JobRequest { id: job.id, gpus: job.gpus, sens }
            })
            .collect();
        let grants = self.mechanism.allocate(fleet, &requests);
        let unplaced = runnable
            .into_iter()
            .filter(|id| !grants.contains_key(id))
            .collect();
        RoundPlan { grants, unplaced }
    }

}

/// Build the policy view of one job over the current fleet state.
/// Shared by the round planner (deploy leader path) and the simulation
/// core's [`crate::sim::ClusterModel`], so both rank jobs identically —
/// there is one definition of every policy key for every fleet shape.
///
/// - SRTF's remaining-time estimate uses the oracle `W_j^Fair` (on a
///   one-type fleet: the homogeneous proportional throughput, exactly
///   the pre-unification key).
/// - DRF's dominant share and Tetris's alignment use the best-case
///   demand on the *slowest* type (the conservative demand the fairness
///   oracle is defined against; on one type, the job's only demand).
pub fn policy_view(
    fleet: &Fleet,
    job: &Job,
    sens: &Sensitivity,
) -> PolicyJobView {
    policy_view_with_free(fleet, round_start_free(fleet), job, sens)
}

/// The free-resource tuple of a *round-start* (reset) fleet — what the
/// Tetris alignment in [`policy_view`] dots demands against. Both view
/// callers evaluate against the round's reset state (the planner
/// asserts the fleet holds no placements; the simulation core defines
/// views against the about-to-be-reset fleet), so free equals capacity
/// in every dimension and this never has to read per-server counters.
/// GPU and CPU totals are integer-valued and exact either way; the
/// memory total deliberately replicates the per-server *summation
/// order* of the old free scan (a single `spec × n` multiply differs by
/// ulps for non-dyadic per-server memory, and alignment tie-breaks pin
/// schedules). Compute once per round and feed [`policy_view_with_free`]
/// to make each view O(1).
pub fn round_start_free(fleet: &Fleet) -> (f64, f64, f64) {
    let mem: f64 = fleet
        .pools
        .iter()
        .map(|p| {
            (0..p.cluster.num_servers())
                .map(|_| p.cluster.spec.mem_gb)
                .sum::<f64>()
        })
        .sum();
    (fleet.total_gpus() as f64, fleet.total_cpus(), mem)
}

/// [`policy_view`] with the round-start free tuple precomputed
/// ([`round_start_free`]) — the per-round hot path builds all views off
/// one tuple instead of rescanning the fleet per job.
pub fn policy_view_with_free(
    fleet: &Fleet,
    free: (f64, f64, f64),
    job: &Job,
    sens: &Sensitivity,
) -> PolicyJobView {
    let fair = sens.fair_throughput();
    let remaining_est_s = if fair > 0.0 {
        job.remaining_samples() / fair
    } else {
        f64::INFINITY
    };
    let best = sens.floor_matrix().best_demand();
    // DRF dominant share over fleet totals.
    let dominant_share = (job.gpus as f64 / fleet.total_gpus() as f64)
        .max(best.cpus / fleet.total_cpus())
        .max(best.mem_gb / fleet.total_mem_gb());
    let alignment = (job.gpus as f64 * free.0
        + best.cpus * free.1
        + best.mem_gb * free.2)
        / (fleet.total_gpus() as f64 * fleet.total_cpus()).max(1.0);
    PolicyJobView {
        id: job.id,
        arrival_s: job.arrival_s,
        attained_service_s: job.attained_service_s,
        remaining_est_s,
        duration_prop_s: job.duration_prop_s,
        gpus: job.gpus,
        dominant_share,
        alignment,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerSpec;
    use crate::job::ModelKind;
    use crate::mechanism::Tune;
    use crate::policy::Fifo;
    use crate::profiler::OptimisticProfiler;

    fn setup(n_servers: usize) -> (Fleet, OptimisticProfiler) {
        let spec = ServerSpec::default();
        (
            Fleet::homogeneous(spec, n_servers),
            OptimisticProfiler::noiseless(spec),
        )
    }

    fn make_job(id: u64, model: ModelKind, gpus: u32, arrival: f64) -> Job {
        let mut j = Job::new(JobId(id), model, gpus, arrival, 3600.0);
        j.total_samples = 1e9; // long-running
        j
    }

    #[test]
    fn admission_respects_gpu_capacity() {
        let (mut fleet, profiler) = setup(1); // 8 GPUs
        let jobs: Vec<Job> = (0..4)
            .map(|i| make_job(i, ModelKind::Gnmt, 4, i as f64))
            .collect();
        let ctxs: Vec<Sensitivity> = jobs
            .iter()
            .map(|j| profiler.profile(j))
            .collect();
        let refs: Vec<(&Job, &Sensitivity)> =
            jobs.iter().zip(ctxs.iter()).collect();
        let planner =
            RoundPlanner::new(Box::new(Fifo), Box::new(Tune::default()));
        let plan = planner.plan(&mut fleet, &refs, 100.0);
        // Only the first two 4-GPU jobs fit 8 GPUs.
        assert_eq!(plan.grants.len(), 2);
        assert!(plan.grants.contains_key(&JobId(0)));
        assert!(plan.grants.contains_key(&JobId(1)));
        assert!(plan.unplaced.is_empty());
    }

    #[test]
    fn backfill_admits_smaller_later_jobs() {
        let (mut fleet, profiler) = setup(1);
        // 6-GPU job, then an 8-GPU job (doesn't fit), then a 2-GPU job
        // (backfills).
        let jobs = vec![
            make_job(0, ModelKind::Lstm, 6, 0.0),
            make_job(1, ModelKind::Lstm, 8, 1.0),
            make_job(2, ModelKind::Lstm, 2, 2.0),
        ];
        let ctxs: Vec<Sensitivity> = jobs
            .iter()
            .map(|j| profiler.profile(j))
            .collect();
        let refs: Vec<(&Job, &Sensitivity)> =
            jobs.iter().zip(ctxs.iter()).collect();
        let planner = RoundPlanner::new(Box::new(Fifo), Box::new(Tune::default()));
        let plan = planner.plan(&mut fleet, &refs, 10.0);
        assert!(plan.grants.contains_key(&JobId(0)));
        assert!(!plan.grants.contains_key(&JobId(1)));
        assert!(plan.grants.contains_key(&JobId(2)));
    }

    #[test]
    fn quota_admission_caps_contended_tenant() {
        use crate::job::TenantId;
        let (mut fleet, profiler) = setup(1); // 8 GPUs
        // Tenant 0 floods the queue first (8 jobs); tenant 1 arrives
        // later with 4 jobs, but its 1:1 quota guarantees it half the
        // cluster — FIFO alone would hand all 8 GPUs to tenant 0.
        let mut jobs: Vec<Job> = (0..12)
            .map(|i| make_job(i, ModelKind::Lstm, 1, i as f64))
            .collect();
        for j in jobs.iter_mut().skip(8) {
            j.tenant = TenantId(1);
        }
        let ctxs: Vec<Sensitivity> = jobs
            .iter()
            .map(|j| profiler.profile(j))
            .collect();
        let refs: Vec<(&Job, &Sensitivity)> =
            jobs.iter().zip(ctxs.iter()).collect();
        let quotas = TenantQuotas::new()
            .with(TenantId(0), 1.0)
            .with(TenantId(1), 1.0);
        let planner = RoundPlanner::with_quotas(
            Box::new(Fifo),
            Box::new(Tune::default()),
            Some(quotas),
        );
        let plan = planner.plan(&mut fleet, &refs, 100.0);
        // 4 GPUs per tenant despite FIFO favouring tenant 0's backlog...
        let granted_t1 = (8..12)
            .filter(|&i| plan.grants.contains_key(&JobId(i)))
            .count();
        assert_eq!(granted_t1, 4, "tenant 1 must get its weighted share");
        // ...and capacity is fully used (work conserving).
        assert_eq!(plan.grants.len(), 8);
    }

    #[test]
    fn planner_consistent_cluster_state() {
        let (mut fleet, profiler) = setup(2);
        let jobs: Vec<Job> = (0..10)
            .map(|i| make_job(i, ModelKind::ResNet18, 1, i as f64))
            .collect();
        let ctxs: Vec<Sensitivity> = jobs
            .iter()
            .map(|j| profiler.profile(j))
            .collect();
        let refs: Vec<(&Job, &Sensitivity)> =
            jobs.iter().zip(ctxs.iter()).collect();
        let planner = RoundPlanner::new(Box::new(Fifo), Box::new(Tune::default()));
        let plan = planner.plan(&mut fleet, &refs, 0.0);
        assert_eq!(plan.grants.len(), 10);
        assert!(fleet.check_consistency().is_ok());
    }

    #[test]
    fn planner_routes_by_type_on_mixed_fleet() {
        // The same planner, handed a two-type fleet, produces typed
        // grants — no second coordinator needed.
        let fleet_spec = Fleet::two_tier(1);
        let profiler = OptimisticProfiler::noiseless_fleet(&fleet_spec);
        let mut fleet = fleet_spec;
        let jobs = vec![
            make_job(0, ModelKind::Gnmt, 8, 0.0),
            make_job(1, ModelKind::ShuffleNetV2, 8, 1.0),
        ];
        let ctxs: Vec<Sensitivity> = jobs
            .iter()
            .map(|j| profiler.profile(j))
            .collect();
        let refs: Vec<(&Job, &Sensitivity)> =
            jobs.iter().zip(ctxs.iter()).collect();
        let planner =
            RoundPlanner::new(Box::new(Fifo), Box::new(Tune::default()));
        let plan = planner.plan(&mut fleet, &refs, 0.0);
        assert_eq!(plan.grants.len(), 2);
        use crate::cluster::GpuGen;
        assert_eq!(plan.grants[&JobId(0)].gen, GpuGen::V100);
        assert_eq!(plan.grants[&JobId(1)].gen, GpuGen::P100);
        assert!(fleet.check_consistency().is_ok());
    }
}
