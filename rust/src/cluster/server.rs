//! A single server: generation, fixed capacity, free-resource counters.

use super::gen::GpuGen;
use super::Share;

/// Hardware shape of one server (homogeneous across the cluster, §2.3).
///
/// The default matches the paper's testbed: 8×V100, 24 CPU cores, 500 GB
/// DRAM (CPU:GPU ratio 3, fair-share memory 62.5 GB/GPU, §5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerSpec {
    pub gpus: u32,
    pub cpus: u32,
    pub mem_gb: f64,
}

impl Default for ServerSpec {
    fn default() -> Self {
        ServerSpec { gpus: 8, cpus: 24, mem_gb: 500.0 }
    }
}

impl ServerSpec {
    /// Build a spec from a CPU:GPU ratio (paper §5.5 sweeps 3..=6).
    pub fn with_cpu_ratio(ratio: u32) -> ServerSpec {
        ServerSpec { gpus: 8, cpus: 8 * ratio, mem_gb: 500.0 }
    }

    pub fn cpu_gpu_ratio(&self) -> f64 {
        self.cpus as f64 / self.gpus as f64
    }
}

/// Mutable per-server free-resource state. Every server carries its GPU
/// generation — heterogeneity is data on the server, not a separate
/// cluster type.
#[derive(Debug, Clone)]
pub struct Server {
    pub id: usize,
    pub gen: GpuGen,
    pub spec: ServerSpec,
    pub free_gpus: u32,
    pub free_cpus: f64,
    pub free_mem_gb: f64,
}

impl Server {
    /// A V100 server (the calibration basis).
    pub fn new(id: usize, spec: ServerSpec) -> Server {
        Server::of(GpuGen::default(), id, spec)
    }

    /// A server of an explicit generation.
    pub fn of(gen: GpuGen, id: usize, spec: ServerSpec) -> Server {
        Server {
            id,
            gen,
            spec,
            free_gpus: spec.gpus,
            free_cpus: spec.cpus as f64,
            free_mem_gb: spec.mem_gb,
        }
    }

    /// Restore the pristine free counters (round reset). Assigning from
    /// the spec — rather than releasing share by share — guarantees the
    /// round-start state is bit-identical every round; the round-plan
    /// memoization's replay equivalence (a replan from round-start state
    /// reproduces the cached plan exactly) depends on this, and float
    /// subtract-then-add round trips are not exact.
    pub fn reset_free(&mut self) {
        self.free_gpus = self.spec.gpus;
        self.free_cpus = self.spec.cpus as f64;
        self.free_mem_gb = self.spec.mem_gb;
    }

    /// Whether a share fits in the remaining capacity (with a small epsilon
    /// on the fractional dimensions to absorb float drift).
    pub fn fits(&self, share: &Share) -> bool {
        share.gpus <= self.free_gpus
            && share.cpus <= self.free_cpus + 1e-9
            && share.mem_gb <= self.free_mem_gb + 1e-9
    }

    /// Whether the GPU demand alone fits (used by Synergy-TUNE's
    /// GPU-first placement step, §4.2).
    pub fn fits_gpus(&self, gpus: u32) -> bool {
        gpus <= self.free_gpus
    }

    /// Subtract a share from the free counters. Panics on overallocation.
    pub fn allocate(&mut self, share: &Share) {
        assert!(
            self.fits(share),
            "overallocation on server {}: want {:?}, free=({}, {}, {})",
            self.id, share, self.free_gpus, self.free_cpus, self.free_mem_gb
        );
        self.free_gpus -= share.gpus;
        self.free_cpus = (self.free_cpus - share.cpus).max(0.0);
        self.free_mem_gb = (self.free_mem_gb - share.mem_gb).max(0.0);
    }

    /// Return a share to the free counters. Panics if it would exceed
    /// capacity (double release).
    pub fn release(&mut self, share: &Share) {
        self.free_gpus += share.gpus;
        self.free_cpus += share.cpus;
        self.free_mem_gb += share.mem_gb;
        assert!(
            self.free_gpus <= self.spec.gpus
                && self.free_cpus <= self.spec.cpus as f64 + 1e-6
                && self.free_mem_gb <= self.spec.mem_gb + 1e-6,
            "double release on server {}: free=({}, {}, {})",
            self.id, self.free_gpus, self.free_cpus, self.free_mem_gb
        );
        self.free_cpus = self.free_cpus.min(self.spec.cpus as f64);
        self.free_mem_gb = self.free_mem_gb.min(self.spec.mem_gb);
    }

    /// Scalar "fullness" key used for best-fit ordering: servers with the
    /// least free resources sort first (Synergy-TUNE packs tightly, §4.2).
    pub fn free_score(&self) -> f64 {
        self.free_gpus as f64 / self.spec.gpus as f64
            + self.free_cpus / self.spec.cpus as f64
            + self.free_mem_gb / self.spec.mem_gb
    }

    /// [`Server::free_score`] as an order-preserving integer key for the
    /// free-capacity index. Free counters are clamped to `[0, capacity]`,
    /// so the score is a non-negative finite float and `to_bits` keeps
    /// `a < b ⇔ key(a) < key(b)` — the index's `BTreeSet` ordering is
    /// exactly the float ordering the linear best-fit scan used.
    pub fn free_score_key(&self) -> u64 {
        self.free_score().to_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_testbed() {
        let s = ServerSpec::default();
        assert_eq!(s.gpus, 8);
        assert_eq!(s.cpus, 24);
        assert_eq!(s.mem_gb, 500.0);
        assert_eq!(s.cpu_gpu_ratio(), 3.0);
    }

    #[test]
    fn ratio_constructor() {
        assert_eq!(ServerSpec::with_cpu_ratio(6).cpus, 48);
        assert_eq!(ServerSpec::with_cpu_ratio(6).cpu_gpu_ratio(), 6.0);
    }

    #[test]
    fn fits_checks_all_dimensions() {
        let s = Server::new(0, ServerSpec::default());
        assert!(s.fits(&Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 }));
        assert!(!s.fits(&Share { gpus: 9, cpus: 1.0, mem_gb: 1.0 }));
        assert!(!s.fits(&Share { gpus: 1, cpus: 25.0, mem_gb: 1.0 }));
        assert!(!s.fits(&Share { gpus: 1, cpus: 1.0, mem_gb: 501.0 }));
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut s = Server::new(0, ServerSpec::default());
        let share = Share { gpus: 2, cpus: 10.5, mem_gb: 125.0 };
        s.allocate(&share);
        assert_eq!(s.free_gpus, 6);
        assert!((s.free_cpus - 13.5).abs() < 1e-9);
        s.release(&share);
        assert_eq!(s.free_gpus, 8);
        assert!((s.free_cpus - 24.0).abs() < 1e-9);
        assert!((s.free_mem_gb - 500.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut s = Server::new(0, ServerSpec::default());
        s.release(&Share { gpus: 1, cpus: 0.0, mem_gb: 0.0 });
    }

    #[test]
    fn free_score_orders_fuller_servers_first() {
        let mut a = Server::new(0, ServerSpec::default());
        let b = Server::new(1, ServerSpec::default());
        a.allocate(&Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 });
        assert!(a.free_score() < b.free_score());
    }
}
