//! GPU generations and their compute scaling.
//!
//! Machine *type* is first-class data in the cluster model (paper A.2.1:
//! "K: the set of different types of machines"): every server carries a
//! [`GpuGen`], and a mixed-generation fleet is just a cluster whose
//! pools differ in it. Only the GPU stage of the input pipeline changes
//! across generations — host-side pre-processing (CPU) and storage fetch
//! are unchanged — so a generation is characterized by a multiplicative
//! factor on the model's single-GPU compute throughput.
//!
//! The factors are calibrated from the public cross-generation speedups
//! used by heterogeneity-aware schedulers (Gavel [44], Gandiva-Fair
//! [12]): roughly K80 : P100 : V100 : A100 ≈ 0.25 : 0.55 : 1 : 2, with
//! language models (dense matmul, tensor-core friendly) gaining more
//! from newer generations than input-bound vision models. V100 is the
//! calibration basis (scale 1) — the paper's homogeneous testbed is the
//! one-type special case of this representation.

use crate::job::Task;

/// A GPU generation (machine type `i ∈ K`, paper A.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuGen {
    K80,
    P100,
    V100,
    A100,
}

/// All generations, slowest first.
pub const ALL_GENS: [GpuGen; 4] =
    [GpuGen::K80, GpuGen::P100, GpuGen::V100, GpuGen::A100];

impl Default for GpuGen {
    /// The calibration basis (the paper's 8×V100 testbed, §5.1).
    fn default() -> Self {
        GpuGen::V100
    }
}

impl GpuGen {
    pub fn name(&self) -> &'static str {
        match self {
            GpuGen::K80 => "k80",
            GpuGen::P100 => "p100",
            GpuGen::V100 => "v100",
            GpuGen::A100 => "a100",
        }
    }

    pub fn by_name(name: &str) -> Option<GpuGen> {
        match name {
            "k80" => Some(GpuGen::K80),
            "p100" => Some(GpuGen::P100),
            "v100" => Some(GpuGen::V100),
            "a100" => Some(GpuGen::A100),
            _ => None,
        }
    }

    /// Multiplier on a model's single-GPU compute throughput relative to
    /// the V100 basis the zoo is calibrated against.
    pub fn compute_scale(&self, task: Task) -> f64 {
        // Language models (transformer/RNN matmuls) track tensor-core
        // gains; image/speech pipelines gain slightly less per
        // generation (they re-bottleneck on input earlier).
        match (self, task) {
            (GpuGen::K80, Task::Language) => 0.20,
            (GpuGen::K80, _) => 0.25,
            (GpuGen::P100, Task::Language) => 0.50,
            (GpuGen::P100, _) => 0.55,
            (GpuGen::V100, _) => 1.0,
            (GpuGen::A100, Task::Language) => 2.2,
            (GpuGen::A100, _) => 1.9,
        }
    }

    /// Per-generation salt for the profiler's measurement-noise stream:
    /// distinct types observe independent noise for the same job. V100
    /// salts to 0 so a one-type V100 fleet reproduces the pre-unification
    /// homogeneous profiler's noise stream bit-for-bit.
    pub fn seed_salt(&self) -> u64 {
        match self {
            GpuGen::V100 => 0,
            GpuGen::K80 => 0x4B80,
            GpuGen::P100 => 0xB100,
            GpuGen::A100 => 0xA100,
        }
    }

    /// Slowest-generation helper for the fairness oracle (A.2.2).
    pub fn slowest(gens: &[GpuGen]) -> GpuGen {
        *gens
            .iter()
            .min_by(|a, b| {
                a.compute_scale(Task::Image)
                    .partial_cmp(&b.compute_scale(Task::Image))
                    .unwrap()
            })
            .expect("at least one generation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for g in ALL_GENS {
            assert_eq!(GpuGen::by_name(g.name()), Some(g));
        }
        assert_eq!(GpuGen::by_name("h100"), None);
    }

    #[test]
    fn scales_are_monotone_across_generations() {
        for task in [Task::Image, Task::Language, Task::Speech] {
            let scales: Vec<f64> =
                ALL_GENS.iter().map(|g| g.compute_scale(task)).collect();
            for w in scales.windows(2) {
                assert!(w[0] < w[1], "{task:?}: {scales:?}");
            }
        }
    }

    #[test]
    fn v100_is_the_calibration_basis() {
        for task in [Task::Image, Task::Language, Task::Speech] {
            assert_eq!(GpuGen::V100.compute_scale(task), 1.0);
        }
        assert_eq!(GpuGen::default(), GpuGen::V100);
        assert_eq!(GpuGen::V100.seed_salt(), 0);
    }

    #[test]
    fn seed_salts_are_distinct() {
        let salts: Vec<u64> = ALL_GENS.iter().map(|g| g.seed_salt()).collect();
        for (i, a) in salts.iter().enumerate() {
            for b in &salts[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn slowest_picks_k80() {
        assert_eq!(GpuGen::slowest(&ALL_GENS), GpuGen::K80);
        assert_eq!(
            GpuGen::slowest(&[GpuGen::V100, GpuGen::P100]),
            GpuGen::P100
        );
    }
}
