//! The fleet: the canonical, type-generic cluster representation.
//!
//! A [`Fleet`] is a set of disjoint *type pools*, one per GPU generation
//! present (paper A.2.1): pool `i` is `s_i` identical machines of
//! generation `i`, modeled as one [`Cluster`] so the per-pool
//! free-capacity indices (and all allocation invariants, consistency
//! checks and proportional shares) carry over — a mechanism scanning for
//! a best-fit server of one type stays O(servers-of-that-type). The
//! paper's per-round constraint that a job never spans two types
//! (A.2.2) is enforced by construction: placements live inside a single
//! pool's `Cluster`.
//!
//! Heterogeneity is *data*, not a code path: the paper's homogeneous
//! testbed (§2.3) is the one-pool special case ([`Fleet::homogeneous`]),
//! and every scheduler layer — profiler, mechanisms, simulator,
//! coordinator — operates on `Fleet` regardless of how many pools it
//! holds.

use super::gen::GpuGen;
use super::{Cluster, ServerSpec, TopologySpec};
use crate::job::JobId;

/// Specification of one machine type: generation + per-machine resources
/// + machine count (`s_i`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TypeSpec {
    pub gen: GpuGen,
    pub spec: ServerSpec,
    pub machines: usize,
}

/// One homogeneous pool inside a fleet.
#[derive(Debug, Clone)]
pub struct TypePool {
    pub gen: GpuGen,
    pub cluster: Cluster,
}

/// A fleet: disjoint homogeneous type pools.
#[derive(Debug, Clone)]
pub struct Fleet {
    pub pools: Vec<TypePool>,
    /// Planning fan-out width (`--shards`): how many worker threads the
    /// resumable planner may spread the per-pool placement folds over.
    /// Schedule-invisible — per-pool results merge in fixed pool order,
    /// so output is byte-identical for any value. 1 = serial (default).
    shards: usize,
    /// The abstract topology spec ([`Fleet::set_topology`]), retained so
    /// membership changes ([`Fleet::add_server`]) can re-derive the
    /// concrete rack layout for the pool's new size.
    topology_spec: TopologySpec,
}

impl Fleet {
    /// Build from type specifications. Types must be distinct.
    pub fn new(types: &[TypeSpec]) -> Fleet {
        for (i, a) in types.iter().enumerate() {
            for b in &types[i + 1..] {
                assert_ne!(a.gen, b.gen, "duplicate machine type {:?}", a.gen);
            }
        }
        Fleet {
            pools: types
                .iter()
                .map(|t| TypePool {
                    gen: t.gen,
                    cluster: Cluster::homogeneous_of(t.gen, t.spec, t.machines),
                })
                .collect(),
            shards: 1,
            topology_spec: TopologySpec::default(),
        }
    }

    /// The one-type special case: `n` identical V100 machines (the
    /// paper's homogeneous cluster, §2.3).
    pub fn homogeneous(spec: ServerSpec, n: usize) -> Fleet {
        Fleet {
            pools: vec![TypePool {
                gen: GpuGen::default(),
                cluster: Cluster::homogeneous(spec, n),
            }],
            shards: 1,
            topology_spec: TopologySpec::default(),
        }
    }

    /// One-type V100 fleet over an explicit set of server ids (the
    /// deploy leader plans each round over only the workers currently
    /// alive, so placements keep addressing workers by stable id).
    pub fn with_server_ids(spec: ServerSpec, ids: &[usize]) -> Fleet {
        Fleet::with_server_ids_of(GpuGen::default(), spec, ids)
    }

    /// [`Fleet::with_server_ids`] for an explicit generation — the
    /// deploy leader mirrors whatever generation its workers registered
    /// instead of assuming V100.
    pub fn with_server_ids_of(
        gen: GpuGen,
        spec: ServerSpec,
        ids: &[usize],
    ) -> Fleet {
        Fleet {
            pools: vec![TypePool {
                gen,
                cluster: Cluster::with_server_ids_of(gen, spec, ids),
            }],
            shards: 1,
            topology_spec: TopologySpec::default(),
        }
    }

    /// The standard two-type evaluation fleet: half V100 machines, half
    /// P100 machines of the paper's server shape.
    pub fn two_tier(machines_per_type: usize) -> Fleet {
        let spec = ServerSpec::default();
        Fleet::new(&[
            TypeSpec { gen: GpuGen::P100, spec, machines: machines_per_type },
            TypeSpec { gen: GpuGen::V100, spec, machines: machines_per_type },
        ])
    }

    /// Number of distinct machine types (`|K|`).
    pub fn n_types(&self) -> usize {
        self.pools.len()
    }

    /// Whether this fleet is the homogeneous special case.
    pub fn is_single_type(&self) -> bool {
        self.pools.len() == 1
    }

    pub fn gens(&self) -> Vec<GpuGen> {
        self.pools.iter().map(|p| p.gen).collect()
    }

    pub fn pool(&self, gen: GpuGen) -> Option<&TypePool> {
        self.pools.iter().find(|p| p.gen == gen)
    }

    pub fn pool_mut(&mut self, gen: GpuGen) -> Option<&mut TypePool> {
        self.pools.iter_mut().find(|p| p.gen == gen)
    }

    /// Total GPUs across all types (`G`, A.2.1).
    pub fn total_gpus(&self) -> u32 {
        self.pools.iter().map(|p| p.cluster.total_gpus()).sum()
    }

    /// Free GPUs across all pools — O(|K|): each pool answers from its
    /// free-capacity index's exact integer aggregate, not a server scan
    /// (type assignment queries this once per pool per round).
    pub fn free_gpus(&self) -> u32 {
        self.pools.iter().map(|p| p.cluster.free_gpus()).sum()
    }

    pub fn total_cpus(&self) -> f64 {
        self.pools.iter().map(|p| p.cluster.total_cpus()).sum()
    }

    pub fn free_cpus(&self) -> f64 {
        self.pools.iter().map(|p| p.cluster.free_cpus()).sum()
    }

    pub fn total_mem_gb(&self) -> f64 {
        self.pools.iter().map(|p| p.cluster.total_mem_gb()).sum()
    }

    pub fn free_mem_gb(&self) -> f64 {
        self.pools.iter().map(|p| p.cluster.free_mem_gb()).sum()
    }

    /// GPUs of the largest single pool — the gang-fit bound (A.2.2: a
    /// job never spans two types in a round).
    pub fn max_pool_gpus(&self) -> u32 {
        self.pools
            .iter()
            .map(|p| p.cluster.total_gpus())
            .max()
            .unwrap_or(0)
    }

    /// Which pool hosts `job`, if placed.
    pub fn host_gen(&self, job: JobId) -> Option<GpuGen> {
        self.pools
            .iter()
            .find(|p| p.cluster.placement(job).is_some())
            .map(|p| p.gen)
    }

    /// Evict every placement in every pool (round reset, §3.2).
    pub fn evict_all(&mut self) {
        for p in &mut self.pools {
            p.cluster.evict_all();
        }
    }

    /// Install a rack topology fleet-wide: each pool gets the spec
    /// concretized for its own machine count (racks are per-pool — a
    /// pool's scan order is the only server order that exists), so a
    /// tri-type fleet under `racks:2` has 2 racks *per pool*. Call once
    /// at construction, before planning.
    pub fn set_topology(&mut self, spec: TopologySpec) {
        self.topology_spec = spec;
        for p in &mut self.pools {
            let n = p.cluster.num_servers();
            p.cluster.set_topology(spec.for_servers(n));
        }
    }

    /// Host failure in pool `pool` (fault injection): takes the pool's
    /// deterministic victim — its highest online scan position — offline
    /// and returns the evicted job ids in id order. `None` when the pool
    /// index is out of range or the pool is already fully offline (the
    /// fault is a no-op; nothing preempted, no membership change).
    pub fn fail_server(&mut self, pool: usize) -> Option<Vec<JobId>> {
        let p = self.pools.get_mut(pool)?;
        let pos = p.cluster.last_online_position()?;
        Some(p.cluster.take_offline(pos))
    }

    /// Host restore/growth in pool `pool` (fault injection): revives the
    /// lowest offline position if one exists, else grows the pool by a
    /// fresh server and re-derives the rack layout for the new size from
    /// the retained [`TopologySpec`]. Returns `false` when the pool
    /// index is out of range.
    pub fn add_server(&mut self, pool: usize) -> bool {
        let spec = self.topology_spec;
        let Some(p) = self.pools.get_mut(pool) else {
            return false;
        };
        match p.cluster.first_offline_position() {
            Some(pos) => p.cluster.bring_online(pos),
            None => {
                p.cluster.add_server();
                let n = p.cluster.num_servers();
                p.cluster.set_topology(spec.for_servers(n));
            }
        }
        true
    }

    /// Turn on every pool's undo journal (prefix-resumable planning; see
    /// [`Cluster::enable_journal`]).
    pub fn enable_journal(&mut self) {
        for p in &mut self.pools {
            p.cluster.enable_journal();
        }
    }

    /// Whether the pools journal their mutations (all-or-nothing: the
    /// fleet enables journaling fleet-wide or not at all).
    pub fn journal_enabled(&self) -> bool {
        self.pools.iter().all(|p| p.cluster.journal_enabled())
    }

    /// Set the planning fan-out width (`--shards`; clamped to ≥ 1).
    /// Schedule-invisible: any value produces byte-identical plans.
    pub fn set_shards(&mut self, n: usize) {
        self.shards = n.max(1);
    }

    /// The planning fan-out width (1 = serial).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Aggregate GPU utilization in [0, 1] (0.0 for a fully-offline
    /// fleet rather than dividing by zero capacity).
    pub fn gpu_utilization(&self) -> f64 {
        let total = self.total_gpus();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.free_gpus() as f64 / total as f64
    }

    /// Aggregate CPU allocation fraction in [0, 1] (0.0 for a
    /// fully-offline fleet rather than dividing by zero capacity).
    pub fn cpu_utilization(&self) -> f64 {
        let total = self.total_cpus();
        if total == 0.0 {
            return 0.0;
        }
        1.0 - self.free_cpus() / total
    }

    /// Consistency check across every pool.
    pub fn check_consistency(&self) -> Result<(), String> {
        for p in &self.pools {
            p.cluster
                .check_consistency()
                .map_err(|e| format!("{:?}: {e}", p.gen))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Placement, Share};

    #[test]
    fn two_tier_capacity() {
        let f = Fleet::two_tier(2);
        assert_eq!(f.pools.len(), 2);
        assert_eq!(f.total_gpus(), 32);
        assert_eq!(f.total_cpus(), 96.0);
        assert_eq!(f.free_gpus(), 32);
        assert_eq!(f.max_pool_gpus(), 16);
        assert!(!f.is_single_type());
        assert!(f.check_consistency().is_ok());
    }

    #[test]
    fn homogeneous_is_one_v100_pool() {
        let f = Fleet::homogeneous(ServerSpec::default(), 4);
        assert!(f.is_single_type());
        assert_eq!(f.gens(), vec![GpuGen::V100]);
        assert_eq!(f.total_gpus(), 32);
        assert_eq!(f.max_pool_gpus(), 32);
        assert_eq!(f.pools[0].cluster.gen, GpuGen::V100);
        for s in &f.pools[0].cluster.servers {
            assert_eq!(s.gen, GpuGen::V100);
        }
    }

    #[test]
    fn pools_are_independent() {
        let mut f = Fleet::two_tier(1);
        let share = Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 };
        f.pool_mut(GpuGen::V100)
            .unwrap()
            .cluster
            .place(JobId(1), Placement::single(0, share));
        assert_eq!(f.host_gen(JobId(1)), Some(GpuGen::V100));
        assert_eq!(f.pool(GpuGen::P100).unwrap().cluster.free_gpus(), 8);
        assert_eq!(f.free_gpus(), 12);
        f.evict_all();
        assert_eq!(f.free_gpus(), 16);
        assert_eq!(f.host_gen(JobId(1)), None);
    }

    #[test]
    #[should_panic(expected = "duplicate machine type")]
    fn duplicate_types_panic() {
        let spec = ServerSpec::default();
        Fleet::new(&[
            TypeSpec { gen: GpuGen::V100, spec, machines: 1 },
            TypeSpec { gen: GpuGen::V100, spec, machines: 1 },
        ]);
    }

    #[test]
    fn utilization_tracks_placements() {
        let mut f = Fleet::two_tier(1);
        assert_eq!(f.gpu_utilization(), 0.0);
        f.pool_mut(GpuGen::P100).unwrap().cluster.place(
            JobId(2),
            Placement::single(0, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 }),
        );
        assert_eq!(f.gpu_utilization(), 0.5);
    }

    #[test]
    fn set_topology_concretizes_per_pool() {
        let mut f = Fleet::two_tier(4);
        f.set_topology(TopologySpec::racks(2));
        for p in &f.pools {
            let t = p.cluster.topology();
            assert_eq!(t.racks, 2);
            assert_eq!(t.servers_per_rack, 2, "ceil(4 machines / 2 racks)");
        }
        // Default (no call): every pool is flat.
        let g = Fleet::two_tier(4);
        assert!(g.pools.iter().all(|p| p.cluster.topology().is_flat()));
    }

    #[test]
    fn sparse_ids_build_a_single_v100_pool() {
        let f = Fleet::with_server_ids(ServerSpec::default(), &[0, 2, 5]);
        assert!(f.is_single_type());
        assert_eq!(f.total_gpus(), 24);
        assert_eq!(f.pools[0].cluster.server(5).free_gpus, 8);
    }

    #[test]
    fn fail_then_add_restores_the_same_position() {
        let mut f = Fleet::two_tier(2);
        let share = Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 };
        // Jobs on both P100 machines; failing pool 0 preempts only the
        // one on the victim (highest position).
        f.pools[0].cluster.place(JobId(1), Placement::single(0, share));
        f.pools[0].cluster.place(JobId(2), Placement::single(1, share));
        let victims = f.fail_server(0).unwrap();
        assert_eq!(victims, vec![JobId(2)]);
        assert_eq!(f.total_gpus(), 24);
        assert_eq!(f.pools[0].cluster.online_servers(), 1);
        assert!(f.check_consistency().is_ok());
        // Restore revives the offline position (no growth).
        assert!(f.add_server(0));
        assert_eq!(f.total_gpus(), 32);
        assert_eq!(f.pools[0].cluster.num_servers(), 2);
        assert!(f.check_consistency().is_ok());
    }

    #[test]
    fn add_with_nothing_offline_grows_and_reracks() {
        let mut f = Fleet::homogeneous(ServerSpec::default(), 4);
        f.set_topology(TopologySpec::racks(2));
        assert!(f.add_server(0));
        let c = &f.pools[0].cluster;
        assert_eq!(c.num_servers(), 5);
        assert_eq!(f.total_gpus(), 40);
        // Rack layout re-derived for 5 machines: ceil(5/2) = 3 per rack.
        assert_eq!(c.topology().servers_per_rack, 3);
        assert!(f.check_consistency().is_ok());
    }

    #[test]
    fn fault_edges_are_no_ops() {
        let mut f = Fleet::homogeneous(ServerSpec::default(), 1);
        assert!(f.fail_server(7).is_none(), "pool out of range");
        assert!(!f.add_server(7));
        assert_eq!(f.fail_server(0), Some(vec![]));
        // Pool fully offline: further failures have no victim.
        assert!(f.fail_server(0).is_none());
        assert_eq!(f.total_gpus(), 0);
        assert_eq!(f.gpu_utilization(), 0.0);
        assert_eq!(f.cpu_utilization(), 0.0);
        assert!(f.check_consistency().is_ok());
    }
}
