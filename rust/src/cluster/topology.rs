//! Rack topology (ISSUE 7): a deterministic, position-derived rack
//! layout over one pool's scan order.
//!
//! Synergy's mechanism treats servers as interchangeable, but real
//! multi-GPU gangs pay heavily for crossing racks (the Philly analysis,
//! arXiv:1901.05758). The model here is deliberately minimal: a pool's
//! servers are assigned to `racks` contiguous groups of
//! `servers_per_rack` by *scan position* — no configuration file, no
//! per-server labels — so the layout is a pure function of the pool
//! shape and therefore bit-reproducible across runs, hosts and
//! `--threads`.
//!
//! The flat topology (`racks == 1`, the default) is the pre-topology
//! behaviour *by construction*: every server maps to rack 0, rack
//! ranking degenerates to a single class (candidate orders are
//! untouched), and [`Topology::link_penalty`] returns exactly `1.0`
//! without performing a division — so flat runs are byte-identical to
//! pre-topology schedules (golden-pinned).
//!
//! Two layers:
//!
//! - [`TopologySpec`] — the config/CLI-level description (`--topology
//!   racks:R`, the `topology` section of `ExperimentConfig`): rack
//!   count, per-rack-boundary link cost, and the `placement_aware`
//!   switch the locality ablation flips off;
//! - [`Topology`] — the concrete per-pool instance, with
//!   `servers_per_rack` derived from the pool size
//!   ([`TopologySpec::for_servers`]).

/// Default per-rack-boundary throughput cost: a gang spanning `r` racks
/// runs at `rate / (1 + link_cost × (r − 1))`. Calibrated loosely to the
/// Philly analysis' observation that cross-rack data-parallel training
/// loses a noticeable double-digit share of throughput to interconnect
/// contention; sweeps override it.
pub const DEFAULT_LINK_COST: f64 = 0.15;

/// Config-level topology description (what `--topology racks:R` and the
/// `topology` section of `ExperimentConfig` carry): how many racks to
/// split each pool into, the cross-rack link cost, and whether placement
/// actually *uses* locality (the ablation's locality-blind arm keeps the
/// link cost charged but hides racks from the packing order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TopologySpec {
    /// Number of racks per pool. 1 = flat (the default, byte-identical
    /// to the pre-topology scheduler).
    pub racks: u32,
    /// Per-rack-boundary throughput penalty factor (see
    /// [`Topology::link_penalty`]).
    pub link_cost: f64,
    /// When false, candidate ordering ignores racks entirely while the
    /// link cost still charges — the locality-blind ablation arm.
    pub placement_aware: bool,
}

impl Default for TopologySpec {
    fn default() -> Self {
        TopologySpec {
            racks: 1,
            link_cost: DEFAULT_LINK_COST,
            placement_aware: true,
        }
    }
}

impl TopologySpec {
    /// The flat (pre-topology) layout.
    pub fn flat() -> TopologySpec {
        TopologySpec::default()
    }

    /// `racks` racks at the default link cost, locality-aware.
    pub fn racks(racks: u32) -> TopologySpec {
        TopologySpec { racks, ..TopologySpec::default() }
    }

    pub fn is_flat(&self) -> bool {
        self.racks <= 1
    }

    /// Parse the CLI form: `flat` or `racks:R` (R ≥ 1).
    pub fn parse(s: &str) -> Result<TopologySpec, String> {
        if s == "flat" {
            return Ok(TopologySpec::flat());
        }
        let rest = s.strip_prefix("racks:").ok_or_else(|| {
            format!("topology '{s}': expected 'flat' or 'racks:R'")
        })?;
        let racks: u32 = rest.parse().map_err(|_| {
            format!("topology '{s}': rack count must be a positive integer")
        })?;
        if racks == 0 {
            return Err(format!("topology '{s}': need at least one rack"));
        }
        Ok(TopologySpec::racks(racks))
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.racks == 0 {
            return Err("topology: need at least one rack".to_string());
        }
        if !(self.link_cost >= 0.0 && self.link_cost.is_finite()) {
            return Err(format!(
                "topology: link_cost must be finite and >= 0, got {}",
                self.link_cost
            ));
        }
        Ok(())
    }

    /// Concretize for a pool of `n_servers`: contiguous scan-position
    /// groups of `ceil(n / racks)` servers (the last rack may be short —
    /// `rack_of` clamps, so every server maps to a valid rack even when
    /// `racks > n_servers`).
    pub fn for_servers(&self, n_servers: usize) -> Topology {
        let racks = self.racks.max(1);
        let spr = (n_servers as u32).div_ceil(racks).max(1);
        Topology {
            racks,
            servers_per_rack: spr,
            link_cost: self.link_cost,
            placement_aware: self.placement_aware,
        }
    }
}

/// The concrete topology of one pool: `racks` contiguous groups of
/// `servers_per_rack` servers in scan-position order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Topology {
    pub racks: u32,
    pub servers_per_rack: u32,
    pub link_cost: f64,
    /// See [`TopologySpec::placement_aware`].
    pub placement_aware: bool,
}

impl Default for Topology {
    fn default() -> Self {
        Topology::flat()
    }
}

impl Topology {
    /// The flat single-rack layout (pre-topology behaviour).
    pub fn flat() -> Topology {
        Topology {
            racks: 1,
            // Never consulted when flat (`rack_of` short-circuits), but
            // keep it saturating so arithmetic stays safe regardless.
            servers_per_rack: u32::MAX,
            link_cost: DEFAULT_LINK_COST,
            placement_aware: true,
        }
    }

    pub fn is_flat(&self) -> bool {
        self.racks <= 1
    }

    /// Rack of the server at scan position `pos`. Positions past the
    /// nominal grid clamp into the last rack, so sparse/short pools
    /// still map totally.
    pub fn rack_of(&self, pos: u32) -> u32 {
        if self.is_flat() {
            0
        } else {
            (pos / self.servers_per_rack).min(self.racks - 1)
        }
    }

    /// Throughput divisor for a gang spanning `racks_spanned` racks:
    /// `1 + link_cost × (racks_spanned − 1)`. Exactly `1.0` (no
    /// division performed by callers' guard) for single-rack gangs — the
    /// flat pass-through is bit-exact by construction.
    pub fn link_penalty(&self, racks_spanned: u32) -> f64 {
        if racks_spanned <= 1 {
            1.0
        } else {
            1.0 + self.link_cost * (racks_spanned - 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_flat_and_racks() {
        assert_eq!(TopologySpec::parse("flat").unwrap(), TopologySpec::flat());
        let t = TopologySpec::parse("racks:4").unwrap();
        assert_eq!(t.racks, 4);
        assert!(!t.is_flat());
        assert!(t.placement_aware);
        assert!(TopologySpec::parse("racks:0").is_err());
        assert!(TopologySpec::parse("racks:x").is_err());
        assert!(TopologySpec::parse("fat-tree").is_err());
        assert!(TopologySpec::parse("racks:").is_err());
    }

    #[test]
    fn validate_rejects_bad_link_cost() {
        assert!(TopologySpec::default().validate().is_ok());
        let bad = TopologySpec { link_cost: -0.1, ..Default::default() };
        assert!(bad.validate().is_err());
        let nan = TopologySpec { link_cost: f64::NAN, ..Default::default() };
        assert!(nan.validate().is_err());
    }

    #[test]
    fn for_servers_splits_contiguously_with_ceil() {
        // 2 racks over 4 servers: positions 0,1 → rack 0; 2,3 → rack 1.
        let t = TopologySpec::racks(2).for_servers(4);
        assert_eq!(t.servers_per_rack, 2);
        assert_eq!(
            (0..4).map(|p| t.rack_of(p)).collect::<Vec<_>>(),
            vec![0, 0, 1, 1]
        );
        // Odd split: 3 racks over 5 servers → spr = 2, last rack short.
        let t = TopologySpec::racks(3).for_servers(5);
        assert_eq!(
            (0..5).map(|p| t.rack_of(p)).collect::<Vec<_>>(),
            vec![0, 0, 1, 1, 2]
        );
        // More racks than servers: every server still maps, in range.
        let t = TopologySpec::racks(8).for_servers(3);
        for p in 0..3 {
            assert!(t.rack_of(p) < 8);
        }
        // Clamp: positions past the nominal grid land in the last rack.
        let t = TopologySpec::racks(2).for_servers(3);
        assert_eq!(t.rack_of(10), 1);
    }

    #[test]
    fn flat_maps_everything_to_rack_zero_and_unit_penalty() {
        let t = Topology::flat();
        assert!(t.is_flat());
        for p in [0u32, 1, 7, 1000] {
            assert_eq!(t.rack_of(p), 0);
        }
        // The pass-through invariant: the penalty for a one-rack gang is
        // *exactly* 1.0 — callers can guard on it and skip the division,
        // keeping flat schedules bit-identical to pre-topology ones.
        assert_eq!(t.link_penalty(0), 1.0);
        assert_eq!(t.link_penalty(1), 1.0);
    }

    #[test]
    fn link_penalty_grows_per_rack_boundary() {
        let t = TopologySpec { racks: 4, link_cost: 0.25, placement_aware: true }
            .for_servers(8);
        assert_eq!(t.link_penalty(1), 1.0);
        assert!((t.link_penalty(2) - 1.25).abs() < 1e-12);
        assert!((t.link_penalty(4) - 1.75).abs() < 1e-12);
    }
}
