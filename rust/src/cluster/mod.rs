//! Cluster and server abstractions: multi-dimensional, type-aware
//! resource bookkeeping.
//!
//! The canonical cluster representation is the [`Fleet`] (paper A.2.1):
//! disjoint pools of identical servers, one pool per GPU generation
//! ([`GpuGen`]) present. Every [`Server`] carries its generation; the
//! paper's homogeneous testbed (§2.3) is the one-pool special case
//! ([`Fleet::homogeneous`]), not a separate code path.
//!
//! A [`Cluster`] is one such pool — a homogeneous set of [`Server`]s,
//! each with integral GPUs, integral CPU cores, and memory in GB. It is
//! the per-type free-capacity index the mechanisms scan (best-fit stays
//! O(servers-of-type), §4.2). Allocation and release maintain the
//! invariant `0 <= free <= capacity` in every dimension; violations are
//! bugs and panic in debug builds.

mod fleet;
mod gen;
mod server;

pub use fleet::{Fleet, TypePool, TypeSpec};
pub use gen::{GpuGen, ALL_GENS};
pub use server::{Server, ServerSpec};

use crate::job::JobId;
use std::collections::BTreeMap;

/// A single job's resource grant on one server.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Share {
    pub gpus: u32,
    pub cpus: f64,
    pub mem_gb: f64,
}

impl Share {
    pub fn zero() -> Share {
        Share { gpus: 0, cpus: 0.0, mem_gb: 0.0 }
    }

    pub fn add(&self, other: &Share) -> Share {
        Share {
            gpus: self.gpus + other.gpus,
            cpus: self.cpus + other.cpus,
            mem_gb: self.mem_gb + other.mem_gb,
        }
    }
}

/// A job's placement: per-server shares. Multi-GPU jobs may span servers,
/// in which case CPU/mem are proportional to GPUs on each (paper §4.2).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Placement {
    pub shares: BTreeMap<usize, Share>,
}

impl Placement {
    pub fn single(server: usize, share: Share) -> Placement {
        let mut shares = BTreeMap::new();
        shares.insert(server, share);
        Placement { shares }
    }

    /// Total resources across servers.
    pub fn total(&self) -> Share {
        self.shares
            .values()
            .fold(Share::zero(), |acc, s| acc.add(s))
    }

    /// Number of servers this job is spread over.
    pub fn span(&self) -> usize {
        self.shares.len()
    }

    pub fn is_fragmented(&self) -> bool {
        self.span() > 1
    }
}

/// One homogeneous pool: servers of a single generation plus the
/// placement of running jobs.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// GPU generation of every server in this pool.
    pub gen: GpuGen,
    pub spec: ServerSpec,
    pub servers: Vec<Server>,
    placements: BTreeMap<JobId, Placement>,
}

impl Cluster {
    /// Build a homogeneous cluster of `n` V100 servers (the calibration
    /// basis — the paper's testbed shape).
    pub fn homogeneous(spec: ServerSpec, n: usize) -> Cluster {
        Cluster::homogeneous_of(GpuGen::default(), spec, n)
    }

    /// Build a homogeneous pool of `n` servers of generation `gen`.
    pub fn homogeneous_of(gen: GpuGen, spec: ServerSpec, n: usize) -> Cluster {
        Cluster {
            gen,
            spec,
            servers: (0..n).map(|id| Server::of(gen, id, spec)).collect(),
            placements: BTreeMap::new(),
        }
    }

    /// Build a cluster over an explicit set of server ids (the deploy
    /// leader plans each round over only the workers currently alive, so
    /// placements keep addressing workers by their stable id across
    /// failures).
    pub fn with_server_ids(spec: ServerSpec, ids: &[usize]) -> Cluster {
        let gen = GpuGen::default();
        Cluster {
            gen,
            spec,
            servers: ids.iter().map(|&id| Server::of(gen, id, spec)).collect(),
            placements: BTreeMap::new(),
        }
    }

    pub fn num_servers(&self) -> usize {
        self.servers.len()
    }

    pub fn total_gpus(&self) -> u32 {
        self.spec.gpus * self.servers.len() as u32
    }

    pub fn total_cpus(&self) -> f64 {
        self.spec.cpus as f64 * self.servers.len() as f64
    }

    pub fn total_mem_gb(&self) -> f64 {
        self.spec.mem_gb * self.servers.len() as f64
    }

    pub fn free_gpus(&self) -> u32 {
        self.servers.iter().map(|s| s.free_gpus).sum()
    }

    pub fn free_cpus(&self) -> f64 {
        self.servers.iter().map(|s| s.free_cpus).sum()
    }

    pub fn free_mem_gb(&self) -> f64 {
        self.servers.iter().map(|s| s.free_mem_gb).sum()
    }

    /// GPU-proportional CPU share for `gpus` GPUs (paper §2: C_g).
    pub fn proportional_cpus(&self, gpus: u32) -> f64 {
        self.spec.cpus as f64 / self.spec.gpus as f64 * gpus as f64
    }

    /// GPU-proportional memory share for `gpus` GPUs (paper §2: M_g).
    pub fn proportional_mem_gb(&self, gpus: u32) -> f64 {
        self.spec.mem_gb / self.spec.gpus as f64 * gpus as f64
    }

    /// The server with id `id` (ids are positional for
    /// [`Cluster::homogeneous`] but sparse for
    /// [`Cluster::with_server_ids`]).
    pub fn server(&self, id: usize) -> &Server {
        &self.servers[self.server_index(id)]
    }

    /// Index into `servers` for a server id (ids are positional for
    /// [`Cluster::homogeneous`] but sparse for
    /// [`Cluster::with_server_ids`]).
    fn server_index(&self, id: usize) -> usize {
        if id < self.servers.len() && self.servers[id].id == id {
            return id; // fast path: dense ids
        }
        self.servers
            .iter()
            .position(|s| s.id == id)
            .unwrap_or_else(|| panic!("unknown server id {id}"))
    }

    /// Commit a placement for `job`. Panics if any server lacks capacity or
    /// the job already has a placement (allocation bugs must be loud).
    pub fn place(&mut self, job: JobId, placement: Placement) {
        assert!(
            !self.placements.contains_key(&job),
            "job {job:?} placed twice"
        );
        for (&sid, share) in &placement.shares {
            let idx = self.server_index(sid);
            self.servers[idx].allocate(share);
        }
        self.placements.insert(job, placement);
    }

    /// Release a job's resources. No-op if the job has no placement.
    pub fn evict(&mut self, job: JobId) -> Option<Placement> {
        let placement = self.placements.remove(&job)?;
        for (&sid, share) in &placement.shares {
            let idx = self.server_index(sid);
            self.servers[idx].release(share);
        }
        Some(placement)
    }

    pub fn placement(&self, job: JobId) -> Option<&Placement> {
        self.placements.get(&job)
    }

    pub fn placements(&self) -> &BTreeMap<JobId, Placement> {
        &self.placements
    }

    /// Evict every job (used at the start of each scheduling round: the
    /// paper recomputes placements every round, §3.2).
    pub fn evict_all(&mut self) {
        let jobs: Vec<JobId> = self.placements.keys().copied().collect();
        for j in jobs {
            self.evict(j);
        }
    }

    /// GPU utilization in [0, 1].
    pub fn gpu_utilization(&self) -> f64 {
        1.0 - self.free_gpus() as f64 / self.total_gpus() as f64
    }

    /// CPU allocation fraction in [0, 1].
    pub fn cpu_utilization(&self) -> f64 {
        1.0 - self.free_cpus() / self.total_cpus()
    }

    /// Check every server's bookkeeping against the placement map;
    /// returns an error description on the first inconsistency.
    pub fn check_consistency(&self) -> Result<(), String> {
        let mut used: BTreeMap<usize, Share> = BTreeMap::new();
        for p in self.placements.values() {
            for (&sid, share) in &p.shares {
                let e = used.entry(sid).or_insert_with(Share::zero);
                *e = e.add(share);
            }
        }
        for server in &self.servers {
            let u = used.get(&server.id).copied().unwrap_or_else(Share::zero);
            let exp_gpus = self.spec.gpus - u.gpus;
            if server.free_gpus != exp_gpus {
                return Err(format!(
                    "server {}: free_gpus={} expected {}",
                    server.id, server.free_gpus, exp_gpus
                ));
            }
            if (server.free_cpus - (self.spec.cpus as f64 - u.cpus)).abs()
                > 1e-6
            {
                return Err(format!(
                    "server {}: free_cpus={} expected {}",
                    server.id,
                    server.free_cpus,
                    self.spec.cpus as f64 - u.cpus
                ));
            }
            if (server.free_mem_gb - (self.spec.mem_gb - u.mem_gb)).abs()
                > 1e-6
            {
                return Err(format!(
                    "server {}: free_mem={} expected {}",
                    server.id,
                    server.free_mem_gb,
                    self.spec.mem_gb - u.mem_gb
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobId;

    fn spec() -> ServerSpec {
        ServerSpec { gpus: 8, cpus: 24, mem_gb: 500.0 }
    }

    #[test]
    fn homogeneous_capacity() {
        let c = Cluster::homogeneous(spec(), 4);
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.total_cpus(), 96.0);
        assert_eq!(c.total_mem_gb(), 2000.0);
        assert_eq!(c.free_gpus(), 32);
    }

    #[test]
    fn proportional_shares_match_paper_example() {
        // Paper §2: server with 4 GPUs, 16 CPUs, 200GB; a 1-GPU job gets
        // 4 CPUs and 50 GB.
        let c = Cluster::homogeneous(
            ServerSpec { gpus: 4, cpus: 16, mem_gb: 200.0 },
            1,
        );
        assert_eq!(c.proportional_cpus(1), 4.0);
        assert_eq!(c.proportional_mem_gb(1), 50.0);
    }

    #[test]
    fn place_and_evict_roundtrip() {
        let mut c = Cluster::homogeneous(spec(), 2);
        let share = Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 };
        c.place(JobId(1), Placement::single(0, share));
        assert_eq!(c.free_gpus(), 12);
        assert_eq!(c.servers[0].free_gpus, 4);
        assert!(c.check_consistency().is_ok());
        let p = c.evict(JobId(1)).unwrap();
        assert_eq!(p.total().gpus, 4);
        assert_eq!(c.free_gpus(), 16);
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    fn fragmented_placement_spans_servers() {
        let mut c = Cluster::homogeneous(spec(), 2);
        let mut p = Placement::default();
        p.shares.insert(0, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 });
        p.shares.insert(1, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 });
        assert!(p.is_fragmented());
        assert_eq!(p.total().gpus, 16);
        c.place(JobId(7), p);
        assert_eq!(c.free_gpus(), 0);
        assert_eq!(c.gpu_utilization(), 1.0);
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    #[should_panic(expected = "placed twice")]
    fn double_place_panics() {
        let mut c = Cluster::homogeneous(spec(), 1);
        let share = Share { gpus: 1, cpus: 1.0, mem_gb: 10.0 };
        c.place(JobId(1), Placement::single(0, share));
        c.place(JobId(1), Placement::single(0, share));
    }

    #[test]
    #[should_panic]
    fn overallocation_panics() {
        let mut c = Cluster::homogeneous(spec(), 1);
        let share = Share { gpus: 9, cpus: 1.0, mem_gb: 1.0 };
        c.place(JobId(1), Placement::single(0, share));
    }

    #[test]
    fn sparse_server_ids_round_trip() {
        // Deploy failover plans over surviving worker ids only; ids stay
        // stable (non-positional) so placements address real workers.
        let mut c = Cluster::with_server_ids(spec(), &[0, 2, 5]);
        assert_eq!(c.num_servers(), 3);
        assert_eq!(c.total_gpus(), 24);
        let share = Share { gpus: 4, cpus: 12.0, mem_gb: 100.0 };
        c.place(JobId(1), Placement::single(5, share));
        assert_eq!(c.server(5).free_gpus, 4);
        assert_eq!(c.server(2).free_gpus, 8);
        assert!(c.check_consistency().is_ok());
        let p = c.evict(JobId(1)).unwrap();
        assert!(p.shares.contains_key(&5));
        assert_eq!(c.free_gpus(), 24);
    }

    #[test]
    #[should_panic(expected = "unknown server id")]
    fn sparse_ids_reject_unknown_server() {
        let mut c = Cluster::with_server_ids(spec(), &[0, 2]);
        let share = Share { gpus: 1, cpus: 1.0, mem_gb: 10.0 };
        c.place(JobId(1), Placement::single(1, share));
    }

    #[test]
    fn evict_all_restores_capacity() {
        let mut c = Cluster::homogeneous(spec(), 2);
        for i in 0..4 {
            c.place(
                JobId(i),
                Placement::single(
                    (i % 2) as usize,
                    Share { gpus: 2, cpus: 6.0, mem_gb: 100.0 },
                ),
            );
        }
        c.evict_all();
        assert_eq!(c.free_gpus(), 16);
        assert_eq!(c.free_cpus(), 48.0);
        assert!(c.placements().is_empty());
    }

    #[test]
    fn utilization_fractions() {
        let mut c = Cluster::homogeneous(spec(), 2);
        c.place(
            JobId(0),
            Placement::single(0, Share { gpus: 8, cpus: 12.0, mem_gb: 0.0 }),
        );
        assert_eq!(c.gpu_utilization(), 0.5);
        assert_eq!(c.cpu_utilization(), 0.25);
    }
}
