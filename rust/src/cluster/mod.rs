//! Cluster and server abstractions: multi-dimensional, type-aware
//! resource bookkeeping.
//!
//! The canonical cluster representation is the [`Fleet`] (paper A.2.1):
//! disjoint pools of identical servers, one pool per GPU generation
//! ([`GpuGen`]) present. Every [`Server`] carries its generation; the
//! paper's homogeneous testbed (§2.3) is the one-pool special case
//! ([`Fleet::homogeneous`]), not a separate code path.
//!
//! A [`Cluster`] is one such pool — a homogeneous set of [`Server`]s,
//! each with integral GPUs, integral CPU cores, and memory in GB. It
//! carries a *free-capacity index* — servers bucketed by free GPUs, each
//! bucket ordered both by packing score and by scan position — that
//! [`crate::mechanism::best_fit`] / [`crate::mechanism::first_fit`] and
//! TUNE's victim search walk instead of scanning every server per fit
//! attempt. The index is maintained incrementally through
//! [`Cluster::place`] / [`Cluster::evict`], reproduces the pre-index
//! linear-scan tie-breaks exactly (golden-pinned), and is re-verified
//! against a fresh scan by [`Cluster::check_consistency`]. Allocation
//! and release maintain the invariant `0 <= free <= capacity` in every
//! dimension; violations are bugs and panic in debug builds.
//! `check_consistency` itself is a test/debug facility: production hot
//! paths only ever invoke it behind `debug_assertions`.
//!
//! For prefix-resumable round planning the cluster additionally carries
//! an optional **undo journal** ([`Cluster::enable_journal`]): every
//! `place`/`evict` records the touched servers' *pre-mutation* free
//! counters plus the placement-map delta, and
//! [`Cluster::rollback_journal_to`] rewinds to any earlier
//! [`Cluster::journal_mark`] in O(changes). Restoring by assignment —
//! not by arithmetic inverses — is what makes rollback *bitwise* exact:
//! a `free - c + c` float round trip is not the identity, a stored
//! `free` is. The journal's base (mark 0) is the round-reset state
//! ([`Cluster::evict_all`] clears the journal), so rolling back to a
//! mid-plan mark reproduces exactly the state a fresh replan would
//! reach after the same step prefix.

mod fleet;
mod gen;
mod server;
mod topology;

pub use fleet::{Fleet, TypePool, TypeSpec};
pub use gen::{GpuGen, ALL_GENS};
pub use server::{Server, ServerSpec};
pub use topology::{Topology, TopologySpec, DEFAULT_LINK_COST};

use crate::job::JobId;
use std::collections::{btree_set, BTreeMap, BTreeSet};

/// A single job's resource grant on one server.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Share {
    pub gpus: u32,
    pub cpus: f64,
    pub mem_gb: f64,
}

impl Share {
    pub const fn zero() -> Share {
        Share { gpus: 0, cpus: 0.0, mem_gb: 0.0 }
    }

    pub fn add(&self, other: &Share) -> Share {
        Share {
            gpus: self.gpus + other.gpus,
            cpus: self.cpus + other.cpus,
            mem_gb: self.mem_gb + other.mem_gb,
        }
    }
}

/// Inline capacity of [`Shares`]. Gang spans are almost always tiny (a
/// 16-GPU job on 8-GPU servers spans 2; the paper's consolidation-strict
/// default keeps most jobs on one server), so placements up to this span
/// live entirely inline; wider spans spill to a heap vector.
const SHARES_INLINE: usize = 4;

/// A placement's per-server share map: a small-vector of
/// `(server id, Share)` entries kept sorted by server id — the same
/// deterministic iteration order as the `BTreeMap` it replaced, without
/// per-node heap allocation on the per-round placement hot path.
#[derive(Debug, Clone)]
pub struct Shares {
    len: usize,
    buf: [(usize, Share); SHARES_INLINE],
    /// Holds *all* entries once `len > SHARES_INLINE` (never shrinks
    /// back inline; placements are built, not edited down).
    spill: Vec<(usize, Share)>,
}

impl Shares {
    pub fn new() -> Shares {
        Shares {
            len: 0,
            buf: [(0, Share::zero()); SHARES_INLINE],
            spill: Vec::new(),
        }
    }

    fn spilled(&self) -> bool {
        !self.spill.is_empty()
    }

    /// The entries as a sorted-by-server-id slice.
    pub fn as_slice(&self) -> &[(usize, Share)] {
        if self.spilled() {
            &self.spill
        } else {
            &self.buf[..self.len]
        }
    }

    /// Insert or replace the share for `sid`, keeping id order.
    pub fn insert(&mut self, sid: usize, share: Share) {
        match self.as_slice().binary_search_by(|e| e.0.cmp(&sid)) {
            Ok(i) => {
                if self.spilled() {
                    self.spill[i].1 = share;
                } else {
                    self.buf[i].1 = share;
                }
            }
            Err(i) => {
                if !self.spilled() && self.len < SHARES_INLINE {
                    let mut k = self.len;
                    while k > i {
                        self.buf[k] = self.buf[k - 1];
                        k -= 1;
                    }
                    self.buf[i] = (sid, share);
                } else {
                    if !self.spilled() {
                        self.spill.extend_from_slice(&self.buf[..self.len]);
                    }
                    self.spill.insert(i, (sid, share));
                }
                self.len += 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn get(&self, sid: &usize) -> Option<&Share> {
        self.as_slice()
            .binary_search_by(|e| e.0.cmp(sid))
            .ok()
            .map(|i| &self.as_slice()[i].1)
    }

    pub fn contains_key(&self, sid: &usize) -> bool {
        self.get(sid).is_some()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&usize, &Share)> {
        self.as_slice().iter().map(|e| (&e.0, &e.1))
    }

    pub fn keys(&self) -> impl Iterator<Item = &usize> {
        self.as_slice().iter().map(|e| &e.0)
    }

    pub fn values(&self) -> impl Iterator<Item = &Share> {
        self.as_slice().iter().map(|e| &e.1)
    }
}

impl Default for Shares {
    fn default() -> Shares {
        Shares::new()
    }
}

impl PartialEq for Shares {
    fn eq(&self, other: &Shares) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::ops::Index<&usize> for Shares {
    type Output = Share;
    fn index(&self, sid: &usize) -> &Share {
        self.get(sid)
            .unwrap_or_else(|| panic!("no share on server {sid}"))
    }
}

fn share_entry_refs(e: &(usize, Share)) -> (&usize, &Share) {
    (&e.0, &e.1)
}

impl<'a> IntoIterator for &'a Shares {
    type Item = (&'a usize, &'a Share);
    type IntoIter = std::iter::Map<
        std::slice::Iter<'a, (usize, Share)>,
        fn(&'a (usize, Share)) -> (&'a usize, &'a Share),
    >;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter().map(share_entry_refs)
    }
}

/// Owning iterator over `(server id, Share)` entries in id order.
pub struct SharesIntoIter {
    shares: Shares,
    next: usize,
}

impl Iterator for SharesIntoIter {
    type Item = (usize, Share);
    fn next(&mut self) -> Option<(usize, Share)> {
        let e = self.shares.as_slice().get(self.next)?;
        self.next += 1;
        Some(*e)
    }
}

impl IntoIterator for Shares {
    type Item = (usize, Share);
    type IntoIter = SharesIntoIter;
    fn into_iter(self) -> SharesIntoIter {
        SharesIntoIter { shares: self, next: 0 }
    }
}

/// A job's placement: per-server shares. Multi-GPU jobs may span servers,
/// in which case CPU/mem are proportional to GPUs on each (paper §4.2).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Placement {
    pub shares: Shares,
}

impl Placement {
    pub fn single(server: usize, share: Share) -> Placement {
        let mut shares = Shares::new();
        shares.insert(server, share);
        Placement { shares }
    }

    /// Total resources across servers.
    pub fn total(&self) -> Share {
        self.shares
            .values()
            .fold(Share::zero(), |acc, s| acc.add(s))
    }

    /// Number of servers this job is spread over.
    pub fn span(&self) -> usize {
        self.shares.len()
    }

    pub fn is_fragmented(&self) -> bool {
        self.span() > 1
    }
}

/// The free-capacity index of one pool: servers bucketed by their
/// current free-GPU count, each bucket held in two orders —
///
/// - `(free_score bits, scan position)` ascending, which is exactly the
///   order the pre-index linear best-fit scan selected servers in
///   (minimal score, earliest position on ties — the strict `<` kept
///   the first minimum);
/// - scan position ascending, the first-fit order.
///
/// `free_score() >= 0` always (free counters are clamped to
/// `[0, capacity]`), so `f64::to_bits` is an order-preserving key.
/// Positions are indices into `Cluster::servers`, which never changes
/// after construction.
#[derive(Debug, Clone, Default)]
struct FreeIndex {
    by_score: Vec<BTreeSet<(u64, u32)>>,
    by_pos: Vec<BTreeSet<u32>>,
    /// Aggregate free GPUs (exact integer bookkeeping, so
    /// [`Cluster::free_gpus`] is O(1) instead of a server scan).
    free_gpus: u32,
    /// Aggregate free CPUs — a *telemetry gauge*, maintained by float
    /// add/subtract and therefore possibly a few ulps off a fresh
    /// summation. Scheduling never reads it (the scan-based
    /// [`Cluster::free_cpus`] stays the schedule-visible truth);
    /// equality checks exclude it.
    free_cpus: f64,
    /// Aggregate free memory — telemetry gauge, same caveats as
    /// `free_cpus`.
    free_mem_gb: f64,
}

/// Structural equality only: the float gauge aggregates are maintained
/// incrementally and may differ in low bits from a freshly built index,
/// which must not fail [`Cluster::check_index`]'s set comparison (the
/// gauges get their own tolerance check there).
impl PartialEq for FreeIndex {
    fn eq(&self, other: &FreeIndex) -> bool {
        self.by_score == other.by_score
            && self.by_pos == other.by_pos
            && self.free_gpus == other.free_gpus
    }
}

impl FreeIndex {
    fn build(servers: &[Server], max_gpus: u32) -> FreeIndex {
        Self::build_masked(servers, max_gpus, &[])
    }

    /// Build, skipping positions marked offline (an empty mask means
    /// everything is online). Offline servers exist positionally but
    /// must never appear in fit walks or aggregates.
    fn build_masked(
        servers: &[Server],
        max_gpus: u32,
        offline: &[bool],
    ) -> FreeIndex {
        let buckets = max_gpus as usize + 1;
        let mut idx = FreeIndex {
            by_score: vec![BTreeSet::new(); buckets],
            by_pos: vec![BTreeSet::new(); buckets],
            free_gpus: 0,
            free_cpus: 0.0,
            free_mem_gb: 0.0,
        };
        for (pos, s) in servers.iter().enumerate() {
            if offline.get(pos).copied().unwrap_or(false) {
                continue;
            }
            idx.attach(s, pos as u32);
        }
        idx
    }

    fn attach(&mut self, s: &Server, pos: u32) {
        let g = s.free_gpus as usize;
        self.by_score[g].insert((s.free_score_key(), pos));
        self.by_pos[g].insert(pos);
        self.free_gpus += s.free_gpus;
        self.free_cpus += s.free_cpus;
        self.free_mem_gb += s.free_mem_gb;
    }

    /// Reset to the all-pristine state (every online server fully
    /// free; offline positions stay detached).
    fn reset(&mut self, servers: &[Server], offline: &[bool]) {
        for b in &mut self.by_score {
            b.clear();
        }
        for b in &mut self.by_pos {
            b.clear();
        }
        self.free_gpus = 0;
        self.free_cpus = 0.0;
        self.free_mem_gb = 0.0;
        for (pos, s) in servers.iter().enumerate() {
            if offline.get(pos).copied().unwrap_or(false) {
                continue;
            }
            self.attach(s, pos as u32);
        }
    }

    /// Remove a server's entry. Must be called *before* mutating the
    /// server's free counters (the stored key is recomputed from them).
    fn detach(&mut self, s: &Server, pos: u32) {
        let g = s.free_gpus as usize;
        let in_score = self.by_score[g].remove(&(s.free_score_key(), pos));
        let in_pos = self.by_pos[g].remove(&pos);
        debug_assert!(
            in_score && in_pos,
            "server {pos} missing from free index"
        );
        self.free_gpus -= s.free_gpus;
        self.free_cpus -= s.free_cpus;
        self.free_mem_gb -= s.free_mem_gb;
    }
}

/// Ascending-key merge over the per-free-GPU bucket sets of a
/// [`FreeIndex`]: yields servers in global key order across the selected
/// buckets. With at most `spec.gpus + 1` buckets the per-step head scan
/// is a handful of comparisons, so a fit probe that matches early costs
/// O(matches · buckets) instead of a full O(servers) scan.
struct MergedBuckets<'a, K, F> {
    servers: &'a [Server],
    heads: Vec<(btree_set::Iter<'a, K>, Option<K>)>,
    pos_of: F,
}

impl<'a, K: Ord + Copy, F: Fn(&K) -> u32> MergedBuckets<'a, K, F> {
    fn new(
        servers: &'a [Server],
        buckets: Vec<&'a BTreeSet<K>>,
        pos_of: F,
    ) -> MergedBuckets<'a, K, F> {
        let heads = buckets
            .into_iter()
            .filter(|b| !b.is_empty())
            .map(|b| {
                let mut it = b.iter();
                let head = it.next().copied();
                (it, head)
            })
            .collect();
        MergedBuckets { servers, heads, pos_of }
    }
}

impl<'a, K: Ord + Copy, F: Fn(&K) -> u32> Iterator for MergedBuckets<'a, K, F> {
    type Item = &'a Server;

    fn next(&mut self) -> Option<&'a Server> {
        let mut best: Option<(K, usize)> = None;
        for (i, (_, head)) in self.heads.iter().enumerate() {
            if let Some(k) = *head {
                if best.map(|(bk, _)| k < bk).unwrap_or(true) {
                    best = Some((k, i));
                }
            }
        }
        let (k, i) = best?;
        let (it, head) = &mut self.heads[i];
        *head = it.next().copied();
        Some(&self.servers[(self.pos_of)(&k) as usize])
    }
}

/// One inverse operation of the undo journal. `Server` entries store the
/// *pre-mutation* free counters (restore is assignment, hence bitwise
/// exact); placement-map deltas carry the removed placement so an undone
/// evict can reinsert it verbatim.
#[derive(Debug, Clone)]
enum UndoOp {
    /// Free counters of the server at scan position `pos` before a
    /// `place`/`evict` touched it.
    Server {
        pos: u32,
        free_gpus: u32,
        free_cpus: f64,
        free_mem_gb: f64,
    },
    /// `place` inserted this job; undo removes the placement.
    Placed(JobId),
    /// `evict` removed this job's placement; undo reinserts it.
    Evicted(JobId, Placement),
}

/// Undo journal for prefix-resumable round planning: a linear history of
/// inverse ops since the last hard reset ([`Cluster::evict_all`]).
/// Positions into it ([`Cluster::journal_mark`]) are the checkpoints the
/// planning driver rolls back to.
#[derive(Debug, Clone, Default)]
pub struct Journal {
    ops: Vec<UndoOp>,
}

/// One homogeneous pool: servers of a single generation plus the
/// placement of running jobs.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// GPU generation of every server in this pool.
    pub gen: GpuGen,
    pub spec: ServerSpec,
    pub servers: Vec<Server>,
    placements: BTreeMap<JobId, Placement>,
    index: FreeIndex,
    /// `max(server id) + 1` — sizing bound for id-keyed scratch bitsets
    /// (TUNE's victim search); ids are sparse under
    /// [`Cluster::with_server_ids`].
    id_bound: usize,
    /// Undo journal (`None` = journaling off, the default — zero cost on
    /// the batch-allocation paths that never resume).
    journal: Option<Journal>,
    /// Telemetry counter: candidate servers examined by the fit helpers'
    /// free-capacity-index walks since the last
    /// [`Cluster::take_fit_walk`]. A `Cell` because the fit helpers take
    /// `&Cluster`; never read by scheduling.
    fit_walk: std::cell::Cell<u64>,
    /// Rack topology over this pool's scan order. Defaults to
    /// [`Topology::flat`] (pre-topology behaviour, byte-identical by
    /// construction) and is immutable during a planning pass — set once
    /// at fleet construction ([`Fleet::set_topology`]), so prefix-purity
    /// of the resumable planning folds is untouched.
    topology: Topology,
    /// Offline mask by scan position (host churn, ISSUE 9). An offline
    /// server keeps its position — rack membership is positional and
    /// must not shift under its neighbours — but is detached from the
    /// free-capacity index with zeroed free counters, so fit walks,
    /// totals, and admission budgets all exclude it.
    offline: Vec<bool>,
    /// Number of online servers (capacity totals are `spec × online`).
    online: usize,
}

impl Cluster {
    /// Build a homogeneous cluster of `n` V100 servers (the calibration
    /// basis — the paper's testbed shape).
    pub fn homogeneous(spec: ServerSpec, n: usize) -> Cluster {
        Cluster::homogeneous_of(GpuGen::default(), spec, n)
    }

    /// Build a homogeneous pool of `n` servers of generation `gen`.
    pub fn homogeneous_of(gen: GpuGen, spec: ServerSpec, n: usize) -> Cluster {
        Cluster::from_servers(
            gen,
            spec,
            (0..n).map(|id| Server::of(gen, id, spec)).collect(),
        )
    }

    /// Build a cluster over an explicit set of server ids (the deploy
    /// leader plans each round over only the workers currently alive, so
    /// placements keep addressing workers by their stable id across
    /// failures).
    pub fn with_server_ids(spec: ServerSpec, ids: &[usize]) -> Cluster {
        Cluster::with_server_ids_of(GpuGen::default(), spec, ids)
    }

    /// [`Cluster::with_server_ids`] for an explicit generation — the
    /// deploy leader mirrors whatever generation its workers registered.
    pub fn with_server_ids_of(
        gen: GpuGen,
        spec: ServerSpec,
        ids: &[usize],
    ) -> Cluster {
        Cluster::from_servers(
            gen,
            spec,
            ids.iter().map(|&id| Server::of(gen, id, spec)).collect(),
        )
    }

    fn from_servers(gen: GpuGen, spec: ServerSpec, servers: Vec<Server>) -> Cluster {
        let index = FreeIndex::build(&servers, spec.gpus);
        let id_bound =
            servers.iter().map(|s| s.id + 1).max().unwrap_or(0);
        let n = servers.len();
        Cluster {
            gen,
            spec,
            servers,
            placements: BTreeMap::new(),
            index,
            id_bound,
            journal: None,
            fit_walk: std::cell::Cell::new(0),
            topology: Topology::flat(),
            offline: vec![false; n],
            online: n,
        }
    }

    /// Install a rack topology over this pool (normally via
    /// [`Fleet::set_topology`], which derives `servers_per_rack` from the
    /// pool size). Call before planning starts; the topology is read-only
    /// configuration afterwards.
    pub fn set_topology(&mut self, topology: Topology) {
        self.topology = topology;
    }

    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Rack of a server *id* (racks are defined over scan positions; this
    /// resolves sparse ids to their position first).
    pub fn rack_of(&self, server_id: usize) -> u32 {
        if self.topology.is_flat() {
            return 0;
        }
        self.topology.rack_of(self.server_index(server_id) as u32)
    }

    /// Number of distinct racks a placement's shares span (0 for an empty
    /// placement, always 1 under the flat topology).
    pub fn racks_spanned(&self, placement: &Placement) -> u32 {
        if placement.shares.is_empty() {
            return 0;
        }
        if self.topology.is_flat() {
            return 1;
        }
        let mut racks = BTreeSet::new();
        for sid in placement.shares.keys() {
            racks.insert(self.rack_of(*sid));
        }
        racks.len() as u32
    }

    /// Server *positions* in this pool, offline ones included (rack
    /// derivation is positional — see [`Fleet::set_topology`]).
    pub fn num_servers(&self) -> usize {
        self.servers.len()
    }

    /// Servers currently online (capacity totals count only these).
    pub fn online_servers(&self) -> usize {
        self.online
    }

    /// Whether the server at scan position `pos` is offline.
    pub fn is_offline(&self, pos: usize) -> bool {
        self.offline[pos]
    }

    pub fn total_gpus(&self) -> u32 {
        self.spec.gpus * self.online as u32
    }

    pub fn total_cpus(&self) -> f64 {
        self.spec.cpus as f64 * self.online as f64
    }

    pub fn total_mem_gb(&self) -> f64 {
        self.spec.mem_gb * self.online as f64
    }

    /// Free GPUs across the pool — O(1) from the index's exact integer
    /// aggregate (type assignment queries this every round per pool).
    pub fn free_gpus(&self) -> u32 {
        self.index.free_gpus
    }

    pub fn free_cpus(&self) -> f64 {
        self.servers.iter().map(|s| s.free_cpus).sum()
    }

    pub fn free_mem_gb(&self) -> f64 {
        self.servers.iter().map(|s| s.free_mem_gb).sum()
    }

    /// O(1) free-CPU *telemetry gauge* off the index aggregate. May
    /// differ from [`Cluster::free_cpus`] by float ulps (incremental
    /// add/subtract vs fresh summation) — never use it on a scheduling
    /// path; the per-round utilization samples that goldens pin keep
    /// reading the scan.
    pub fn free_cpus_gauge(&self) -> f64 {
        self.index.free_cpus
    }

    /// O(1) free-memory telemetry gauge (same caveats as
    /// [`Cluster::free_cpus_gauge`]).
    pub fn free_mem_gb_gauge(&self) -> f64 {
        self.index.free_mem_gb
    }

    /// Telemetry: count one candidate server examined by a
    /// free-capacity-index walk.
    pub(crate) fn note_fit_probe(&self) {
        self.fit_walk.set(self.fit_walk.get() + 1);
    }

    /// Telemetry: drain the fit-walk probe counter (candidates examined
    /// since the last call).
    pub fn take_fit_walk(&self) -> u64 {
        self.fit_walk.replace(0)
    }

    /// GPU-proportional CPU share for `gpus` GPUs (paper §2: C_g).
    pub fn proportional_cpus(&self, gpus: u32) -> f64 {
        self.spec.cpus as f64 / self.spec.gpus as f64 * gpus as f64
    }

    /// GPU-proportional memory share for `gpus` GPUs (paper §2: M_g).
    pub fn proportional_mem_gb(&self, gpus: u32) -> f64 {
        self.spec.mem_gb / self.spec.gpus as f64 * gpus as f64
    }

    /// The server with id `id` (ids are positional for
    /// [`Cluster::homogeneous`] but sparse for
    /// [`Cluster::with_server_ids`]).
    pub fn server(&self, id: usize) -> &Server {
        &self.servers[self.server_index(id)]
    }

    /// Index into `servers` for a server id (ids are positional for
    /// [`Cluster::homogeneous`] but sparse for
    /// [`Cluster::with_server_ids`]).
    fn server_index(&self, id: usize) -> usize {
        if id < self.servers.len() && self.servers[id].id == id {
            return id; // fast path: dense ids
        }
        self.servers
            .iter()
            .position(|s| s.id == id)
            .unwrap_or_else(|| panic!("unknown server id {id}"))
    }

    /// Commit a placement for `job`. Panics if any server lacks capacity or
    /// the job already has a placement (allocation bugs must be loud).
    /// Maintains the free-capacity index incrementally.
    pub fn place(&mut self, job: JobId, placement: Placement) {
        assert!(
            !self.placements.contains_key(&job),
            "job {job:?} placed twice"
        );
        for (&sid, share) in &placement.shares {
            let idx = self.server_index(sid);
            if let Some(j) = &mut self.journal {
                let s = &self.servers[idx];
                j.ops.push(UndoOp::Server {
                    pos: idx as u32,
                    free_gpus: s.free_gpus,
                    free_cpus: s.free_cpus,
                    free_mem_gb: s.free_mem_gb,
                });
            }
            self.index.detach(&self.servers[idx], idx as u32);
            self.servers[idx].allocate(share);
            self.index.attach(&self.servers[idx], idx as u32);
        }
        if let Some(j) = &mut self.journal {
            j.ops.push(UndoOp::Placed(job));
        }
        self.placements.insert(job, placement);
    }

    /// Release a job's resources. No-op if the job has no placement.
    /// Maintains the free-capacity index incrementally.
    pub fn evict(&mut self, job: JobId) -> Option<Placement> {
        let placement = self.placements.remove(&job)?;
        for (&sid, share) in &placement.shares {
            let idx = self.server_index(sid);
            if let Some(j) = &mut self.journal {
                let s = &self.servers[idx];
                j.ops.push(UndoOp::Server {
                    pos: idx as u32,
                    free_gpus: s.free_gpus,
                    free_cpus: s.free_cpus,
                    free_mem_gb: s.free_mem_gb,
                });
            }
            self.index.detach(&self.servers[idx], idx as u32);
            self.servers[idx].release(share);
            self.index.attach(&self.servers[idx], idx as u32);
        }
        if let Some(j) = &mut self.journal {
            j.ops.push(UndoOp::Evicted(job, placement.clone()));
        }
        Some(placement)
    }

    pub fn placement(&self, job: JobId) -> Option<&Placement> {
        self.placements.get(&job)
    }

    pub fn placements(&self) -> &BTreeMap<JobId, Placement> {
        &self.placements
    }

    /// Evict every job (used at the start of each scheduling round: the
    /// paper recomputes placements every round, §3.2).
    ///
    /// This is a *hard reset*: free counters are restored from the spec
    /// rather than released share by share, so the round-start state is
    /// bit-identical every round regardless of the placement history.
    /// The round-plan memoization depends on that invariant — a replan
    /// from round-start state must reproduce the cached plan exactly,
    /// and float subtract-then-add round trips are not exact.
    pub fn evict_all(&mut self) {
        self.placements.clear();
        for (pos, s) in self.servers.iter_mut().enumerate() {
            // Offline servers stay zeroed — resurrecting a failed
            // host's capacity on the round reset would un-fail it.
            if self.offline[pos] {
                continue;
            }
            s.reset_free();
        }
        self.index.reset(&self.servers, &self.offline);
        // A hard reset invalidates (and re-bases) the undo history: the
        // journal's mark 0 *is* this pristine state.
        if let Some(j) = &mut self.journal {
            j.ops.clear();
        }
    }

    /// Turn on the undo journal (prefix-resumable planning). The current
    /// state becomes the journal base; callers normally enable it once,
    /// right after construction, and let [`Cluster::evict_all`] re-base
    /// it every round.
    pub fn enable_journal(&mut self) {
        if self.journal.is_none() {
            self.journal = Some(Journal::default());
        }
    }

    pub fn journal_enabled(&self) -> bool {
        self.journal.is_some()
    }

    /// Current journal position — a checkpoint [`Cluster::rollback_journal_to`]
    /// can rewind to. 0 when journaling is off.
    pub fn journal_mark(&self) -> usize {
        self.journal.as_ref().map(|j| j.ops.len()).unwrap_or(0)
    }

    /// Rewind state to an earlier [`Cluster::journal_mark`], undoing every
    /// recorded op in reverse: placement deltas are reverted and server
    /// counters are *assigned* their recorded pre-mutation values (bitwise
    /// exact — no arithmetic inverses), with the free-capacity index
    /// re-keyed incrementally. O(ops since the mark). Panics if journaling
    /// is off or the mark is in the future.
    pub fn rollback_journal_to(&mut self, mark: usize) {
        let mut journal =
            self.journal.take().expect("rollback without a journal");
        assert!(
            mark <= journal.ops.len(),
            "journal mark {mark} is ahead of the log ({})",
            journal.ops.len()
        );
        while journal.ops.len() > mark {
            match journal.ops.pop().expect("len checked") {
                UndoOp::Server { pos, free_gpus, free_cpus, free_mem_gb } => {
                    let p = pos as usize;
                    self.index.detach(&self.servers[p], pos);
                    self.servers[p].free_gpus = free_gpus;
                    self.servers[p].free_cpus = free_cpus;
                    self.servers[p].free_mem_gb = free_mem_gb;
                    self.index.attach(&self.servers[p], pos);
                }
                UndoOp::Placed(id) => {
                    self.placements.remove(&id);
                }
                UndoOp::Evicted(id, p) => {
                    self.placements.insert(id, p);
                }
            }
        }
        self.journal = Some(journal);
    }

    /// Upper bound on server ids (`max id + 1`) for id-keyed scratch
    /// bitsets; ids are sparse under [`Cluster::with_server_ids`].
    pub fn server_id_bound(&self) -> usize {
        self.id_bound
    }

    /// The scan position a failure event takes next: the *highest*
    /// online position (deterministic victim rule — newest capacity
    /// fails first, and the paired restore rule below brings the same
    /// position back on a lone fail/add cycle). `None` when the pool is
    /// fully offline.
    pub fn last_online_position(&self) -> Option<usize> {
        (0..self.servers.len()).rev().find(|&p| !self.offline[p])
    }

    /// The scan position a restore event revives next: the *lowest*
    /// offline position. `None` when nothing is offline (the add grows
    /// the pool instead).
    pub fn first_offline_position(&self) -> Option<usize> {
        (0..self.servers.len()).find(|&p| self.offline[p])
    }

    /// Jobs whose placements touch the server at scan position `pos`,
    /// in id order (the deterministic preemption order). Includes jobs
    /// that already finished mid-round but whose leases have not
    /// released yet — callers decide what counts as a preemption.
    pub fn jobs_on_position(&self, pos: usize) -> Vec<JobId> {
        let sid = self.servers[pos].id;
        self.placements
            .iter()
            .filter(|(_, p)| p.shares.contains_key(&sid))
            .map(|(&id, _)| id)
            .collect()
    }

    /// Take the server at scan position `pos` offline (host failure):
    /// every placement touching it is evicted (whole gangs — a
    /// placement is indivisible), the server is detached from the
    /// free-capacity index, and its free counters are zeroed so totals,
    /// budgets, and fit walks exclude it. Returns the evicted job ids
    /// in id order. Any resume checkpoints are invalid across a
    /// membership change, so the journal is cleared (re-based) — the
    /// planning driver must also drop its `PlanTrace`. Panics if the
    /// position is already offline.
    pub fn take_offline(&mut self, pos: usize) -> Vec<JobId> {
        assert!(
            !self.offline[pos],
            "server at position {pos} is already offline"
        );
        let victims = self.jobs_on_position(pos);
        for &id in &victims {
            self.evict(id);
        }
        debug_assert_eq!(
            self.servers[pos].free_gpus, self.spec.gpus,
            "victim server still carries allocations after eviction"
        );
        self.index.detach(&self.servers[pos], pos as u32);
        let s = &mut self.servers[pos];
        s.free_gpus = 0;
        s.free_cpus = 0.0;
        s.free_mem_gb = 0.0;
        self.offline[pos] = true;
        self.online -= 1;
        if let Some(j) = &mut self.journal {
            j.ops.clear();
        }
        victims
    }

    /// Bring the offline server at scan position `pos` back online:
    /// free counters reset from the spec (a returning host starts
    /// empty) and the server re-attaches to the free-capacity index.
    /// Clears (re-bases) the journal like [`Cluster::take_offline`].
    /// Panics if the position is not offline.
    pub fn bring_online(&mut self, pos: usize) {
        assert!(
            self.offline[pos],
            "server at position {pos} is not offline"
        );
        self.servers[pos].reset_free();
        self.index.attach(&self.servers[pos], pos as u32);
        self.offline[pos] = false;
        self.online += 1;
        if let Some(j) = &mut self.journal {
            j.ops.clear();
        }
    }

    /// Grow the pool by one fresh server (id = the current id bound) at
    /// the next scan position; returns the new id. The caller re-derives
    /// the rack topology for the new pool size
    /// ([`TopologySpec::for_servers`] via [`Fleet::set_topology`]).
    /// Clears (re-bases) the journal like [`Cluster::take_offline`].
    pub fn add_server(&mut self) -> usize {
        let id = self.id_bound;
        let s = Server::of(self.gen, id, self.spec);
        let pos = self.servers.len();
        self.index.attach(&s, pos as u32);
        self.servers.push(s);
        self.offline.push(false);
        self.online += 1;
        self.id_bound = id + 1;
        if let Some(j) = &mut self.journal {
            j.ops.clear();
        }
        id
    }

    /// Servers with at least `min_gpus` free GPUs, in best-fit order:
    /// ascending `(free_score, scan position)`. The first server in this
    /// order that fits a demand is *exactly* the server the pre-index
    /// linear scan selected (minimal score, earliest position on ties),
    /// so packing decisions are golden-pinned byte-identical.
    pub fn servers_by_fullness(
        &self,
        min_gpus: u32,
    ) -> impl Iterator<Item = &Server> {
        MergedBuckets::new(
            &self.servers,
            self.index.by_score[(min_gpus as usize).min(self.index.by_score.len())..]
                .iter()
                .collect(),
            |&(_, pos)| pos,
        )
    }

    /// Servers with at least `min_gpus` free GPUs, in scan-position
    /// (first-fit) order — byte-identical to the pre-index linear scan.
    pub fn servers_by_position(
        &self,
        min_gpus: u32,
    ) -> impl Iterator<Item = &Server> {
        MergedBuckets::new(
            &self.servers,
            self.index.by_pos[(min_gpus as usize).min(self.index.by_pos.len())..]
                .iter()
                .collect(),
            |&pos| pos,
        )
    }

    /// GPU utilization in [0, 1]. A fully-offline pool reports 0.0
    /// rather than dividing by zero capacity.
    pub fn gpu_utilization(&self) -> f64 {
        let total = self.total_gpus();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.free_gpus() as f64 / total as f64
    }

    /// CPU allocation fraction in [0, 1]. A fully-offline pool reports
    /// 0.0 rather than dividing by zero capacity.
    pub fn cpu_utilization(&self) -> f64 {
        let total = self.total_cpus();
        if total == 0.0 {
            return 0.0;
        }
        1.0 - self.free_cpus() / total
    }

    /// Check the incrementally-maintained free-capacity index against a
    /// fresh rebuild from the servers' current free counters. On
    /// divergence, names the first differing bucket and its contents —
    /// the likeliest failure class is a server stranded in a stale
    /// bucket or holding a stale score key while the integer aggregate
    /// still matches.
    pub fn check_index(&self) -> Result<(), String> {
        // Guard the rebuild: a counter inflated past capacity would land
        // outside the bucket range and panic inside `FreeIndex::build`
        // instead of producing a diagnostic.
        for s in &self.servers {
            if s.free_gpus > self.spec.gpus {
                return Err(format!(
                    "server {}: free_gpus={} exceeds capacity {}",
                    s.id, s.free_gpus, self.spec.gpus
                ));
            }
        }
        let fresh =
            FreeIndex::build_masked(&self.servers, self.spec.gpus, &self.offline);
        // The float gauge aggregates are outside FreeIndex equality
        // (incremental maintenance drifts by ulps); hold them to a
        // capacity-scaled tolerance instead.
        let cpu_tol = 1e-6 * (1.0 + self.total_cpus());
        let mem_tol = 1e-6 * (1.0 + self.total_mem_gb());
        if (self.index.free_cpus - fresh.free_cpus).abs() > cpu_tol
            || (self.index.free_mem_gb - fresh.free_mem_gb).abs() > mem_tol
        {
            return Err(format!(
                "free index gauges diverged: cpus {} vs scan {}, \
                 mem {} vs scan {}",
                self.index.free_cpus,
                fresh.free_cpus,
                self.index.free_mem_gb,
                fresh.free_mem_gb
            ));
        }
        if fresh == self.index {
            return Ok(());
        }
        for g in 0..fresh.by_score.len() {
            if self.index.by_score[g] != fresh.by_score[g] {
                return Err(format!(
                    "free index by_score[{g}] diverged: index has \
                     {:?}, fresh scan has {:?}",
                    self.index.by_score[g], fresh.by_score[g]
                ));
            }
            if self.index.by_pos[g] != fresh.by_pos[g] {
                return Err(format!(
                    "free index by_pos[{g}] diverged: index has {:?}, \
                     fresh scan has {:?}",
                    self.index.by_pos[g], fresh.by_pos[g]
                ));
            }
        }
        Err(format!(
            "free index aggregate diverged: index free_gpus={}, scan={}",
            self.index.free_gpus, fresh.free_gpus
        ))
    }

    /// Check every server's bookkeeping against the placement map (and
    /// the free-capacity index against the servers); returns an error
    /// description on the first inconsistency.
    pub fn check_consistency(&self) -> Result<(), String> {
        self.check_index()?;
        let mut used: BTreeMap<usize, Share> = BTreeMap::new();
        for p in self.placements.values() {
            for (&sid, share) in &p.shares {
                let e = used.entry(sid).or_insert_with(Share::zero);
                *e = e.add(share);
            }
        }
        for (pos, server) in self.servers.iter().enumerate() {
            let u = used.get(&server.id).copied().unwrap_or_else(Share::zero);
            if self.offline[pos] {
                // An offline server must carry no placements and keep its
                // free counters zeroed (it is invisible to fits/totals).
                if u.gpus != 0 || u.cpus != 0.0 || u.mem_gb != 0.0 {
                    return Err(format!(
                        "offline server {}: still referenced by placements \
                         ({} gpus)",
                        server.id, u.gpus
                    ));
                }
                if server.free_gpus != 0
                    || server.free_cpus != 0.0
                    || server.free_mem_gb != 0.0
                {
                    return Err(format!(
                        "offline server {}: free counters not zeroed \
                         (gpus={}, cpus={}, mem={})",
                        server.id,
                        server.free_gpus,
                        server.free_cpus,
                        server.free_mem_gb
                    ));
                }
                continue;
            }
            let exp_gpus = self.spec.gpus - u.gpus;
            if server.free_gpus != exp_gpus {
                return Err(format!(
                    "server {}: free_gpus={} expected {}",
                    server.id, server.free_gpus, exp_gpus
                ));
            }
            if (server.free_cpus - (self.spec.cpus as f64 - u.cpus)).abs()
                > 1e-6
            {
                return Err(format!(
                    "server {}: free_cpus={} expected {}",
                    server.id,
                    server.free_cpus,
                    self.spec.cpus as f64 - u.cpus
                ));
            }
            if (server.free_mem_gb - (self.spec.mem_gb - u.mem_gb)).abs()
                > 1e-6
            {
                return Err(format!(
                    "server {}: free_mem={} expected {}",
                    server.id,
                    server.free_mem_gb,
                    self.spec.mem_gb - u.mem_gb
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobId;

    fn spec() -> ServerSpec {
        ServerSpec { gpus: 8, cpus: 24, mem_gb: 500.0 }
    }

    #[test]
    fn homogeneous_capacity() {
        let c = Cluster::homogeneous(spec(), 4);
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.total_cpus(), 96.0);
        assert_eq!(c.total_mem_gb(), 2000.0);
        assert_eq!(c.free_gpus(), 32);
    }

    #[test]
    fn proportional_shares_match_paper_example() {
        // Paper §2: server with 4 GPUs, 16 CPUs, 200GB; a 1-GPU job gets
        // 4 CPUs and 50 GB.
        let c = Cluster::homogeneous(
            ServerSpec { gpus: 4, cpus: 16, mem_gb: 200.0 },
            1,
        );
        assert_eq!(c.proportional_cpus(1), 4.0);
        assert_eq!(c.proportional_mem_gb(1), 50.0);
    }

    #[test]
    fn place_and_evict_roundtrip() {
        let mut c = Cluster::homogeneous(spec(), 2);
        let share = Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 };
        c.place(JobId(1), Placement::single(0, share));
        assert_eq!(c.free_gpus(), 12);
        assert_eq!(c.servers[0].free_gpus, 4);
        assert!(c.check_consistency().is_ok());
        let p = c.evict(JobId(1)).unwrap();
        assert_eq!(p.total().gpus, 4);
        assert_eq!(c.free_gpus(), 16);
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    fn fragmented_placement_spans_servers() {
        let mut c = Cluster::homogeneous(spec(), 2);
        let mut p = Placement::default();
        p.shares.insert(0, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 });
        p.shares.insert(1, Share { gpus: 8, cpus: 24.0, mem_gb: 500.0 });
        assert!(p.is_fragmented());
        assert_eq!(p.total().gpus, 16);
        c.place(JobId(7), p);
        assert_eq!(c.free_gpus(), 0);
        assert_eq!(c.gpu_utilization(), 1.0);
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    #[should_panic(expected = "placed twice")]
    fn double_place_panics() {
        let mut c = Cluster::homogeneous(spec(), 1);
        let share = Share { gpus: 1, cpus: 1.0, mem_gb: 10.0 };
        c.place(JobId(1), Placement::single(0, share));
        c.place(JobId(1), Placement::single(0, share));
    }

    #[test]
    #[should_panic]
    fn overallocation_panics() {
        let mut c = Cluster::homogeneous(spec(), 1);
        let share = Share { gpus: 9, cpus: 1.0, mem_gb: 1.0 };
        c.place(JobId(1), Placement::single(0, share));
    }

    #[test]
    fn sparse_server_ids_round_trip() {
        // Deploy failover plans over surviving worker ids only; ids stay
        // stable (non-positional) so placements address real workers.
        let mut c = Cluster::with_server_ids(spec(), &[0, 2, 5]);
        assert_eq!(c.num_servers(), 3);
        assert_eq!(c.total_gpus(), 24);
        let share = Share { gpus: 4, cpus: 12.0, mem_gb: 100.0 };
        c.place(JobId(1), Placement::single(5, share));
        assert_eq!(c.server(5).free_gpus, 4);
        assert_eq!(c.server(2).free_gpus, 8);
        assert!(c.check_consistency().is_ok());
        let p = c.evict(JobId(1)).unwrap();
        assert!(p.shares.contains_key(&5));
        assert_eq!(c.free_gpus(), 24);
    }

    #[test]
    #[should_panic(expected = "unknown server id")]
    fn sparse_ids_reject_unknown_server() {
        let mut c = Cluster::with_server_ids(spec(), &[0, 2]);
        let share = Share { gpus: 1, cpus: 1.0, mem_gb: 10.0 };
        c.place(JobId(1), Placement::single(1, share));
    }

    #[test]
    fn evict_all_restores_capacity() {
        let mut c = Cluster::homogeneous(spec(), 2);
        for i in 0..4 {
            c.place(
                JobId(i),
                Placement::single(
                    (i % 2) as usize,
                    Share { gpus: 2, cpus: 6.0, mem_gb: 100.0 },
                ),
            );
        }
        c.evict_all();
        assert_eq!(c.free_gpus(), 16);
        assert_eq!(c.free_cpus(), 48.0);
        assert!(c.placements().is_empty());
    }

    #[test]
    fn round_reset_is_bitwise_pristine() {
        // The memoization soundness invariant: evict_all restores the
        // exact spec counters no matter what fractional shares passed
        // through (arithmetic release round trips would drift by ulps).
        let mut c = Cluster::homogeneous(spec(), 2);
        for i in 0..3u64 {
            c.place(
                JobId(i),
                Placement::single(
                    (i % 2) as usize,
                    Share { gpus: 1, cpus: 9.3 - i as f64 * 0.7, mem_gb: 13.7 },
                ),
            );
        }
        c.evict_all();
        for s in &c.servers {
            assert_eq!(s.free_gpus, spec().gpus);
            assert_eq!(s.free_cpus.to_bits(), (spec().cpus as f64).to_bits());
            assert_eq!(s.free_mem_gb.to_bits(), spec().mem_gb.to_bits());
        }
        assert!(c.placements().is_empty());
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    fn shares_small_vec_stays_sorted_and_spills() {
        let mut sh = Shares::new();
        let mk = |g| Share { gpus: g, cpus: 1.0, mem_gb: 1.0 };
        for sid in [5usize, 1, 3, 0, 7, 2] {
            sh.insert(sid, mk(sid as u32));
        }
        assert_eq!(sh.len(), 6, "spilled past inline capacity");
        let ids: Vec<usize> = sh.keys().copied().collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 5, 7], "id order preserved");
        assert_eq!(sh[&5].gpus, 5);
        // Replacement keeps length and order.
        sh.insert(3, mk(99));
        assert_eq!(sh.len(), 6);
        assert_eq!(sh.get(&3).unwrap().gpus, 99);
        assert!(!sh.contains_key(&4));
        // Owning iteration matches borrowed iteration.
        let owned: Vec<usize> = sh.clone().into_iter().map(|(s, _)| s).collect();
        assert_eq!(owned, ids);
    }

    #[test]
    fn index_orders_servers_like_the_scan() {
        let mut c = Cluster::homogeneous(spec(), 3);
        // Server 1 fullest, then 2, then 0 (untouched).
        c.place(
            JobId(1),
            Placement::single(1, Share { gpus: 6, cpus: 18.0, mem_gb: 400.0 }),
        );
        c.place(
            JobId(2),
            Placement::single(2, Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 }),
        );
        let by_fullness: Vec<usize> =
            c.servers_by_fullness(1).map(|s| s.id).collect();
        assert_eq!(by_fullness, vec![1, 2, 0], "ascending free score");
        let by_pos: Vec<usize> =
            c.servers_by_position(1).map(|s| s.id).collect();
        assert_eq!(by_pos, vec![0, 1, 2], "scan order");
        // GPU filter excludes the fuller servers.
        let roomy: Vec<usize> = c.servers_by_fullness(5).map(|s| s.id).collect();
        assert_eq!(roomy, vec![0]);
        assert_eq!(c.free_gpus(), 14);
        assert!(c.check_index().is_ok());
        c.evict(JobId(1));
        assert_eq!(c.free_gpus(), 20);
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    fn index_ties_break_by_scan_position() {
        // Identical loads on servers 2 and 0: equal free scores must
        // yield the earlier scan position first (the pre-index strict-<
        // kept the first minimum).
        let mut c = Cluster::homogeneous(spec(), 3);
        let share = Share { gpus: 2, cpus: 6.0, mem_gb: 100.0 };
        c.place(JobId(1), Placement::single(2, share));
        c.place(JobId(2), Placement::single(0, share));
        let order: Vec<usize> = c.servers_by_fullness(1).map(|s| s.id).collect();
        assert_eq!(order, vec![0, 2, 1]);
    }

    /// Bitwise snapshot of a cluster's mutable state (free counters as
    /// bit patterns + placements), for exact-rollback assertions.
    fn state_bits(c: &Cluster) -> (Vec<(u32, u64, u64)>, Vec<JobId>, u32) {
        (
            c.servers
                .iter()
                .map(|s| {
                    (s.free_gpus, s.free_cpus.to_bits(), s.free_mem_gb.to_bits())
                })
                .collect(),
            c.placements().keys().copied().collect(),
            c.free_gpus(),
        )
    }

    #[test]
    fn journal_rollback_is_bitwise_exact() {
        let mut c = Cluster::homogeneous(spec(), 3);
        c.enable_journal();
        // Non-dyadic shares: arithmetic release would drift by ulps; the
        // journal must restore by assignment.
        let odd = Share { gpus: 1, cpus: 9.3, mem_gb: 13.7 };
        c.place(JobId(1), Placement::single(0, odd));
        let mark = c.journal_mark();
        let snapshot = state_bits(&c);
        c.place(JobId(2), Placement::single(1, odd));
        c.place(JobId(3), Placement::single(0, odd));
        c.evict(JobId(1)).unwrap();
        assert_ne!(state_bits(&c), snapshot);
        c.rollback_journal_to(mark);
        assert_eq!(state_bits(&c), snapshot, "rollback must be bit-exact");
        assert!(c.check_consistency().is_ok());
        // The prefix survives and the journal can keep extending.
        assert!(c.placement(JobId(1)).is_some());
        c.place(JobId(4), Placement::single(2, odd));
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    fn journal_rollback_to_base_is_the_round_reset() {
        let mut c = Cluster::homogeneous(spec(), 2);
        c.enable_journal();
        let base = state_bits(&c);
        for i in 0..3 {
            c.place(
                JobId(i),
                Placement::single(
                    (i % 2) as usize,
                    Share { gpus: 2, cpus: 5.1, mem_gb: 77.7 },
                ),
            );
        }
        c.rollback_journal_to(0);
        assert_eq!(state_bits(&c), base);
        assert!(c.placements().is_empty());
        // evict_all re-bases the journal: mark 0 is pristine again.
        c.place(JobId(9), Placement::single(0, Share { gpus: 1, cpus: 1.0, mem_gb: 1.0 }));
        c.evict_all();
        assert_eq!(c.journal_mark(), 0);
        assert_eq!(state_bits(&c), base);
    }

    #[test]
    fn check_consistency_catches_a_corrupted_index() {
        // The release build never runs check_consistency on the hot path,
        // so the test suite must prove it still detects corruption when
        // tests do run it: desync a server's counters behind the index's
        // back (free_cpus feeds the score key; free_gpus the bucket).
        let mut c = Cluster::homogeneous(spec(), 2);
        c.place(
            JobId(1),
            Placement::single(0, Share { gpus: 2, cpus: 6.0, mem_gb: 100.0 }),
        );
        assert!(c.check_consistency().is_ok());
        let mut corrupted = c.clone();
        corrupted.servers[0].free_cpus -= 1.0; // stale score key
        assert!(corrupted.check_consistency().is_err());
        let mut corrupted = c.clone();
        corrupted.servers[1].free_gpus = 3; // stale bucket + aggregate
        assert!(corrupted.check_consistency().is_err());
        // Upward corruption (counter past capacity) must yield an error,
        // not an out-of-bucket panic while rebuilding the fresh index.
        let mut corrupted = c.clone();
        corrupted.servers[1].free_gpus = spec().gpus + 1;
        let err = corrupted.check_consistency().unwrap_err();
        assert!(err.contains("exceeds capacity"), "{err}");
    }

    #[test]
    fn telemetry_gauges_track_the_scans() {
        let mut c = Cluster::homogeneous(spec(), 3);
        assert_eq!(c.free_cpus_gauge(), c.free_cpus());
        assert_eq!(c.free_mem_gb_gauge(), c.free_mem_gb());
        // Non-dyadic shares through place/evict: gauges stay within
        // tolerance of the scans (and check_consistency verifies it).
        let odd = Share { gpus: 1, cpus: 9.3, mem_gb: 13.7 };
        for i in 0..3 {
            c.place(JobId(i), Placement::single(i as usize, odd));
        }
        c.evict(JobId(1)).unwrap();
        assert!((c.free_cpus_gauge() - c.free_cpus()).abs() < 1e-6);
        assert!((c.free_mem_gb_gauge() - c.free_mem_gb()).abs() < 1e-6);
        assert!(c.check_consistency().is_ok());
        // The hard round reset restores the gauges exactly.
        c.evict_all();
        assert_eq!(c.free_cpus_gauge(), c.total_cpus());
        assert_eq!(c.free_mem_gb_gauge(), c.total_mem_gb());
        // A corrupted gauge is caught even though index equality
        // excludes it.
        let mut corrupted = c.clone();
        corrupted.index.free_cpus += 5.0;
        let err = corrupted.check_consistency().unwrap_err();
        assert!(err.contains("gauges diverged"), "{err}");
    }

    #[test]
    fn fit_walk_counter_drains() {
        let c = Cluster::homogeneous(spec(), 2);
        assert_eq!(c.take_fit_walk(), 0);
        c.note_fit_probe();
        c.note_fit_probe();
        assert_eq!(c.take_fit_walk(), 2, "probes accumulate");
        assert_eq!(c.take_fit_walk(), 0, "take drains");
    }

    #[test]
    fn utilization_fractions() {
        let mut c = Cluster::homogeneous(spec(), 2);
        c.place(
            JobId(0),
            Placement::single(0, Share { gpus: 8, cpus: 12.0, mem_gb: 0.0 }),
        );
        assert_eq!(c.gpu_utilization(), 0.5);
        assert_eq!(c.cpu_utilization(), 0.25);
    }

    #[test]
    fn racks_span_counts_distinct_racks() {
        let mut c = Cluster::homogeneous(spec(), 4);
        c.set_topology(TopologySpec::racks(2).for_servers(4));
        assert_eq!(c.rack_of(0), 0);
        assert_eq!(c.rack_of(1), 0);
        assert_eq!(c.rack_of(2), 1);
        assert_eq!(c.rack_of(3), 1);
        let share = Share { gpus: 2, cpus: 6.0, mem_gb: 100.0 };
        let mut same_rack = Placement::default();
        same_rack.shares.insert(0, share);
        same_rack.shares.insert(1, share);
        assert_eq!(c.racks_spanned(&same_rack), 1);
        let mut cross = Placement::default();
        cross.shares.insert(1, share);
        cross.shares.insert(2, share);
        assert_eq!(c.racks_spanned(&cross), 2);
        assert_eq!(c.racks_spanned(&Placement::default()), 0);
        // Flat (the default): everything is one rack.
        let flat = Cluster::homogeneous(spec(), 4);
        assert_eq!(flat.racks_spanned(&cross), 1);
        assert_eq!(flat.rack_of(3), 0);
    }

    #[test]
    fn rack_of_resolves_sparse_ids_by_position() {
        // Racks are positional: ids 0,2,5 sit at positions 0,1,2, so with
        // 2 racks over 3 servers (spr = 2) id 5 — position 2 — is rack 1.
        let mut c = Cluster::with_server_ids(spec(), &[0, 2, 5]);
        c.set_topology(TopologySpec::racks(2).for_servers(3));
        assert_eq!(c.rack_of(0), 0);
        assert_eq!(c.rack_of(2), 0);
        assert_eq!(c.rack_of(5), 1);
    }

    #[test]
    fn take_offline_evicts_victims_and_shrinks_totals() {
        let mut c = Cluster::homogeneous(spec(), 3);
        let share = Share { gpus: 4, cpus: 12.0, mem_gb: 250.0 };
        c.place(JobId(1), Placement::single(0, share));
        // Gang spanning the victim and a survivor: the whole gang goes.
        let mut gang = Placement::default();
        gang.shares.insert(1, share);
        gang.shares.insert(2, share);
        c.place(JobId(2), gang);
        // Victim rule: highest online position (2) fails first.
        assert_eq!(c.last_online_position(), Some(2));
        let victims = c.take_offline(2);
        assert_eq!(victims, vec![JobId(2)]);
        assert!(c.is_offline(2));
        assert_eq!(c.online_servers(), 2);
        assert_eq!(c.num_servers(), 3, "positions are retained");
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.total_cpus(), 48.0);
        // Survivor's placement is intact; the gang freed its survivor half.
        assert_eq!(c.free_gpus(), 12);
        assert!(c.placements().contains_key(&JobId(1)));
        assert!(c.check_consistency().is_ok());
        // Offline server is invisible to fullness walks.
        assert!(c.servers_by_fullness(1).all(|s| s.id != 2));
    }

    #[test]
    fn bring_online_restores_exact_capacity() {
        let mut c = Cluster::homogeneous(spec(), 2);
        let share = Share { gpus: 3, cpus: 9.0, mem_gb: 100.0 };
        c.place(JobId(1), Placement::single(1, share));
        let victims = c.take_offline(1);
        assert_eq!(victims, vec![JobId(1)]);
        assert_eq!(c.first_offline_position(), Some(1));
        c.bring_online(1);
        assert_eq!(c.online_servers(), 2);
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.free_gpus(), 16, "a returning host starts empty");
        assert_eq!(c.first_offline_position(), None);
        assert!(c.check_consistency().is_ok());
        // Bit-pristine: counters identical to the round-reset state.
        assert_eq!(c.servers[1].free_gpus, spec().gpus);
        assert_eq!(
            c.servers[1].free_cpus.to_bits(),
            (spec().cpus as f64).to_bits()
        );
    }

    #[test]
    fn add_server_grows_pool_with_fresh_id() {
        let mut c = Cluster::with_server_ids(spec(), &[0, 2, 5]);
        let id = c.add_server();
        assert_eq!(id, 6, "fresh id = old id bound");
        assert_eq!(c.num_servers(), 4);
        assert_eq!(c.online_servers(), 4);
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.server_id_bound(), 7);
        let share = Share { gpus: 1, cpus: 3.0, mem_gb: 10.0 };
        c.place(JobId(9), Placement::single(6, share));
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    fn evict_all_keeps_offline_servers_detached() {
        let mut c = Cluster::homogeneous(spec(), 3);
        c.enable_journal();
        c.take_offline(0);
        let share = Share { gpus: 2, cpus: 6.0, mem_gb: 100.0 };
        c.place(JobId(1), Placement::single(1, share));
        c.evict_all();
        assert_eq!(c.free_gpus(), 16, "round reset excludes offline pos 0");
        assert_eq!(c.servers[0].free_gpus, 0, "offline counters stay zeroed");
        assert!(c.check_consistency().is_ok());
        // Fully-offline pool: utilization is defined (0.0), not NaN.
        c.take_offline(1);
        c.take_offline(2);
        assert_eq!(c.last_online_position(), None);
        assert_eq!(c.total_gpus(), 0);
        assert_eq!(c.gpu_utilization(), 0.0);
        assert_eq!(c.cpu_utilization(), 0.0);
        assert!(c.check_consistency().is_ok());
    }

    #[test]
    #[should_panic(expected = "already offline")]
    fn double_take_offline_panics() {
        let mut c = Cluster::homogeneous(spec(), 2);
        c.take_offline(1);
        c.take_offline(1);
    }
}
