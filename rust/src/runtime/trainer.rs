//! Device-resident training loop over an AOT train-step executable.

use super::meta::ArtifactMeta;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};

/// Synthetic token corpus with learnable structure: a noisy affine bigram
/// process (`next ≈ (a·cur + b) mod V` with occasional uniform noise), so
/// the transformer's loss curve actually descends during the e2e run.
pub struct SyntheticCorpus {
    pub vocab: usize,
    rng: Pcg64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        SyntheticCorpus { vocab, rng: Pcg64::new(seed, 0xC047) }
    }

    /// Next (batch, seq) token matrix, row-major i32.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let v = self.vocab as u64;
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut cur = self.rng.below(v);
            for _ in 0..seq {
                out.push(cur as i32);
                cur = if self.rng.chance(0.1) {
                    self.rng.below(v) // noise
                } else {
                    (cur.wrapping_mul(5).wrapping_add(17)) % v
                };
            }
        }
        out
    }
}

/// A training session: compiled executable + device-resident state.
pub struct Trainer {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    params: xla::PjRtBuffer,
    momentum: xla::PjRtBuffer,
    pub step: usize,
}

impl Trainer {
    /// Initialize parameters host-side (same rules as model.init_params:
    /// gamma→1, beta/bias→0, embeddings→N(0, 0.02), matrices→N(0, 1/√fan))
    /// and upload to the device.
    pub fn new(
        client: &xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        meta: ArtifactMeta,
        seed: u64,
    ) -> Result<Trainer> {
        meta.validate().map_err(|e| anyhow!("bad meta: {e}"))?;
        let mut host = vec![0f32; meta.param_count];
        let mut rng = Pcg64::new(seed, 0x1417);
        for p in &meta.params {
            let slice = &mut host[p.offset..p.offset + p.len()];
            if p.name.ends_with(".gamma") {
                slice.fill(1.0);
            } else if p.name.ends_with(".beta")
                || p.name.ends_with(".b1")
                || p.name.ends_with(".b2")
            {
                slice.fill(0.0);
            } else {
                let std = if p.name.contains("embed") {
                    0.02
                } else {
                    (1.0 / p.fan_in() as f64).sqrt()
                };
                for x in slice.iter_mut() {
                    *x = (rng.normal() * std) as f32;
                }
            }
        }
        let params = Self::upload_f32(client, &host, &[meta.param_count])?;
        let zeros = vec![0f32; meta.param_count];
        let momentum = Self::upload_f32(client, &zeros, &[meta.param_count])?;
        Ok(Trainer {
            meta,
            exe,
            client: client.clone(),
            params,
            momentum,
            step: 0,
        })
    }

    fn upload_f32(
        client: &xla::PjRtClient,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    fn upload_i32(
        client: &xla::PjRtClient,
        data: &[i32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// One training step. `tokens` is row-major (batch, seq). Returns the
    /// scalar loss.
    pub fn train_step(&mut self, tokens: &[i32], lr: f32) -> Result<f32> {
        let b = self.meta.batch;
        let s = self.meta.seq_len;
        if tokens.len() != b * s {
            return Err(anyhow!(
                "expected {}x{} tokens, got {}", b, s, tokens.len()
            ));
        }
        let tok_buf = Self::upload_i32(&self.client, tokens, &[b, s])?;
        let lr_buf = Self::upload_f32(&self.client, &[lr], &[])?;
        let outs = self
            .exe
            .execute_b(&[&self.params, &self.momentum, &tok_buf, &lr_buf])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let replica = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no output replica"))?;
        self.step += 1;
        if replica.len() >= 3 {
            // PJRT untupled the outputs: feed buffers straight back.
            let mut it = replica.into_iter();
            self.params = it.next().unwrap();
            self.momentum = it.next().unwrap();
            let loss_buf = it.next().unwrap();
            let lit = loss_buf
                .to_literal_sync()
                .map_err(|e| anyhow!("loss readback: {e:?}"))?;
            Ok(lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0])
        } else {
            // Tuple output: decompose via literal (slower path).
            let lit = replica[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}"))?;
            let parts = lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
            let mut it = parts.into_iter();
            let p = it.next().ok_or_else(|| anyhow!("missing params"))?;
            let m = it.next().ok_or_else(|| anyhow!("missing momentum"))?;
            let loss = it.next().ok_or_else(|| anyhow!("missing loss"))?;
            let pv = p.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            let mv = m.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            self.params =
                Self::upload_f32(&self.client, &pv, &[self.meta.param_count])?;
            self.momentum =
                Self::upload_f32(&self.client, &mv, &[self.meta.param_count])?;
            Ok(loss.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0])
        }
    }

    /// Read the current parameters back to the host (checkpointing).
    pub fn params_to_host(&self) -> Result<Vec<f32>> {
        let lit = self
            .params
            .to_literal_sync()
            .map_err(|e| anyhow!("readback: {e:?}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Restore parameters from a host vector (checkpoint resume) and reset
    /// momentum.
    pub fn restore(&mut self, params: &[f32]) -> Result<()> {
        if params.len() != self.meta.param_count {
            return Err(anyhow!("bad checkpoint length"));
        }
        self.params =
            Self::upload_f32(&self.client, params, &[self.meta.param_count])?;
        let zeros = vec![0f32; self.meta.param_count];
        self.momentum =
            Self::upload_f32(&self.client, &zeros, &[self.meta.param_count])?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tokens_in_range() {
        let mut c = SyntheticCorpus::new(256, 1);
        let toks = c.batch(4, 32);
        assert_eq!(toks.len(), 128);
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn corpus_has_structure() {
        // The bigram rule must dominate: successor repetition rate far
        // above the uniform baseline.
        let mut c = SyntheticCorpus::new(256, 2);
        let toks = c.batch(64, 32);
        let mut predictable = 0usize;
        let mut total = 0usize;
        for row in toks.chunks(32) {
            for w in row.windows(2) {
                total += 1;
                let expect = (w[0] as u64 * 5 + 17) % 256;
                if w[1] as u64 == expect {
                    predictable += 1;
                }
            }
        }
        let rate = predictable as f64 / total as f64;
        assert!(rate > 0.8, "structure rate {rate}");
    }
}
