//! PJRT runtime: load AOT HLO-text artifacts and run training steps.
//!
//! The bridge between Layer 3 (this crate) and Layers 1-2 (the JAX/Pallas
//! compute lowered by `python/compile/aot.py`). HLO **text** is the
//! interchange format — xla_extension 0.5.1 rejects jax≥0.5's 64-bit
//! instruction-id protos, but its text parser reassigns ids.
//!
//! [`Trainer`] keeps the flat parameter and momentum vectors as
//! device-resident [`xla::PjRtBuffer`]s and feeds each step's outputs back
//! as the next step's inputs (`execute_b`), so the per-step host traffic
//! is just the token batch and the scalar loss.

mod meta;
mod trainer;

pub use meta::ArtifactMeta;
pub use trainer::{SyntheticCorpus, Trainer};

use anyhow::{Context, Result};

/// A loaded PJRT CPU client plus compiled executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo(&self, path: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path}: {e:?}"))
    }

    /// Load a model variant (train + eval executables + metadata) from an
    /// artifacts directory.
    pub fn load_variant(&self, artifacts_dir: &str, variant: &str)
        -> Result<(ArtifactMeta, xla::PjRtLoadedExecutable)>
    {
        let meta_path = format!("{artifacts_dir}/{variant}.meta.json");
        let meta = ArtifactMeta::from_file(&meta_path)
            .with_context(|| format!("loading {meta_path}"))?;
        let hlo_path = format!("{artifacts_dir}/{}", meta.train_hlo);
        let exe = self.load_hlo(&hlo_path)?;
        Ok((meta, exe))
    }
}
