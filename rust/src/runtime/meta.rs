//! Artifact metadata sidecar (`artifacts/<variant>.meta.json`), written by
//! `python/compile/aot.py` and read here so the rust side knows buffer
//! shapes, the flat-parameter layout, and per-tensor init rules.

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One parameter tensor inside the flat vector.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamEntry {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fan-in for init scaling (first dim, matching model.py).
    pub fn fan_in(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }
}

/// Parsed metadata for one AOT variant.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub variant: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_count: usize,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub params: Vec<ParamEntry>,
}

impl ArtifactMeta {
    pub fn from_json(doc: &Json) -> Result<ArtifactMeta> {
        let get_usize = |key: &str| {
            doc.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("meta missing '{key}'"))
        };
        let get_str = |key: &str| {
            doc.get(key)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("meta missing '{key}'"))
        };
        let params = doc
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("meta missing 'params'"))?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p
                        .get("name")
                        .as_str()
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p
                        .get("offset")
                        .as_usize()
                        .ok_or_else(|| anyhow!("param missing offset"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactMeta {
            variant: get_str("variant")?,
            vocab: get_usize("vocab")?,
            d_model: get_usize("d_model")?,
            n_layers: get_usize("n_layers")?,
            seq_len: get_usize("seq_len")?,
            batch: get_usize("batch")?,
            param_count: get_usize("param_count")?,
            train_hlo: get_str("train_hlo")?,
            eval_hlo: get_str("eval_hlo")?,
            params,
        })
    }

    pub fn from_file(path: &str) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&doc)
    }

    /// Sanity check: offsets contiguous and total == param_count.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for p in &self.params {
            if p.offset != off {
                return Err(anyhow!(
                    "param {} offset {} != expected {off}",
                    p.name, p.offset
                ));
            }
            off += p.len();
        }
        if off != self.param_count {
            return Err(anyhow!(
                "param_count {} != layout total {off}",
                self.param_count
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_doc() -> Json {
        Json::parse(
            r#"{"variant": "tiny", "vocab": 256, "d_model": 64,
                "n_layers": 2, "n_heads": 4, "d_ff": 256, "seq_len": 32,
                "batch": 4, "param_count": 20,
                "train_hlo": "train_step_tiny.hlo.txt",
                "eval_hlo": "eval_step_tiny.hlo.txt",
                "params": [
                  {"name": "a", "shape": [2, 5], "offset": 0},
                  {"name": "b", "shape": [10], "offset": 10}
                ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let meta = ArtifactMeta::from_json(&sample_doc()).unwrap();
        assert_eq!(meta.variant, "tiny");
        assert_eq!(meta.params.len(), 2);
        assert_eq!(meta.params[0].len(), 10);
        assert_eq!(meta.params[0].fan_in(), 2);
        assert!(meta.validate().is_ok());
    }

    #[test]
    fn bad_offsets_detected() {
        let mut meta = ArtifactMeta::from_json(&sample_doc()).unwrap();
        meta.params[1].offset = 11;
        assert!(meta.validate().is_err());
    }

    #[test]
    fn reads_real_artifact_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny.meta.json");
        if std::path::Path::new(path).exists() {
            let meta = ArtifactMeta::from_file(path).unwrap();
            assert_eq!(meta.variant, "tiny");
            assert!(meta.validate().is_ok());
            assert!(meta.param_count > 100_000);
        }
    }
}
