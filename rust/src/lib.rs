//! # Synergy — resource-sensitive DNN cluster scheduling
//!
//! A from-scratch reproduction of *"Synergy: Resource Sensitive DNN
//! Scheduling in Multi-Tenant Clusters"* (Mohan et al., 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the scheduler itself: round-based
//!   coordination, scheduling policies (FIFO/SRTF/LAS/FTF + DRF/Tetris
//!   baselines), allocation mechanisms (GPU-proportional, Synergy-GREEDY,
//!   Synergy-TUNE, Synergy-OPT via an in-crate LP/ILP solver), optimistic
//!   profiling, an event-driven cluster simulator, and a deploy mode that
//!   runs *real* training jobs through the PJRT runtime.
//! - **Layer 2** — a JAX GPT-style transformer train step, AOT-lowered to
//!   HLO text (`python/compile/model.py` + `aot.py`), executed from rust.
//! - **Layer 1** — Pallas kernels (fused attention, layernorm) inside the
//!   Layer-2 graph (`python/compile/kernels/`).
//!
//! Python never runs on the scheduling path: `make artifacts` lowers the
//! compute once; the rust binary is self-contained afterwards.
//!
//! Module map (see DESIGN.md for the paper-section cross-reference):
//!
//! The resource model is *type-generic* end to end (one-resource-model
//! unification): machine generation is data on every server, a cluster
//! is a [`cluster::Fleet`] of per-type pools, and the homogeneous paper
//! setting is the one-type special case of the same profiler, mechanism
//! and simulator code that handles mixed fleets (paper Appendix A.2).
//! [`hetero`] is only a front-end over that stack.
//!
//! | module | role |
//! |---|---|
//! | [`cluster`] | generations, servers, fleets: type-aware resource bookkeeping |
//! | [`job`] | jobs, demand vectors, the 10-model zoo (paper Table 4) |
//! | [`perf`] | ground-truth throughput model per machine type (MinIO cache, CPU prep, scaled GPU step) |
//! | [`profiler`] | optimistic profiling, one sensitivity matrix per type (paper §3.1, A.2.1) |
//! | [`policy`] | scheduling policies (paper §2.2, §5.7) |
//! | [`mechanism`] | type-generic allocation mechanisms (paper §3.3, §4, A.2.2–A.2.3) |
//! | [`lp`] | simplex + branch-and-bound ILP (Synergy-OPT substrate) |
//! | [`sim`] | event-driven fleet simulator (paper §4.3) |
//! | [`hetero`] | heterogeneous front-end over the one engine (paper A.2) |
//! | [`trace`] | Philly-derived synthetic workload generation (paper §5.1) |
//! | [`workload`] | pluggable trace ingestion: `WorkloadSource` trait, Philly CSV + Alibaba readers, tenants & quota admission, streaming replay |
//! | [`metrics`] | JCT/makespan/utilization accounting, per-tenant fairness |
//! | [`telemetry`] | deterministic run profiles: delta-compressed per-round/per-pool/per-tenant series + plan-stage trace (default off) |
//! | [`coordinator`] | the round loop tying everything together |
//! | [`runtime`] | PJRT client: load HLO-text artifacts, run train steps |
//! | [`deploy`] | leader/worker cluster over TCP running real jobs |
//! | [`config`] | typed experiment configuration |
//! | [`util`] | substrates: PCG RNG, JSON, CLI, stats, property testing |

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod deploy;
pub mod hetero;
pub mod job;
pub mod lp;
pub mod mechanism;
pub mod metrics;
pub mod perf;
pub mod policy;
pub mod profiler;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
