//! Philly-derived workload trace generation (paper §5.1 "Traces").
//!
//! The paper uses Microsoft's public Philly trace directly for §5.3.1 and
//! a *production-derived* synthetic trace everywhere else. Without the
//! original trace files (offline environment), both paths are generated
//! from the published marginals:
//!
//! - **GPU demand** — Philly's demand distribution is dominated by 1-GPU
//!   jobs with a tail of 2/4/8/16-GPU jobs (Philly analysis paper [33]).
//! - **Duration** — `10^x` minutes with x ~ U[1.5, 3] w.p. 0.8 and
//!   x ~ U[3, 4] w.p. 0.2 (exactly the paper's recipe, following
//!   Gavel [44]).
//! - **Arrivals** — static (all at t=0) or Poisson(λ jobs/hour).
//! - **Model mix** — a workload *split* (image%, language%, speech%)
//!   selects the task family; the model within the family is uniform.
//!
//! Real trace files (Philly CSV, Alibaba machine-utilization) are
//! ingested by [`crate::workload`], which also hosts the streaming
//! [`crate::workload::SyntheticSource`] this module's [`generate`] wraps.

use crate::job::{Job, ModelKind, Task};
use crate::util::rng::Pcg64;

/// Workload split: percentage of image/language/speech jobs (sums to 100).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Split {
    pub image: u32,
    pub language: u32,
    pub speech: u32,
}

impl Split {
    pub const fn new(image: u32, language: u32, speech: u32) -> Split {
        Split { image, language, speech }
    }

    pub fn validate(&self) {
        assert_eq!(
            self.image + self.language + self.speech,
            100,
            "split must sum to 100"
        );
    }

    /// Sample a model according to the split.
    pub fn sample_model(&self, rng: &mut Pcg64) -> ModelKind {
        self.validate();
        let task = match rng.weighted(&[
            self.image as f64,
            self.language as f64,
            self.speech as f64,
        ]) {
            0 => Task::Image,
            1 => Task::Language,
            _ => Task::Speech,
        };
        *rng.choose(&ModelKind::of_task(task).as_slice())
    }
}

/// Common splits from the paper's evaluation.
pub const SPLIT_DEFAULT: Split = Split::new(20, 70, 10); // §5.3
pub const SPLIT_STATIC: Split = Split::new(60, 30, 10); // §5.2 FIFO
pub const SPLIT_DYNAMIC: Split = Split::new(30, 60, 10); // §5.2 SRTF
pub const SPLIT_WORST: Split = Split::new(50, 0, 50); // §5.4 / §5.7 W2

/// GPU-demand distribution. `multi_gpu=false` forces 1-GPU jobs (the
/// paper's "single-GPU trace"); otherwise demands follow a Philly-like
/// mix up to 16 GPUs.
#[derive(Debug, Clone, Copy)]
pub struct GpuDemandDist {
    pub multi_gpu: bool,
}

impl GpuDemandDist {
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        if !self.multi_gpu {
            return 1;
        }
        // Philly-like: mostly small jobs, tail of gang-scheduled ones.
        let choices = [1u32, 2, 4, 8, 16];
        let weights = [70.0, 10.0, 10.0, 7.0, 3.0];
        choices[rng.weighted(&weights)]
    }
}

/// Trace generator configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceConfig {
    pub n_jobs: usize,
    pub split: Split,
    pub multi_gpu: bool,
    /// None => static trace (all arrive at t=0);
    /// Some(λ) => Poisson arrivals at λ jobs/hour.
    pub jobs_per_hour: Option<f64>,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_jobs: 1000,
            split: SPLIT_DEFAULT,
            multi_gpu: false,
            jobs_per_hour: Some(8.0),
            seed: 1,
        }
    }
}

/// Sample the paper's duration distribution, seconds.
pub fn sample_duration_s(rng: &mut Pcg64) -> f64 {
    let x = if rng.chance(0.8) {
        rng.range_f64(1.5, 3.0)
    } else {
        rng.range_f64(3.0, 4.0)
    };
    10f64.powf(x) * 60.0
}

/// Generate a job trace.
///
/// Since the `workload/` refactor this is a thin batch wrapper over
/// [`crate::workload::SyntheticSource`]; the output is byte-identical to
/// the historical in-place generator for any `cfg` (golden-tested in
/// `tests/workload.rs`).
pub fn generate(cfg: &TraceConfig) -> Vec<Job> {
    use crate::workload::{SyntheticSource, WorkloadSource};
    SyntheticSource::new(*cfg).drain_jobs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn static_trace_all_arrive_at_zero() {
        let cfg = TraceConfig {
            n_jobs: 50,
            jobs_per_hour: None,
            ..Default::default()
        };
        let jobs = generate(&cfg);
        assert_eq!(jobs.len(), 50);
        assert!(jobs.iter().all(|j| j.arrival_s == 0.0));
    }

    #[test]
    fn dynamic_trace_mean_interarrival_matches_load() {
        let cfg = TraceConfig {
            n_jobs: 5000,
            jobs_per_hour: Some(12.0),
            ..Default::default()
        };
        let jobs = generate(&cfg);
        let gaps: Vec<f64> = jobs
            .windows(2)
            .map(|w| w[1].arrival_s - w[0].arrival_s)
            .collect();
        let m = mean(&gaps);
        assert!((m - 300.0).abs() < 20.0, "mean gap {m}");
        assert!(gaps.iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn duration_distribution_bounds() {
        let mut rng = Pcg64::seeded(5);
        let ds: Vec<f64> = (0..20_000).map(|_| sample_duration_s(&mut rng)).collect();
        let lo = 10f64.powf(1.5) * 60.0;
        let hi = 10f64.powf(4.0) * 60.0;
        assert!(ds.iter().all(|&d| (lo..=hi).contains(&d)));
        // ~20% above 10^3 minutes.
        let long = ds.iter().filter(|&&d| d >= 1000.0 * 60.0).count() as f64
            / ds.len() as f64;
        assert!((0.17..0.23).contains(&long), "long fraction {long}");
    }

    #[test]
    fn split_proportions_respected() {
        let cfg = TraceConfig {
            n_jobs: 10_000,
            split: Split::new(30, 60, 10),
            ..Default::default()
        };
        let jobs = generate(&cfg);
        let frac = |t: Task| {
            jobs.iter().filter(|j| j.model.task() == t).count() as f64
                / jobs.len() as f64
        };
        assert!((frac(Task::Image) - 0.30).abs() < 0.02);
        assert!((frac(Task::Language) - 0.60).abs() < 0.02);
        assert!((frac(Task::Speech) - 0.10).abs() < 0.02);
    }

    #[test]
    fn single_gpu_trace_has_only_1gpu_jobs() {
        let cfg = TraceConfig { n_jobs: 500, multi_gpu: false, ..Default::default() };
        assert!(generate(&cfg).iter().all(|j| j.gpus == 1));
    }

    #[test]
    fn multi_gpu_trace_mix() {
        let cfg = TraceConfig { n_jobs: 5000, multi_gpu: true, ..Default::default() };
        let jobs = generate(&cfg);
        let ones = jobs.iter().filter(|j| j.gpus == 1).count() as f64
            / jobs.len() as f64;
        assert!((0.65..0.75).contains(&ones));
        assert!(jobs.iter().any(|j| j.gpus == 16));
        assert!(jobs.iter().all(|j| [1, 2, 4, 8, 16].contains(&j.gpus)));
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.model, y.model);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn zero_language_split_samples_no_language_models() {
        let cfg = TraceConfig {
            n_jobs: 2000,
            split: SPLIT_WORST,
            ..Default::default()
        };
        assert!(generate(&cfg)
            .iter()
            .all(|j| j.model.task() != Task::Language));
    }
}
